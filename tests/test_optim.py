"""Optimizer + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import (AdamWConfig, CompressionConfig, apply_updates,
                         clip_by_global_norm, compress, global_norm,
                         init_error_state, init_state, lr_at)


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, clip_norm=1e9)
    params = {"w": jnp.array([[3.0, -2.0]])}
    state = init_state(params)
    for _ in range(100):
        grads = jax.tree.map(lambda p: 2 * p, params)  # d/dp ||p||^2
        params, state, m = apply_updates(cfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_clipping():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below the threshold: untouched
    g2 = {"a": jnp.full((4,), 0.01)}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(np.asarray(c2["a"]), 0.01, rtol=1e-6)


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110, min_lr_frac=0.1)
    assert float(lr_at(cfg, 0)) == pytest.approx(0.1)
    assert float(lr_at(cfg, 9)) == pytest.approx(1.0)
    assert float(lr_at(cfg, 110)) == pytest.approx(0.1, abs=1e-3)
    # monotone decay after warmup
    vals = [float(lr_at(cfg, s)) for s in range(10, 110, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_weight_decay_only_on_matrices():
    cfg = AdamWConfig(lr=0.1, weight_decay=1.0, warmup_steps=0, clip_norm=1e9)
    params = {"mat": jnp.ones((2, 2)), "bias": jnp.ones((2,))}
    state = init_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    p2, _, _ = apply_updates(cfg, params, zero_g, state)
    assert float(p2["mat"][0, 0]) < 1.0   # decayed
    assert float(p2["bias"][0]) == 1.0    # exempt


@pytest.mark.parametrize("scheme", ["int8", "topk"])
def test_compression_error_feedback_preserves_signal(scheme):
    """Sum of compressed outputs ~ sum of raw grads (EF property)."""
    cfg = CompressionConfig(scheme=scheme, topk_frac=0.25)
    params = {"w": jnp.zeros((64,))}
    err = init_error_state(params)
    rng = np.random.default_rng(0)
    total_raw = np.zeros(64)
    total_comp = np.zeros(64)
    for i in range(50):
        g = {"w": jnp.asarray(rng.normal(size=64).astype(np.float32))}
        c, err = compress(cfg, g, err)
        total_raw += np.asarray(g["w"])
        total_comp += np.asarray(c["w"])
    resid = np.abs(total_raw - total_comp).max()
    assert resid < np.abs(total_raw).max() * 0.5 + 1.0  # residual bounded


def test_compression_convergence_on_quadratic():
    """EF-compressed AdamW still minimizes a quadratic."""
    acfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0, clip_norm=1e9)
    ccfg = CompressionConfig(scheme="topk", topk_frac=0.25)
    params = {"w": jnp.linspace(-2, 2, 32)}
    state = init_state(params)
    err = init_error_state(params)
    for _ in range(300):
        grads = jax.tree.map(lambda p: 2 * p, params)
        grads, err = compress(ccfg, grads, err)
        params, state, _ = apply_updates(acfg, params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.15


def test_int8_roundtrip_bounded_error():
    from repro.optim.compression import _int8_roundtrip

    g = jnp.asarray(np.random.default_rng(0).normal(size=1000) * 5)
    r = _int8_roundtrip(g)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.max(jnp.abs(r - g))) <= scale * 0.5 + 1e-6
