"""Distributed semantics on 8 fake devices (subprocess; the main test
process keeps its single real device)."""
import os
import subprocess
import sys
import textwrap

import pytest

from tests.conftest import REPO_ROOT, subprocess_env


def _run(code: str, n_devices: int = 8):
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True,
                       env=subprocess_env(n_devices), cwd=REPO_ROOT,
                       timeout=600)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_train_step_matches_single_device():
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_reduced
        from repro.launch.mesh import make_mesh
        from repro.launch.steps import TrainSettings, init_opt_state, make_train_step
        from repro.models import transformer as tf
        from repro.models.layers.common import sharding_ctx
        from repro.sharding.partition import batch_spec, param_specs

        cfg = get_reduced('starcoder2-3b')
        key = jax.random.PRNGKey(0)
        batch = {'tokens': jax.random.randint(key, (8, 16), 0, cfg.vocab_size)}
        settings = TrainSettings()
        step = make_train_step(cfg, settings)

        # single-device reference
        params = tf.init_params(cfg, key)
        opt = init_opt_state(cfg, params, settings)
        p_ref, o_ref, m_ref = jax.jit(step)(params, opt, batch)

        # sharded (4 data x 2 model)
        mesh = make_mesh((4, 2), ('data', 'model'))
        with sharding_ctx(mesh):
            params2 = tf.init_params(cfg, key)
            opt2 = init_opt_state(cfg, params2, settings)
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
            p_sh = ns(param_specs(params2, mesh))
            o_sh = ns(param_specs(opt2, mesh))
            b_sh = ns(batch_spec(mesh, batch))
            params2 = jax.device_put(params2, p_sh)
            opt2 = jax.device_put(opt2, o_sh)
            batch2 = jax.device_put(batch, b_sh)
            p_out, o_out, m_out = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None))(params2, opt2, batch2)

        np.testing.assert_allclose(float(m_ref['loss']), float(m_out['loss']),
                                   rtol=2e-4)
        for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_out)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=5e-4)
        print('SHARDED_OK')
    """)


def test_moe_expert_parallel_matches():
    _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_reduced
        from repro.launch.mesh import make_mesh
        from repro.models import transformer as tf
        from repro.models.layers.common import sharding_ctx
        from repro.sharding.partition import batch_spec, param_specs

        cfg = dataclasses.replace(get_reduced('olmoe-1b-7b'), capacity_factor=64.0)
        key = jax.random.PRNGKey(0)
        batch = {'tokens': jax.random.randint(key, (4, 8), 0, cfg.vocab_size)}
        params = tf.init_params(cfg, key)
        ref, _, _ = tf.forward(cfg, params, tokens=batch['tokens'], mode='train')

        mesh = make_mesh((2, 4), ('data', 'model'))  # experts 8 over model 4
        with sharding_ctx(mesh):
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
            p_sh = ns(param_specs(params, mesh))
            params2 = jax.device_put(params, p_sh)
            out, _, _ = jax.jit(
                lambda p, t: tf.forward(cfg, p, tokens=t, mode='train'),
                in_shardings=(p_sh, None))(params2, batch['tokens'])
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-3)
        print('EP_OK')
    """)


def test_unfolded_tp_lstm_matches():
    """The distributed Unfolded schedule (gate-dim TP) is exact."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.core.schedules import run_layer_unfolded
        from repro.core.unfolded import lstm_param_specs, run_layer_unfolded_tp
        from repro.launch.mesh import make_mesh
        from repro.models.layers.lstm import init_lstm_layer

        key = jax.random.PRNGKey(0)
        H, B, T = 64, 2, 6
        params = init_lstm_layer(key, H, H, jnp.float32)
        xs = jax.random.normal(key, (B, T, H)) * 0.5
        ref = run_layer_unfolded(params, xs)

        mesh = make_mesh((8,), ('model',))
        specs = lstm_param_specs()
        p_sh = {k: NamedSharding(mesh, specs[k]) for k in params}
        params2 = jax.device_put(params, p_sh)
        out = jax.jit(lambda p, x: run_layer_unfolded_tp(p, x, mesh),
                      in_shardings=(p_sh, None))(params2, xs)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
        print('TP_OK')
    """)


def test_seq_sharded_decode_matches_single_device():
    """§Perf cell-A iteration 2: decode with the KV cache sharded on the
    sequence dim must be numerically identical to single-device decode."""
    _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_reduced
        from repro.launch.mesh import make_mesh
        from repro.models import transformer as tf
        from repro.models.layers.common import sharding_ctx
        from repro.sharding.partition import cache_specs, param_specs

        cfg = get_reduced('starcoder2-3b')
        key = jax.random.PRNGKey(0)
        params = tf.init_params(cfg, key)
        tokens = jax.random.randint(key, (4, 24), 0, cfg.vocab_size)

        # single-device reference: prefill + 3 decode steps
        logits, cache = tf.prefill(cfg, params, {'tokens': tokens}, seq_len=32)
        outs_ref = []
        c_ref = cache
        for t in range(3):
            tok = jnp.full((4, 1), t + 5, jnp.int32)
            lg, c_ref = tf.decode_step(cfg, params, c_ref, {'tokens': tok})
            outs_ref.append(lg)

        mesh = make_mesh((2, 4), ('data', 'model'))  # T=32 sharded 4-way
        with sharding_ctx(mesh):
            ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
            p_sh = ns(param_specs(params, mesh, fsdp=False))
            c_sh = ns(cache_specs(cache, mesh))
            params2 = jax.device_put(params, p_sh)
            c2 = jax.device_put(cache, c_sh)  # same prefill state as ref
            step = jax.jit(
                lambda p, c, t: tf.decode_step(cfg, p, c, {'tokens': t}),
                in_shardings=(p_sh, c_sh, None), out_shardings=(None, c_sh))
            for t in range(3):
                tok = jnp.full((4, 1), t + 5, jnp.int32)
                lg, c2 = step(params2, c2, tok)
                np.testing.assert_allclose(np.asarray(lg),
                                           np.asarray(outs_ref[t]), atol=2e-4)
        print('SEQ_SHARDED_DECODE_OK')
    """)


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint saved on one mesh restores onto a different mesh."""
    _run(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.checkpoint import Checkpointer
        from repro.launch.mesh import make_mesh

        tree = {{'w': jnp.arange(32, dtype=jnp.float32).reshape(8, 4)}}
        m1 = make_mesh((8, 1), ('data', 'model'))
        sh1 = {{'w': NamedSharding(m1, jax.sharding.PartitionSpec('data', None))}}
        t1 = jax.device_put(tree, sh1)
        ck = Checkpointer('{tmp_path}')
        ck.save(3, t1, blocking=True)

        m2 = make_mesh((2, 4), ('data', 'model'))  # 'new job topology'
        sh2 = {{'w': NamedSharding(m2, jax.sharding.PartitionSpec(None, 'model'))}}
        out = ck.restore(3, tree, sh2)
        np.testing.assert_array_equal(np.asarray(out['w']), np.asarray(tree['w']))
        assert out['w'].sharding == sh2['w']
        print('ELASTIC_OK')
    """)
