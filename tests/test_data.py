"""Data pipeline: determinism, host sharding, learnable structure."""
import numpy as np
import pytest

from repro.data import DataConfig, SyntheticPipeline


def test_determinism():
    cfg = DataConfig(vocab_size=64, seq_len=16, global_batch=4, seed=7)
    a = SyntheticPipeline(cfg).batch_at(13)
    b = SyntheticPipeline(cfg).batch_at(13)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = SyntheticPipeline(cfg).batch_at(14)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_host_sharding_disjoint_and_deterministic():
    full = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1)
    h0 = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1,
                    num_hosts=2, host_id=0)
    h1 = DataConfig(vocab_size=64, seq_len=8, global_batch=8, seed=1,
                    num_hosts=2, host_id=1)
    b0 = SyntheticPipeline(h0).batch_at(3)["tokens"]
    b1 = SyntheticPipeline(h1).batch_at(3)["tokens"]
    assert b0.shape == (4, 8) and b1.shape == (4, 8)
    assert not np.array_equal(b0, b1)  # different streams per host


def test_markov_has_learnable_structure():
    """Bigram stats of the stream match the generating table (so a trained
    bigram model beats uniform)."""
    cfg = DataConfig(vocab_size=8, seq_len=256, global_batch=8, seed=3)
    pipe = SyntheticPipeline(cfg)
    counts = np.zeros((8, 8))
    for step in range(4):
        toks = pipe.batch_at(step)["tokens"]
        for row in toks:
            np.add.at(counts, (row[:-1], row[1:]), 1)
    emp = counts / np.maximum(counts.sum(-1, keepdims=True), 1)
    # empirical bigram ~ generator table
    assert np.abs(emp - pipe._trans).max() < 0.15
    # and decidedly non-uniform
    assert emp.max() > 2.0 / 8


def test_embed_stub_batches():
    cfg = DataConfig(vocab_size=32, seq_len=8, global_batch=2, embed_dim=16)
    b = SyntheticPipeline(cfg).batch_at(0)
    assert b["embeds"].shape == (2, 8, 16)
    assert b["labels"].shape == (2, 8)
    assert b["embeds"].dtype == np.float32


def test_tokens_in_range():
    cfg = DataConfig(vocab_size=11, seq_len=64, global_batch=4, source="markov")
    t = SyntheticPipeline(cfg).batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 11
