"""runtime.obs unit tests: span nesting, histogram quantiles on known
data, the no-op tracer path, chrome-trace JSON schema round-trip, the
predicted-vs-measured launch-cost table, and the shared bench timer."""
import json

import pytest

from repro.runtime.obs import (LAUNCH_COSTS_PATH, Counter, Histogram,
                               LaunchCostTable, MetricsRegistry, NULL_TRACER,
                               NullTracer, Tracer, as_tracer, measure_us,
                               slot_signature)


# ---------------------------------------------------------------------------
# counters + histograms
# ---------------------------------------------------------------------------


def test_counter():
    c = Counter()
    assert c.value == 0
    c.add()
    c.add(4)
    assert c.value == 5


def test_histogram_quantiles_known_data():
    h = Histogram()
    for v in range(1, 101):  # 1..100: nearest-rank quantiles are exact
        h.observe(float(v))
    assert h.count == 100
    assert h.quantile(0.5) == 50.0
    assert h.quantile(0.9) == 90.0
    assert h.quantile(0.99) == 99.0
    assert h.quantile(1.0) == 100.0
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    assert snap["mean"] == pytest.approx(50.5)
    assert snap["p50"] == 50.0 and snap["p90"] == 90.0


def test_histogram_reservoir_is_bounded_but_stats_exact():
    h = Histogram(cap=64)
    n = 10_000
    for v in range(n):
        h.observe(float(v))
    assert h.count == n                      # full count kept
    assert len(h._sample) == 64              # memory bounded
    assert h.min == 0.0 and h.max == n - 1   # exact extremes
    assert h.mean == pytest.approx((n - 1) / 2)
    # reservoir quantile is approximate but must stay in range
    assert 0.0 <= h.quantile(0.5) <= n - 1


def test_histogram_empty():
    h = Histogram()
    assert h.quantile(0.5) == 0.0
    assert h.snapshot()["count"] == 0


def test_metrics_registry_reuses_instruments():
    m = MetricsRegistry()
    assert m.counter("a") is m.counter("a")
    assert m.histogram("b") is m.histogram("b")
    m.counter("a").add(2)
    m.histogram("b").observe(3.0)
    snap = m.snapshot()
    assert snap["counters"]["a"] == 2
    assert snap["histograms"]["b"]["count"] == 1
    assert "a" in m.describe() and "b" in m.describe()


# ---------------------------------------------------------------------------
# spans + tracer
# ---------------------------------------------------------------------------


def test_span_nesting_depth_and_timing():
    tr = Tracer()
    with tr.span("outer", a=1):
        with tr.span("inner"):
            pass
    # inner files first (exits first), depths record the nesting
    names = [(s.name, s.depth) for s in tr.events]
    assert names == [("inner", 1), ("outer", 0)]
    inner, outer = tr.events
    assert outer.tags == {"a": 1}
    assert outer.start_us <= inner.start_us
    assert (inner.start_us + inner.dur_us
            <= outer.start_us + outer.dur_us + 1e-6)


def test_span_tag_and_span_at_and_instant():
    tr = Tracer()
    with tr.span("s") as sp:
        sp.tag(extra="x")
    assert tr.events[0].tags == {"extra": "x"}
    sp = tr.span_at("req", 10.0, 25.0, track="requests", uid=7)
    assert sp.dur_us == 15.0 and sp.track == "requests"
    tr.instant("fault", slot=3)
    assert tr.events[-1].dur_us is None  # instants have no duration


def test_plan_id_stable_and_sequential():
    tr = Tracer()
    a, b = object(), object()
    assert tr.plan_id(a) == 0
    assert tr.plan_id(b) == 1
    assert tr.plan_id(a) == 0


def test_observe_launch_feeds_histogram_and_table():
    tr = Tracer()
    sig = slot_signature("lstm", 64, 2, 1, 12, "float32")
    for us in (100.0, 110.0, 120.0):
        tr.observe_launch(sig, est_cycles=550.0, dur_us=us)
    snap = tr.snapshot()
    assert snap["metrics"]["histograms"][f"launch_us/{sig}"]["count"] == 3
    row = snap["launch_costs"][sig]
    assert row["n"] == 3 and row["med_us"] == 110.0
    assert row["cycles_per_us"] == pytest.approx(5.0)
    assert snap["predicted_vs_measured"]["signatures"] == 1
    assert snap["predicted_vs_measured"]["mean_cycles_per_us"] == \
        pytest.approx(5.0)


def test_slot_signature_format():
    assert (slot_signature("lstm", 64, 2, 1, 12, "float32")
            == "lstm|H64|G2|B1|bt12|float32|fwd")
    assert (slot_signature("gru", 96, 1, 4, 1, "bfloat16",
                           directions=("fwd", "bwd"), chained=True)
            == "gru|H96|G1|B4|bt1|bfloat16|bwd+fwd|chained")


# ---------------------------------------------------------------------------
# the no-op path
# ---------------------------------------------------------------------------


def test_null_tracer_is_inert():
    assert NULL_TRACER.enabled is False
    assert isinstance(NULL_TRACER, NullTracer)
    sp = NULL_TRACER.span("x", a=1)
    assert sp is NULL_TRACER.span("y")  # one reused span object
    with sp as s:
        s.tag(b=2)
    assert NULL_TRACER.events == ()     # nothing ever recorded
    obj = {"h": [1, 2]}
    assert NULL_TRACER.fence(obj) is obj  # identity, no jax import needed
    NULL_TRACER.instant("x")
    NULL_TRACER.span_at("x", 0.0, 1.0)
    NULL_TRACER.observe_launch("sig", 1.0, 1.0)
    assert NULL_TRACER.snapshot()["spans"] == 0
    assert len(NULL_TRACER.launch_costs) == 0


def test_as_tracer():
    assert as_tracer(None) is NULL_TRACER
    tr = Tracer()
    assert as_tracer(tr) is tr


# ---------------------------------------------------------------------------
# chrome trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_schema_round_trip(tmp_path):
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner", slot=0):
            pass
        tr.instant("marker", slot=1)
    tr.span_at("request", tr.events[0].start_us,
               tr.events[0].start_us + 5.0, track="requests", uid=3)
    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))

    data = json.loads(open(path).read())
    assert data["displayTimeUnit"] == "ms"
    evs = data["traceEvents"]
    by_ph = {}
    for e in evs:
        by_ph.setdefault(e["ph"], []).append(e)
    # process metadata + one thread_name per track (exec, requests)
    meta_names = {e["name"]: e for e in by_ph["M"] if e["name"] != ""}
    assert meta_names["process_name"]["args"]["name"] == "repro"
    thread_names = {e["args"]["name"] for e in by_ph["M"]
                    if e["name"] == "thread_name"}
    assert thread_names == {"exec", "requests"}
    # complete events: inner's interval nests inside outer's
    X = {e["name"]: e for e in by_ph["X"]}
    assert set(X) == {"outer", "inner", "request"}
    assert X["outer"]["ts"] <= X["inner"]["ts"]
    assert (X["inner"]["ts"] + X["inner"]["dur"]
            <= X["outer"]["ts"] + X["outer"]["dur"] + 1e-3)
    # the instant marker
    (inst,) = by_ph["i"]
    assert inst["name"] == "marker" and inst["s"] == "t"
    assert inst["args"] == {"slot": 1}
    # exec and requests land on different tids
    assert X["request"]["tid"] != X["inner"]["tid"]


# ---------------------------------------------------------------------------
# launch-cost persistence
# ---------------------------------------------------------------------------


def test_launch_cost_table_save_load_merge(tmp_path):
    path = str(tmp_path / "launch_costs.json")
    t1 = LaunchCostTable()
    t1.record("sigA", 100.0, 10.0)
    t1.record("sigB", 200.0, 20.0)
    assert t1.save(path) == path
    loaded = LaunchCostTable.load(path)
    assert set(loaded) == {"sigA", "sigB"}
    assert loaded["sigA"]["cycles_per_us"] == pytest.approx(10.0)

    # merge contract: this run's signatures overwrite, unseen ones kept
    t2 = LaunchCostTable()
    t2.record("sigB", 200.0, 40.0)
    t2.record("sigC", 300.0, 30.0)
    t2.save(path)
    merged = LaunchCostTable.load(path)
    assert set(merged) == {"sigA", "sigB", "sigC"}
    assert merged["sigA"]["med_us"] == 10.0   # kept from run 1
    assert merged["sigB"]["med_us"] == 40.0   # overwritten by run 2
    assert "sigA" in open(path).read()        # plain JSON on disk
    assert LAUNCH_COSTS_PATH.endswith("launch_costs.json")


def test_launch_cost_describe():
    t = LaunchCostTable()
    assert "none measured" in t.describe()
    t.record("sig", 100.0, 10.0)
    assert "10.0us" in t.describe() and "100cy" in t.describe()


# ---------------------------------------------------------------------------
# the shared bench timer
# ---------------------------------------------------------------------------


def test_measure_us_warmup_excluded_and_positive():
    calls = []

    def fn(x):
        calls.append(x)
        return x

    us = measure_us(fn, 1, repeats=3, warmup=2, reduce="median")
    assert us >= 0.0
    assert len(calls) == 5  # 2 warmup + 3 timed

    assert measure_us(fn, 1, repeats=2, reduce="min") >= 0.0


def test_measure_us_rejects_bad_reduce():
    with pytest.raises(ValueError):
        measure_us(lambda: None, reduce="mean")


def test_tracer_describe_mentions_spans():
    tr = Tracer()
    with tr.span("s"):
        pass
    assert "1 spans" in tr.describe()
