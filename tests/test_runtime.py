"""Fault tolerance: recovery-from-checkpoint, straggler watchdog,
exact-replay semantics."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import FTConfig, StragglerWatchdog, TrainLoop


def _toy_setup(tmp_path, ckpt_every=5):
    """A deterministic 'trainer': params accumulate batch sums."""

    def train_step(params, opt, batch):
        new_p = {"w": params["w"] + batch.sum()}
        new_o = {"count": opt["count"] + 1}
        return new_p, new_o, {"loss": -params["w"]}

    def batch_fn(step):
        return jnp.asarray([step], jnp.float32)  # pure function of step

    cfg = FTConfig(ckpt_dir=str(tmp_path), ckpt_every=ckpt_every,
                   max_restarts=3)
    return train_step, batch_fn, cfg


def test_recovery_produces_exact_result(tmp_path):
    train_step, batch_fn, cfg = _toy_setup(tmp_path)
    p0 = {"w": jnp.zeros(())}
    o0 = {"count": jnp.zeros((), jnp.int32)}

    loop = TrainLoop(train_step, batch_fn, cfg)
    loop.failure_at_steps = {12}
    p, o, step = loop.run(p0, o0, 0, 20)
    assert loop.restarts == 1
    assert step == 20
    # the result equals the fault-free run: sum of 0..19
    assert float(p["w"]) == sum(range(20))
    assert int(o["count"]) == 20  # replayed steps counted exactly once


def test_gives_up_after_max_restarts(tmp_path):
    train_step, batch_fn, cfg = _toy_setup(tmp_path)
    loop = TrainLoop(train_step, batch_fn, cfg)
    loop.failure_at_steps = {6, 7, 8, 9}  # re-injected after each restart
    with pytest.raises(RuntimeError):
        loop.run({"w": jnp.zeros(())}, {"count": jnp.zeros((), jnp.int32)},
                 0, 20)


def test_no_checkpoint_yet_raises_cleanly(tmp_path):
    train_step, batch_fn, cfg = _toy_setup(tmp_path, ckpt_every=100)
    loop = TrainLoop(train_step, batch_fn, cfg)
    loop.failure_at_steps = {2}
    with pytest.raises(RuntimeError, match="no checkpoint"):
        loop.run({"w": jnp.zeros(())}, {"count": jnp.zeros((), jnp.int32)},
                 0, 10)


def test_straggler_watchdog():
    wd = StragglerWatchdog(factor=3.0, alpha=0.5)
    for s in range(10):
        assert not wd.observe(s, 0.1)
    assert wd.observe(10, 1.0)       # 10x the EWMA -> flagged
    assert wd.flagged == [10]
    # the outlier must not poison the EWMA
    assert not wd.observe(11, 0.12)


def test_metrics_history_records_all_steps(tmp_path):
    train_step, batch_fn, cfg = _toy_setup(tmp_path)
    loop = TrainLoop(train_step, batch_fn, cfg)
    loop.run({"w": jnp.zeros(())}, {"count": jnp.zeros((), jnp.int32)}, 0, 7)
    assert [m["step"] for m in loop.metrics_history] == list(range(7))
