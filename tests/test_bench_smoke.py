"""Smoke-run the benchmark suites: ``benchmarks/run.py --suite kernels``
and ``--suite dispatch`` must execute end-to-end, write their JSON
artifacts, and show (a) the sequence-fused LSTM path beating the per-step
Pallas path and (b) dispatcher-packed prefill launching strictly fewer
kernels than per-request wavefront — the perf trajectory this repo
accumulates from PR 1 on."""
import json
import os
import re
import subprocess
import sys

from tests.conftest import REPO_ROOT, SRC


def test_kernel_suite_writes_json(tmp_path):
    out = tmp_path / "BENCH_kernels.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "benchmarks", "run.py"),
         "--suite", "kernels", "--json", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]

    data = json.loads(out.read_text())
    assert data["suite"] == "kernels"
    rows = {r["name"]: r for r in data["rows"]}
    assert "kernel/lstm_seq/fused_pallas" in rows
    assert "kernel/lstm_seq/per_step_pallas" in rows
    # the tentpole claim, measured: 1 launch beats T launches
    assert "launches=1" in rows["kernel/lstm_seq/fused_pallas"]["derived"]
    fused = rows["kernel/lstm_seq/fused_pallas"]["us_per_call"]
    per_step = rows["kernel/lstm_seq/per_step_pallas"]["us_per_call"]
    assert fused < per_step, (fused, per_step)


def test_dispatch_suite_writes_json(tmp_path):
    out = tmp_path / "BENCH_dispatch.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO_ROOT, "benchmarks", "run.py"),
         "--suite", "dispatch", "--json", str(out)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]

    data = json.loads(out.read_text())
    assert data["suite"] == "dispatch"
    rows = {r["name"]: r for r in data["rows"]}
    # the dispatch claim, measured: packed prefill launches strictly fewer
    # kernels than per-request wavefront, at oracle-verified-equal outputs
    packed = rows["dispatch/packed_prefill"]
    naive = rows["dispatch/per_request_wavefront"]
    n_packed = int(re.search(r"launches=(\d+)", packed["derived"]).group(1))
    n_naive = int(re.search(r"launches=(\d+)", naive["derived"]).group(1))
    assert n_packed < n_naive, (n_packed, n_naive)
    assert "max_err" in packed["derived"]

    def launches(row, key="launches"):
        return int(re.search(rf"{key}=(\d+)", rows[row]["derived"]).group(1))

    # the decode claim, measured: a planned steady-state tick launches
    # strictly fewer kernels than the old L-per-tick loop (bit-equal gated
    # inside the bench before emission)
    tick = launches("dispatch/decode_planned_tick", "launches_per_tick")
    loop = launches("dispatch/decode_loop_tick", "launches_per_tick")
    assert tick < loop, (tick, loop)
    # ...and the loop baseline is fair: it runs the k active rows only
    # (no stale pool columns padded in), so the planned win is launch
    # structure, not wasted compute
    assert "retired rows skipped" in rows["dispatch/decode_loop_tick"][
        "derived"]
    # the cross-B claim, measured: packed mixed-B prefill launches fewer
    # kernels than the equal-signature unpacked plan
    assert (launches("dispatch/cross_b_packed_prefill")
            < launches("dispatch/cross_b_unpacked_prefill"))
    # the bidir claim (ISSUE-5), measured: the interleaved fwd/bwd
    # wavefront launches strictly fewer kernels than the retired per-layer
    # fused fallback on the same bidirectional admission wave (bit-equal
    # gated inside the bench before emission)
    assert (launches("dispatch/bidir_interleaved_prefill")
            < launches("dispatch/bidir_per_layer_fallback"))
    assert "bidirectional" in rows["dispatch/bidir_interleaved_prefill"][
        "derived"]
    # the robustness claim (ISSUE-6), measured: the degraded-mode rows ran
    # the guarded ladder (recovery oracle-equal gated inside the bench)
    # and priced each rung against the healthy fused path
    assert "fallback=fused" in rows["dispatch/fault_healthy_forward"][
        "derived"]
    for rung in ("per_step", "reference"):
        derived = rows[f"dispatch/fault_{rung}_fallback"]["derived"]
        assert f"fallback={rung}" in derived
        assert "degraded=" in derived
    # the observability claim (ISSUE-7), measured: tracing costs < 5% on
    # both the forward and the chained decode tick, per the bench's
    # drift-cancelling pairwise estimator (bit-identity gated inside the
    # bench before emission — the rows exist at all only because traced
    # outputs matched untraced bit-for-bit)
    for kind in ("forward", "decode_tick"):
        derived = rows[f"dispatch/obs_traced_{kind}"]["derived"]
        overhead = float(re.search(r"overhead=([+-][\d.]+)%",
                                   derived).group(1))
        assert overhead < 5.0, (kind, derived)
        assert rows[f"dispatch/obs_untraced_{kind}"]["us_per_call"] > 0
    # the static-analysis claim (ISSUE-8), measured: verify="plan" (the
    # default) costs < 5% on the steady-state forward — verification runs
    # once per plan-cache miss, so the amortized cost is noise (bit-
    # identity gated inside the bench) — and the one-time plancheck proof
    # itself was timed over the mixed-batch plan with all rules proven
    derived = rows["dispatch/verify_on_forward"]["derived"]
    overhead = float(re.search(r"overhead=([+-][\d.]+)%",
                               derived).group(1))
    assert overhead < 5.0, derived
    assert rows["dispatch/verify_off_forward"]["us_per_call"] > 0
    assert "rules proven" in rows["dispatch/verify_plancheck"]["derived"]
    # the calibration claim (ISSUE-9), measured: the replay-calibrated
    # cost table flipped the canonical forward from the analytic G-merged
    # wavefront to the fused schedule (flip asserted inside the bench,
    # bit-equal gated) AND the flipped plan is wall-clock no slower —
    # measured mode must beat the analytic default wherever the table
    # disagrees with it
    flip_a = rows["dispatch/costmodel_analytic_forward"]
    flip_m = rows["dispatch/costmodel_measured_forward"]
    assert "schedule=wavefront" in flip_a["derived"]
    assert "schedule=fused" in flip_m["derived"]
    assert (launches("dispatch/costmodel_measured_forward")
            < launches("dispatch/costmodel_analytic_forward"))
    assert flip_m["us_per_call"] <= flip_a["us_per_call"], \
        (flip_m["us_per_call"], flip_a["us_per_call"])
    # the precision claim (ISSUE-10), measured: at the stripe-bound
    # H512/B8/T64 shape the int8 resident set sustains a >= 2x larger
    # time block than fp32, and the int8 forward stayed within its
    # documented rel-err bound vs the dequantized oracle (gated inside
    # the bench before emission — the row exists only because it passed)
    q8 = rows["dispatch/quant_int8_forward"]["derived"]
    fp = rows["dispatch/quant_fp32_forward"]["derived"]
    assert "precision=int8" in q8 and "precision=fp32" in fp
    bt_fp = int(re.search(r"bt=(\d+)", fp).group(1))
    bt_q8 = int(re.search(r"bt=(\d+)", q8).group(1))
    assert bt_q8 >= 2 * bt_fp, (bt_q8, bt_fp)
    rel = float(re.search(r"max_rel_err=([\d.e+-]+)", q8).group(1))
    assert rel < 1e-5, q8
