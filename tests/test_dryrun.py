"""Dry-run machinery end-to-end on a small fake mesh (subprocess)."""
import json
import os
import subprocess
import sys

import pytest

from tests.conftest import REPO_ROOT, subprocess_env


@pytest.mark.parametrize("arch,shape", [
    ("starcoder2-3b", "decode_32k"),
    ("recurrentgemma-2b", "long_500k"),
])
def test_dryrun_cell_small_mesh(arch, shape, tmp_path):
    env = subprocess_env(16)
    env["REPRO_DRYRUN_SMALL"] = "1"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "both", "--out", str(tmp_path),
         "--no-hlo"],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, timeout=900)
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr[-2000:]}"
    assert "[FAILED" not in r.stdout
    cells = [json.load(open(tmp_path / f)) for f in os.listdir(tmp_path)
             if f.endswith(".json")]
    assert len(cells) == 2  # both meshes
    for c in cells:
        assert c["status"] == "ok"
        assert c["memory"]["peak_bytes_per_device"] > 0
        assert c["cost_analysis"].get("flops", 0) > 0


def test_dryrun_skip_rule(tmp_path):
    """Pure full-attention arch must SKIP long_500k (documented), not fail."""
    env = subprocess_env(16)
    env["REPRO_DRYRUN_SMALL"] = "1"
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "deepseek-67b",
         "--shape", "long_500k", "--mesh", "pod", "--out", str(tmp_path),
         "--no-hlo"],
        capture_output=True, text=True, env=env, cwd=REPO_ROOT, timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr[-2000:]
    assert "skipped" in r.stdout
