"""MeasuredCostTable / MeasuredCostModel contracts: persistence round-trip,
cross-run merge (newer wins, counts accumulate), backend isolation, schema
staleness, and the scorer's resolution ladder (exact hit -> interpolated
neighbor -> analytic fallback)."""
import json

import pytest

from repro.calib import (MeasuredCostModel, MeasuredCostTable, TABLE_VERSION,
                         analytic_shape_cycles, parse_signature)
from repro.core.perfmodel import Design
from repro.runtime.obs import slot_signature

SIG = slot_signature("lstm", 64, 3, 1, 1, "float32")
DESIGN = Design(macs=16384, schedule="unfolded")


def _table(backend="testbe", med=100.0, n=5, sig=SIG):
    t = MeasuredCostTable(backend)
    t.record(sig, med, med * 1.2, n,
             analytic_shape_cycles("lstm", 64, 3, 1, 1, DESIGN))
    return t


def test_round_trip(tmp_path):
    path = str(tmp_path / "t.json")
    t = _table()
    t.save(path)
    back = MeasuredCostTable.load(path, backend="testbe")
    e = back.lookup(SIG)
    assert e is not None
    assert e["med_us"] == pytest.approx(100.0)
    assert e["p90_us"] == pytest.approx(120.0)
    assert e["n"] == 5 and e["runs"] == 1
    assert e["stamp"] is not None  # persisted records carry a real stamp
    assert back.signatures() == [SIG]
    assert len(back) == 1


def test_merge_newer_wins_counts_accumulate(tmp_path):
    path = str(tmp_path / "t.json")
    _table(med=100.0, n=5).save(path)
    # a second, later run re-measures the same signature
    _table(med=200.0, n=3).save(path)
    e = MeasuredCostTable.load(path, backend="testbe").lookup(SIG)
    assert e["med_us"] == pytest.approx(200.0)  # newer run's summary
    assert e["n"] == 8                          # sample history accumulates
    assert e["runs"] == 2


def test_resave_of_loaded_table_does_not_double_count(tmp_path):
    path = str(tmp_path / "t.json")
    _table(med=100.0, n=5).save(path)
    loaded = MeasuredCostTable.load(path, backend="testbe")
    loaded.save(path)  # no new records: same lineage, no accumulation
    e = MeasuredCostTable.load(path, backend="testbe").lookup(SIG)
    assert e["n"] == 5 and e["runs"] == 1


def test_backend_mismatch_is_invisible_but_preserved(tmp_path):
    path = str(tmp_path / "t.json")
    _table(backend="interpret(cpu)").save(path)
    other = MeasuredCostTable.load(path, backend="tpu")
    assert len(other) == 0 and other.lookup(SIG) is None
    # ...and saving under the other backend keeps the first one's entries
    other.record(SIG, 1.0, 1.1, 2, 10.0)
    other.save(path)
    orig = MeasuredCostTable.load(path, backend="interpret(cpu)")
    assert orig.lookup(SIG)["med_us"] == pytest.approx(100.0)


def test_stale_schema_version_loads_empty(tmp_path):
    path = str(tmp_path / "t.json")
    _table().save(path)
    raw = json.loads(open(path).read())
    raw["version"] = TABLE_VERSION + 1
    open(path, "w").write(json.dumps(raw))
    assert len(MeasuredCostTable.load(path, backend="testbe")) == 0


def test_missing_file_loads_empty(tmp_path):
    t = MeasuredCostTable.load(str(tmp_path / "nope.json"), backend="x")
    assert len(t) == 0 and t.mean_cycles_per_us() == 0.0


def test_parse_signature_inverts_slot_signature():
    assert parse_signature("lstm|H64|G3|B1|bt1|float32|fwd|chained") == {
        "family": "lstm", "H": 64, "G": 3, "B": 1, "chunk_len": 1,
        "dtype": "float32", "dirs": "fwd", "chained": True,
        "precision": "fp32"}
    assert parse_signature(SIG)["chained"] is False
    assert parse_signature(SIG)["precision"] == "fp32"  # untagged default
    assert parse_signature("garbage") is None
    assert parse_signature("a|b|c|d|e|f|g") is None  # malformed ints


def test_parse_signature_precision_tag():
    sig = slot_signature("lstm", 64, 3, 1, 1, "float32", precision="int8")
    assert sig.endswith("|pint8")
    f = parse_signature(sig)
    assert f["precision"] == "int8" and f["chained"] is False
    # tag order with chained (precision rides before the chained marker)
    both = slot_signature("lstm", 64, 3, 1, 1, "float32", precision="bf16",
                          chained=True)
    f = parse_signature(both)
    assert f["precision"] == "bf16" and f["chained"] is True
    # fp32 stays untagged: persisted pre-precision tables parse unchanged
    assert "|p" not in slot_signature("lstm", 64, 3, 1, 1, "float32")


# -- the scorer's resolution ladder -------------------------------------


def test_exact_hit_returns_median():
    m = MeasuredCostModel(_table())
    assert m.active
    assert m.slot_us("lstm", 64, 3, 1, 1, "float32") == pytest.approx(100.0)
    assert (m.hits, m.interpolated, m.fallbacks) == (1, 0, 0)


def test_near_miss_interpolates_by_analytic_ratio():
    m = MeasuredCostModel(_table())
    got = m.slot_us("lstm", 64, 3, 2, 1, "float32")  # B=2: neighbor of B=1
    ratio = (analytic_shape_cycles("lstm", 64, 3, 2, 1, DESIGN)
             / analytic_shape_cycles("lstm", 64, 3, 1, 1, DESIGN))
    assert got == pytest.approx(100.0 * ratio)
    assert (m.hits, m.interpolated, m.fallbacks) == (0, 1, 0)


def test_no_close_neighbor_falls_back_to_analytic_conversion():
    m = MeasuredCostModel(_table())
    # H ratio 1024/64 = 16 > NEIGHBOR_MAX_RATIO: not interpolatable
    got = m.slot_us("lstm", 1024, 3, 1, 1, "float32")
    est = analytic_shape_cycles("lstm", 1024, 3, 1, 1, DESIGN)
    assert got == pytest.approx(est / m.table.mean_cycles_per_us())
    assert (m.hits, m.interpolated, m.fallbacks) == (0, 0, 1)


def test_categorical_fields_never_cross():
    # a chained query must not interpolate from a sequence-slot entry
    m = MeasuredCostModel(_table())
    m.slot_us("lstm", 64, 3, 1, 1, "float32", chained=True)
    assert m.interpolated == 0 and m.fallbacks == 1
    # nor a gru query from an lstm entry
    m.slot_us("gru", 64, 3, 1, 1, "float32")
    assert m.interpolated == 0 and m.fallbacks == 2


def test_precision_populations_never_cross():
    """ISSUE-10 regression: an int8 measurement must never price an fp32
    query (or vice versa) — not as an exact hit, and not through the <=4x
    neighbor ladder, which would silently blend the two launch costs.
    Each precision resolves its own entries; a query with no same-
    precision entry anywhere falls back to the analytic estimate."""
    int8_sig = slot_signature("lstm", 64, 3, 1, 1, "float32",
                              precision="int8")
    t = MeasuredCostTable("testbe")
    t.record(int8_sig, 50.0, 60.0, 5,
             analytic_shape_cycles("lstm", 64, 3, 1, 1, DESIGN,
                                   precision="int8"))
    m = MeasuredCostModel(t)
    # the fp32 query at the SAME shape: neither a hit nor a neighbor
    m.slot_us("lstm", 64, 3, 1, 1, "float32")
    assert (m.hits, m.interpolated, m.fallbacks) == (0, 0, 1)
    # ...even at a near-neighbor shape well inside the 4x ladder
    m.slot_us("lstm", 64, 3, 2, 1, "float32")
    assert (m.hits, m.interpolated, m.fallbacks) == (0, 0, 2)
    # the int8 query resolves its own entry exactly...
    assert m.slot_us("lstm", 64, 3, 1, 1, "float32",
                     precision="int8") == pytest.approx(50.0)
    assert m.hits == 1
    # ...and interpolates int8-to-int8 through the ladder
    m.slot_us("lstm", 64, 3, 2, 1, "float32", precision="int8")
    assert m.interpolated == 1

    # the mirror direction: an fp32 entry never resolves an int8 query
    m2 = MeasuredCostModel(_table())
    m2.slot_us("lstm", 64, 3, 1, 1, "float32", precision="int8")
    m2.slot_us("lstm", 64, 3, 2, 1, "float32", precision="int8")
    assert (m2.hits, m2.interpolated, m2.fallbacks) == (0, 0, 2)


def test_cold_start_is_inactive():
    m = MeasuredCostModel(MeasuredCostTable("testbe"))
    assert not m.active
    assert "cold start" in m.describe()
    # the planner's gate: an inactive model is treated as no model at all
    from repro.dispatch.planner import _active_cost_model
    assert _active_cost_model(m) is None
    assert _active_cost_model(None) is None
    active = MeasuredCostModel(_table())
    assert _active_cost_model(active) is active
