"""Replay harness smoke: candidates lower to real kernel launches, timings
land in a backend-tagged table, and the enumerators produce the shapes the
planner actually launches (signature-consistent with real plans)."""
import jax
import jax.numpy as jnp

from repro.calib import (Candidate, calibrate, candidates_for, check_table,
                         current_backend, dedupe, replay_candidate,
                         sweep_grid)
from repro.configs.sharp_lstm import lstm_config
from repro.models.layers.lstm import init_lstm_stack
from repro import rnn


def test_replay_candidate_measures():
    c = Candidate(family="lstm", H=64, G=1, B=1, block_t=1)
    r = replay_candidate(c, interpret=True, repeats=2, warmup=1)
    assert r["med_us"] > 0 and r["p90_us"] >= r["med_us"] and r["n"] == 2


def test_replay_chained_candidate_measures():
    c = Candidate(family="lstm", H=64, G=3, B=1, block_t=1, chained=True)
    r = replay_candidate(c, interpret=True, repeats=2, warmup=1)
    assert r["med_us"] > 0


def test_calibrate_builds_backend_tagged_table():
    cands = [Candidate(family="lstm", H=64, G=1, B=1, block_t=1),
             Candidate(family="gru", H=64, G=1, B=1, block_t=1)]
    table = calibrate(cands, interpret=True, repeats=2, warmup=1)
    assert table.backend == current_backend(True)
    assert len(table) == 2
    for sig in table.signatures():
        e = table.lookup(sig)
        assert e["med_us"] > 0 and e["est_cycles"] > 0
    # the `make calibrate` gate: a fresh replay agrees with the table it
    # was just built from, within a generous tolerance
    assert check_table(table, interpret=True, tolerance=1000.0,
                       repeats=1) == []


def test_sweep_grid_dedupes_and_covers_chained():
    cands = sweep_grid(families=("lstm",), Hs=(64,), Gs=(1, 3), Bs=(1,),
                       block_ts=(1,), chained_Ls=(3,))
    sigs = [c.signature() for c in cands]
    assert len(sigs) == len(set(sigs))
    assert any(c.chained for c in cands)
    assert dedupe(cands + cands) == cands


def test_candidates_for_matches_real_plan_signatures():
    cfg = lstm_config(64, layers=3)
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    cands = candidates_for(cs, shapes=((2, 8),))
    sigs = {c.signature() for c in cands}
    # the forward plan's slots are all covered
    p = cs.lower(2, 8)
    assert {s.signature() for s in p.slots} <= sigs
    # ...and both sides of the decode decision are enumerated
    assert any(c.chained for c in cands)
    assert any(not c.chained and c.block_t == 1 and c.G == 1
               for c in cands)
