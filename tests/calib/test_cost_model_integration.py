"""ExecutionPolicy(cost_model="measured") through the planner and facade:
cold-start bit-identity with analytic mode, the chained-vs-loop decode
flip under a table that contradicts the perfmodel, counter surfacing in
CompiledStack.stats/describe, and dual-score plan_candidates tracing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.calib import (MeasuredCostTable, analytic_shape_cycles,
                         current_backend)
from repro.configs.sharp_lstm import lstm_config
from repro.core.perfmodel import Design
from repro.models.layers.lstm import init_lstm_stack
from repro.runtime.obs import slot_signature

H, L, B = 64, 3, 2
DESIGN = Design(macs=16384, schedule="unfolded")


@pytest.fixture(scope="module")
def stack():
    return init_lstm_stack(jax.random.PRNGKey(0), lstm_config(H, layers=L),
                           jnp.float32)


@pytest.fixture(scope="module")
def xs():
    return jax.random.normal(jax.random.PRNGKey(1), (B, 8, H)) * 0.5


def _flip_table_path(tmp_path, chained_us=12000.0, layer_us=100.0):
    """A table for THIS backend claiming one chained decode launch costs
    ``chained_us`` while a single per-layer launch costs ``layer_us`` —
    the interpreter reality the analytic launch-count term contradicts."""
    t = MeasuredCostTable(current_backend(True))
    t.record(slot_signature("lstm", H, L, B, 1, "float32", ("fwd",), True),
             chained_us, chained_us * 1.1, 5,
             analytic_shape_cycles("lstm", H, L, B, 1, DESIGN, chained=True))
    t.record(slot_signature("lstm", H, 1, B, 1, "float32"),
             layer_us, layer_us * 1.2, 5,
             analytic_shape_cycles("lstm", H, 1, B, 1, DESIGN))
    path = str(tmp_path / "measured_costs.json")
    t.save(path)
    return path


def test_policy_validates_cost_model_fields():
    pol = rnn.ExecutionPolicy(cost_model="measured", cost_table="x.json")
    assert "cost_model=measured" in pol.describe()
    with pytest.raises(ValueError, match="cost_model"):
        rnn.ExecutionPolicy(cost_model="vibes")
    with pytest.raises(ValueError, match="cost_table"):
        rnn.ExecutionPolicy(cost_table=7)
    assert rnn.COST_MODELS == ("analytic", "measured")


def test_cold_start_measured_is_bit_identical_to_analytic(stack, xs):
    analytic = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    cold = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, cost_model="measured",
        cost_table=os.path.join("definitely", "missing.json")))
    assert cold.cost_model is not None and not cold.cost_model.active
    assert analytic.lower(B, 8).describe() == cold.lower(B, 8).describe()
    np.testing.assert_array_equal(np.asarray(analytic.forward(xs)),
                                  np.asarray(cold.forward(xs)))
    assert cold.stats.measured_hits == 0
    assert cold.stats.analytic_fallbacks == 0
    # decode stays the chained single launch too
    _, st = cold.prefill(xs)
    cold.decode(xs[:, :1], st)
    assert cold.last_decode_plan.launches == 1


def test_measured_table_flips_decode_to_per_layer(tmp_path, stack, xs):
    path = _flip_table_path(tmp_path)
    analytic = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    measured = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, cost_model="measured", cost_table=path))

    _, st_a = analytic.prefill(xs)
    _, st_m = measured.prefill(xs)
    y_a, new_a = analytic.decode(xs[:, :1], st_a)
    y_m, new_m = measured.decode(xs[:, :1], st_m)

    assert analytic.last_decode_plan.launches == 1
    assert measured.last_decode_plan.launches == L  # the flip
    assert all(ip.schedule != "decode"
               for ip in measured.last_decode_plan.items)
    # the flipped plan computes the identical tick
    np.testing.assert_array_equal(np.asarray(y_a), np.asarray(y_m))
    np.testing.assert_array_equal(np.asarray(new_a["h"]),
                                  np.asarray(new_m["h"]))
    assert measured.stats.measured_hits > 0


def test_measured_table_can_also_confirm_chained(tmp_path, stack, xs):
    # a table agreeing with the perfmodel (chained cheap) keeps the chain
    path = _flip_table_path(tmp_path, chained_us=10.0, layer_us=1000.0)
    measured = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, cost_model="measured", cost_table=path))
    _, st = measured.prefill(xs)
    measured.decode(xs[:, :1], st)
    assert measured.last_decode_plan.launches == 1
    assert measured.last_decode_plan.items[0].schedule == "decode"


def test_describe_and_stats_surface_cost_model(tmp_path, stack, xs):
    analytic = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    assert "cost model: analytic" in analytic.describe()

    path = _flip_table_path(tmp_path)
    measured = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, cost_model="measured", cost_table=path))
    measured.forward(xs)
    d = measured.describe()
    assert "cost model: measured" in d and "table entries" in d
    # every lookup resolved somehow, and the counters reached .stats
    cm = measured.cost_model
    assert (measured.stats.measured_hits
            == cm.hits + cm.interpolated)
    assert measured.stats.analytic_fallbacks == cm.fallbacks
    assert (measured.stats.measured_hits
            + measured.stats.analytic_fallbacks) > 0


def test_plan_candidates_trace_carries_both_scores(tmp_path, stack, xs):
    path = _flip_table_path(tmp_path)
    measured = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, cost_model="measured", cost_table=path,
        trace=True))
    _, st = measured.prefill(xs)
    measured.decode(xs[:, :1], st)
    inst = [e for e in measured.tracer.events
            if e.name == "plan_candidates"
            and e.tags.get("cost_model") == "measured"]
    assert inst, "no measured plan_candidates instant traced"
    decode_inst = [e for e in inst
                   if {c["schedule"] for c in e.tags["candidates"]}
                   == {"chained", "per_layer"}]
    assert decode_inst
    for c in decode_inst[0].tags["candidates"]:
        assert c["est_cycles"] > 0   # the analytic score, always present
        assert c["est_us"] > 0       # ...and the measured score beside it
    assert decode_inst[0].tags["chosen"] == "per_layer"


def test_analytic_plan_candidates_untouched(stack, xs):
    analytic = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                      trace=True))
    _, st = analytic.prefill(xs)
    analytic.decode(xs[:, :1], st)
    inst = [e for e in analytic.tracer.events
            if e.name == "plan_candidates"
            and "chained" in {c["schedule"]
                              for c in e.tags.get("candidates", ())}]
    assert inst and inst[0].tags["chosen"] == "chained"
    assert all("est_us" not in c for c in inst[0].tags["candidates"])
