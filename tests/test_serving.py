"""Serving engine: batched continuous batching == per-request greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


def _reference_greedy(cfg, params, prompt, max_new):
    """Single-request greedy loop via raw prefill/decode."""
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = tf.prefill(cfg, params, {"tokens": tokens}, seq_len=64)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(max_new - 1):
        lg, cache = tf.decode_step(cfg, params, cache,
                                   {"tokens": jnp.asarray([[out[-1]]], jnp.int32)})
        out.append(int(jnp.argmax(lg[0, 0])))
    return out


@pytest.mark.parametrize("arch", ["starcoder2-3b", "recurrentgemma-2b"])
def test_engine_matches_reference(arch):
    cfg = get_reduced(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, tokens=p, max_new_tokens=6))
    done = engine.run_to_completion()
    assert sorted(c.uid for c in done) == [0, 1, 2]
    for c in done:
        ref = _reference_greedy(cfg, params, prompts[c.uid], 6)
        assert c.tokens == ref, (c.uid, c.tokens, ref)


def test_eos_stops_generation():
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    ref = _reference_greedy(cfg, params, prompt, 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    engine.submit(Request(uid=0, tokens=prompt, max_new_tokens=8, eos_id=eos))
    done = engine.run_to_completion()
    assert done[0].tokens == ref[:3]


def test_prefill_buckets_bound_compiles():
    """Many distinct prompt lengths -> prefill only ever sees power-of-two
    bucket lengths, so XLA compiles once per bucket, not once per length."""
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    lengths = [3, 5, 6, 7, 9, 11, 13]
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    prompts = {}
    for uid, n in enumerate(lengths):
        prompts[uid] = rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
        engine.submit(Request(uid=uid, tokens=prompts[uid], max_new_tokens=4))
    done = engine.run_to_completion()
    assert len(done) == len(lengths)
    # 7 distinct lengths collapse to buckets {2, 4, 8}
    assert engine.prefill_lengths == {2, 4, 8}
    assert all((b & (b - 1)) == 0 for b in engine.prefill_lengths)
    # bucketed chunked prefill stays exact vs the full-prompt reference
    for c in done:
        ref = _reference_greedy(cfg, params, prompts[c.uid], 4)
        assert c.tokens == ref, (c.uid, c.tokens, ref)


def test_zero_token_request_completes_without_prefill():
    """max_new_tokens=0: complete immediately with no generated tokens —
    must never occupy a slot, compile a prefill, or stall the admit wave
    for the real requests behind it."""
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    p0 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, size=5).astype(np.int32)
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    engine.submit(Request(uid=0, tokens=p0, max_new_tokens=0))
    engine.submit(Request(uid=1, tokens=p1, max_new_tokens=3))
    done = {c.uid: c for c in engine.run_to_completion()}
    assert done[0].tokens == []
    assert done[1].tokens == _reference_greedy(cfg, params, p1, 3)


def test_step_with_empty_queue_is_a_noop():
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    engine.step()  # nothing queued mid-tick
    assert engine.steps == 0 and not engine.done
    assert engine.prefill_lengths == set()


def test_slots_are_reused():
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for uid in range(5):  # 5 requests through 2 slots
        engine.submit(Request(
            uid=uid, tokens=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
            max_new_tokens=3))
    done = engine.run_to_completion()
    assert len(done) == 5
    assert all(len(c.tokens) == 3 for c in done)
