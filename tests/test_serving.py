"""Serving engine: batched continuous batching == per-request greedy decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


def _reference_greedy(cfg, params, prompt, max_new):
    """Single-request greedy loop via raw prefill/decode."""
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    logits, cache = tf.prefill(cfg, params, {"tokens": tokens}, seq_len=64)
    out = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(max_new - 1):
        lg, cache = tf.decode_step(cfg, params, cache,
                                   {"tokens": jnp.asarray([[out[-1]]], jnp.int32)})
        out.append(int(jnp.argmax(lg[0, 0])))
    return out


@pytest.mark.parametrize("arch", ["starcoder2-3b", "recurrentgemma-2b"])
def test_engine_matches_reference(arch):
    cfg = get_reduced(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for uid, p in enumerate(prompts):
        engine.submit(Request(uid=uid, tokens=p, max_new_tokens=6))
    done = engine.run_to_completion()
    assert sorted(c.uid for c in done) == [0, 1, 2]
    for c in done:
        ref = _reference_greedy(cfg, params, prompts[c.uid], 6)
        assert c.tokens == ref, (c.uid, c.tokens, ref)


def test_eos_stops_generation():
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    ref = _reference_greedy(cfg, params, prompt, 8)
    eos = ref[2]  # force an early stop at the 3rd generated token
    engine = ServingEngine(cfg, params, max_batch=1, max_seq=64)
    engine.submit(Request(uid=0, tokens=prompt, max_new_tokens=8, eos_id=eos))
    done = engine.run_to_completion()
    assert done[0].tokens == ref[:3]


def test_slots_are_reused():
    cfg = get_reduced("starcoder2-3b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    engine = ServingEngine(cfg, params, max_batch=2, max_seq=64)
    for uid in range(5):  # 5 requests through 2 slots
        engine.submit(Request(
            uid=uid, tokens=rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
            max_new_tokens=3))
    done = engine.run_to_completion()
    assert len(done) == 5
    assert all(len(c.tokens) == 3 for c in done)
