"""Chained decode kernels: one launch walks a whole L-layer T=1 tick.

The contract the planned serving decode relies on: ``lstm_decode`` /
``gru_decode`` are bit-identical to L per-layer sequence-kernel launches
(the pre-existing decode loop), the inter-layer value chaining through VMEM
scratch across sequential grid steps — and they are structurally ONE
pallas_call where the loop is L.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.common import pallas_launch_count
from repro.kernels.gru_cell.ops import gru_decode, gru_seq
from repro.kernels.lstm_cell.ops import lstm_decode, lstm_seq


def _stack(L, H, X, gates, seed=0):
    key = jax.random.PRNGKey(seed)
    layers = []
    for l in range(L):
        key, k1, k2, k3 = jax.random.split(key, 4)
        x_dim = X if l == 0 else H
        layers.append({
            "W": jax.random.normal(k1, (x_dim, gates * H)) * 0.2,
            "U": jax.random.normal(k2, (H, gates * H)) * 0.2,
            "b": jax.random.normal(k3, (gates * H,)) * 0.1,
        })
    return layers


def _decode_args(layers, x, gates, H):
    """Pack a stack + input frame into the decode kernels' argument shapes
    (layer 0's input half hoisted; its W slot zero-filled when X != H)."""
    L = len(layers)
    xw0 = (jnp.einsum("btx,xg->btg", x, layers[0]["W"])
           + layers[0]["b"]).reshape(x.shape[0], 1, gates, H)[:, 0]
    W0 = (layers[0]["W"].reshape(H, gates, H)
          if layers[0]["W"].shape[0] == H
          else jnp.zeros((H, gates, H), jnp.float32))
    Ws = jnp.stack([W0] + [layers[l]["W"].reshape(H, gates, H)
                           for l in range(1, L)])
    bs = jnp.stack([l_["b"].reshape(gates, H) for l_ in layers])
    Us = jnp.stack([l_["U"].reshape(H, gates, H) for l_ in layers])
    return xw0, Ws, bs, Us


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("X", [32, 48])  # X != H exercises the hoisted-W0 path
def test_lstm_decode_bit_identical_to_per_layer_loop(dtype, X):
    L, B, H = 3, 2, 32
    layers = _stack(L, H, X, 4, seed=1)
    x = (jax.random.normal(jax.random.PRNGKey(2), (B, 1, X)) * 0.5
         ).astype(dtype)
    h = (jax.random.normal(jax.random.PRNGKey(3), (L, B, H)) * 0.3
         ).astype(dtype)
    c = jax.random.normal(jax.random.PRNGKey(4), (L, B, H)) * 0.3

    # the pre-existing decode loop: L per-layer T=1 launches
    y, h_ref, c_ref = x, [], []
    for l, lay in enumerate(layers):
        xw = (jnp.einsum("btx,xg->btg", y, lay["W"])
              + lay["b"]).reshape(B, 1, 4, H)
        hs, h_n, c_n = lstm_seq(lay["U"].reshape(H, 4, H), xw, h[l], c[l],
                                block_t=1, interpret=True)
        h_ref.append(h_n)
        c_ref.append(c_n)
        y = hs.astype(x.dtype)

    xw0, Ws, bs, Us = _decode_args(layers, x, 4, H)
    h_n, c_n = lstm_decode(xw0, Ws, bs, Us, h, c, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(h_n.astype(jnp.float32)),
        np.asarray(jnp.stack(h_ref).astype(jnp.float32)))
    np.testing.assert_array_equal(np.asarray(c_n),
                                  np.asarray(jnp.stack(c_ref)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gru_decode_bit_identical_to_per_layer_loop(dtype):
    L, B, H = 4, 3, 24
    layers = _stack(L, H, H, 3, seed=5)
    x = (jax.random.normal(jax.random.PRNGKey(6), (B, 1, H)) * 0.5
         ).astype(dtype)
    h = (jax.random.normal(jax.random.PRNGKey(7), (L, B, H)) * 0.3
         ).astype(dtype)

    y, h_ref = x, []
    for l, lay in enumerate(layers):
        xw = (jnp.einsum("btx,xg->btg", y, lay["W"])
              + lay["b"]).reshape(B, 1, 3, H)
        hs, h_n = gru_seq(lay["U"].reshape(H, 3, H), xw, h[l], block_t=1,
                          interpret=True)
        h_ref.append(h_n)
        y = hs.astype(x.dtype)

    xw0, Ws, bs, Us = _decode_args(layers, x, 3, H)
    h_n = gru_decode(xw0, Ws, bs, Us, h, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(h_n.astype(jnp.float32)),
        np.asarray(jnp.stack(h_ref).astype(jnp.float32)))


def test_bf16_weight_stack_matches_per_layer_loop():
    """Low-precision WEIGHTS (not just activations): with f32 activations
    the hoist promotes to f32 and the chained tick stays bit-identical;
    fully-bf16 stacks agree to one bf16 ulp per deeper layer (interpret
    mode emulates in-kernel bf16 dots in f32 — see lstm_decode)."""
    L, B, H = 3, 2, 16
    key = jax.random.PRNGKey(11)
    layers = []
    for _ in range(L):
        key, k1, k2, k3 = jax.random.split(key, 4)
        layers.append({
            "W": (jax.random.normal(k1, (H, 4 * H)) * 0.2
                  ).astype(jnp.bfloat16),
            "U": (jax.random.normal(k2, (H, 4 * H)) * 0.2
                  ).astype(jnp.bfloat16),
            "b": (jax.random.normal(k3, (4 * H,)) * 0.1
                  ).astype(jnp.bfloat16),
        })
    for ad, exact in ((jnp.float32, True), (jnp.bfloat16, False)):
        x = (jax.random.normal(jax.random.PRNGKey(12), (B, 1, H)) * 0.5
             ).astype(ad)
        h = (jax.random.normal(jax.random.PRNGKey(13), (L, B, H)) * 0.3
             ).astype(ad)
        c = jax.random.normal(jax.random.PRNGKey(14), (L, B, H)) * 0.3
        y, h_ref, c_ref = x, [], []
        for l, lay in enumerate(layers):
            xw = (jnp.einsum("btx,xg->btg", y, lay["W"])
                  + lay["b"]).reshape(B, 1, 4, H)
            hs, h_n, c_n = lstm_seq(lay["U"].reshape(H, 4, H), xw, h[l],
                                    c[l], block_t=1, interpret=True)
            h_ref.append(h_n)
            c_ref.append(c_n)
            y = hs.astype(x.dtype)
        xw0, Ws, bs, Us = _decode_args(layers, x, 4, H)
        h_n, c_n = lstm_decode(xw0, Ws, bs, Us, h, c, interpret=True)
        got = np.asarray(h_n.astype(jnp.float32))
        want = np.asarray(jnp.stack(h_ref).astype(jnp.float32))
        if exact:
            np.testing.assert_array_equal(got, want)
            np.testing.assert_array_equal(np.asarray(c_n),
                                          np.asarray(jnp.stack(c_ref)))
        else:
            np.testing.assert_allclose(got, want, atol=2e-2)


def test_decode_is_one_launch_where_the_loop_is_L():
    L, B, H = 5, 2, 16
    layers = _stack(L, H, H, 4, seed=8)
    x = jax.random.normal(jax.random.PRNGKey(9), (B, 1, H)) * 0.5
    h = jnp.zeros((L, B, H))
    c = jnp.zeros((L, B, H))
    xw0, Ws, bs, Us = _decode_args(layers, x, 4, H)

    chained = pallas_launch_count(
        lambda *a: lstm_decode(*a, interpret=True), xw0, Ws, bs, Us, h, c)

    def loop(x, h, c):
        y, outs = x, []
        for l, lay in enumerate(layers):
            xw = (jnp.einsum("btx,xg->btg", y, lay["W"])
                  + lay["b"]).reshape(B, 1, 4, H)
            hs, h_n, c_n = lstm_seq(lay["U"].reshape(H, 4, H), xw, h[l],
                                    c[l], block_t=1, interpret=True)
            y = hs.astype(x.dtype)
            outs.append(h_n)
        return outs

    assert chained == 1
    assert pallas_launch_count(loop, x, h, c) == L
