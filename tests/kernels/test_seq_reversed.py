"""Time-reversed sequence-kernel parity (ISSUE-5 satellite).

The dispatcher's bidirectional bwd cells use *pre-launch reversal*: flip
the hoisted xw stripe on the time axis, run the unchanged sequence kernel,
flip the produced hs stripe back.  Two contracts are pinned here:

1. a reversed-input ``lstm_seq``/``gru_seq`` walk matches the step-loop
   oracle walking original time *descending* (fp32 and bf16, any T);
2. the executor's chunked composition — descending chunk walk with state
   chained across launches and exact remainder chunks — BIT-equals the
   single-launch whole-T reversed walk (the exactness claim behind the
   interleaved bidirectional wavefront).
"""
import jax
import jax.numpy as jnp
import numpy as np

from tests._hyp import given, settings, st

from repro.kernels.gru_cell.ops import gru_seq
from repro.kernels.gru_cell.ref import gru_step_ref
from repro.kernels.lstm_cell.ops import lstm_seq
from repro.kernels.lstm_cell.ref import lstm_cell_ref

H = 40


def _mk(B, T, dtype, seed=0, gates=4):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    U = (jax.random.normal(ks[0], (H, gates, H), jnp.float32) * 0.2
         ).astype(dtype)
    xw = jax.random.normal(ks[1], (B, T, gates, H), jnp.float32).astype(dtype)
    h0 = (jax.random.normal(ks[2], (B, H), jnp.float32) * 0.5).astype(dtype)
    c0 = jax.random.normal(ks[3], (B, H), jnp.float32) * 0.5
    return U, xw, h0, c0


def _rev_lstm_oracle(U4, xw, h0, c0):
    """Step loop over original time DESCENDING (the bwd walk)."""
    T = xw.shape[1]
    h, c = h0, c0.astype(jnp.float32)
    outs = [None] * T
    for t in range(T - 1, -1, -1):
        h, c = lstm_cell_ref(U4, xw[:, t], h, c)
        outs[t] = h
    return jnp.stack(outs, axis=1), h, c


def _rev_gru_oracle(U3, xw, h0):
    T = xw.shape[1]
    h = h0
    outs = [None] * T
    for t in range(T - 1, -1, -1):
        h = gru_step_ref(U3, xw[:, t], h)
        outs[t] = h
    return jnp.stack(outs, axis=1), h


@settings(max_examples=10, deadline=None)
@given(T=st.integers(1, 18), bt=st.sampled_from([1, 3, 4, 8]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_reversed_lstm_seq_matches_descending_step_loop(T, bt, dtype):
    """flip ∘ lstm_seq ∘ flip == the descending step-loop oracle, ragged
    T-stripe remainders (bt not dividing T) included."""
    dt = jnp.dtype(dtype)
    U4, xw, h0, c0 = _mk(2, T, dt, seed=T * 31 + bt)
    hs, h_n, c_n = lstm_seq(U4, jnp.flip(xw, 1), h0, c0, block_t=bt,
                            interpret=True)
    hs = jnp.flip(hs, 1)
    ref_hs, ref_h, ref_c = _rev_lstm_oracle(U4, xw, h0, c0)
    atol = 1e-4 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(hs, np.float32),
                               np.asarray(ref_hs, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(h_n, np.float32),
                               np.asarray(ref_h, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(c_n), np.asarray(ref_c), atol=atol)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(1, 18), bt=st.sampled_from([1, 3, 4, 8]),
       dtype=st.sampled_from(["float32", "bfloat16"]))
def test_reversed_gru_seq_matches_descending_step_loop(T, bt, dtype):
    dt = jnp.dtype(dtype)
    U3, xw, h0, _ = _mk(2, T, dt, seed=T * 17 + bt, gates=3)
    hs, h_n = gru_seq(U3, jnp.flip(xw, 1), h0, block_t=bt, interpret=True)
    hs = jnp.flip(hs, 1)
    ref_hs, ref_h = _rev_gru_oracle(U3, xw, h0)
    atol = 1e-4 if dtype == "float32" else 2e-2
    np.testing.assert_allclose(np.asarray(hs, np.float32),
                               np.asarray(ref_hs, np.float32), atol=atol)
    np.testing.assert_allclose(np.asarray(h_n, np.float32),
                               np.asarray(ref_h, np.float32), atol=atol)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(1, 18), bt=st.sampled_from([1, 3, 4, 8]))
def test_chunked_descending_lstm_walk_bit_equals_single_launch(T, bt):
    """The executor's composition — per-chunk flip, state chained across
    launches in descending chunk order, exact remainder chunk — is
    BIT-identical to one whole-T reversed launch (fp32: the f32 state
    round-trips exactly between chunk launches)."""
    U4, xw, h0, c0 = _mk(2, T, jnp.float32, seed=T * 7 + bt)
    one_hs, one_h, one_c = lstm_seq(U4, jnp.flip(xw, 1), h0, c0,
                                    block_t=min(bt, T), interpret=True)
    one_hs = jnp.flip(one_hs, 1)

    nk = -(-T // bt)
    h, c = h0, c0
    outs = [None] * nk
    for k in range(nk - 1, -1, -1):  # the bwd walk's own chunk order
        sl = xw[:, k * bt:k * bt + bt]
        hs, h, c = lstm_seq(U4, jnp.flip(sl, 1), h, c,
                            block_t=sl.shape[1], interpret=True)
        h = h.astype(h0.dtype)
        outs[k] = jnp.flip(hs, 1)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(outs, 1)),
                                  np.asarray(one_hs))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(one_h))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(one_c))


@settings(max_examples=10, deadline=None)
@given(T=st.integers(1, 18), bt=st.sampled_from([1, 3, 4, 8]))
def test_chunked_descending_gru_walk_bit_equals_single_launch(T, bt):
    U3, xw, h0, _ = _mk(2, T, jnp.float32, seed=T * 13 + bt, gates=3)
    one_hs, one_h = gru_seq(U3, jnp.flip(xw, 1), h0, block_t=min(bt, T),
                            interpret=True)
    one_hs = jnp.flip(one_hs, 1)

    nk = -(-T // bt)
    h = h0
    outs = [None] * nk
    for k in range(nk - 1, -1, -1):
        sl = xw[:, k * bt:k * bt + bt]
        hs, h = gru_seq(U3, jnp.flip(sl, 1), h, block_t=sl.shape[1],
                        interpret=True)
        h = h.astype(h0.dtype)
        outs[k] = jnp.flip(hs, 1)
    np.testing.assert_array_equal(np.asarray(jnp.concatenate(outs, 1)),
                                  np.asarray(one_hs))
    np.testing.assert_array_equal(np.asarray(h), np.asarray(one_h))
