"""Per-kernel allclose: flash-decode GQA attention vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref


def _mk(B, T, Hq, Hk, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, Hq, D), jnp.float32)
    kc = jax.random.normal(ks[1], (B, T, Hk, D), jnp.float32)
    vc = jax.random.normal(ks[2], (B, T, Hk, D), jnp.float32)
    valid = jax.random.randint(ks[3], (B,), 1, T + 1)
    return q, kc, vc, valid


@pytest.mark.parametrize("B,T,Hq,Hk,D", [
    (1, 64, 4, 4, 32),    # MHA
    (2, 128, 8, 2, 64),   # GQA
    (1, 512, 16, 1, 128),  # MQA
    (3, 256, 8, 8, 64),
])
def test_allclose(B, T, Hq, Hk, D):
    q, kc, vc, valid = _mk(B, T, Hq, Hk, D)
    o = decode_attention(q, kc, vc, valid)
    r = decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_block_sweep():
    q, kc, vc, valid = _mk(2, 256, 8, 2, 32)
    ref = decode_attention_ref(q, kc, vc, valid)
    for bt in (32, 64, 128, 256):
        o = decode_attention(q, kc, vc, valid, block_t=bt)
        np.testing.assert_allclose(np.asarray(o), np.asarray(ref), atol=2e-5)


def test_valid_one_equals_first_value():
    """With a single live slot, output == v[0] per head group."""
    B, T, Hq, Hk, D = 1, 64, 4, 2, 16
    q, kc, vc, _ = _mk(B, T, Hq, Hk, D)
    valid = jnp.ones((B,), jnp.int32)
    o = decode_attention(q, kc, vc, valid)
    expect = jnp.repeat(vc[:, 0], Hq // Hk, axis=1)  # (B, Hk*G, D)
    np.testing.assert_allclose(np.asarray(o), np.asarray(expect), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 2), tblocks=st.integers(1, 4),
       Hk=st.sampled_from([1, 2, 4]), G=st.sampled_from([1, 2, 4]),
       D=st.sampled_from([16, 32]))
def test_property(B, tblocks, Hk, G, D):
    T = 64 * tblocks
    q, kc, vc, valid = _mk(B, T, Hk * G, Hk, D, seed=T + Hk)
    o = decode_attention(q, kc, vc, valid, block_t=64)
    r = decode_attention_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)
