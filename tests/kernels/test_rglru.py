"""Per-kernel allclose: RG-LRU scan kernel vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels.rglru.ops import rglru_scan
from repro.kernels.rglru.ref import rglru_scan_ref


def _mk(B, T, W, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    log_a = -jnp.abs(jax.random.normal(ks[0], (B, T, W))) * 0.3
    gx = jax.random.normal(ks[1], (B, T, W))
    h0 = jax.random.normal(ks[2], (B, W))
    return log_a, gx, h0


@pytest.mark.parametrize("B,T,W", [(1, 4, 32), (2, 16, 64), (3, 13, 100),
                                   (1, 64, 513), (2, 7, 2560)])
def test_allclose(B, T, W):
    log_a, gx, h0 = _mk(B, T, W)
    hs, hT = rglru_scan(log_a, gx, h0)
    hr, hTr = rglru_scan_ref(log_a, gx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-6)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(hTr), atol=1e-6)


def test_block_sweep():
    log_a, gx, h0 = _mk(2, 9, 200)
    ref, _ = rglru_scan_ref(log_a, gx, h0)
    for bw in (32, 64, 128, 256):
        hs, _ = rglru_scan(log_a, gx, h0, block_w=bw)
        np.testing.assert_allclose(np.asarray(hs), np.asarray(ref), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 20), W=st.integers(4, 150))
def test_property(B, T, W):
    log_a, gx, h0 = _mk(B, T, W, seed=T * 77 + W)
    hs, hT = rglru_scan(log_a, gx, h0)
    hr, hTr = rglru_scan_ref(log_a, gx, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-6)
    # last output equals the final state
    np.testing.assert_allclose(np.asarray(hs[:, -1]), np.asarray(hT), atol=0)


def test_decay_contract():
    """With log_a = 0 (a=1) the input contribution vanishes: h stays h0."""
    B, T, W = 2, 5, 32
    log_a = jnp.zeros((B, T, W))
    gx = jax.random.normal(jax.random.PRNGKey(0), (B, T, W))
    h0 = jax.random.normal(jax.random.PRNGKey(1), (B, W))
    hs, hT = rglru_scan(log_a, gx, h0)
    np.testing.assert_allclose(np.asarray(hT), np.asarray(h0), atol=1e-6)
