"""Per-kernel allclose: reconfigurable tiled MVM vs oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels.mvm_tile.ops import mvm
from repro.kernels.mvm_tile.ref import mvm_ref


def _mk(B, X, N, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    x = jax.random.normal(ks[0], (B, X), jnp.float32).astype(dtype)
    W = (jax.random.normal(ks[1], (X, N), jnp.float32) * 0.1).astype(dtype)
    b = jax.random.normal(ks[2], (N,), jnp.float32)
    return x, W, b


@pytest.mark.parametrize("B,X,N", [
    (1, 64, 128), (4, 100, 300), (2, 340, 1360), (8, 513, 129), (1, 32, 32),
])
@pytest.mark.parametrize("bn,bk", [(128, 64), (256, 128)])
def test_allclose_fp32(B, X, N, bn, bk):
    x, W, b = _mk(B, X, N, jnp.float32)
    y = mvm(x, W, b, block_n=min(bn, N), block_k=min(bk, X))
    np.testing.assert_allclose(np.asarray(y), np.asarray(mvm_ref(x, W, b)),
                               atol=2e-5, rtol=1e-5)


def test_no_bias_and_vector_input():
    x, W, _ = _mk(1, 96, 160, jnp.float32)
    y = mvm(x[0], W)  # (X,) path
    np.testing.assert_allclose(np.asarray(y), np.asarray(mvm_ref(x, W)[0]),
                               atol=2e-5, rtol=1e-5)
    assert y.shape == (160,)


@pytest.mark.parametrize("dtype,atol", [(jnp.bfloat16, 5e-2), (jnp.float32, 2e-5)])
def test_dtypes(dtype, atol):
    x, W, b = _mk(2, 128, 256, dtype)
    y = mvm(x, W, b)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(mvm_ref(x, W, b), np.float32),
                               atol=atol, rtol=1e-2)


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), X=st.integers(8, 200), N=st.integers(8, 200),
       bn=st.sampled_from([32, 64, 128]), bk=st.sampled_from([32, 64]))
def test_property_edges(B, X, N, bn, bk):
    x, W, b = _mk(B, X, N, jnp.float32, seed=X * 211 + N)
    y = mvm(x, W, b, block_n=min(bn, N), block_k=min(bk, X))
    np.testing.assert_allclose(np.asarray(y), np.asarray(mvm_ref(x, W, b)),
                               atol=3e-5, rtol=1e-4)
