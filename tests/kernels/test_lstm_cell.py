"""Per-kernel allclose: fused LSTM cell vs pure-jnp oracle (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.kernels.lstm_cell.ops import lstm_cell
from repro.kernels.lstm_cell.ref import lstm_cell_ref


def _mk(B, H, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    U4 = (jax.random.normal(ks[0], (H, 4, H), jnp.float32) * 0.2).astype(dtype)
    xw = jax.random.normal(ks[1], (B, 4, H), jnp.float32).astype(dtype)
    h = jax.random.normal(ks[2], (B, H), jnp.float32).astype(dtype)
    c = jax.random.normal(ks[3], (B, H), jnp.float32)
    return U4, xw, h, c


SHAPES = [(1, 32), (2, 64), (3, 100), (2, 256), (1, 340), (2, 513)]
BLOCKS = [(32, 32), (64, 32), (128, 128)]


@pytest.mark.parametrize("B,H", SHAPES)
@pytest.mark.parametrize("bh,bk", BLOCKS)
def test_allclose_fp32(B, H, bh, bk):
    U4, xw, h, c = _mk(B, H, jnp.float32)
    ho, co = lstm_cell(U4, xw, h, c, block_h=min(bh, H), block_k=min(bk, H))
    hr, cr = lstm_cell_ref(U4, xw, h, c)
    np.testing.assert_allclose(np.asarray(ho), np.asarray(hr), atol=2e-5)
    np.testing.assert_allclose(np.asarray(co), np.asarray(cr), atol=2e-5)


@pytest.mark.parametrize("B,H", [(2, 64), (2, 100)])
def test_allclose_bf16(B, H):
    U4, xw, h, c = _mk(B, H, jnp.bfloat16)
    ho, co = lstm_cell(U4, xw, h, c, block_h=64, block_k=32)
    hr, cr = lstm_cell_ref(U4, xw, h, c)
    np.testing.assert_allclose(np.asarray(ho, np.float32),
                               np.asarray(hr, np.float32), atol=3e-2)


def test_autotuned_blocks():
    U4, xw, h, c = _mk(2, 200, jnp.float32)
    ho, co = lstm_cell(U4, xw, h, c)  # blocks from the autotune table
    hr, cr = lstm_cell_ref(U4, xw, h, c)
    np.testing.assert_allclose(np.asarray(ho), np.asarray(hr), atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 4), H=st.integers(8, 96),
       bh=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 32, 64]))
def test_property_any_shape(B, H, bh, bk):
    U4, xw, h, c = _mk(B, H, jnp.float32, seed=B * 1000 + H)
    ho, co = lstm_cell(U4, xw, h, c, block_h=min(bh, H), block_k=min(bk, H))
    hr, cr = lstm_cell_ref(U4, xw, h, c)
    np.testing.assert_allclose(np.asarray(ho), np.asarray(hr), atol=2e-5)
    # |h| <= 1 by construction (sigmoid * tanh)
    assert np.all(np.abs(np.asarray(ho)) <= 1.0 + 1e-6)
