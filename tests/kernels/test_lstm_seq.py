"""Sequence-fused LSTM kernel: oracle equivalence + launch accounting.

The acceptance grid for the fused path: H in {96, 256}, T in {1, 7, 64},
B in {1, 4}, fp32, including T-block edges (block_t not dividing T) — and
the structural proof that the fused path issues ONE pallas_call per layer
invocation where the per-step scan path issues T.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core import schedules as sch
from repro.kernels.common import pallas_launch_count
from repro.kernels.lstm_cell.ops import (as_cell_kernel, lstm_seq,
                                         lstm_seq_ref)
from repro.models.layers.lstm import init_lstm_layer, reference_unroll


def _mk(B, T, H, seed=0, G=0):
    lead = (G,) if G else ()
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    U4 = jax.random.normal(ks[0], lead + (H, 4, H), jnp.float32) * 0.2
    xw = jax.random.normal(ks[1], lead + (B, T, 4, H), jnp.float32)
    h0 = jax.random.normal(ks[2], lead + (B, H), jnp.float32) * 0.5
    c0 = jax.random.normal(ks[3], lead + (B, H), jnp.float32) * 0.5
    return U4, xw, h0, c0


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("T", [1, 7, 64])
@pytest.mark.parametrize("H", [96, 256])
def test_acceptance_grid_fp32(B, T, H):
    U4, xw, h0, c0 = _mk(B, T, H, seed=B * 1000 + T * 10 + H)
    hs, h_n, c_n = lstm_seq(U4, xw, h0, c0, interpret=True)
    hr, hnr, cnr = lstm_seq_ref(U4, xw, h0, c0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_n), np.asarray(hnr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c_n), np.asarray(cnr), atol=1e-4)


@pytest.mark.parametrize("T,bt", [(7, 3), (7, 4), (13, 5), (64, 48), (5, 8)])
def test_time_block_edges(T, bt):
    """block_t not dividing T: the last stripe reads BlockSpec padding and
    must mask it out of the state walk."""
    U4, xw, h0, c0 = _mk(2, T, 96, seed=T * 100 + bt)
    hs, h_n, c_n = lstm_seq(U4, xw, h0, c0, block_t=bt, interpret=True)
    hr, hnr, cnr = lstm_seq_ref(U4, xw, h0, c0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c_n), np.asarray(cnr), atol=1e-4)


def test_zero_state_default_matches_reference_unroll():
    """End-to-end against the layer ground truth (hoisted input half)."""
    B, T, H = 2, 11, 64
    params = init_lstm_layer(jax.random.PRNGKey(0), H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, T, H)) * 0.5
    xw = (jnp.einsum("btx,xg->btg", xs, params["W"])
          + params["b"]).reshape(B, T, 4, H)
    hs, _, _ = lstm_seq(params["U"].reshape(H, 4, H), xw, interpret=True)
    ref = reference_unroll(params, xs)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(ref), atol=1e-4)


def test_stacked_cells_one_launch():
    """G independent recurrences (distinct U) in one batched launch — the
    wavefront slot shape."""
    G, B, T, H = 3, 2, 6, 64
    U4, xw, h0, c0 = _mk(B, T, H, seed=7, G=G)
    hs, h_n, c_n = lstm_seq(U4, xw, h0, c0, block_t=4, interpret=True)
    hr, hnr, cnr = lstm_seq_ref(U4, xw, h0, c0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    launches = pallas_launch_count(
        lambda u, x, h, c: lstm_seq(u, x, h, c, block_t=4, interpret=True),
        U4, xw, h0, c0)
    assert launches == 1


@pytest.mark.parametrize("T", [1, 7, 64])
def test_one_launch_vs_T_launches(T):
    """The paper's dispatch claim, structurally: the fused path issues ONE
    pallas_call per layer invocation; the seed's per-step scan issues T."""
    B, H = 2, 96
    params = init_lstm_layer(jax.random.PRNGKey(0), H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, T, H)) * 0.5

    fused = pallas_launch_count(
        lambda p, x: sch.run_layer_fused(p, x, interpret=True), params, xs)
    per_step = pallas_launch_count(
        lambda p, x: sch.run_layer_unfolded(
            p, x, cell_kernel=as_cell_kernel(interpret=True)),
        params, xs)
    assert fused == 1
    assert per_step == T


@pytest.mark.parametrize("stacked", [False, True])
def test_c0_omitted_defaults_to_zeros(stacked):
    """Regression: lstm_seq(U4, xw, h0) with c0 omitted used to crash on
    c0[None] (and pass None through in the stacked branch); a missing c0
    must default to fp32 zeros independently of h0 in BOTH branches."""
    B, T, H = 2, 5, 32
    U4, xw, h0, _ = _mk(B, T, H, seed=3, G=2 if stacked else 0)
    hs, h_n, c_n = lstm_seq(U4, xw, h0, interpret=True)
    zeros = jnp.zeros(h0.shape, jnp.float32)
    hs2, hn2, cn2 = lstm_seq(U4, xw, h0, zeros, interpret=True)
    np.testing.assert_array_equal(np.asarray(hs), np.asarray(hs2))
    np.testing.assert_array_equal(np.asarray(h_n), np.asarray(hn2))
    np.testing.assert_array_equal(np.asarray(c_n), np.asarray(cn2))


def test_ragged_b_mask_rows_are_exact_noops():
    """b_valid padding rows pass their state through untouched and valid
    rows are bit-exact vs the unmasked launch — the cross-B packing
    contract."""
    G, B, T, H = 2, 3, 9, 40
    U4, xw, h0, c0 = _mk(B, T, H, seed=11, G=G)
    b_valid = jnp.array([3, 1])
    hs, h_n, c_n = lstm_seq(U4, xw, h0, c0, b_valid=b_valid, block_t=4,
                            interpret=True)
    full, hn_f, cn_f = lstm_seq(U4, xw, h0, c0, block_t=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(hs[0]), np.asarray(full[0]))
    np.testing.assert_array_equal(np.asarray(h_n[1, :1]),
                                  np.asarray(hn_f[1, :1]))
    np.testing.assert_array_equal(np.asarray(c_n[1, :1]),
                                  np.asarray(cn_f[1, :1]))
    # padded rows: state passes through bit-exactly
    np.testing.assert_array_equal(np.asarray(h_n[1, 1:]),
                                  np.asarray(h0[1, 1:]))
    np.testing.assert_array_equal(np.asarray(c_n[1, 1:]),
                                  np.asarray(c0[1, 1:]))


def test_b_valid_rejected_for_unstacked():
    U4, xw, h0, c0 = _mk(2, 4, 16, seed=5)
    with pytest.raises(ValueError, match="stacked"):
        lstm_seq(U4, xw, h0, c0, b_valid=jnp.array([1]), interpret=True)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 20), H=st.sampled_from([8, 40, 96]),
       bt=st.sampled_from([1, 3, 8, 16]))
def test_property_any_shape(B, T, H, bt):
    U4, xw, h0, c0 = _mk(B, T, H, seed=B + T * 7 + H)
    hs, h_n, c_n = lstm_seq(U4, xw, h0, c0, block_t=bt, interpret=True)
    hr, hnr, cnr = lstm_seq_ref(U4, xw, h0, c0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    # |h| <= 1 by construction (sigmoid * tanh)
    assert np.all(np.abs(np.asarray(hs)) <= 1.0 + 1e-6)
