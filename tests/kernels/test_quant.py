"""Unit tests for the shared quantization + block-sparsity utilities
(kernels.quant) — ISSUE-10 satellite.  These are the single source of the
repo's int8 scale convention and the tile-bitmap format, so the contract
is pinned here: symmetric absmax/127 scales, clipped [-127, 127] payload,
idempotent re-quantization (what lets CompiledStack bind the fake-quant
param view once and share ONE oracle across every surface), and value-
exact row compaction round-trips."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.perfmodel import MXU_ROWS
from repro.kernels.quant import (absmax_scale, active_row_indices,
                                 bf16_roundtrip, compact_rows, density,
                                 dequantize_per_gate, expand_rows,
                                 fake_quant_stack, int8_roundtrip, quantize,
                                 quantize_per_gate, stack_density,
                                 stack_tile_maps, tile_bitmap)


def _u(key, H=16, gates=4, scale=0.5):
    return jax.random.normal(jax.random.PRNGKey(key), (H, gates, H)) * scale


# ---------------------------------------------------------------------------
# the scale convention
# ---------------------------------------------------------------------------


def test_absmax_scale_convention():
    x = jnp.asarray([-2.54, 1.0, 0.3])
    assert float(absmax_scale(x)) == pytest.approx(2.54 / 127.0)
    # floored away from zero: an all-zero tensor still quantizes
    assert float(absmax_scale(jnp.zeros(4))) > 0.0


def test_quantize_hits_127_at_absmax():
    x = jnp.asarray([-3.0, 1.5, 3.0])
    q = quantize(x, absmax_scale(x))
    assert q.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(q), [-127, 64, 127])


def test_int8_roundtrip_error_bound():
    g = jax.random.normal(jax.random.PRNGKey(0), (64, 64))
    err = jnp.max(jnp.abs(int8_roundtrip(g) - g))
    # half-step bound: scale/2 = absmax/254
    assert float(err) <= float(jnp.max(jnp.abs(g))) / 254.0 + 1e-7


def test_bf16_roundtrip_is_f32_and_idempotent():
    x = jax.random.normal(jax.random.PRNGKey(1), (32, 32))
    y = bf16_roundtrip(x)
    assert y.dtype == jnp.float32
    np.testing.assert_array_equal(np.asarray(bf16_roundtrip(y)),
                                  np.asarray(y))


# ---------------------------------------------------------------------------
# per-gate quantization
# ---------------------------------------------------------------------------


def test_quantize_per_gate_shapes_and_granularity():
    U = _u(0)
    q, s = quantize_per_gate(U)
    assert q.shape == U.shape and q.dtype == jnp.int8
    assert s.shape == (4,) and s.dtype == jnp.float32
    # one scale per gate slab: each slab's absmax lands exactly on +-127
    assert all(int(jnp.max(jnp.abs(q[:, g]))) == 127 for g in range(4))
    # and the scales really are per-gate (distinct slabs -> distinct scales)
    U2 = U.at[:, 1].multiply(10.0)
    _, s2 = quantize_per_gate(U2)
    assert float(s2[1]) == pytest.approx(10 * float(s[1]), rel=1e-6)
    assert float(s2[0]) == pytest.approx(float(s[0]), rel=1e-6)


def test_per_gate_roundtrip_error_bound():
    U = _u(2)
    q, s = quantize_per_gate(U)
    err = jnp.abs(dequantize_per_gate(q, s) - U)
    assert float(jnp.max(err)) <= float(jnp.max(s)) / 2 + 1e-7


def test_requantization_is_idempotent():
    """quantize(dequantize(q)) == q EXACTLY — the dequantized view's slab
    absmax quantizes back to exactly +-127, so the recomputed scale and
    payload reproduce bit-for-bit.  CompiledStack relies on this: it binds
    the fake-quant param view once, and the executor's hoist re-quantizes
    that view for the packed path — every surface shares one oracle."""
    U = _u(3)
    q, s = quantize_per_gate(U)
    Ud = dequantize_per_gate(q, s)
    q2, s2 = quantize_per_gate(Ud)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
    np.testing.assert_array_equal(np.asarray(s2), np.asarray(s))


# ---------------------------------------------------------------------------
# tile bitmaps + row compaction
# ---------------------------------------------------------------------------


def _sparse_u(H=32, gates=4, zero_tiles=(1, 3)):
    U = np.array(jax.random.normal(jax.random.PRNGKey(7),
                                   (H, gates, H)))
    for t in zero_tiles:
        U[t * MXU_ROWS:(t + 1) * MXU_ROWS] = 0.0
    return jnp.asarray(U)


def test_tile_bitmap_marks_zero_tiles():
    U = _sparse_u()
    assert tile_bitmap(U) == (1, 0, 1, 0)
    assert tile_bitmap(jnp.zeros((16, 4, 16))) == (0, 0)
    # 2D (H, gates*H) layout reads the same occupancy
    assert tile_bitmap(U.reshape(32, -1)) == (1, 0, 1, 0)
    assert density((1, 0, 1, 0)) == 0.5 and density(None) == 1.0
    assert stack_density(((1, 0), (1, 1))) == 0.75


def test_active_row_indices_clip_partial_tile():
    # H=12 with tile=8: second tile holds rows 8..11 only
    assert active_row_indices((1, 1), 12) == list(range(12))
    assert active_row_indices((0, 1), 12) == list(range(8, 12))


def test_compact_expand_roundtrip_exact():
    U = _sparse_u()
    Uc, rows = compact_rows(U, tile_bitmap(U))
    assert Uc.shape[0] == rows.shape[0] == 16  # 2 live tiles x 8 rows
    np.testing.assert_array_equal(np.asarray(expand_rows(Uc, rows, 32)),
                                  np.asarray(U))


def test_compact_rows_padding_is_exact_noop():
    U = _sparse_u()
    Uc, rows = compact_rows(U, tile_bitmap(U), pad_to=20)
    assert Uc.shape[0] == rows.shape[0] == 20
    # padding rows: zero weights at index 0 -> scatter-add back is exact
    np.testing.assert_array_equal(np.asarray(Uc[16:]), 0.0)
    np.testing.assert_array_equal(np.asarray(rows[16:]), 0)
    np.testing.assert_array_equal(np.asarray(expand_rows(Uc, rows, 32)),
                                  np.asarray(U))
    with pytest.raises(ValueError, match="pad_to"):
        compact_rows(U, tile_bitmap(U), pad_to=15)


def test_compact_rows_all_zero_still_nonempty():
    Uc, rows = compact_rows(jnp.zeros((16, 4, 16)), (0, 0))
    assert Uc.shape[0] == rows.shape[0] == 1  # non-empty dot operand
    np.testing.assert_array_equal(np.asarray(expand_rows(Uc, rows, 16)),
                                  0.0)


# ---------------------------------------------------------------------------
# the oracle-side stack transforms
# ---------------------------------------------------------------------------


def _stack(bidirectional=False):
    import dataclasses

    from repro.configs.sharp_lstm import lstm_config
    from repro.models.layers.lstm import init_lstm_stack

    cfg = lstm_config(16, layers=2)
    if bidirectional:
        cfg = dataclasses.replace(cfg, bidirectional=True)
    return init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)


@pytest.mark.parametrize("bidir", [False, True])
def test_fake_quant_stack_touches_u_only(bidir):
    params = _stack(bidir)
    fq = fake_quant_stack(params, "int8")
    for lay, lay_q in zip(params["layers"], fq["layers"]):
        halves = (("fwd", "bwd") if bidir else (None,))
        for hk in halves:
            h, hq = (lay[hk], lay_q[hk]) if hk else (lay, lay_q)
            np.testing.assert_array_equal(np.asarray(h["W"]),
                                          np.asarray(hq["W"]))
            np.testing.assert_array_equal(np.asarray(h["b"]),
                                          np.asarray(hq["b"]))
            assert not np.array_equal(np.asarray(h["U"]),
                                      np.asarray(hq["U"]))
            # the view is the kernels' own round-trip, so it is a fixpoint
            np.testing.assert_array_equal(
                np.asarray(fake_quant_stack(fq, "int8")["layers"][0]["U"]
                           if not hk else
                           fake_quant_stack(fq, "int8")["layers"][0][hk]
                           ["U"]),
                np.asarray(fq["layers"][0]["U"] if not hk
                           else fq["layers"][0][hk]["U"]))
    # fp32 is the identity, not a copy
    assert fake_quant_stack(params, "fp32") is params


def test_stack_tile_maps_or_union_bidir():
    params = _stack(bidirectional=True)
    H = 16
    lay = params["layers"][0]
    fwd_u = np.array(lay["fwd"]["U"])
    bwd_u = np.array(lay["bwd"]["U"])
    fwd_u[0:MXU_ROWS] = 0.0           # fwd zeros tile 0
    bwd_u[MXU_ROWS:2 * MXU_ROWS] = 0.0  # bwd zeros tile 1
    lay["fwd"]["U"] = jnp.asarray(fwd_u)
    lay["bwd"]["U"] = jnp.asarray(bwd_u)
    tm = stack_tile_maps(params)
    assert len(tm) == 2 and len(tm[0]) == H // MXU_ROWS
    # OR-union: a tile is skippable only if BOTH halves zero it
    assert tm[0] == (1, 1)
    bwd_u[0:MXU_ROWS] = 0.0           # now both halves zero tile 0
    lay["bwd"]["U"] = jnp.asarray(bwd_u)
    assert stack_tile_maps(params)[0] == (0, 1)
