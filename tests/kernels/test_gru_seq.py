"""Sequence-fused GRU kernel: oracle equivalence + launch accounting —
the lstm_seq acceptance grid ported to the 3-gate cell."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from tests._hyp import given, settings, st

from repro.core import gru
from repro.kernels.common import pallas_launch_count
from repro.kernels.gru_cell.ops import gru_seq, gru_seq_ref


def _mk(B, T, H, seed=0, G=0):
    lead = (G,) if G else ()
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    U3 = jax.random.normal(ks[0], lead + (H, 3, H), jnp.float32) * 0.2
    xw = jax.random.normal(ks[1], lead + (B, T, 3, H), jnp.float32)
    h0 = jax.random.normal(ks[2], lead + (B, H), jnp.float32) * 0.5
    return U3, xw, h0


@pytest.mark.parametrize("B", [1, 4])
@pytest.mark.parametrize("T", [1, 7, 64])
@pytest.mark.parametrize("H", [96, 256])
def test_acceptance_grid_fp32(B, T, H):
    U3, xw, h0 = _mk(B, T, H, seed=B * 1000 + T * 10 + H)
    hs, h_n = gru_seq(U3, xw, h0, interpret=True)
    hr, hnr = gru_seq_ref(U3, xw, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_n), np.asarray(hnr), atol=1e-4)


@pytest.mark.parametrize("T,bt", [(7, 3), (13, 5), (5, 8)])
def test_time_block_edges(T, bt):
    U3, xw, h0 = _mk(2, T, 96, seed=T * 100 + bt)
    hs, h_n = gru_seq(U3, xw, h0, block_t=bt, interpret=True)
    hr, hnr = gru_seq_ref(U3, xw, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_n), np.asarray(hnr), atol=1e-4)


def test_ragged_b_mask_rows_are_exact_noops():
    """b_valid padding rows pass their state through untouched and valid
    rows are bit-exact vs the unmasked launch — the cross-B packing
    contract (GRU edition)."""
    G, B, T, H = 2, 3, 9, 40
    U3, xw, h0 = _mk(B, T, H, seed=11, G=G)
    hs, h_n = gru_seq(U3, xw, h0, b_valid=jnp.array([3, 2]), block_t=4,
                      interpret=True)
    full, hn_f = gru_seq(U3, xw, h0, block_t=4, interpret=True)
    np.testing.assert_array_equal(np.asarray(hs[0]), np.asarray(full[0]))
    np.testing.assert_array_equal(np.asarray(h_n[1, :2]),
                                  np.asarray(hn_f[1, :2]))
    np.testing.assert_array_equal(np.asarray(h_n[1, 2:]),
                                  np.asarray(h0[1, 2:]))


def test_stacked_cells_one_launch():
    """G independent GRU recurrences in one batched launch — the wavefront
    slot shape the dispatcher packs."""
    G, B, T, H = 3, 2, 6, 64
    U3, xw, h0 = _mk(B, T, H, seed=7, G=G)
    hs, h_n = gru_seq(U3, xw, h0, block_t=4, interpret=True)
    hr, hnr = gru_seq_ref(U3, xw, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
    launches = pallas_launch_count(
        lambda u, x, h: gru_seq(u, x, h, block_t=4, interpret=True),
        U3, xw, h0)
    assert launches == 1


def test_fused_layer_matches_reference_unroll_one_launch():
    params = gru.init_gru_layer(jax.random.PRNGKey(0), 48, 48, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 11, 48)) * 0.5
    out = gru.run_layer_fused(params, xs, interpret=True)
    ref = gru.reference_unroll(params, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)
    n = pallas_launch_count(
        lambda p, x: gru.run_layer_fused(p, x, interpret=True), params, xs)
    assert n == 1


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 20),
       H=st.sampled_from([8, 40, 96]), bt=st.sampled_from([1, 3, 8, 16]))
def test_property_any_shape(B, T, H, bt):
    U3, xw, h0 = _mk(B, T, H, seed=B + T * 7 + H)
    hs, h_n = gru_seq(U3, xw, h0, block_t=bt, interpret=True)
    hr, hnr = gru_seq_ref(U3, xw, h0)
    np.testing.assert_allclose(np.asarray(hs), np.asarray(hr), atol=1e-4)
