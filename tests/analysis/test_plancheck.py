"""analysis.plancheck: the static plan verifier (ISSUE-8 tentpole).

Two halves, mirroring the acceptance criteria.  **Pristine plans pass**:
every planner output the repo produces — uni, bidirectional, heterogeneous
lstm/gru, chained decode, cross-B packed, external-fallback — verifies
clean (these are the same plans ``ExecutionPolicy(verify="plan")``, the
default, now checks on every cache miss, so this half is also the no-
false-positives guarantee for the whole suite).  **Seeded corruptions are
rejected with the right rule**: one mutation per invariant class, applied
with ``dataclasses.replace`` to a pristine plan, each asserting the
verifier raises ``PlanInvariantError`` naming exactly the rule the
mutation breaks.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.analysis.plancheck import (RULES, check_decode_tick, check_plan)
from repro.configs.sharp_lstm import lstm_config
from repro.core import gru
from repro.dispatch.planner import Cell, plan, plan_decode
from repro.dispatch.workitem import WorkItem
from repro.models.layers.lstm import init_lstm_layer, init_lstm_stack
from repro.runtime.errors import PlanInvariantError, PlanRejected

H = 48
POL = rnn.ExecutionPolicy(interpret=True, block_t=8)
r = dataclasses.replace


def _cfg(L=2, **kw):
    cfg = lstm_config(H, layers=L)
    return r(cfg, **kw) if kw else cfg


def _share_plan(L=2, T=24, n=3):
    """Cross-B packed plan: n parameter-sharing ragged-B items."""
    items = [WorkItem.from_config(_cfg(L), T=T, uid=i, B=1 + i, share=7)
             for i in range(n)]
    return plan(items, block_t=8)


def _decode_plan(n=2):
    items = [WorkItem.from_config(_cfg(3), T=1, uid=i, share=7)
             for i in range(n)]
    return plan_decode(items)


def _expect(rule, mutant, **kw):
    with pytest.raises(PlanInvariantError) as ei:
        check_plan(mutant, **kw)
    assert ei.value.rule == rule, \
        f"expected rule {rule!r}, got {ei.value.rule!r}: {ei.value}"
    return ei.value


# ---------------------------------------------------------------------------
# pristine plans pass — every planner output the repo produces
# ---------------------------------------------------------------------------


def test_uni_bidir_hetero_plans_verify_clean():
    stack = init_lstm_stack(jax.random.PRNGKey(0), _cfg(3), jnp.float32)
    rep = check_plan(rnn.compile(stack, POL).lower(2, 24))
    assert rep.items == 1 and rep.cells == 3 * 3  # L=3 · nk=3

    bi = init_lstm_stack(jax.random.PRNGKey(0),
                         _cfg(3, bidirectional=True, dtype="float32"),
                         jnp.float32)
    rep = check_plan(rnn.compile(bi, POL).lower(2, 24))
    assert rep.cells == 2 * 3 * 3  # both directions

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    mixed = {"layers": [init_lstm_layer(k1, H, H, jnp.float32),
                        gru.init_gru_layer(k2, H, H, jnp.float32),
                        init_lstm_layer(k3, H, H, jnp.float32)]}
    rep = check_plan(rnn.compile(mixed, POL).lower(2, 24))
    assert rep.items == 1 and rep.cells == 9
    assert "OK" in rep.describe() and rep.rules == RULES


def test_cross_b_and_decode_and_external_plans_verify_clean():
    rep = check_plan(_share_plan())
    assert rep.items == 3

    rep = check_plan(_decode_plan())
    assert rep.chained == 1 and rep.cells == 2 * 3  # item-rows x layers

    # forced research schedules route items external: nothing on the
    # packed timeline, still a clean (empty) proof
    stack = init_lstm_stack(jax.random.PRNGKey(0), _cfg(2), jnp.float32)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                schedule="sequential"))
    p = cs.lower(2, 12)
    assert 0 in p.external
    assert check_plan(p).cells == 0


def test_remainder_chunks_verify_clean():
    """T=20 at bt=8 -> chunks 8/8/4: the ragged tail is part of the
    tiling proof, not an exception to it."""
    p = plan([WorkItem.from_config(_cfg(2), T=20, uid=0)], block_t=8)
    assert check_plan(p).cells == 2 * 3


# ---------------------------------------------------------------------------
# seeded corruptions: one per invariant class, each caught by ITS rule
# ---------------------------------------------------------------------------


def test_mutation_dropped_slot_is_coverage_missing():
    p = _share_plan()
    err = _expect("coverage-missing", r(p, slots=p.slots[:-1]))
    assert err.cell is not None and err.uids  # names the lost cell


def test_mutation_duplicated_row_is_coverage_duplicate():
    p = _share_plan()
    s0, s1 = p.slots[0], p.slots[1]
    dup = r(s1, groups=s1.groups + s0.groups[:1],
            group_b=s1.group_b + s0.group_b[:1])
    _expect("coverage-duplicate", r(p, slots=(s0, dup) + p.slots[2:]))


def test_mutation_foreign_cell_is_coverage_unknown():
    p = _share_plan()
    s0 = p.slots[0]
    alien = r(s0, groups=s0.groups + ((Cell(99, 0, 0, "fwd"),),),
              group_b=s0.group_b + (1,))
    err = _expect("coverage-unknown", r(p, slots=(alien,) + p.slots[1:]))
    assert err.uids == (99,)


def test_mutation_swapped_waves_are_readiness_violations():
    # nk=1, L=2: the only dependency is the layer walk -> readiness-layer
    p = plan([WorkItem.from_config(_cfg(2), T=8, uid=0)], block_t=8)
    assert len(p.slots) == 2
    s0, s1 = p.slots
    swapped = (r(s0, wave=s1.wave), r(s1, wave=s0.wave))
    _expect("readiness-layer", r(p, slots=swapped))

    # L=1, nk=2: the only dependency is the chunk walk -> readiness-chunk
    p = plan([WorkItem.from_config(_cfg(1), T=16, uid=0)], block_t=8)
    assert len(p.slots) == 2
    s0, s1 = p.slots
    swapped = (r(s0, wave=s1.wave), r(s1, wave=s0.wave))
    _expect("readiness-chunk", r(p, slots=swapped))


def test_mutation_reordered_tuple_is_wave_monotone():
    # waves stay correct; only the executor's tuple order is corrupted
    p = plan([WorkItem.from_config(_cfg(1), T=16, uid=0)], block_t=8)
    _expect("wave-monotone", r(p, slots=tuple(reversed(p.slots))))


def test_mutation_merged_mixed_dtype_row_is_pack_row_mix():
    """Two same-share items in different dtypes never merge on B; force
    the merge and the verifier rejects the row."""
    i32 = WorkItem.from_config(_cfg(1, dtype="float32"), T=8, uid=0,
                               share=7)
    i16 = WorkItem.from_config(_cfg(1, dtype="bfloat16"), T=8, uid=1,
                               share=7)
    p = plan([i32, i16], block_t=8)
    by_dtype = {s.dtype: s for s in p.slots}
    assert len(by_dtype) == 2  # pristine planner keeps them apart
    host = by_dtype["float32"]
    guest_cell = by_dtype["bfloat16"].groups[0][0]
    merged = r(host, groups=((host.groups[0] + (guest_cell,)),),
               group_b=(host.group_b[0] + 1,), B=host.B + 1)
    slots = tuple(merged if s is host else s for s in p.slots)
    _expect("pack-row-mix", r(p, slots=slots))


def test_mutation_wrong_group_width_is_pack_width():
    p = _share_plan()
    s0 = p.slots[0]
    lied = r(s0, group_b=tuple(b + 1 for b in s0.group_b))
    _expect("pack-width", r(p, slots=(lied,) + p.slots[1:]))


def test_mutation_wrong_slot_dtype_is_pack_signature():
    p = plan([WorkItem.from_config(_cfg(2, dtype="float32"), T=8, uid=0)],
             block_t=8)
    s0 = p.slots[0]
    assert s0.dtype == "float32"
    _expect("pack-signature",
            r(p, slots=(r(s0, dtype="bfloat16"),) + p.slots[1:]))


def test_mutation_offtable_tile_config_is_stripe_align():
    p = _share_plan()
    s0 = p.slots[0]
    _expect("stripe-align",
            r(p, slots=(r(s0, tile_k=s0.tile_k * 2),) + p.slots[1:]))


def test_mutation_wrong_chunk_len_is_chunk_tiling():
    p = plan([WorkItem.from_config(_cfg(1), T=16, uid=0)], block_t=8)
    s0 = p.slots[0]
    _expect("chunk-tiling",
            r(p, slots=(r(s0, chunk_len=4),) + p.slots[1:]))


def test_mutation_vmem_overflow_is_vmem_budget():
    p = _share_plan()
    s0 = p.slots[0]
    huge = r(s0, B=1 << 16, group_b=tuple(1 << 16
                                          for _ in s0.group_b))
    err = _expect("vmem-budget", r(p, slots=(huge,) + p.slots[1:]))
    assert err.slot == s0.index
    # ... and the budget is configurable: the pristine plan fails a
    # deliberately tiny one
    _expect("vmem-budget", _share_plan(), vmem_budget=1024)


def test_mutation_scrambled_chain_is_decode_chain():
    p = _decode_plan()
    (slot,) = p.slots
    scrambled = r(slot, groups=(slot.groups[1], slot.groups[0])
                  + slot.groups[2:])
    _expect("decode-chain", r(p, slots=(scrambled,)))


# ---------------------------------------------------------------------------
# structured error + facade/serving wiring
# ---------------------------------------------------------------------------


def test_plan_invariant_error_names_rule_slot_cell():
    p = _share_plan()
    err = _expect("coverage-missing", r(p, slots=p.slots[:-1]))
    assert isinstance(err, rnn.ServingFault)
    assert err.rule in RULES
    assert err.cell is not None and len(err.cell) == 4
    assert "coverage-missing" in str(err)


def test_decode_cost_model_inversion_raises_structured(monkeypatch):
    """The planner's former bare `assert est_chain <= est_layers`
    (regression for the ISSUE-8 satellite): a broken perfmodel now
    surfaces as PlanInvariantError(rule='decode-cost-model')."""
    import repro.dispatch.planner as planner_mod
    monkeypatch.setattr(planner_mod, "decode_plan_cycles",
                        lambda *a, **kw: 10 ** 12)
    with pytest.raises(PlanInvariantError) as ei:
        _decode_plan()
    assert ei.value.rule == "decode-cost-model"


def test_duplicate_uids_shared_helper_raises_plan_rejected():
    items = [WorkItem.from_config(_cfg(1), T=8, uid=0),
             WorkItem.from_config(_cfg(1), T=8, uid=0, B=2)]
    with pytest.raises(PlanRejected) as ei:
        plan(items)
    assert ei.value.uids == (0,)
    dec = [WorkItem.from_config(_cfg(1), T=1, uid=3, share=7)] * 2
    with pytest.raises(PlanRejected):
        plan_decode(dec)


def test_check_decode_tick_rejects_wrong_row_count():
    p = _decode_plan(n=2)
    check_decode_tick(p, 2)
    with pytest.raises(PlanInvariantError) as ei:
        check_decode_tick(p, 3)
    assert ei.value.rule == "decode-active-rows"


def test_policy_verify_wiring_counts_and_is_bit_identical():
    """verify='plan' (the default) proves each plan once per cache miss;
    verify='off' skips; outputs are bit-identical either way."""
    stack = init_lstm_stack(jax.random.PRNGKey(0), _cfg(2), jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 12, H)) * 0.5

    on = rnn.compile(stack, POL)
    assert on.policy.verify == "plan"
    y_on = on.forward(xs)
    assert on.stats.plans_verified == on.stats.plans_built == 1
    on.forward(xs)  # cache hit: no re-verification
    assert on.stats.plans_verified == 1
    assert "1 verified" in on.describe()

    off = rnn.compile(stack, r(POL, verify="off"))
    y_off = off.forward(xs)
    assert off.stats.plans_verified == 0
    np.testing.assert_array_equal(np.asarray(y_on), np.asarray(y_off))
