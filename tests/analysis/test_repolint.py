"""analysis.repolint: the AST lint over the repo's own contracts.

Each rule is exercised on a minimal source snippet (both the violating
and the compliant form, and both in- and out-of-scope paths), and the
acceptance criterion — the lint runs clean over the real ``src/repro``
tree — is itself a test, so a future PR that reintroduces a bare assert
on the serving path or an ad-hoc ``time.time()`` fails here before CI's
``make lint-repro`` ever runs.
"""
import subprocess
import sys
from pathlib import Path

from repro.analysis.repolint import (collect, lint_source, main)

SRC = Path(__file__).resolve().parents[2] / "src" / "repro"


def _rules(src, relpath):
    return [v.rule for v in lint_source(src, relpath)]


# ---------------------------------------------------------------------------
# RL001: deprecated shims
# ---------------------------------------------------------------------------


def test_rl001_flags_deprecated_shim_calls_anywhere():
    src = "from repro.core import schedules\nschedules.run_stack(p, x)\n"
    assert _rules(src, "src/repro/models/foo.py") == ["RL001"]
    assert _rules("run_layer(p, x)\n", "src/repro/serving/bar.py") \
        == ["RL001"]


def test_rl001_allows_suffixed_entry_points_and_defining_modules():
    ok = "from repro.core import schedules\nschedules.run_layer_fused(p, x)\n"
    assert "RL001" not in _rules(ok, "src/repro/dispatch/executor.py")
    # the defining modules may reference their own shims
    assert "RL001" not in _rules("run_layer(p, x)\n",
                                 "src/repro/core/schedules.py")
    assert "RL001" not in _rules("run_layer(p, x)\n",
                                 "src/repro/core/gru.py")


# ---------------------------------------------------------------------------
# RL002: bare assert / RuntimeError on the serving path
# ---------------------------------------------------------------------------


def test_rl002_flags_assert_and_runtime_error_on_serving_path():
    assert _rules("assert x > 0\n", "src/repro/serving/x.py") == ["RL002"]
    assert _rules("raise RuntimeError('boom')\n",
                  "src/repro/dispatch/x.py") == ["RL002"]
    assert _rules("raise AssertionError('unreachable')\n",
                  "src/repro/rnn/x.py") == ["RL002"]


def test_rl002_allows_taxonomy_and_out_of_scope_asserts():
    ok = ("from repro.runtime.errors import LaunchError\n"
          "raise LaunchError('x', uids=(1,), slot=0)\n")
    assert _rules(ok, "src/repro/serving/x.py") == []
    assert _rules("raise ValueError('bad input')\n",
                  "src/repro/rnn/x.py") == []
    # tests and non-serving layers keep their asserts
    assert _rules("assert x\n", "src/repro/core/lstm.py") == []
    assert _rules("assert x\n", "tests/test_foo.py") == []


# ---------------------------------------------------------------------------
# RL003: timing / fencing outside runtime/obs.py
# ---------------------------------------------------------------------------


def test_rl003_flags_timing_and_fencing_in_scope():
    assert _rules("import time\nt0 = time.perf_counter()\n",
                  "src/repro/serving/x.py") == ["RL003"]
    assert _rules("import jax\njax.block_until_ready(y)\n",
                  "src/repro/dispatch/x.py") == ["RL003"]
    assert _rules("import time\ntime.time()\n",
                  "src/repro/runtime/ft.py") == ["RL003"]


def test_rl003_exempts_obs_and_non_runtime_layers():
    assert _rules("import time\ntime.perf_counter()\n",
                  "src/repro/runtime/obs.py") == []
    # launch/checkpoint legitimately stamp wall-clock metadata
    assert _rules("import time\ntime.time()\n",
                  "src/repro/launch/submit.py") == []
    ok = "from repro.runtime import obs\nt0 = obs.monotonic_s()\n"
    assert _rules(ok, "src/repro/serving/x.py") == []


# ---------------------------------------------------------------------------
# RL004: Slot packing-field reads outside planner/executor/analysis
# ---------------------------------------------------------------------------


def test_rl004_flags_slot_internals_outside_owners():
    assert _rules("w = slot.wave\n", "src/repro/serving/x.py") == ["RL004"]
    assert _rules("bs = [s.group_b for s in p.slots]\n",
                  "src/repro/models/x.py") == ["RL004"]


def test_rl004_exempts_owners_and_self_access():
    assert _rules("w = slot.wave\n", "src/repro/dispatch/planner.py") == []
    assert _rules("w = slot.tile_k\n", "src/repro/dispatch/executor.py") == []
    assert _rules("w = slot.chained\n", "src/repro/analysis/plancheck.py") == []
    # a dataclass using a same-named field on itself is not a read of
    # someone else's Slot
    assert _rules("class A:\n  def f(self):\n    return self.wave\n",
                  "src/repro/serving/x.py") == []


# ---------------------------------------------------------------------------
# the acceptance criterion: the real tree is clean, and the CLI agrees
# ---------------------------------------------------------------------------


def test_src_repro_is_lint_clean():
    violations = collect(SRC)
    assert violations == [], "\n".join(str(v) for v in violations)


def test_cli_exit_codes(tmp_path):
    assert main([str(SRC)]) == 0
    bad = tmp_path / "repro" / "serving"
    bad.mkdir(parents=True)
    (bad / "x.py").write_text("assert broken\n")
    assert main([str(tmp_path)]) == 1
    assert main([str(tmp_path / "nope")]) == 2


def test_module_entry_point_runs():
    out = subprocess.run(
        [sys.executable, "-m", "repro.analysis.repolint", str(SRC)],
        capture_output=True, text=True)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "clean" in out.stdout
