"""Tile-engine math: padding, cycles, selection (paper §4.2/§6)."""
import math

import pytest
from tests._hyp import given, settings, st

from repro.core.tiling import (K_CHOICES, TileConfig, block_waste, mvm_cycles,
                               padding_waste, select_block_shape,
                               select_time_block, select_tile,
                               seq_block_footprint)


@settings(max_examples=50, deadline=None)
@given(rows=st.integers(1, 5000), cols=st.integers(1, 5000),
       k=st.sampled_from(K_CHOICES), macs=st.sampled_from([1024, 4096, 65536]))
def test_cycles_bounds(rows, cols, k, macs):
    if k > macs:
        return
    t = TileConfig(k=k, macs=macs)
    fixed = mvm_cycles(rows, cols, t, reconfigure=False)
    rec = mvm_cycles(rows, cols, t, reconfigure=True)
    ideal = rows * cols / macs
    assert rec <= fixed                       # reconfiguration never hurts
    assert fixed >= max(1, math.floor(ideal))  # can't beat the MAC budget
    # fixed cycles == analytic ceil product
    assert fixed == max(1, math.ceil(rows / t.k) * math.ceil(cols / t.cols))


@settings(max_examples=50, deadline=None)
@given(rows=st.integers(1, 3000), cols=st.integers(1, 3000),
       k=st.sampled_from(K_CHOICES))
def test_padding_waste_range(rows, cols, k):
    t = TileConfig(k=k, macs=4096)
    w = padding_waste(rows, cols, t)
    assert 0.0 <= w < 1.0
    if rows % t.k == 0 and cols % t.cols == 0:
        assert w == 0.0


def test_select_tile_is_argmin():
    for rows, cols, macs in [(1360, 340, 4096), (4096, 1024, 65536),
                             (400, 100, 1024)]:
        best = select_tile(rows, cols, macs)
        best_c = mvm_cycles(rows, cols, best, reconfigure=True)
        for k in K_CHOICES:
            if k > macs:
                continue
            c = mvm_cycles(rows, cols, TileConfig(k=k, macs=macs),
                           reconfigure=True)
            assert best_c <= c


@settings(max_examples=40, deadline=None)
@given(m=st.integers(1, 4096), n=st.integers(1, 8192))
def test_block_shape_constraints(m, n):
    bm, bn = select_block_shape(m, n)
    assert bm >= 1 and bn >= 128 or bn >= n  # lane-aligned
    assert bm * bn * 4 <= 4 * 2**20  # default VMEM budget
    assert 0.0 <= block_waste(m, n, bm, bn) < 1.0


def test_block_shape_prefers_zero_waste():
    bm, bn = select_block_shape(1024, 4096)
    assert 1024 % bm == 0 and 4096 % bn == 0  # divisible dims -> no waste


def test_block_shape_selection_is_cached():
    """The exploration used to re-run on every hot-path layer call."""
    select_block_shape.cache_clear()
    select_block_shape(300, 700)
    hits = select_block_shape.cache_info().hits
    select_block_shape(300, 700)
    assert select_block_shape.cache_info().hits == hits + 1


def test_select_time_block():
    assert select_time_block(1, 1, 96) == 1
    bt = select_time_block(64, 4, 256)
    assert 1 <= bt <= 64 and 64 % bt == 0   # zero T-edge waste is available
    assert select_time_block(7, 2, 96) == 7  # exact fit beats padded stripes
    # huge H: U alone blows the budget -> degenerate single-step stripe
    assert select_time_block(64, 8, 2048) == 1


@settings(max_examples=30, deadline=None)
@given(T=st.integers(1, 300), B=st.integers(1, 8),
       H=st.sampled_from([32, 96, 256, 1024]))
def test_time_block_constraints(T, B, H):
    bt = select_time_block(T, B, H)
    assert 1 <= bt <= T
    if bt > 1:  # within the fused kernel's VMEM budget
        assert 4 * (4 * H * H + B * bt * 5 * H + 4 * B * H) <= 8 * 2**20


def test_time_block_int8_doubles_stripe_when_weight_bound():
    """ISSUE-10 acceptance: at the stripe-bound H512/B8/T64 shape the fp32
    resident U is 4 MB of the 8 MB budget and caps bt at 32; the int8
    payload (1 MB + per-gate scales) frees enough VMEM to keep the full
    T=64 stripe — a >= 2x larger time block from precision alone."""
    bt_fp32 = select_time_block(64, 8, 512)
    bt_int8 = select_time_block(64, 8, 512, precision="int8")
    assert bt_fp32 == 32 and bt_int8 == 64
    assert bt_int8 >= 2 * bt_fp32
    # bf16 sits between: half the weight bytes also unlocks the full stripe
    assert select_time_block(64, 8, 512, precision="bf16") == 64
    # footprint math agrees with the selection at the boundary
    assert seq_block_footprint(64, 8, 512) > 8 * 2**20           # fp32: no
    assert seq_block_footprint(64, 8, 512,
                               precision="int8") <= 8 * 2**20    # int8: yes


def test_time_block_density_discount():
    """Block-sparse residency: a half-dense U (+ its row-index operand)
    shrinks the weight term, so the selector keeps a larger stripe at the
    same weight-bound shape; density=1.0 is byte-identical to dense."""
    assert seq_block_footprint(32, 8, 512, density=1.0) == \
        seq_block_footprint(32, 8, 512)
    dense = select_time_block(64, 8, 512)
    sparse = select_time_block(64, 8, 512, density=0.25)
    assert sparse >= 2 * dense
    half = seq_block_footprint(32, 8, 512, density=0.5)
    full = seq_block_footprint(32, 8, 512)
    w = 4 * 4 * 512 * 512
    assert half == full - w + int(w * 0.5) + 4 * 256  # rows operand added
