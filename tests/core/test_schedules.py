"""The paper's core equivalence claim: all four schedules compute the same
LSTM, differing only in dependence structure."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.configs.sharp_lstm import reduced
from repro.core import schedules as sch
from repro.kernels.lstm_cell.ops import as_cell_kernel
from repro.models.layers.lstm import (init_lstm_layer, init_lstm_stack,
                                      reference_unroll)

# this module intentionally exercises the DEPRECATED run_layer/run_stack
# shims — ISSUE-4 keeps them passing through repro.rnn.compile; the
# warnings are the contract, not noise worth failing on here (the shim
# tests in tests/rnn/test_shims.py assert they fire)
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mk(B, T, H, seed=0):
    key = jax.random.PRNGKey(seed)
    params = init_lstm_layer(key, H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, H)) * 0.5
    return params, xs


@pytest.mark.parametrize("schedule", sch.SCHEDULES)
def test_layer_matches_reference(schedule):
    params, xs = _mk(2, 9, 48)
    out = sch.run_layer(params, xs, schedule)
    ref = reference_unroll(params, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 12), H=st.sampled_from([16, 40, 64]),
       schedule=st.sampled_from(sch.SCHEDULES))
def test_property_schedule_equivalence(B, T, H, schedule):
    params, xs = _mk(B, T, H, seed=H + T)
    out = sch.run_layer(params, xs, schedule)
    ref = sch.run_layer(params, xs, "intergate")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_batch_tile_sizes():
    params, xs = _mk(2, 5, 48)
    ref = reference_unroll(params, xs)
    for tc in (16, 48, 100, 4 * 48):
        out = sch.run_layer(params, xs, "batch", tile_cols=tc)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_unfolded_with_pallas_cell_kernel():
    """The fused Pallas cell drops into the unfolded scan unchanged."""
    params, xs = _mk(2, 6, 64)
    ref = reference_unroll(params, xs)
    out = sch.run_layer(params, xs, "unfolded",
                        cell_kernel=as_cell_kernel(interpret=True))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_bidirectional_stack():
    cfg = dataclasses.replace(reduced(), bidirectional=True)
    key = jax.random.PRNGKey(0)
    stack = init_lstm_stack(key, cfg, jnp.float32)
    xs = jax.random.normal(key, (2, 7, cfg.lstm_hidden))
    ref = sch.run_stack(stack, xs, "intergate")
    assert ref.shape == (2, 7, 2 * cfg.lstm_hidden)
    for s in sch.SCHEDULES:
        np.testing.assert_allclose(np.asarray(sch.run_stack(stack, xs, s)),
                                   np.asarray(ref), atol=1e-5)


def test_fused_layer_matches_reference():
    """The sequence-fused Pallas path (one launch) == ground truth."""
    params, xs = _mk(2, 9, 48)
    out = sch.run_layer(params, xs, "fused", interpret=True)
    ref = reference_unroll(params, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


@pytest.mark.parametrize("T,block_t", [(1, 0), (7, 3), (11, 4), (12, 16)])
def test_wavefront_matches_unfolded(T, block_t):
    """Stack-level equivalence: L+nk-1 anti-diagonal slots == serial L·T."""
    cfg = reduced()
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, T, cfg.lstm_input)) * 0.5
    ref = sch.run_stack(stack, xs, "unfolded")
    out = sch.run_stack(stack, xs, "wavefront", block_t=block_t,
                        interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_wavefront_slot_launch_count():
    """A wavefront stack issues exactly L + ceil(T/bt) - 1 fused launches —
    one G-batched kernel per anti-diagonal slot."""
    from repro.kernels.common import pallas_launch_count
    cfg = reduced()
    L, T, bt = cfg.n_layers, 12, 4
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, T, cfg.lstm_input)) * 0.5
    n = pallas_launch_count(
        lambda s, x: sch.run_stack(s, x, "wavefront", block_t=bt,
                                   interpret=True), stack, xs)
    assert n == sch.wavefront_slots(L, T, bt) == L + T // bt - 1


def test_wavefront_bidirectional_interleaves():
    """Bidirectional + wavefront no longer falls back (ISSUE-5): the shim
    lowers to the dispatcher's interleaved fwd/bwd timeline and must still
    match the per-step reference."""
    cfg = dataclasses.replace(reduced(), bidirectional=True)
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (2, 7, cfg.lstm_hidden)) * 0.5
    ref = sch.run_stack(stack, xs, "intergate")
    out = sch.run_stack(stack, xs, "wavefront", block_t=4, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4)


def test_unfolded_hoists_input_gemm():
    """Structural check: unfolded's jaxpr has exactly ONE big input GEMM
    outside the scan, while intergate multiplies W inside the loop."""
    params, xs = _mk(1, 8, 32)
    unf = jax.make_jaxpr(lambda p, x: sch.run_layer(p, x, "unfolded"))(params, xs)
    # the (B,T,X)@(X,4H) einsum appears before the scan: find a dot with a
    # T-sized operand outside any scan
    body_eqns = [e for e in unf.jaxpr.eqns if e.primitive.name == "scan"]
    assert len(body_eqns) == 1
    scan_eqn = body_eqns[0]
    inner = scan_eqn.params["jaxpr"].jaxpr
    outer_dots = [e for e in unf.jaxpr.eqns if e.primitive.name == "dot_general"]
    inner_dots = [e for e in inner.eqns if e.primitive.name == "dot_general"]
    assert len(outer_dots) >= 1  # hoisted W GEMM
    assert len(inner_dots) == 1  # only U·h remains serial
