"""GRU schedules (paper §8 generality claim) — equivalence + model hook."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.core import gru
from repro.core.perfmodel import Design

# intentionally exercises the DEPRECATED gru.run_layer shim (kept passing
# through repro.rnn.compile); tests/rnn/test_shims.py asserts the warning
pytestmark = pytest.mark.filterwarnings("ignore::DeprecationWarning")


def _mk(B, T, H, seed=0):
    params = gru.init_gru_layer(jax.random.PRNGKey(seed), H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, H)) * 0.5
    return params, xs


@pytest.mark.parametrize("schedule", gru.SCHEDULES)
def test_matches_reference(schedule):
    params, xs = _mk(2, 9, 40)
    out = gru.run_layer(params, xs, schedule)
    ref = gru.reference_unroll(params, xs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 3), T=st.integers(1, 10), H=st.sampled_from([8, 24, 48]),
       schedule=st.sampled_from(gru.SCHEDULES))
def test_property_equivalence(B, T, H, schedule):
    params, xs = _mk(B, T, H, seed=H + T)
    out = gru.run_layer(params, xs, schedule)
    ref = gru.run_layer(params, xs, "intergate")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)


def test_h_stays_bounded():
    """GRU h is a convex combination of tanh outputs: |h| <= 1."""
    params, xs = _mk(2, 30, 32)
    out = gru.run_layer(params, xs, "unfolded")
    assert float(jnp.max(jnp.abs(out))) <= 1.0 + 1e-5


def test_perfmodel_unfolded_still_wins_but_less_than_lstm():
    """The multiplicative reset gate keeps all three U MVMs serial, so the
    GRU Unfolded win exists but cannot exceed the LSTM's (paper §8)."""
    from repro.core.perfmodel import step_cycles

    H = 340
    for macs in (4096, 65536):
        d_seq = Design(macs=macs, k=32, schedule="sequential")
        d_unf = Design(macs=macs, k=32, schedule="unfolded")
        gru_gain = (gru.gru_step_cycles(H, H, d_seq)
                    / gru.gru_step_cycles(H, H, d_unf))
        lstm_gain = step_cycles(H, H, d_seq) / step_cycles(H, H, d_unf)
        assert gru_gain > 1.0
        assert gru_gain <= lstm_gain * 1.05, (macs, gru_gain, lstm_gain)
