"""Faithful-reproduction gate: the critical-path model must reproduce the
paper's claims (trend-level).  Each test names the paper artifact."""
import statistics

import pytest

from repro.configs.sharp_lstm import MAC_BUDGETS, SWEEP_HIDDEN_DIMS
from repro.core import perfmodel as pm


def test_fig11_unfolded_always_best():
    sp = pm.fig11_schedule_speedups()
    for m in MAC_BUDGETS:
        for h in SWEEP_HIDDEN_DIMS:
            assert sp[(m, h, "unfolded")] >= sp[(m, h, "intergate")] - 1e-9
            assert sp[(m, h, "intergate")] >= sp[(m, h, "sequential")] - 1e-9


def test_fig11_benefit_diminishes_with_dim_and_fewer_macs():
    """§8: 'the benefit diminishes by increasing the LSTM dimension or
    reducing the number of MACs'."""
    sp = pm.fig11_schedule_speedups()
    for m in MAC_BUDGETS:
        assert sp[(m, 256, "unfolded")] >= sp[(m, 2048, "unfolded")]
    for h in SWEEP_HIDDEN_DIMS:
        assert sp[(65536, h, "unfolded")] >= sp[(1024, h, "unfolded")]


def test_fig10_padding_claims():
    """Fig. 10: <=~1.22x, >=1 everywhere, exactly 1.0 at hidden=512."""
    pad = pm.fig10_padding_speedup()
    vals = list(pad.values())
    assert max(vals) <= 1.30
    assert max(vals) >= 1.10  # 'up to 1.22x' — material gain exists
    assert all(v >= 1.0 - 1e-9 for v in vals)
    for m in MAC_BUDGETS:
        assert pad[(m, 512)] == pytest.approx(1.0)


def test_fig9_no_single_best_k():
    """Fig. 9: 'there is not just one best configuration'."""
    for m in (4096, 16384, 65536):
        best = pm.fig9_best_k(m)
        assert len(set(best.values())) > 1, (m, best)


def test_fig12_utilization_trends():
    """Fig. 12: SHARP util decreases 1K->64K but stays >= 50%-ish; SHARP
    beats E-PUR everywhere; the E-PUR gap widens with MACs (1.3x-2x)."""
    f12 = pm.fig12_latency_utilization()
    avg = lambda m, k: statistics.mean(f12[(m, h)][k] for h in SWEEP_HIDDEN_DIMS)
    prev = 1.1
    for m in MAC_BUDGETS:
        u = avg(m, "utilization")
        assert u <= prev + 1e-9
        prev = u
        assert u >= 0.45
        assert u >= avg(m, "epur_utilization")
    assert (avg(65536, "utilization") / avg(65536, "epur_utilization")
            >= 1.3)


def test_fig12_latency_scales_with_macs():
    """§8: 'linearly reduces the execution time (AVG) by increasing MACs'."""
    f12 = pm.fig12_latency_utilization()
    avg = lambda m: statistics.mean(
        f12[(m, h)]["latency_us"] for h in SWEEP_HIDDEN_DIMS)
    lat = [avg(m) for m in MAC_BUDGETS]
    assert lat[0] > lat[1] > lat[2] > lat[3]
    assert lat[0] / lat[3] > 20  # near-linear over the 64x resource range


def test_table6_epur_trends():
    """Table 6: speedup in [1.0, ~3.3], growing with the MAC budget."""
    t6 = pm.table6_vs_epur()
    for name in ("EESEN", "GMAT", "BYSDNE", "RLDRADSPR"):
        row = [t6[(name, m)] for m in MAC_BUDGETS]
        assert all(r >= 0.99 for r in row)
        assert row[-1] > row[0]          # scales with resources
        assert 1.2 <= row[-1] <= 3.5     # paper: 1.66..2.3 at 64K


def test_table4_brainwave():
    """Table 4: >1.65x everywhere, larger for smaller dims; fitted model
    within 35% relative error of every paper entry."""
    t4 = pm.table4_vs_brainwave()
    paper = pm.TABLE4_PAPER
    dims = sorted({h for (h, _) in t4})
    vals = [t4[k] for k in sorted(t4)]
    assert all(v > 1.5 for v in vals)
    assert t4[(256, 150)] > t4[(1536, 50)]  # adaptability claim
    for k, v in t4.items():
        assert abs(v - paper[k]) / paper[k] < 0.35, (k, v, paper[k])


def test_energy_and_gflops_per_watt():
    """Fig. 14 energy reduction grows with MACs; §10: ~0.32 TFLOPS/W at the
    paper's 50% utilization point."""
    e = pm.fig14_energy()
    avg_red = {m: statistics.mean(e[(m, h)]["reduction"]
                                  for h in SWEEP_HIDDEN_DIMS)
               for m in MAC_BUDGETS}
    assert avg_red[65536] > avg_red[1024]
    assert avg_red[65536] > 0.15
    # at the paper's stated 50% avg utilization the arithmetic is fixed:
    gfw_at_half = pm.PEAK_TFLOPS[65536] * 0.5 / pm.POWER_W[65536] / 1e9
    assert abs(gfw_at_half - 321) / 321 < 0.05
    # our model's own avg utilization lands in the same regime
    assert 250 <= pm.gflops_per_watt() <= 550
