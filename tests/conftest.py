"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests see the real single
CPU device; multi-device tests spawn subprocesses with their own flags."""
import os
import sys

import jax
import pytest

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO_ROOT, "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "chaos: fault-injection suite (guarded ladder, quarantine, "
        "deadlines) — run via `make chaos` or `-m chaos`")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def subprocess_env(n_devices: int = 8):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    return env
