"""Smoke-run ``examples/trace_demo.py`` (the `make trace-demo` target CI
uploads artifacts from): it must execute end-to-end and leave behind a
valid chrome://tracing JSON, a metrics snapshot with launch quantiles,
and the predicted-vs-measured launch-cost table."""
import json
import os
import subprocess
import sys

from tests.conftest import REPO_ROOT, SRC


def test_trace_demo_writes_artifacts(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable,
         os.path.join(REPO_ROOT, "examples", "trace_demo.py"),
         "--out-dir", str(tmp_path)],
        cwd=REPO_ROOT, env=env, capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "observability:" in proc.stdout

    # the chrome trace: X spans for the whole pipeline, on the exec track
    trace = json.loads((tmp_path / "trace.json").read_text())
    assert trace["displayTimeUnit"] == "ms"
    names = {e["name"] for e in trace["traceEvents"] if e["ph"] == "X"}
    assert {"forward", "prefill", "decode_tick", "plan", "hoist",
            "slot_launch"} <= names

    # the metrics snapshot: per-signature quantiles + the aggregate ratio
    snap = json.loads((tmp_path / "metrics_snapshot.json").read_text())
    assert snap["spans"] > 0
    assert snap["metrics"]["histograms"]["decode_tick_us"]["count"] == 3
    assert snap["predicted_vs_measured"]["signatures"] >= 2
    assert snap["predicted_vs_measured"]["mean_cycles_per_us"] > 0

    # the persisted launch-cost table (the autotune-style artifact)
    costs = json.loads((tmp_path / "launch_costs.json").read_text())
    assert costs["signatures"]
    for sig, row in costs["signatures"].items():
        assert sig.startswith(("lstm|", "gru|"))
        assert row["med_us"] > 0 and row["cycles_per_us"] > 0
