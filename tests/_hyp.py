"""Hypothesis, or a deterministic stand-in when it isn't installed.

``pip install -r requirements-dev.txt`` gets the real thing; environments
without it (hermetic CI images, minimal containers) still collect AND run
every property test: ``given`` degrades to a fixed-seed sweep that always
includes the all-min / all-max corner examples plus pseudo-random draws up
to ``max_examples``.  Only the strategy subset this suite uses is
implemented (integers, sampled_from, booleans).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # deterministic fallback
    HAVE_HYPOTHESIS = False
    import functools
    import inspect
    import random

    class _Strategy:
        def __init__(self, draw, edges):
            self.draw = draw
            self.edges = tuple(edges)

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value),
                             (min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            xs = list(elements)
            return _Strategy(lambda r: r.choice(xs), (xs[0], xs[-1]))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)), (False, True))

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                names = sorted(strategies)
                examples = [
                    {k: strategies[k].edges[0] for k in names},
                    {k: strategies[k].edges[-1] for k in names},
                ]
                rng = random.Random(0x5114B9)  # fixed seed: reproducible
                while len(examples) < n:
                    examples.append(
                        {k: strategies[k].draw(rng) for k in names})
                for ex in examples[:n]:
                    fn(*args, **kwargs, **ex)

            # hide the strategy params from pytest's fixture resolution
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature()
            return wrapper
        return deco
