"""Checkpointer: roundtrip, async commit protocol, GC, elasticity hooks."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,), jnp.bfloat16)},
            "opt": {"count": jnp.array(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = _tree()
    ck.save(10, tree, blocking=True)
    assert ck.latest_step() == 10
    out = ck.restore(10, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_async_save_commits(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=False)
    ck.wait()
    assert ck.latest_step() == 1
    assert os.path.exists(tmp_path / "step_1" / ".complete")


def test_incomplete_checkpoint_ignored(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    # simulate a crash mid-write: step_2 exists without the commit marker
    os.makedirs(tmp_path / "step_2")
    assert ck.latest_step() == 1
    with pytest.raises(FileNotFoundError):
        ck.restore(2, _tree())


def test_gc_keeps_newest(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(), blocking=True)
    names = sorted(os.listdir(tmp_path))
    assert "step_3" in names and "step_4" in names
    assert "step_1" not in names and "step_2" not in names


def test_structure_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(1, _tree(), blocking=True)
    with pytest.raises(AssertionError):
        ck.restore(1, {"just": jnp.zeros(3)})


def test_restore_respects_dtype(tmp_path):
    ck = Checkpointer(str(tmp_path))
    tree = {"w": jnp.ones((4,), jnp.bfloat16)}
    ck.save(5, tree, blocking=True)
    out = ck.restore(5, tree)
    assert out["w"].dtype == jnp.bfloat16
