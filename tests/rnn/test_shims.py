"""The deprecated surfaces stay working, warn, and fail clearly; the
internal code paths never touch them (what CI's ``make deprecations`` run
— ``-W error::DeprecationWarning:repro\\.`` — enforces fleet-wide)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.configs.sharp_lstm import lstm_config, reduced
from repro.core import gru
from repro.core import schedules as sch
from repro.models.layers.lstm import init_lstm_layer, init_lstm_stack


def _stack():
    return init_lstm_stack(jax.random.PRNGKey(0), reduced(), jnp.float32)


def _xs(T=9):
    return jax.random.normal(jax.random.PRNGKey(1), (2, T, 48)) * 0.5


def test_run_stack_warns_and_matches_facade():
    stack, xs = _stack(), _xs()
    with pytest.warns(DeprecationWarning, match="repro.rnn.compile"):
        out = sch.run_stack(stack, xs, "unfolded")
    ref = rnn.compile(stack, rnn.ExecutionPolicy(
        schedule="unfolded")).forward(xs)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_run_layer_warns_and_matches_reference():
    params = init_lstm_layer(jax.random.PRNGKey(0), 48, 48, jnp.float32)
    xs = _xs()
    with pytest.warns(DeprecationWarning):
        out = sch.run_layer(params, xs, "intergate")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(sch.run_layer_intergate(params, xs)),
        atol=1e-6)


def test_gru_run_layer_warns_and_unknown_schedule_is_valueerror():
    """Regression (ISSUE-4 satellite): an unknown schedule used to escape
    as a bare KeyError from gru's function table; now it is a ValueError
    naming the field and the allowed values."""
    params = gru.init_gru_layer(jax.random.PRNGKey(0), 48, 48, jnp.float32)
    xs = _xs()
    with pytest.warns(DeprecationWarning):
        out = gru.run_layer(params, xs, "unfolded")
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(gru.run_layer_unfolded(params, xs)),
        atol=1e-6)
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError) as e:
            gru.run_layer(params, xs, "bogus")
    assert "ExecutionPolicy.schedule" in str(e.value)
    assert "KeyError" not in repr(e)
    # 'batch' exists for lstm but not gru: the error says so
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="no gru reference"):
            gru.run_layer(params, xs, "batch")


def test_run_stack_unknown_schedule_lists_wavefront():
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError) as e:
            sch.run_stack(_stack(), _xs(), "wavefrunt")
    assert "wavefront" in str(e.value)


def test_wavefront_shim_routes_through_dispatcher():
    """run_stack('wavefront') is the dispatcher's packed timeline now (the
    LSTM-only run_stack_wavefront is retired) with the launch geometry
    preserved: L + ceil(T/bt) - 1 slot launches."""
    from repro.kernels.common import pallas_launch_count

    assert not hasattr(sch, "run_stack_wavefront")
    stack, xs = _stack(), _xs(T=12)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        n = pallas_launch_count(
            lambda s, x: sch.run_stack(s, x, "wavefront", block_t=4,
                                       interpret=True), stack, xs)
        out = sch.run_stack(stack, xs, "wavefront", block_t=4,
                            interpret=True)
    assert n == sch.wavefront_slots(2, 12, 4) == 4
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(sch.reference_stack(stack, xs)),
                               atol=1e-4)


def test_impl_only_kwargs_pin_to_reference_implementation():
    """cell_kernel/tile_cols/... are implementation escape hatches the
    policy surface does not carry; the shim runs them directly."""
    params = init_lstm_layer(jax.random.PRNGKey(0), 48, 48, jnp.float32)
    xs = _xs()
    with pytest.warns(DeprecationWarning):
        out = sch.run_layer(params, xs, "batch", tile_cols=16)
    np.testing.assert_allclose(
        np.asarray(out),
        np.asarray(sch.run_layer_batch(params, xs, tile_cols=16)), atol=1e-6)


def test_impl_only_kwargs_dispatch_per_family():
    """Review fix: the escape-hatch path walks each layer through its OWN
    family's implementation table — a GRU stack pinned to an LSTM-only
    schedule must fail with a clear per-family error (it used to be fed to
    the LSTM fns and die in a U.reshape(H, 4, H)), and an unsupported
    schedule gets a non-contradictory message (the old one listed
    'wavefront' as both unknown and allowed)."""
    gstack = gru.init_gru_stack(jax.random.PRNGKey(0), 48, 48, 2,
                                jnp.float32)
    xs = _xs()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="no per-layer gru"):
            sch.run_stack(gstack, xs, "batch", tile_cols=16)
    # "wavefront" has no per-layer implementation anywhere: the error says
    # why and does not list wavefront among the options
    lstack = _stack()
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="no per-layer") as e:
            sch.run_stack(lstack, xs, "wavefront", tile_cols=16)
    assert "wavefront" not in str(e.value).split("options")[1]


def test_internal_paths_emit_no_deprecation_warnings():
    """The acceptance claim behind CI's deprecations gate: facade forward/
    prefill/decode and the serving engine never touch the deprecated
    surface."""
    from repro.serving import RecurrentRequest, RecurrentServingEngine

    stack = _stack()
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
        ys, st = cs.prefill(_xs(T=6))
        cs.decode(ys[:, -1], st)
        eng = RecurrentServingEngine(reduced(), stack, max_batch=2,
                                     interpret=True)
        eng.submit(RecurrentRequest(
            uid=0, frames=np.asarray(_xs(T=5)[0]), max_new_frames=2))
        eng.run_to_completion()
