"""ISSUE-7 integration: tracing threaded through compile -> forward ->
prefill -> decode and the serving engine.  The claims: a traced run emits
the expected nested span tree + per-signature launch metrics + the
predicted-vs-measured table; tracing OFF leaves outputs bit-identical
(and binds the shared no-op tracer); the fault trail is a ring buffer."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.configs.sharp_lstm import lstm_config
from repro.models.layers.lstm import init_lstm_stack
from repro.rnn.compiled import StackStats
from repro.runtime.obs import NULL_TRACER
from repro.serving import RecurrentRequest, RecurrentServingEngine

H, T, L = 48, 8, 2
CFG = lstm_config(H, layers=L)


def _stack(seed=0):
    return init_lstm_stack(jax.random.PRNGKey(seed), CFG, jnp.float32)


def _xs(seed=1, B=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, T, H)) * 0.5


def _traced_session(cs):
    """forward + prefill + 3 feedback decode ticks (the demo's shape)."""
    xs = _xs()
    cs.forward(xs)
    ys, state = cs.prefill(xs)
    y_t = ys[:, -1:]
    for _ in range(3):
        y_t, state = cs.decode(y_t, state)
    return y_t


def test_traced_run_emits_expected_span_tree(tmp_path):
    cs = rnn.compile(_stack(), rnn.ExecutionPolicy(interpret=True,
                                                   trace=True))
    _traced_session(cs)
    tr = cs.tracer
    assert tr.enabled and tr is not NULL_TRACER

    names = {s.name for s in tr.events}
    assert {"forward", "prefill", "decode_tick", "plan", "hoist",
            "slot_launch", "plan_candidates"} <= names
    # nesting: the API-level spans are roots, the per-slot work nests
    for s in tr.events:
        if s.name in ("forward", "prefill", "decode_tick"):
            assert s.depth == 0
        if s.name in ("plan", "hoist", "slot_launch"):
            assert s.depth >= 1
    # every launch span carries its slot signature and a real duration
    launches = [s for s in tr.events if s.name == "slot_launch"]
    assert launches
    for s in launches:
        assert s.tags["sig"].startswith("lstm|H48|")
        assert s.dur_us > 0.0
    # the 3 chained decode launches share one signature
    assert sum("|chained" in s.tags["sig"] for s in launches) == 3

    # metrics: decode tick histogram saw the 3 ticks; launch quantiles +
    # predicted-vs-measured ratio are populated per signature
    snap = tr.snapshot()
    assert snap["metrics"]["histograms"]["decode_tick_us"]["count"] == 3
    assert snap["launch_costs"]
    for sig, row in snap["launch_costs"].items():
        assert row["med_us"] > 0 and row["est_cycles"] > 0
        assert row["cycles_per_us"] > 0
    pvm = snap["predicted_vs_measured"]
    assert pvm["signatures"] == len(snap["launch_costs"])
    assert pvm["mean_cycles_per_us"] > 0

    # chrome export round-trips as valid trace-event JSON
    path = tr.export_chrome_trace(str(tmp_path / "trace.json"))
    data = json.loads(open(path).read())
    X = [e for e in data["traceEvents"] if e["ph"] == "X"]
    assert {"forward", "decode_tick", "slot_launch"} <= {e["name"]
                                                         for e in X}
    # describe() surfaces the observability section through the facade
    assert "observability:" in cs.describe()
    assert "launch costs" in cs.describe()


def test_trace_off_is_bit_identical_and_binds_null_tracer():
    stack, xs = _stack(), _xs()
    off = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    on = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True, trace=True))
    assert off.tracer is NULL_TRACER  # one shared inert instance

    np.testing.assert_array_equal(np.asarray(off.forward(xs)),
                                  np.asarray(on.forward(xs)))
    _, st_off = off.prefill(xs)
    _, st_on = on.prefill(xs)
    for k in st_off:
        np.testing.assert_array_equal(np.asarray(st_off[k]),
                                      np.asarray(st_on[k]))
    y_off, _ = off.decode(xs[:, -1:], st_off)
    y_on, _ = on.decode(xs[:, -1:], st_on)
    np.testing.assert_array_equal(np.asarray(y_off), np.asarray(y_on))
    assert off.tracer.events == ()  # nothing recorded on the no-op path


def test_planner_candidate_scores_in_trace():
    cs = rnn.compile(_stack(), rnn.ExecutionPolicy(interpret=True,
                                                   trace=True))
    cs.forward(_xs())
    (cand,) = [s for s in cs.tracer.events if s.name == "plan_candidates"]
    assert "chosen" in cand.tags
    # the rejected alternatives ride along, scored
    assert len(cand.tags["candidates"]) >= 1
    for c in cand.tags["candidates"]:
        assert c["est_cycles"] > 0 and c["schedule"]
    # the chosen candidate is the argmin of the scores
    best = min(cand.tags["candidates"], key=lambda c: c["est_cycles"])
    assert cand.tags["chosen"] == f"{best['schedule']}@bt{best['block_t']}"


@pytest.mark.chaos
def test_fallback_rungs_and_faults_in_trace():
    pol = rnn.ExecutionPolicy(interpret=True, on_fault="fallback",
                              trace=True)
    cs = rnn.compile(_stack(), pol)
    base = np.asarray(rnn.compile(_stack(),
                                  rnn.ExecutionPolicy(interpret=True))
                      .forward(_xs()))
    cs.fault.arm(range(8), through_level=0, once=False)
    np.testing.assert_allclose(np.asarray(cs.forward(_xs())), base,
                               atol=1e-5)

    tr = cs.tracer
    rungs = [s for s in tr.events if s.name == "fallback_rung"]
    faults = [s for s in tr.events if s.name == "launch_fault"]
    assert rungs and faults
    assert {s.tags["rung"] for s in rungs} == {"per_step"}
    assert all(s.tags["rung"] == "fused" for s in faults)
    n_slots = len(cs.plan.slots)
    assert tr.metrics.counter("launch_faults").value == n_slots
    assert tr.metrics.counter("degraded_launches").value == n_slots


@pytest.mark.chaos
def test_fault_trail_is_a_ring_buffer(monkeypatch):
    monkeypatch.setattr(StackStats, "MAX_FAULT_TRAIL", 3)
    pol = rnn.ExecutionPolicy(interpret=True, on_fault="fallback")
    cs = rnn.compile(_stack(), pol)
    cs.fault.arm(range(64), through_level=0, once=False)  # every launch
    xs = _xs()
    for _ in range(4):
        cs.forward(xs)  # n_slots fault entries per call, forever
    n_slots = len(cs.plan.slots)
    assert cs.stats.faults_total == 4 * n_slots  # true count survives
    assert len(cs.stats.faults) == 3             # memory stays bounded
    # the trail keeps the MOST RECENT entries
    assert cs.stats.faults == ["degraded slot %d: fused->per_step" % i
                               for i in range(n_slots)][-3:] \
        or len(set(cs.stats.faults)) <= 3
    assert f"{cs.stats.faults_total} faults" in cs.describe()


def test_traced_serving_engine_records_request_lifetimes():
    params = _stack()
    eng = RecurrentServingEngine(CFG, params, max_batch=2, interpret=True,
                                 trace=True)
    rng = np.random.default_rng(0)
    for uid in range(3):  # 3 requests through 2 slots: two admission waves
        eng.submit(RecurrentRequest(
            uid=uid, frames=rng.standard_normal((6, H)).astype(np.float32),
            max_new_frames=2))
    done = eng.run_to_completion()
    assert len(done) == 3

    tr = eng.tracer
    assert tr is eng.compiled.tracer and tr.enabled
    admits = [s for s in tr.events if s.name == "admit"]
    assert len(admits) == eng.prefill_waves >= 2
    reqs = [s for s in tr.events if s.name == "request"]
    assert {s.tags["uid"] for s in reqs} == {0, 1, 2}
    for s in reqs:
        assert s.track == "requests"
        assert s.tags["status"] == "ok"
        assert s.tags["ticks"] >= 1 and s.dur_us > 0
    assert tr.metrics.counter("requests_ok").value == 3
    # serving gauges observed every tick
    snap = tr.snapshot()["metrics"]["histograms"]
    assert snap["slot_occupancy"]["count"] == eng.decode_ticks
    assert snap["queue_depth"]["count"] == eng.decode_ticks
