"""ExecutionPolicy validation: every bad field fails at construction with
an error naming the field and the allowed values (ISSUE-4 satellite — the
old surface let an unknown schedule string die as a bare KeyError deep in
core.gru.run_layer)."""
import dataclasses

import pytest

from repro.rnn import DTYPES, SCHEDULES, ExecutionPolicy


def test_defaults_are_valid():
    pol = ExecutionPolicy()
    assert pol.schedule == "auto" and pol.packing and pol.block_t == 0
    assert "auto" in pol.describe()


def test_unknown_schedule_names_field_and_values():
    with pytest.raises(ValueError) as e:
        ExecutionPolicy(schedule="bogus")
    msg = str(e.value)
    assert "ExecutionPolicy.schedule" in msg and "'bogus'" in msg
    for s in SCHEDULES:
        assert s in msg  # the full allowed list is spelled out


@pytest.mark.parametrize("field,value", [
    ("block_t", -1), ("block_t", "4"), ("block_t", True),
    ("interpret", "yes"), ("interpret", 1),
    ("dtype", "float64"), ("dtype", 32),
    ("packing", "on"),
    ("macs", 0), ("macs", -5), ("macs", 2.5), ("macs", False),
    ("on_fault", "retry"), ("on_fault", True),
    ("check_finite", "yes"), ("check_finite", 1),
    ("verify", "bogus"), ("verify", True), ("verify", None),
    ("precision", "int4"), ("precision", 8), ("precision", None),
    ("sparsity", "row"), ("sparsity", True), ("sparsity", None),
])
def test_bad_fields_name_themselves(field, value):
    with pytest.raises(ValueError, match=f"ExecutionPolicy.{field}"):
        ExecutionPolicy(**{field: value})


def test_valid_corners_accepted():
    for s in SCHEDULES:
        ExecutionPolicy(schedule=s)
    for d in DTYPES:
        ExecutionPolicy(dtype=d)
    ExecutionPolicy(block_t=16, interpret=False, packing=False, macs=1024)


def test_fault_knobs_default_fail_fast():
    """ISSUE-6: the library default stays fail-fast ("raise", no finite
    checks); the knobs validate like every other field and show up in
    describe()."""
    from repro.rnn import ON_FAULT

    pol = ExecutionPolicy()
    assert pol.on_fault == "raise" and pol.check_finite is False
    assert "on_fault=raise" in pol.describe()
    for mode in ON_FAULT:
        assert ExecutionPolicy(on_fault=mode).on_fault == mode
    assert "check_finite=True" in \
        ExecutionPolicy(check_finite=True).describe()


def test_verify_defaults_on_and_validates():
    """ISSUE-8: static plan verification is on by default ("plan"); the
    knob validates like every other field and shows up in describe()."""
    from repro.rnn import VERIFY

    pol = ExecutionPolicy()
    assert pol.verify == "plan"
    assert "verify=plan" in pol.describe()
    for mode in VERIFY:
        assert ExecutionPolicy(verify=mode).verify == mode


def test_precision_sparsity_default_exact_and_validate():
    """ISSUE-10: the default stays the bit-exact dense path; the knobs
    validate with the full allowed list spelled out and ride in
    describe()."""
    from repro.dispatch.workitem import PRECISIONS, SPARSITIES

    pol = ExecutionPolicy()
    assert pol.precision == "fp32" and pol.sparsity == "none"
    for p in PRECISIONS:
        assert ExecutionPolicy(precision=p).precision == p
    for s in SPARSITIES:
        assert ExecutionPolicy(sparsity=s).sparsity == s
    assert "precision=int8" in ExecutionPolicy(precision="int8").describe()
    assert "sparsity=block" in ExecutionPolicy(sparsity="block").describe()
    with pytest.raises(ValueError) as e:
        ExecutionPolicy(precision="fp16")
    msg = str(e.value)
    assert "ExecutionPolicy.precision" in msg and "'fp16'" in msg
    for p in PRECISIONS:
        assert p in msg
    with pytest.raises(ValueError) as e:
        ExecutionPolicy(sparsity="2:4")
    msg = str(e.value)
    assert "ExecutionPolicy.sparsity" in msg
    for s in SPARSITIES:
        assert s in msg


def test_policy_is_frozen_and_hashable():
    pol = ExecutionPolicy()
    with pytest.raises(dataclasses.FrozenInstanceError):
        pol.schedule = "fused"
    assert hash(pol) == hash(ExecutionPolicy())


def test_compile_rejects_schedule_strings():
    """The old positional schedule-string habit gets a pointed TypeError,
    not a confusing attribute crash later."""
    from repro import rnn

    with pytest.raises(TypeError, match="ExecutionPolicy"):
        rnn.compile({"layers": [None]}, "unfolded")
