"""repro.rnn.compile: one planned execution path (ISSUE-4 tentpole).

Covers the acceptance criteria: a mixed-family (lstm/gru) stack through
``compile().forward()`` is oracle-equal to the per-layer sequential
reference AND its plan wavefronts across families (fewer launches than the
per-layer-cell count); prefill/decode resume exactly; plans are cached;
``import repro`` exposes the facade."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.configs.sharp_lstm import lstm_config, reduced
from repro.core import gru
from repro.core import schedules as sch
from repro.kernels.common import pallas_launch_count
from repro.models.layers.lstm import init_lstm_layer, init_lstm_stack

H = 48
POL = rnn.ExecutionPolicy(interpret=True)


def _mixed_stack(seed=3):
    """lstm -> gru -> lstm, one hidden width (the heterogeneous case the
    old run_stack could not wavefront)."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    return {"layers": [init_lstm_layer(k1, H, H, jnp.float32),
                       gru.init_gru_layer(k2, H, H, jnp.float32),
                       init_lstm_layer(k3, H, H, jnp.float32)]}


def _xs(B=2, T=12, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, T, H)) * 0.5


# ---------------------------------------------------------------------------
# heterogeneous stacks (ISSUE-4 satellite + acceptance criterion)
# ---------------------------------------------------------------------------


def test_mixed_stack_matches_sequential_reference():
    stack = _mixed_stack()
    xs = _xs()
    cs = rnn.compile(stack, POL)
    assert cs.families == ("lstm", "gru", "lstm") and cs.heterogeneous
    ys = cs.forward(xs)
    ref = sch.reference_stack(stack, xs, "unfolded")
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), atol=1e-4)
    # the research schedules agree too (same stack, per-layer library)
    np.testing.assert_allclose(
        np.asarray(sch.reference_stack(stack, xs, "sequential")),
        np.asarray(ref), atol=1e-4)


def test_mixed_stack_wavefronts_across_families():
    """The plan is a genuine cross-family wavefront: same-family cells of
    one wave merge into G-batched launches, so the launch count is
    strictly below the per-layer-cell count L·nk (what per-(layer, chunk)
    dispatch would issue), and both families appear in the slot timeline."""
    stack = _mixed_stack()
    xs = _xs(T=12)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(
        schedule="wavefront", block_t=4, interpret=True))
    p = cs.lower(2, 12)
    ip = p.item(0)
    assert ip.schedule == "wavefront" and ip.nk == 3
    assert {s.family for s in p.slots} == {"lstm", "gru"}
    assert any(s.g > 1 for s in p.slots)  # lstm layers 0+2 share a wave
    assert p.launches < ip.item.L * ip.nk == 9
    # wavefront invariant holds per cell
    for s in p.slots:
        for c in s.cells:
            assert c.layer + c.chunk == s.wave
    # structural proof: the jaxpr launches exactly plan.launches kernels
    n = pallas_launch_count(lambda pr, x: rnn.CompiledStack(
        pr, cs.policy).forward(x), stack, xs)
    assert n == p.launches
    ys = cs.forward(xs)
    ref = sch.reference_stack(stack, xs, "unfolded")
    np.testing.assert_allclose(np.asarray(ys), np.asarray(ref), atol=1e-4)


def test_mixed_stack_prefill_decode_resume_exactly():
    """prefill's (h, c) state resumes a mixed stack's decode bit-exactly
    against running the extended sequence in one shot (gru rows of "c" are
    zeros by contract)."""
    stack = _mixed_stack()
    xs = _xs(T=9)
    cs = rnn.compile(stack, POL)
    ys, st = cs.prefill(xs)
    assert st["h"].shape == (3, 2, H) and st["c"].shape == (3, 2, H)
    assert float(jnp.max(jnp.abs(st["c"][1]))) == 0.0  # gru layer: no c
    y1, st1 = cs.decode(ys[:, -1], st)
    full = sch.reference_stack(
        stack, jnp.concatenate([xs, ys[:, -1:]], axis=1), "unfolded")
    np.testing.assert_allclose(np.asarray(y1[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)
    # mixed decode is the documented per-layer fallback: L launches
    assert cs.last_decode_plan.launches == 3


# ---------------------------------------------------------------------------
# homogeneous paths: parity with the dispatcher + chained decode
# ---------------------------------------------------------------------------


def test_facade_adds_zero_launches_vs_direct_dispatch():
    """compile().forward() is the SAME plan/execute pipeline as direct
    dispatch.plan/execute — zero facade overhead (the BENCH_dispatch
    ``facade`` row asserts this too)."""
    from repro.dispatch import WorkItem, execute, plan

    cfg = lstm_config(64, layers=3)
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (1, 24, 64)) * 0.5
    direct_plan = plan([WorkItem.from_config(cfg, T=24, uid=0)])
    n_direct = pallas_launch_count(
        lambda pr, x: execute(direct_plan, {0: pr}, {0: x}, interpret=True),
        stack, xs)
    cs = rnn.compile(stack, POL)
    n_facade = pallas_launch_count(
        lambda pr, x: rnn.CompiledStack(pr, POL).forward(x), stack, xs)
    assert n_facade == n_direct == cs.lower(1, 24).launches
    np.testing.assert_array_equal(
        np.asarray(cs.forward(xs)),
        np.asarray(execute(direct_plan, {0: stack}, {0: xs},
                           interpret=True)[0]))


@pytest.mark.parametrize("family", ["lstm", "gru"])
def test_homogeneous_decode_is_one_chained_launch(family):
    if family == "lstm":
        stack = init_lstm_stack(jax.random.PRNGKey(0),
                                lstm_config(H, layers=3), jnp.float32)
    else:
        stack = gru.init_gru_stack(jax.random.PRNGKey(0), H, H, 3,
                                   jnp.float32)
    cs = rnn.compile(stack, POL)
    xs = _xs(T=7)
    ys, st = cs.prefill(xs)
    y1, st1 = cs.decode(ys[:, -1], st)
    assert cs.last_decode_plan.launches == 1  # one chained slot per tick
    full = sch.reference_stack(
        stack, jnp.concatenate([xs, ys[:, -1:]], axis=1), "unfolded")
    np.testing.assert_allclose(np.asarray(y1[:, 0]),
                               np.asarray(full[:, -1]), atol=1e-4)
    # ... and a second tick reuses the cached decode plan
    before = cs.stats.decode_plans_built
    cs.decode(y1[:, 0], st1)
    assert cs.stats.decode_plans_built == before


def test_multi_request_prefill_packs_one_plan():
    """A list of ragged prompts = the serving admission wave: one plan,
    cross-B-packed, each request's output and state exact vs solo."""
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, POL)
    seqs = [_xs(B=1, T=t, seed=10 + t) for t in (12, 12, 8)]
    res = cs.prefill(seqs)
    assert len(res) == 3
    assert cs.plan.launches < cs.plan.naive_launches  # genuinely packed
    for xs_i, (ys_i, st_i) in zip(seqs, res):
        solo_y, solo_st = rnn.compile(stack, POL).prefill(xs_i)
        np.testing.assert_array_equal(np.asarray(ys_i), np.asarray(solo_y))
        np.testing.assert_array_equal(np.asarray(st_i["h"]),
                                      np.asarray(solo_st["h"]))


def test_plan_cache_and_stats_accounting():
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, POL)
    xs = _xs(T=10)
    cs.forward(xs)
    p1 = cs.plan
    cs.forward(xs)             # same shape: cache hit
    assert cs.plan is p1
    assert cs.stats.plans_built == 1 and cs.stats.forward_calls == 2
    assert cs.stats.launches == 2 * p1.launches
    assert cs.stats.est_cycles > 0
    cs.prefill(xs)             # same shape through prefill: SAME cache key
    assert cs.plan is p1 and cs.stats.plans_built == 1
    cs.forward(_xs(T=5))       # new shape: one more plan
    assert cs.stats.plans_built == 2
    assert "CompiledStack" in cs.describe()


def test_block_t_honored_under_auto_schedule():
    """Regression: ExecutionPolicy.block_t used to be dropped whenever
    schedule stayed "auto" — the documented stripe override must pin the
    wavefront stripe there too."""
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=3), jnp.float32)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(block_t=4, interpret=True))
    p = cs.lower(2, 12)
    ip = p.item(0)
    assert ip.block_t == 4 and ip.nk == 3 and ip.schedule == "wavefront"
    xs = _xs(T=12)
    np.testing.assert_allclose(
        np.asarray(cs.forward(xs)),
        np.asarray(sch.reference_stack(stack, xs)), atol=1e-4)


def test_mixed_dtype_prefill_keeps_per_request_signatures():
    """Regression: a mixed-precision admission wave used to stamp every
    item with the first request's dtype — items must carry their own, so
    f32 and bf16 cells never share a launch signature."""
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, POL)
    seqs = [_xs(B=1, T=8), _xs(B=1, T=8).astype(jnp.bfloat16)]
    res = cs.prefill(seqs)
    dts = [ip.item.dtype for ip in cs.plan.items]
    assert dts == ["float32", "bfloat16"]
    for s in cs.plan.slots:  # no cross-dtype merges
        assert len({dts[c.uid] for c in s.cells}) == 1
        assert s.dtype == dts[s.cells[0].uid]
    # each request still exact vs its solo run
    for xs_i, (ys_i, _) in zip(seqs, res):
        solo_y, _ = rnn.compile(stack, POL).prefill(xs_i)
        np.testing.assert_array_equal(np.asarray(ys_i), np.asarray(solo_y))


def test_prefill_rejects_stateless_schedules():
    """Review fix: prefill under a forced reference/per_step schedule used
    to silently execute the per-layer fused path (different schedule AND
    launch accounting than the plan reports) — it must refuse instead."""
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(schedule="unfolded"))
    with pytest.raises(ValueError, match="no .* state surface"):
        cs.prefill(_xs(T=5))
    # forward still runs the requested reference schedule
    assert cs.forward(_xs(T=5)).shape == (2, 5, H)


def test_plan_cache_is_bounded_lru():
    """Review fix: ragged admission waves must not grow the plan cache
    without bound (long-running serving)."""
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=1), jnp.float32)
    cs = rnn.compile(stack, POL)
    cs.MAX_CACHED_PLANS = 4
    for t in range(3, 10):
        cs.lower(1, t)
    assert len(cs._plans) == 4
    assert cs.lower(1, 9) is cs._plans[next(reversed(cs._plans))]  # hit


def test_forced_reference_schedules_run_and_match():
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    xs = _xs(T=9)
    ref = sch.reference_stack(stack, xs, "intergate")
    for s in ("sequential", "batch", "intergate", "unfolded"):
        cs = rnn.compile(stack, rnn.ExecutionPolicy(schedule=s))
        np.testing.assert_allclose(np.asarray(cs.forward(xs)),
                                   np.asarray(ref), atol=1e-5)
        assert cs.plan.item(0).schedule == s
        assert cs.plan.launches == 0  # pure-jnp reference: no kernels


def test_compile_from_config_and_families():
    cfg = reduced()
    cs = rnn.compile(cfg, POL)
    assert cs.families == ("lstm",) * cfg.n_layers
    ys = cs.forward(_xs(T=6))
    assert ys.shape == (2, 6, H)
    cg = rnn.compile(cfg, POL, rnn_family="gru")
    assert cg.families == ("gru",) * cfg.n_layers
    assert cg.forward(_xs(T=6)).shape == (2, 6, H)


def test_2d_input_auto_batches():
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, POL)
    xs = _xs(B=1, T=7)
    np.testing.assert_array_equal(np.asarray(cs.forward(xs[0])),
                                  np.asarray(cs.forward(xs)[0]))


# ---------------------------------------------------------------------------
# bidirectional stacks: interleaved wavefront through the facade (ISSUE-5)
# ---------------------------------------------------------------------------


def _bi_cfg(L=3, hidden=H):
    return dataclasses.replace(lstm_config(hidden, layers=L),
                               bidirectional=True, dtype="float32")


def test_bidirectional_forward_bit_identical_and_launch_proof():
    """The acceptance criterion end to end: compile().forward() on a
    bidirectional stack is BIT-identical to reference_stack and plans
    strictly fewer launches than 2·L·⌈T/bt⌉ — structurally proven on the
    compiled facade, not just the planner."""
    cfg, T, bt, L = _bi_cfg(L=3), 12, 4, 3
    cs = rnn.compile(cfg, rnn.ExecutionPolicy(
        schedule="wavefront", block_t=bt, interpret=True))
    xs = _xs(B=2, T=T)
    ys = cs.forward(xs)
    assert ys.shape == (2, T, 2 * H)
    ref = sch.reference_stack(cs.params, xs, "fused")
    np.testing.assert_array_equal(np.asarray(ys), np.asarray(ref))
    np.testing.assert_allclose(
        np.asarray(ys), np.asarray(sch.reference_stack(cs.params, xs)),
        atol=1e-4)
    p = cs.plan
    nk = p.item(0).nk
    assert p.launches < 2 * L * nk == 2 * L * (T // bt)
    n = pallas_launch_count(
        lambda pr, x: rnn.CompiledStack(pr, cs.policy).forward(x),
        cs.params, xs)
    assert n == p.launches
    # every slot's fwd/bwd pair merged: one G=2 launch per wave here
    assert all(s.g == 2 for s in p.slots) and len(p.slots) == L * nk


def test_bidirectional_prefill_returns_per_direction_state():
    cfg = _bi_cfg(L=2)
    cs = rnn.compile(cfg, POL)
    xs = _xs(B=1, T=9)
    ys, st = cs.prefill(xs)
    assert set(st) == {"fwd", "bwd"}
    assert st["fwd"]["h"].shape == (2, 1, H)
    assert st["bwd"]["c"].shape == (2, 1, H)
    np.testing.assert_array_equal(np.asarray(ys),
                                  np.asarray(cs.forward(xs)))


def test_bidirectional_decode_raises_with_pointer():
    cfg = _bi_cfg(L=2)
    cs = rnn.compile(cfg, POL)
    with pytest.raises(ValueError, match=r"forward\(\)/prefill\(\)"):
        cs.decode(jnp.zeros((1, 1, H)), {"h": jnp.zeros((2, 1, H))})


def test_plan_cache_keys_carry_direction_info():
    """ISSUE-5: cache keys distinguish uni and bidirectional timelines
    explicitly (not just by stack identity)."""
    uni = rnn.compile(init_lstm_stack(jax.random.PRNGKey(0),
                                      lstm_config(H, layers=2), jnp.float32),
                      POL)
    bi = rnn.compile(_bi_cfg(L=2), POL)
    uni.lower(1, 8)
    bi.lower(1, 8)
    (uk,), (bk,) = uni._plans.keys(), bi._plans.keys()
    assert uk != bk
    assert "uni" in uk and "bi" in bk


# ---------------------------------------------------------------------------
# clear errors + the repro package facade (ISSUE-4 satellites)
# ---------------------------------------------------------------------------


def test_clear_errors():
    stack = init_lstm_stack(jax.random.PRNGKey(0),
                            lstm_config(H, layers=2), jnp.float32)
    cs = rnn.compile(stack, POL)
    with pytest.raises(ValueError, match=r"\(B, T, 48\)"):
        cs.forward(jnp.zeros((2, 5, 7)))
    with pytest.raises(ValueError, match="T=0"):
        cs.forward(jnp.zeros((2, 0, H)))
    with pytest.raises(TypeError, match="ModelConfig"):
        rnn.compile([1, 2, 3])
    with pytest.raises(ValueError, match="recurrent"):
        from repro.configs import get_config

        rnn.compile(get_config("starcoder2-3b"))
    bi = dataclasses.replace(reduced(), bidirectional=True)
    cbi = rnn.compile(bi, POL)
    with pytest.raises(ValueError, match="decode"):
        cbi.decode(jnp.zeros((1, 1, H)), {"h": jnp.zeros((2, 1, H))})


def test_repro_package_exposes_rnn_lazily():
    import repro

    assert repro.rnn.compile is rnn.compile            # lazy attr access
    assert "rnn" in dir(repro) and "dispatch" in dir(repro)
    from repro import rnn as rnn2                      # submodule import

    assert rnn2 is rnn
    with pytest.raises(AttributeError, match="no attribute"):
        repro.not_a_module
