"""The precision/sparsity axis through the ONE planned path (ISSUE-10
tentpole): ``ExecutionPolicy(precision=..., sparsity=...)`` must run
forward/prefill/decode through the same plan/execute pipeline and stay
within the DOCUMENTED error contract against the dequantized oracle
``reference_stack(fake_quant_stack(params, precision), xs)``:

* fp32 — bit-exact default (covered across the suite);
* bf16 — the kernel consumes the round-tripped f32 weights, so it is
  BIT-identical to the fp32 pipeline run on the fake-quant param view;
* int8 — the kernel accumulates ``(h @ Uq) * s`` where the oracle computes
  ``h @ (Uq * s)``; the only error is that distributivity gap, bounded
  here (and in the READMEs) by rel-err <= 1e-6 * depth — a ceiling with
  ~10x margin over the measured ~2e-7 at L=3;
* sparsity="block" — value-exact up to dot reduction order (skipped tiles
  contribute exactly 0.0), gated at atol=1e-6 against the dense pipeline.

The matrix covers lstm/gru x uni/bidir x ragged multi-request B.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import rnn
from repro.configs.sharp_lstm import lstm_config
from repro.core import gru
from repro.core import schedules as sch
from repro.core.perfmodel import MXU_ROWS
from repro.kernels.quant import fake_quant_stack, stack_tile_maps
from repro.models.layers.lstm import init_lstm_stack

H = 48
POL = rnn.ExecutionPolicy(interpret=True)


#: kernel-vs-pure-jnp reduction-order headroom — the fp32 path shows the
#: same order of gap (~2e-7) against its own oracle
KERNEL_GAP = 1e-6


def INT8_REL_BOUND(L):
    """The documented int8 error contract: per-step distributivity gap
    compounds at most linearly through the stack depth."""
    return 1e-6 * L


def _stack(family, L=3, bidir=False, seed=0):
    if family == "gru":
        assert not bidir  # no bidirectional GRU stacks in the repo
        return gru.init_gru_stack(jax.random.PRNGKey(seed), H, H, L,
                                  jnp.float32)
    cfg = lstm_config(H, layers=L)
    if bidir:
        cfg = dataclasses.replace(cfg, bidirectional=True)
    return init_lstm_stack(jax.random.PRNGKey(seed), cfg, jnp.float32)


def _xs(B=2, T=10, seed=1):
    return jax.random.normal(jax.random.PRNGKey(seed), (B, T, H)) * 0.5


def _rel_err(got, want):
    return float(jnp.max(jnp.abs(got - want)) / jnp.max(jnp.abs(want)))


# ---------------------------------------------------------------------------
# forward: the full family x direction matrix against the dequantized oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family,bidir", [("lstm", False), ("lstm", True),
                                          ("gru", False)])
@pytest.mark.parametrize("precision", ["bf16", "int8"])
def test_forward_within_oracle_bound(family, bidir, precision):
    for L in (1, 3):
        stack = _stack(family, L=L, bidir=bidir)
        xs = _xs()
        cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                    precision=precision))
        oracle = sch.reference_stack(fake_quant_stack(stack, precision),
                                     _xs())
        rel = _rel_err(cs.forward(xs), oracle)
        # KERNEL_GAP covers the kernel-vs-jnp reduction-order noise the
        # fp32 path shows against ITS oracle too (~2e-7 here); int8 adds
        # its per-depth distributivity term on top
        bound = KERNEL_GAP + (INT8_REL_BOUND(L)
                              if precision == "int8" else 0.0)
        assert rel <= bound, (family, bidir, precision, L, rel, bound)


@pytest.mark.parametrize("family,bidir", [("lstm", False), ("lstm", True),
                                          ("gru", False)])
def test_bf16_is_bit_identical_to_fp32_on_fake_quant_view(family, bidir):
    """bf16 adds NO kernel-side error: the pipeline consumes the round-
    tripped f32 weights, so it must match the fp32 pipeline run on the
    fake-quant param view bit-for-bit."""
    stack = _stack(family, bidir=bidir)
    xs = _xs()
    got = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, precision="bf16")).forward(xs)
    want = rnn.compile(fake_quant_stack(stack, "bf16"), POL).forward(xs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int8_plan_carries_precision_end_to_end():
    """The knob is not a facade veneer: the lowered plan's WorkItem and
    every slot carry precision='int8', so the planner priced (and the
    verifier budgeted) the quantized launch, not the fp32 one."""
    cs = rnn.compile(_stack("lstm"), rnn.ExecutionPolicy(
        interpret=True, precision="int8"))
    p = cs.lower(2, 10)
    assert all(ip.item.precision == "int8" for ip in p.items)
    assert all(s.precision == "int8" for s in p.slots)
    assert "pint8" in p.slots[0].signature()


# ---------------------------------------------------------------------------
# prefill / decode resume under int8
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("family", ["lstm", "gru"])
def test_int8_prefill_decode_resume_within_bound(family):
    L = 3
    stack = _stack(family, L=L)
    xs = _xs(T=8)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                precision="int8"))
    fq = fake_quant_stack(stack, "int8")
    ys, st = cs.prefill(xs)
    assert _rel_err(ys, sch.reference_stack(fq, xs)) <= \
        KERNEL_GAP + INT8_REL_BOUND(L)
    # decode resumes the quantized state; the tick itself runs the dense
    # dequantized weights, so the only drift is what prefill carried in
    y1, _ = cs.decode(ys[:, -1], st)
    full = sch.reference_stack(fq, jnp.concatenate([xs, ys[:, -1:]],
                                                   axis=1))
    assert _rel_err(y1[:, 0], full[:, -1]) <= \
        KERNEL_GAP + INT8_REL_BOUND(L + 1)
    assert cs.last_decode_plan.launches == 1  # still the chained tick


def test_int8_ragged_multirequest_prefill_matches_solo():
    """The serving admission wave under int8: ragged prompts pack into one
    plan and each request's output is BIT-equal to its solo int8 compile
    (packing must never change numerics, quantized or not)."""
    stack = _stack("lstm", L=2)
    pol = rnn.ExecutionPolicy(interpret=True, precision="int8")
    cs = rnn.compile(stack, pol)
    seqs = [_xs(B=1, T=t, seed=10 + t) for t in (10, 10, 6)]
    res = cs.prefill(seqs)
    assert cs.plan.launches < cs.plan.naive_launches  # genuinely packed
    for xs_i, (ys_i, st_i) in zip(seqs, res):
        solo_y, solo_st = rnn.compile(stack, pol).prefill(xs_i)
        np.testing.assert_array_equal(np.asarray(ys_i), np.asarray(solo_y))
        np.testing.assert_array_equal(np.asarray(st_i["h"]),
                                      np.asarray(solo_st["h"]))


# ---------------------------------------------------------------------------
# block sparsity: zero row-tiles skipped, value-exact
# ---------------------------------------------------------------------------


def _zero_tiles(stack, layer_tiles):
    """Zero out whole MXU row-tiles of each layer's U: {layer: (tiles,)}."""
    out = {"layers": [dict(lay) for lay in stack["layers"]]}
    for li, tiles in layer_tiles.items():
        U = np.array(out["layers"][li]["U"])
        for t in tiles:
            U[t * MXU_ROWS:(t + 1) * MXU_ROWS] = 0.0
        out["layers"][li]["U"] = jnp.asarray(U)
    return out


def test_block_sparse_forward_value_exact():
    stack = _zero_tiles(_stack("lstm", L=2), {0: (1, 3), 1: (0, 2, 4)})
    xs = _xs()
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                sparsity="block"))
    # the compiled item really carries the occupancy bitmaps...
    p = cs.lower(2, 10)
    tm = stack_tile_maps(stack)
    assert all(ip.item.tile_map == tm for ip in p.items)
    assert p.items[0].item.density < 1.0
    # ...and the pruned path is value-exact vs the dense pipeline
    dense = rnn.compile(stack, POL).forward(xs)
    np.testing.assert_allclose(np.asarray(cs.forward(xs)),
                               np.asarray(dense), atol=1e-6)


def test_block_sparse_dense_stack_is_identity():
    """A stack with no zero tiles under sparsity='block' is all-ones
    bitmaps — same compaction width as dense, bit-equal output."""
    stack = _stack("lstm", L=2)
    xs = _xs()
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                sparsity="block"))
    assert cs.lower(2, 10).items[0].item.density == 1.0
    np.testing.assert_allclose(
        np.asarray(cs.forward(xs)),
        np.asarray(rnn.compile(stack, POL).forward(xs)), atol=1e-6)


def test_int8_plus_block_sparse_compose():
    """The two axes stack: quantize-then-compact, gated against the
    dequantized oracle of the SAME (sparse) parameters."""
    L = 2
    stack = _zero_tiles(_stack("lstm", L=L), {0: (0, 2), 1: (1, 3, 5)})
    xs = _xs()
    cs = rnn.compile(stack, rnn.ExecutionPolicy(
        interpret=True, precision="int8", sparsity="block"))
    oracle = sch.reference_stack(fake_quant_stack(stack, "int8"), xs)
    assert _rel_err(cs.forward(xs), oracle) <= KERNEL_GAP + INT8_REL_BOUND(L)


def test_bidir_sparse_or_union_runs_exact():
    """Bidirectional halves share one slot launch, so the bitmap is the
    OR-union of the two directions — still value-exact vs dense."""
    stack = _stack("lstm", L=2, bidir=True)
    lay = stack["layers"][0]
    for half, tiles in (("fwd", (0, 1)), ("bwd", (1, 2))):
        U = np.array(lay[half]["U"])
        for t in tiles:
            U[t * MXU_ROWS:(t + 1) * MXU_ROWS] = 0.0
        lay[half]["U"] = jnp.asarray(U)
    xs = _xs()
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                sparsity="block"))
    tm = stack_tile_maps(stack)
    assert tm[0][1] == 0  # only the tile BOTH halves zero is skippable
    assert cs.lower(2, 10).items[0].item.tile_map == tm
    np.testing.assert_allclose(
        np.asarray(cs.forward(xs)),
        np.asarray(rnn.compile(stack, POL).forward(xs)), atol=1e-6)
