"""Chunkwise-parallel mLSTM == recurrent scan (the §Perf cell-B optimization
must be numerically exact, including gradients and state handoff)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.models.layers.xlstm import (apply_mlstm, apply_mlstm_chunked,
                                       init_mlstm)


def _setup(B, T, d, H, seed=0):
    p = init_mlstm(jax.random.PRNGKey(seed), d, H, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, d)) * 0.5
    return p, x


@pytest.mark.parametrize("B,T,d,H,chunk", [
    (2, 64, 32, 2, 16), (1, 128, 48, 4, 32), (3, 96, 24, 2, 48),
])
def test_forward_equivalence(B, T, d, H, chunk):
    p, x = _setup(B, T, d, H)
    y_ref, st_ref = apply_mlstm(p, x, H)
    y_chk, st_chk = apply_mlstm_chunked(p, x, H, chunk=chunk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), atol=1e-5)
    for k in ("C", "n", "m"):
        np.testing.assert_allclose(np.asarray(st_chk[k]), np.asarray(st_ref[k]),
                                   atol=1e-5)


def test_gradient_equivalence():
    p, x = _setup(1, 64, 24, 2)

    def loss_rec(p):
        y, _ = apply_mlstm(p, x, 2)
        return jnp.sum(jnp.square(y))

    def loss_chk(p):
        y, _ = apply_mlstm_chunked(p, x, 2, chunk=16)
        return jnp.sum(jnp.square(y))

    g_rec = jax.grad(loss_rec)(p)
    g_chk = jax.grad(loss_chk)(p)
    for a, b in zip(jax.tree.leaves(g_rec), jax.tree.leaves(g_chk)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-4, rtol=2e-3)


def test_state_handoff_continuation():
    """Decoding from a chunked-prefill state matches recurrent prefill."""
    p, x = _setup(2, 64, 32, 2)
    _, st_ref = apply_mlstm(p, x, 2)
    _, st_chk = apply_mlstm_chunked(p, x, 2, chunk=16)
    x2 = jax.random.normal(jax.random.PRNGKey(9), (2, 4, 32)) * 0.5
    y_ref, _ = apply_mlstm(p, x2, 2, st_ref)
    y_chk, _ = apply_mlstm(p, x2, 2, st_chk)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(nc=st.integers(2, 6), L=st.sampled_from([8, 16, 32]),
       H=st.sampled_from([1, 2, 4]), seed=st.integers(0, 3))
def test_property_chunk_grid(nc, L, H, seed):
    T = nc * L
    d = 8 * H
    p, x = _setup(1, T, d, H, seed)
    y_ref, _ = apply_mlstm(p, x, H)
    y_chk, _ = apply_mlstm_chunked(p, x, H, chunk=L)
    np.testing.assert_allclose(np.asarray(y_chk), np.asarray(y_ref), atol=2e-5)


def test_fallback_on_indivisible_T():
    p, x = _setup(1, 50, 16, 2)  # 50 % 128 != 0 -> recurrent fallback
    y, _ = apply_mlstm_chunked(p, x, 2)
    y_ref, _ = apply_mlstm(p, x, 2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-6)


def test_chunked_scan_equivalence():
    """chunked_scan (remat) is bit-equivalent to lax.scan."""
    from repro.models.layers.common import chunked_scan

    def step(c, x):
        c = c * 0.9 + x
        return c, c * 2.0

    xs = jax.random.normal(jax.random.PRNGKey(0), (64, 8))
    c0 = jnp.zeros((8,))
    c_ref, ys_ref = jax.lax.scan(step, c0, xs)
    c_chk, ys_chk = chunked_scan(step, c0, xs, chunk=16)
    np.testing.assert_array_equal(np.asarray(c_ref), np.asarray(c_chk))
    np.testing.assert_array_equal(np.asarray(ys_ref), np.asarray(ys_chk))
    # gradient path
    g1 = jax.grad(lambda x: jax.lax.scan(step, c0, x)[1].sum())(xs)
    g2 = jax.grad(lambda x: chunked_scan(step, c0, x, chunk=16)[1].sum())(xs)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)
