"""Attention paths agree: naive == blockwise == local(SWA) == decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.models.layers import attention as at
from repro.models.layers.rope import apply_rope, mrope_angles, rope_angles


def _qkv(B, S, Hq, Hk, D, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, S, Hq, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, D), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("Hq,Hk", [(4, 4), (8, 2), (4, 1)])
def test_blockwise_equals_naive(Hq, Hk):
    q, k, v = _qkv(2, 64, Hq, Hk, 32)
    ref = at.naive_attention(q, k, v)
    out = at.blockwise_attention(q, k, v, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(nq=st.integers(1, 4), nk=st.integers(1, 4), seed=st.integers(0, 5))
def test_blockwise_chunk_grid(nq, nk, seed):
    S = 48
    q, k, v = _qkv(1, S, 4, 2, 16, seed)
    ref = at.naive_attention(q, k, v)
    qc = S // nq if S % nq == 0 else S
    kc = S // nk if S % nk == 0 else S
    if S % qc or S % kc:
        return
    out = at.blockwise_attention(q, k, v, q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("S,W", [(64, 16), (60, 16), (33, 8), (16, 16)])
def test_local_equals_naive_windowed(S, W):
    q, k, v = _qkv(2, S, 4, 2, 16)
    ref = at.naive_attention(q, k, v, window=W)
    out = at.local_attention(q, k, v, window=W)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_decode_equals_last_row_of_naive():
    B, S, Hq, Hk, D = 2, 32, 8, 2, 16
    q, k, v = _qkv(B, S, Hq, Hk, D)
    full = at.naive_attention(q, k, v)
    o = at.decode_attention(q[:, -1:], k, v, jnp.full((B,), S, jnp.int32))
    np.testing.assert_allclose(np.asarray(o[:, 0]), np.asarray(full[:, -1]),
                               atol=2e-5)


def test_chunked_decode_equals_direct():
    """The flash-decode chunked path (long caches) == direct softmax."""
    B, T, Hq, Hk, D = 2, 64, 8, 2, 16
    q, k, v = _qkv(B, T, Hq, Hk, D)
    import repro.models.layers.attention as A
    idx = jnp.array([40, 64], jnp.int32)
    direct = at.decode_attention(q[:, -1:], k, v, idx)
    chunked = A._decode_attention_chunked(q[:, -1:], k, v, idx, window=0,
                                          chunk=16)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               atol=2e-5)
    # windowed
    d2 = at.decode_attention(q[:, -1:], k, v, idx, window=8)
    c2 = A._decode_attention_chunked(q[:, -1:], k, v, idx, window=8, chunk=16)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(d2), atol=2e-5)


def test_rope_preserves_norm_and_relativity():
    S, D = 16, 32
    x = jax.random.normal(jax.random.PRNGKey(0), (1, S, 2, D))
    pos = jnp.arange(S)[None]
    cos, sin = rope_angles(pos, D, 10000.0)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # relativity: <R(p)q, R(p+d)k> depends only on d
    q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, D))
    k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, D))
    def dot_at(p, d):
        cq, sq = rope_angles(jnp.array([[p]]), D, 10000.0)
        ck, sk = rope_angles(jnp.array([[p + d]]), D, 10000.0)
        return float((apply_rope(q, cq, sq) * apply_rope(k, ck, sk)).sum())
    assert abs(dot_at(0, 3) - dot_at(7, 3)) < 1e-3


def test_mrope_degenerates_to_rope_for_text():
    """Equal position streams == standard RoPE (Qwen2-VL property)."""
    S, D = 12, 32
    pos = jnp.arange(S)[None]
    pos3 = jnp.broadcast_to(pos[None], (3, 1, S))
    c1, s1 = rope_angles(pos, D, 10000.0)
    c3, s3 = mrope_angles(pos3, D, 10000.0, (4, 6, 6))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c3), atol=1e-6)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s3), atol=1e-6)
