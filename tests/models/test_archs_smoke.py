"""Per-architecture smoke: reduced config forward + one train step on CPU,
asserting output shapes and finiteness (assignment requirement (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.launch.steps import TrainSettings, init_opt_state, make_train_step
from repro.models import transformer as tf

ARCHS = list_archs()


def _batch(cfg, B=2, S=16, seed=0):
    key = jax.random.PRNGKey(seed)
    if cfg.embed_stub:
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.float32),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_reduced(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, _, aux = tf.forward(cfg, params, tokens=batch.get("tokens"),
                                embeds=batch.get("embeds"), mode="train")
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    if cfg.n_experts:
        assert float(aux) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    cfg = get_reduced(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    settings = TrainSettings()
    opt = init_opt_state(cfg, params, settings)
    step = jax.jit(make_train_step(cfg, settings))
    batch = _batch(cfg)
    new_params, new_opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(new_opt["adam"]["count"]) == 1
    # params actually moved
    delta = max(float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                      - b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(new_params)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["deepseek-67b", "arctic-480b"])
def test_microbatched_matches_single(arch):
    """Gradient accumulation == full-batch step (same loss, close params)."""
    cfg = get_reduced(arch)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, B=4, S=8)
    s1 = TrainSettings(microbatches=1)
    s2 = TrainSettings(microbatches=2)
    p1, _, m1 = jax.jit(make_train_step(cfg, s1))(
        params, init_opt_state(cfg, params, s1), batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, s2))(
        params, init_opt_state(cfg, params, s2), batch)
    if cfg.n_experts:
        # microbatching changes MoE capacity groups; only finiteness holds
        assert np.isfinite(float(m2["loss"]))
    else:
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-4)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=1e-4)
