"""System-level correctness: prefill + token-by-token decode reproduces the
full forward pass for every architecture (attention caches, ring buffers,
RG-LRU/xLSTM state handoff, MoE, M-RoPE — all at once)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, list_archs
from repro.models import transformer as tf

ARCHS = list_archs()


@pytest.mark.parametrize("arch", ARCHS)
def test_incremental_decode_matches_full(arch):
    cfg = get_reduced(arch)
    if cfg.n_experts:
        # capacity drops are order-dependent by design; disable for the test
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    B, S, TAIL = 2, 24, 4
    key = jax.random.PRNGKey(1)
    if cfg.embed_stub:
        embeds = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        full, _, _ = tf.forward(cfg, params, embeds=embeds, mode="train")
        logits_p, cache = tf.prefill(cfg, params,
                                     {"embeds": embeds[:, :S - TAIL]}, seq_len=S)
        outs = [logits_p]
        for t in range(S - TAIL, S):
            lg, cache = tf.decode_step(cfg, params, cache,
                                       {"embeds": embeds[:, t:t + 1]})
            outs.append(lg)
    else:
        tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        full, _, _ = tf.forward(cfg, params, tokens=tokens, mode="train")
        logits_p, cache = tf.prefill(cfg, params,
                                     {"tokens": tokens[:, :S - TAIL]}, seq_len=S)
        outs = [logits_p]
        for t in range(S - TAIL, S):
            lg, cache = tf.decode_step(cfg, params, cache,
                                       {"tokens": tokens[:, t:t + 1]})
            outs.append(lg)
    inc = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(inc), np.asarray(full), atol=2e-3)


def test_ring_cache_bounds_memory():
    """SWA archs allocate a window-sized ring, not the full sequence."""
    cfg = get_reduced("h2o-danube-3-4b")  # window 16
    cache = tf.init_cache(cfg, batch=1, seq_len=1024)
    assert cache["layers"]["k"].shape[2] == cfg.window  # (L, B, T=W, KV)


def test_full_attention_cache_is_full_length():
    cfg = get_reduced("deepseek-67b")
    cache = tf.init_cache(cfg, batch=1, seq_len=64)
    assert cache["layers"]["k"].shape[2] == 64
