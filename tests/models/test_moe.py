"""MoE routing invariants + dense-reference equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hyp import given, settings, st

from repro.models.layers import moe as moe_lib


def _params(d, ff, E, seed=0):
    return moe_lib.init_moe(jax.random.PRNGKey(seed), d, ff, E, jnp.float32)


def test_matches_dense_reference_when_no_drops():
    B, S, d, ff, E, k = 2, 8, 16, 32, 8, 2
    p = _params(d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    y, aux = moe_lib.apply_moe(p, x, k=k, capacity_factor=1.0,
                               deterministic_capacity=B * S)  # no drops
    y_ref = moe_lib.moe_reference(p, x, k=k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(T=st.integers(4, 64), E=st.sampled_from([4, 8]), k=st.integers(1, 3),
       seed=st.integers(0, 3))
def test_route_invariants(T, E, k, seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (T, E))
    C = max(1, T * k // E)
    e_idx, s_idx, w, valid = moe_lib.route(logits, k, C, E)
    e, s, v = np.asarray(e_idx), np.asarray(s_idx), np.asarray(valid)
    w = np.asarray(w)
    # weights: renormalized top-k sums to 1
    np.testing.assert_allclose(w.sum(-1), 1.0, atol=1e-5)
    # expert ids in range; no duplicate expert per token
    assert (e >= 0).all() and (e < E).all()
    for t in range(e.shape[0]):
        assert len(set(e[t])) == k
    # each (expert, slot) pair held by at most one (token, choice)
    pairs = [(int(e[t, j]), int(s[t, j]))
             for t in range(T) for j in range(k) if v[t, j]]
    assert len(pairs) == len(set(pairs))
    # all valid slots below capacity
    assert all(0 <= slot < C for _, slot in pairs)
    # capacity accounting: expert load == min(demand, C)
    demand = np.bincount(e.reshape(-1), minlength=E)
    load = np.bincount([p[0] for p in pairs], minlength=E)
    np.testing.assert_array_equal(load, np.minimum(demand, C))


def test_dropped_tokens_contribute_zero():
    """At tiny capacity, overflow tokens fall back to the residual (output 0)."""
    B, S, d, ff, E, k = 1, 64, 8, 16, 4, 1  # demand ~16/expert vs capacity 8
    p = _params(d, ff, E)
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, d))
    y, _ = moe_lib.apply_moe(p, x, k=k, capacity_factor=1e-9)  # capacity -> min
    # some tokens must be dropped (16 tokens, 4 experts, cap 8 floor)
    y_full, _ = moe_lib.apply_moe(p, x, k=k, deterministic_capacity=B * S,
                                  capacity_factor=1.0)
    assert not np.allclose(np.asarray(y), np.asarray(y_full))


def test_aux_loss_balanced_vs_skewed():
    E = 8
    T = 256
    balanced = jnp.tile(jnp.eye(E), (T // E, 1)) * 4.0
    skewed = jnp.zeros((T, E)).at[:, 0].set(4.0)
    top_b = jax.lax.top_k(balanced, 1)[1]
    top_s = jax.lax.top_k(skewed, 1)[1]
    lb = moe_lib.aux_load_balance_loss(balanced, top_b, E)
    ls = moe_lib.aux_load_balance_loss(skewed, top_s, E)
    assert float(ls) > float(lb)  # skew is penalized
    assert float(lb) == pytest.approx(1.0, abs=0.3)  # ~1 at perfect balance


def test_arctic_dense_residual_branch():
    from repro.models.layers.mlp import apply_mlp

    d, ff, E = 8, 16, 4
    p = moe_lib.init_moe(jax.random.PRNGKey(0), d, ff, E, jnp.float32,
                         dense_ff=16)
    assert "dense" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 4, d))
    y, _ = moe_lib.apply_moe(p, x, k=2, capacity_factor=4.0)
    y_no_dense = y - apply_mlp(p["dense"], x)
    assert np.isfinite(np.asarray(y)).all()
    assert not np.allclose(np.asarray(y), np.asarray(y_no_dense))
