"""End-to-end behaviour: the training driver reduces loss and survives an
injected fault; the serving driver drains a request queue."""
import sys

import pytest

from repro.launch import serve as serve_mod
from repro.launch import train as train_mod


def test_train_driver_end_to_end(tmp_path):
    loop = train_mod.main([
        "--arch", "xlstm-125m", "--reduced", "--steps", "25", "--batch", "8",
        "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "10",
    ])
    hist = loop.metrics_history
    assert len(hist) == 25
    import numpy as np
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    assert last < first  # learning the markov structure


def test_train_driver_with_fault_and_compression(tmp_path):
    loop = train_mod.main([
        "--arch", "starcoder2-3b", "--reduced", "--steps", "14", "--batch", "4",
        "--seq", "16", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        "--fail-at", "8", "--compression", "int8",
    ])
    assert loop.restarts == 1


def test_serve_driver_end_to_end():
    done = serve_mod.main([
        "--arch", "starcoder2-3b", "--reduced", "--requests", "3",
        "--max-batch", "2", "--max-seq", "48", "--max-new", "4",
    ])
    assert len(done) == 3
