"""Decode through the dispatcher: parity, launch proofs, cross-B packing.

The ISSUE-3 contracts: (1) dispatcher-planned decode ticks are bit-identical
to the pre-existing L-launch per-layer loop across families, dtypes, and
ragged active-slot patterns; (2) a planned tick is ONE launch (<= L); (3)
cross-B packed prefill plans launch strictly fewer kernels than the
equal-signature unpacked (per-B-signature) plan, exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gru
from repro.dispatch import WorkItem, execute, plan, plan_decode
from repro.kernels.common import pallas_launch_count
from repro.kernels.gru_cell.ops import gru_seq
from repro.kernels.lstm_cell.ops import lstm_seq
from repro.models.layers.lstm import init_lstm_stack
from repro.configs.sharp_lstm import lstm_config

L, H = 3, 32


def _params(family, dtype=jnp.float32, seed=0):
    if family == "lstm":
        return init_lstm_stack(jax.random.PRNGKey(seed),
                               lstm_config(H, layers=L), dtype)
    return gru.init_gru_stack(jax.random.PRNGKey(seed), H, H, L, dtype)


def _hand_tick(family, params, y, h, c):
    """The pre-existing decode loop: L per-layer T=1 sequence launches."""
    gates = 4 if family == "lstm" else 3
    h_new, c_new = [], []
    for l, layer in enumerate(params["layers"]):
        xw = (jnp.einsum("btx,xg->btg", y, layer["W"])
              + layer["b"]).reshape(y.shape[0], 1, gates, H)
        if family == "lstm":
            hs, h_n, c_n = lstm_seq(layer["U"].reshape(H, 4, H), xw, h[l],
                                    c[l], block_t=1, interpret=True)
            c_new.append(c_n)
        else:
            hs, h_n = gru_seq(layer["U"].reshape(H, 3, H), xw, h[l],
                              block_t=1, interpret=True)
        h_new.append(h_n)
        y = hs.astype(y.dtype)
    return y, jnp.stack(h_new), (jnp.stack(c_new) if c_new else None)


@pytest.mark.parametrize("family", ["lstm", "gru"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("ks", [(1,), (2,), (3, 1), (1, 4, 2)])
def test_planned_ticks_bit_identical_to_L_launch_loop(family, dtype, ks):
    """Each tick of a ragged schedule (k active requests varying per tick)
    is planned and must match the hand loop bit-for-bit, state included."""
    params = _params(family, dtype=jnp.float32, seed=3)
    dstr = "float32" if dtype == jnp.float32 else "bfloat16"
    rng = np.random.default_rng(7)
    for tick, k in enumerate(ks):
        y = jnp.asarray(rng.standard_normal((k, 1, H)) * 0.5, dtype)
        h = jnp.asarray(rng.standard_normal((L, k, H)) * 0.3, dtype)
        c = jnp.asarray(rng.standard_normal((L, k, H)) * 0.3, jnp.float32)

        items = [WorkItem(uid=i, family=family, B=1, T=1, H=H, L=L,
                          dtype=dstr, share=0) for i in range(k)]
        p = plan_decode(items)
        assert len(p.slots) == 1 and p.slots[0].chained
        assert p.launches == 1 <= L
        inputs = {i: y[i:i + 1] for i in range(k)}
        init = {i: ({"h": h[:, i:i + 1], "c": c[:, i:i + 1]}
                    if family == "lstm" else {"h": h[:, i:i + 1]})
                for i in range(k)}
        outs, states = execute(p, {i: params for i in range(k)}, inputs,
                               interpret=True, collect_state=True,
                               init_state=init)

        y_ref, h_ref, c_ref = _hand_tick(
            family, params, y, h, c if family == "lstm" else None)
        for i in range(k):
            np.testing.assert_array_equal(
                np.asarray(outs[i].astype(jnp.float32)),
                np.asarray(y_ref[i:i + 1].astype(jnp.float32)))
            np.testing.assert_array_equal(
                np.asarray(states[i]["h"].astype(jnp.float32)),
                np.asarray(h_ref[:, i:i + 1].astype(jnp.float32)))
            if family == "lstm":
                np.testing.assert_array_equal(
                    np.asarray(states[i]["c"]),
                    np.asarray(c_ref[:, i:i + 1]))


def test_planned_tick_is_one_launch():
    """Structural proof: a planned tick executes as ONE pallas launch
    where the pre-existing loop issues L."""
    params = _params("lstm")
    k = 3
    items = [WorkItem(uid=i, family="lstm", B=1, T=1, H=H, L=L, share=0)
             for i in range(k)]
    p = plan_decode(items)
    inputs = {i: jnp.zeros((1, 1, H)) for i in range(k)}

    n = pallas_launch_count(
        lambda xs: execute(p, {i: params for i in range(k)}, xs,
                           interpret=True), inputs)
    assert n == p.launches == 1

    y = jnp.zeros((k, 1, H))
    h = jnp.zeros((L, k, H))
    c = jnp.zeros((L, k, H))
    assert pallas_launch_count(
        lambda *a: _hand_tick("lstm", params, *a), y, h, c) == L


def test_cross_b_prefill_packs_fewer_launches():
    """Mixed-B same-signature traffic: cross-B packing (pad + in-kernel
    mask) must plan strictly fewer launches than the per-B-signature plan,
    at exactly equal outputs."""
    cfg = lstm_config(H, layers=L)
    T = 12
    items = [WorkItem.from_config(cfg, T=T, B=b, uid=i)
             for i, b in enumerate((2, 1, 1))]
    packed = plan(items)
    unpacked = plan(items, cross_b=False)
    assert packed.launches < unpacked.launches

    params = {i: init_lstm_stack(jax.random.PRNGKey(9), cfg, jnp.float32)
              for i in range(3)}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(20 + i),
                                   (it.B, T, H)) * 0.5
              for i, it in enumerate(items)}
    outs_p = execute(packed, params, inputs, interpret=True)
    outs_u = execute(unpacked, params, inputs, interpret=True)
    for i in inputs:
        np.testing.assert_array_equal(np.asarray(outs_p[i]),
                                      np.asarray(outs_u[i]))


def test_share_concats_rows_instead_of_g_batching():
    """Parameter-sharing items' same-layer cells concatenate on B: the
    packed plan's slots carry ONE multi-cell row where the unshared plan
    carries G single-cell rows — and outputs stay exact."""
    cfg = lstm_config(H, layers=L)
    T = 8
    shared = [WorkItem.from_config(cfg, T=T, uid=i, share=0)
              for i in range(2)]
    solo = [WorkItem.from_config(cfg, T=T, uid=i) for i in range(2)]
    ps, pu = plan(shared), plan(solo)
    assert any(len(grp) > 1 for s in ps.slots for grp in s.groups)
    assert all(len(grp) == 1 for s in pu.slots for grp in s.groups)
    assert all(s.B == 2 for s in ps.slots)

    params = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    inputs = {i: jax.random.normal(jax.random.PRNGKey(30 + i),
                                   (1, T, H)) * 0.5 for i in range(2)}
    outs_s = execute(ps, {i: params for i in range(2)}, inputs,
                     interpret=True)
    outs_u = execute(pu, {i: params for i in range(2)}, inputs,
                     interpret=True)
    for i in inputs:
        np.testing.assert_array_equal(np.asarray(outs_s[i]),
                                      np.asarray(outs_u[i]))


def test_plan_decode_validates_items():
    ok = WorkItem(uid=0, family="lstm", B=1, T=1, H=H, L=L, share=0)
    with pytest.raises(ValueError, match="at least one"):
        plan_decode([])
    with pytest.raises(ValueError, match="T=1"):
        plan_decode([WorkItem(uid=0, family="lstm", B=1, T=2, H=H, L=L,
                              share=0)])
    with pytest.raises(ValueError, match="share"):
        plan_decode([WorkItem(uid=0, family="lstm", B=1, T=1, H=H, L=L)])
    with pytest.raises(ValueError, match="must share"):
        plan_decode([ok, WorkItem(uid=1, family="lstm", B=1, T=1, H=2 * H,
                                  L=L, share=0)])
    with pytest.raises(ValueError, match="family"):
        plan_decode([WorkItem(uid=0, family="rglru", B=1, T=1, H=H, L=1,
                              share=0)])


def test_plan_decode_bidirectional_error_names_item_and_alternative():
    """ISSUE-5 satellite regression: the bidirectional rejection used to be
    a bare ValueError — it must name the offending item, its layer count,
    and point at the supported forward()/prefill() path."""
    bi = WorkItem(uid=7, family="lstm", B=1, T=1, H=H, L=L, share=0,
                  bidirectional=True)
    with pytest.raises(ValueError,
                       match=r"item 7.*3 layer.*forward\(\)"):
        plan_decode([bi])
