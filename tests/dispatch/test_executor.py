"""Executor: exact equivalence of packed execution + launch-count proofs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sharp_lstm import lstm_config
from repro.core import gru, schedules as sch
from repro.dispatch import WorkItem, execute, plan
from repro.kernels.common import pallas_launch_count
from repro.kernels.lstm_cell.ops import lstm_seq_ref
from repro.models.layers.lstm import init_lstm_stack

MIX = [(lstm_config(64, layers=3), 24), (lstm_config(96, layers=2), 16),
       (lstm_config(64, layers=4), 12)]


def _setup(mix=MIX):
    items = [WorkItem.from_config(c, T=t, uid=i)
             for i, (c, t) in enumerate(mix)]
    params = {i: init_lstm_stack(jax.random.PRNGKey(i), c, jnp.float32)
              for i, (c, _) in enumerate(mix)}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(100 + i),
                                   (1, t, c.lstm_hidden)) * 0.5
              for i, (c, t) in enumerate(mix)}
    return items, params, inputs


def test_packed_matches_oracle_and_single_item_execution():
    items, params, inputs = _setup()
    p = plan(items)
    outs = execute(p, params, inputs, interpret=True)
    for i, (cfg, t) in enumerate(MIX):
        oracle = sch.reference_stack(params[i], inputs[i])
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(oracle),
                                   atol=1e-4)
        solo = execute(plan([items[i]]), {i: params[i]}, {i: inputs[i]},
                       interpret=True)
        # packing is numerically inert: the G-batched kernel walks each
        # cell independently, so packed == solo exactly
        np.testing.assert_array_equal(np.asarray(outs[i]),
                                      np.asarray(solo[i]))


def test_packed_launches_below_per_request_wavefront():
    items, params, inputs = _setup()
    p = plan(items)
    n_packed = pallas_launch_count(
        lambda pr, xs: execute(p, pr, xs, interpret=True), params, inputs)
    # per-request baseline: each item planned and executed alone (forced
    # onto the wavefront stripe the retired run_stack_wavefront used)
    n_per_req = 0
    for i in inputs:
        solo = plan([items[i]], schedule="wavefront",
                    block_t=min(items[i].T, 16))
        n_per_req += pallas_launch_count(
            lambda pr, xs, sp=solo: execute(sp, pr, xs, interpret=True),
            {i: params[i]}, {i: inputs[i]})
    assert n_packed == p.launches
    assert n_packed < n_per_req


def test_final_state_is_exact():
    """The remainder-exact chunking leaves behind the true t=T state — the
    contract the serving engine's decode splice relies on."""
    items, params, inputs = _setup()
    p = plan(items)
    _, states = execute(p, params, inputs, interpret=True,
                        collect_state=True)
    for i, (cfg, t) in enumerate(MIX):
        H = cfg.lstm_hidden
        y = inputs[i]
        for l, layer in enumerate(params[i]["layers"]):
            xw = (jnp.einsum("btx,xg->btg", y, layer["W"])
                  + layer["b"]).reshape(1, t, 4, H)
            hs, h_n, c_n = lstm_seq_ref(
                layer["U"].reshape(H, 4, H), xw,
                jnp.zeros((1, H)), jnp.zeros((1, H)))
            np.testing.assert_allclose(
                np.asarray(states[i]["h"][l]), np.asarray(h_n), atol=1e-5)
            np.testing.assert_allclose(
                np.asarray(states[i]["c"][l]), np.asarray(c_n), atol=1e-5)
            y = hs


@pytest.mark.parametrize("Ts", [(11, 7, 5), (13, 13, 4)])
def test_ragged_lengths_stay_exact(Ts):
    """T-stripe remainders (T % bt != 0) execute at their true length."""
    items, params, inputs = _setup([(c, t) for (c, _), t in zip(MIX, Ts)])
    outs = execute(plan(items), params, inputs, interpret=True)
    for i in inputs:
        oracle = sch.reference_stack(params[i], inputs[i])
        assert outs[i].shape == oracle.shape
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(oracle),
                                   atol=1e-4)


def test_gru_items_execute_and_pack():
    items = [WorkItem(uid=0, family="gru", B=1, T=12, H=48, L=3),
             WorkItem(uid=1, family="gru", B=1, T=12, H=48, L=2)]
    params = {i: gru.init_gru_stack(jax.random.PRNGKey(i), 48, 48, L,
                                    jnp.float32)
              for i, L in ((0, 3), (1, 2))}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(10 + i), (1, 12, 48))
              * 0.5 for i in (0, 1)}
    p = plan(items)
    assert p.launches < p.naive_launches
    outs = execute(p, params, inputs, interpret=True)
    for i in inputs:
        y = inputs[i]
        for layer in params[i]["layers"]:
            y = gru.run_layer_unfolded(layer, y)
        np.testing.assert_allclose(np.asarray(outs[i]), np.asarray(y),
                                   atol=1e-4)


def test_external_fallbacks_still_collect_state():
    """Items the planner leaves unpacked (here: forced per_step) must still
    return exact t=T state when asked — the serving engine depends on it."""
    from dataclasses import replace as dc_replace

    it = WorkItem(uid=0, family="lstm", B=1, T=7, H=48, L=2, X=96)
    cfg = lstm_config(48, layers=2)
    cfg = dc_replace(cfg, lstm_input=96)
    params = {0: init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)}
    inputs = {0: jax.random.normal(jax.random.PRNGKey(1), (1, 7, 96)) * 0.5}
    p = plan([it])
    # force the external path regardless of what the scorer picked
    from dataclasses import replace
    p = replace(p, items=tuple(replace(ip, schedule="per_step")
                               for ip in p.items),
                slots=(), external=(0,))
    outs, states = execute(p, params, inputs, interpret=True,
                           collect_state=True)
    oracle = sch.reference_stack(params[0], inputs[0])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(oracle),
                               atol=1e-4)
    assert states[0]["h"].shape == (2, 1, 48)
    assert states[0]["c"].shape == (2, 1, 48)
    np.testing.assert_allclose(np.asarray(states[0]["h"][1]),
                               np.asarray(oracle[:, -1]), atol=1e-5)


def test_stateless_families_collect_none_not_empty_dict():
    """ISSUE-3 satellite (amended by ISSUE-5): rglru items return an
    explicit ``states[uid] = None`` (documented), not a silent {} that
    KeyErrors at first use.  Bidirectional items are no longer stateless —
    see test_bidirectional_collects_per_direction_state."""
    rg = WorkItem(uid=0, family="rglru", B=1, T=8, H=32, L=1)
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (1, 8, 32))) * 0.3
    gx = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 32))
    _, states = execute(plan([rg]), {0: None}, {0: (la, gx)},
                        interpret=True, collect_state=True)
    assert states[0] is None


def _bi_setup(L=2, H=24, T=6, B=1, seed=2):
    import dataclasses

    bi = WorkItem(uid=0, family="lstm", B=B, T=T, H=H, L=L,
                  bidirectional=True)
    cfg = dataclasses.replace(lstm_config(H, layers=L), bidirectional=True)
    params = {0: init_lstm_stack(jax.random.PRNGKey(seed), cfg, jnp.float32)}
    xs = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, T, H)) * 0.5
    return bi, params, xs


def test_bidirectional_lstm_packed_bit_identical_to_fused_reference():
    """ISSUE-5 tentpole exactness: the interleaved packed timeline —
    chunked fwd/bwd walks, per-cell pre-launch reversal, concat inputs —
    reproduces the retired per-layer fused path BIT for bit (fp32), at
    strictly fewer launches than 2·L·⌈T/bt⌉ (structurally proven)."""
    bi, params, xs = _bi_setup(L=3, H=24, T=14, B=2)
    p = plan([bi], schedule="wavefront", block_t=4)
    outs = execute(p, params, {0: xs}, interpret=True)
    ref = sch.reference_stack(params[0], xs, "fused")
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(ref))
    n = pallas_launch_count(
        lambda pr, x: execute(p, pr, {0: x}, interpret=True), params, xs)
    assert n == p.launches < 2 * 3 * 4  # < 2·L·⌈T/bt⌉


def test_bidirectional_collects_per_direction_state():
    """collect_state for a bidirectional item returns the per-direction
    end-of-walk states (fwd: exact t=T, bwd: exact t=0) instead of the
    pre-ISSUE-5 None."""
    bi, params, xs = _bi_setup(L=2, H=24, T=7)
    _, states = execute(plan([bi]), params, {0: xs}, interpret=True,
                        collect_state=True)
    st = states[0]
    assert set(st) == {"fwd", "bwd"}
    # oracle: per-layer fused halves with return_state
    y = xs
    for l, layer in enumerate(params[0]["layers"]):
        f, (hf, cf) = sch.run_layer_fused(layer["fwd"], y,
                                          interpret=True, return_state=True)
        b, (hb, cb) = sch.run_layer_fused(layer["bwd"], jnp.flip(y, axis=1),
                                          interpret=True, return_state=True)
        np.testing.assert_array_equal(np.asarray(st["fwd"]["h"][l]),
                                      np.asarray(hf))
        np.testing.assert_array_equal(np.asarray(st["bwd"]["h"][l]),
                                      np.asarray(hb))
        np.testing.assert_array_equal(np.asarray(st["fwd"]["c"][l]),
                                      np.asarray(cf))
        np.testing.assert_array_equal(np.asarray(st["bwd"]["c"][l]),
                                      np.asarray(cb))
        y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)


def test_bidirectional_rejects_init_state():
    """The fwd/bwd walks start from opposite sequence ends — there is no
    mid-stream resume point, so a seeded state must be a loud error."""
    bi, params, xs = _bi_setup(L=2, H=24, T=5)
    init = {0: {"h": jnp.zeros((2, 1, 24)), "c": jnp.zeros((2, 1, 24))}}
    with pytest.raises(ValueError, match="bidirectional"):
        execute(plan([bi]), params, {0: xs}, interpret=True,
                init_state=init)


def test_mixed_width_slot_is_exact_and_padded():
    """Ragged-B packing end to end: B=2 and B=1 same-signature items share
    padded slots (group_b records the true widths) and results — outputs
    AND t=T state — are exact vs solo execution."""
    cfg = lstm_config(40, layers=2)
    items = [WorkItem.from_config(cfg, T=10, B=b, uid=i)
             for i, b in enumerate((2, 1))]
    p = plan(items)
    ragged = [s for s in p.slots if len(set(s.group_b + (s.B,))) > 1]
    assert ragged, "expected at least one padded (ragged-B) slot"
    params = {i: init_lstm_stack(jax.random.PRNGKey(i), cfg, jnp.float32)
              for i in range(2)}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(40 + i),
                                   (it.B, 10, 40)) * 0.5
              for i, it in enumerate(items)}
    outs, states = execute(p, params, inputs, interpret=True,
                           collect_state=True)
    for i in inputs:
        solo_out, solo_st = execute(plan([items[i]]), {i: params[i]},
                                    {i: inputs[i]}, interpret=True,
                                    collect_state=True)
        np.testing.assert_array_equal(np.asarray(outs[i]),
                                      np.asarray(solo_out[i]))
        np.testing.assert_array_equal(np.asarray(states[i]["h"]),
                                      np.asarray(solo_st[i]["h"]))
        np.testing.assert_array_equal(np.asarray(states[i]["c"]),
                                      np.asarray(solo_st[i]["c"]))


def test_bidirectional_gru_packs_and_executes():
    it = WorkItem(uid=0, family="gru", B=1, T=6, H=24, L=2,
                  bidirectional=True)
    key = jax.random.PRNGKey(0)
    layers = []
    x_dim = 24
    for _ in range(2):
        key, kf, kb = jax.random.split(key, 3)
        layers.append({"fwd": gru.init_gru_layer(kf, x_dim, 24, jnp.float32),
                       "bwd": gru.init_gru_layer(kb, x_dim, 24, jnp.float32)})
        x_dim = 48
    params = {0: {"layers": layers}}
    xs = jax.random.normal(jax.random.PRNGKey(1), (1, 6, 24)) * 0.5
    p = plan([it])
    assert p.item(0).schedule in ("wavefront", "fused")  # packed, not
    assert not p.external                                # external
    out = execute(p, params, {0: xs}, interpret=True)
    # oracle: fwd/bwd reference unroll per layer
    y = xs
    for layer in layers:
        f = gru.reference_unroll(layer["fwd"], y)
        b = gru.reference_unroll(layer["bwd"], jnp.flip(y, axis=1))
        y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(y), atol=1e-4)


def test_plan_only_items_fail_fast_before_any_work():
    from repro.configs import get_config

    rg = WorkItem.from_config(get_config("recurrentgemma-2b"), T=8, uid=5)
    lstm_it = WorkItem.from_config(lstm_config(48, layers=2), T=8, uid=0)
    p = plan([rg, lstm_it])
    with pytest.raises(NotImplementedError, match="plan-only"):
        execute(p, {0: None, 5: None}, {0: None, 5: None}, interpret=True)


def test_rglru_single_layer_executes():
    from repro.kernels.rglru.ops import rglru_scan_ref

    it = WorkItem(uid=0, family="rglru", B=2, T=16, H=64, L=1)
    la = -jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (2, 16, 64))) * 0.3
    gx = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 64))
    out = execute(plan([it]), {0: None}, {0: (la, gx)}, interpret=True)
    ref, _ = rglru_scan_ref(la, gx, jnp.zeros((2, 64)))
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(ref),
                               atol=1e-5)


def test_init_state_for_external_item_is_rejected_not_dropped():
    """Review fix: external-fallback schedules start from zero state, so an
    init_state for an external item must be a loud error — silently
    dropping it would return zero-state results for a caller expecting a
    resume (the repro.rnn mixed-decode hazard)."""
    from dataclasses import replace

    it = WorkItem(uid=0, family="lstm", B=1, T=3, H=32, L=2)
    cfg = lstm_config(32, layers=2)
    params = {0: init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)}
    inputs = {0: jax.random.normal(jax.random.PRNGKey(1), (1, 3, 32)) * 0.5}
    p = plan([it])
    forced = replace(p, items=tuple(replace(ip, schedule="per_step")
                                    for ip in p.items),
                     slots=(), external=(0,))
    init = {0: {"h": jnp.zeros((2, 1, 32)), "c": jnp.zeros((2, 1, 32))}}
    with pytest.raises(ValueError, match="external-fallback"):
        execute(forced, params, inputs, interpret=True, init_state=init)
    # the packed plan accepts the same init_state
    outs = execute(p, params, inputs, interpret=True, init_state=init)
    assert outs[0].shape == (1, 3, 32)


def test_mixed_families_in_one_plan():
    items, params, inputs = _setup(MIX[:2])
    items.append(WorkItem(uid=2, family="gru", B=1, T=16, H=96, L=2))
    params[2] = gru.init_gru_stack(jax.random.PRNGKey(7), 96, 96, 2,
                                   jnp.float32)
    inputs[2] = jax.random.normal(jax.random.PRNGKey(17), (1, 16, 96)) * 0.5
    p = plan(items)
    fams = {s.family for s in p.slots}
    assert fams == {"lstm", "gru"}
    outs = execute(p, params, inputs, interpret=True)
    assert set(outs) == {0, 1, 2}
