"""Planner: plan determinism, inspectability, packing and fallbacks."""
import pytest

from repro.configs import get_config
from repro.configs.sharp_lstm import EESEN, lstm_config
from repro.dispatch import WorkItem, plan


def _mix(Ts=(24, 16, 12)):
    cfgs = [lstm_config(64, layers=3), lstm_config(96, layers=2),
            lstm_config(64, layers=4)]
    return [WorkItem.from_config(c, T=t, uid=i)
            for i, (c, t) in enumerate(zip(cfgs, Ts))]


def test_plan_is_deterministic():
    p1, p2 = plan(_mix()), plan(_mix())
    assert p1.describe() == p2.describe()
    assert p1.slots == p2.slots
    assert p1.items == p2.items


def test_plan_is_explicit_and_inspectable():
    p = plan(_mix())
    text = p.describe()
    assert "slot" in text and "wave" in text and "K" in text
    for s in p.slots:
        assert s.cells and s.tile_k > 0 and len(s.mvm_block) == 2
        assert s.chunk_len >= 1
        for c in s.cells:
            # the wavefront invariant: every cell sits on its anti-diagonal
            assert c.layer + c.chunk == s.wave
    for ip in p.items:
        assert ip.schedule in ("wavefront", "fused", "per_step", "per_layer")
        assert ip.tile_k > 0


def test_slot_order_respects_dependencies():
    """A cell's inputs — (l-1, k) and (l, k-1) — must run in earlier
    waves, and slots are emitted in wave order."""
    p = plan(_mix())
    waves = [s.wave for s in p.slots]
    assert waves == sorted(waves)
    seen = set()
    for s in p.slots:
        for c in s.cells:
            if c.layer > 0:
                assert (c.uid, c.layer - 1, c.chunk) in seen
            if c.chunk > 0:
                assert (c.uid, c.layer, c.chunk - 1) in seen
        seen.update((c.uid, c.layer, c.chunk) for c in s.cells)


def test_packing_beats_per_item_launches():
    p = plan(_mix())
    assert p.launches < p.naive_launches
    # every same-signature wave merged: at least one slot is G-batched
    assert any(s.g > 1 for s in p.slots)


def test_all_cells_covered_exactly_once():
    p = plan(_mix())
    for ip in p.items:
        cells = [c for s in p.slots for c in s.cells if c.uid == ip.uid]
        assert len(cells) == len(set(cells)) == ip.item.L * ip.nk


def test_rglru_falls_back_and_bidirectional_packs():
    """rglru stays external (diagonal recurrence, per-layer scan); a
    bidirectional item no longer falls back — its fwd/bwd cells enter the
    packed interleaved timeline (ISSUE-5 retired the per-layer path)."""
    rg = WorkItem.from_config(get_config("recurrentgemma-2b"), T=8, uid=0)
    assert rg.family == "rglru"
    bi = WorkItem.from_config(EESEN, T=8, uid=1)
    assert bi.bidirectional and bi.dirs == 2
    lstm_it = WorkItem.from_config(lstm_config(64, layers=3), T=24, uid=2)
    p = plan([rg, bi, lstm_it])
    assert set(p.external) == {0}
    assert p.item(0).naive_launches == rg.L
    ip = p.item(1)
    assert ip.schedule in ("wavefront", "fused")
    cells = [c for s in p.slots for c in s.cells if c.uid == 1]
    assert len(cells) == 2 * bi.L * ip.nk  # every (layer, chunk, dir) once
    assert {c.direction for c in cells} == {"fwd", "bwd"}


def _bi_item(L=3, T=12, B=1, uid=0, share=None):
    import dataclasses

    cfg = dataclasses.replace(lstm_config(64, layers=L),
                              bidirectional=True)
    return WorkItem.from_config(cfg, T=T, B=B, uid=uid, share=share)


def test_bidirectional_launch_count_matches_interleaved_formula():
    """The acceptance proof: an L-layer bidirectional prefill plans at
    most 2·L·⌈T/bt⌉ launches (the per-direction-per-chunk count) —
    strictly fewer except the nk=2 ragged boundary case, where every wave
    splits — and exactly matches ``bidir_wavefront_launches``: L·nk
    waves, one G-merged launch each, +2 unmerged waves per layer under
    ragged T."""
    from repro.dispatch.planner import bidir_wavefront_launches
    from repro.kernels.common import cdiv

    L = 3
    for T, bt in ((12, 4), (14, 4), (7, 7), (5, 2), (5, 3)):
        p = plan([_bi_item(L=L, T=T)], schedule="wavefront", block_t=bt)
        ip = p.item(0)
        nk = cdiv(T, ip.block_t)
        assert p.launches == bidir_wavefront_launches(L, T, ip.block_t), \
            (T, bt, p.describe())
        assert p.launches <= 2 * L * nk
        if not (nk == 2 and T % ip.block_t):  # the documented equality
            assert p.launches < 2 * L * nk, (T, bt)
        assert p.launches == ip.naive_launches
    # divisible stripes G-merge every wave: exactly L·nk launches
    assert plan([_bi_item(L=L, T=12)], schedule="wavefront",
                block_t=4).launches == L * 3


def test_bidirectional_interleaved_dependencies_respected():
    """Execution order must satisfy the concat dependency: a layer-l cell
    of chunk k runs only after BOTH directions of layer l-1 produced chunk
    k, and after its own walk's previous chunk (fwd: k-1, bwd: k+1)."""
    p = plan([_bi_item(L=3, T=14)], schedule="wavefront", block_t=4)
    nk = p.item(0).nk
    seen = set()
    for s in p.slots:
        for c in s.cells:
            if c.layer > 0:
                assert (c.layer - 1, c.chunk, "fwd") in seen, c
                assert (c.layer - 1, c.chunk, "bwd") in seen, c
            if c.direction == "fwd" and c.chunk > 0:
                assert (c.layer, c.chunk - 1, "fwd") in seen, c
            if c.direction == "bwd" and c.chunk < nk - 1:
                assert (c.layer, c.chunk + 1, "bwd") in seen, c
        seen.update((c.layer, c.chunk, c.direction) for c in s.cells)


def test_bidirectional_cross_b_packs_but_never_merges_directions():
    """share-equal bidirectional requests B-concat per (layer, chunk,
    direction) row — fwd and bwd halves bind different U matrices, so they
    may share a LAUNCH (two g rows) but never a row."""
    items = [_bi_item(L=2, T=8, uid=i, share=0) for i in range(2)]
    p = plan(items, schedule="wavefront", block_t=4)
    solo = plan([items[0]], schedule="wavefront", block_t=4)
    assert p.launches < 2 * solo.launches  # cross-request merge happened
    for s in p.slots:
        for grp in s.groups:
            assert len({(c.layer, c.direction) for c in grp}) == 1
    assert any(len(grp) == 2 for s in p.slots for grp in s.groups)


def test_duplicate_uids_rejected():
    items = _mix()
    with pytest.raises(ValueError):
        plan([items[0], items[0]])


def test_from_config_requires_a_recurrence():
    with pytest.raises(ValueError):
        WorkItem.from_config(get_config("starcoder2-3b"), T=8)


def test_stripe_candidates_respect_vmem_budget():
    """T-wide stripes the autotune table would reject must not sneak in
    through the planner's candidate widening."""
    from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint

    it = WorkItem(uid=0, family="lstm", B=2, T=512, H=512, L=1)
    ip = plan([it]).item(0)
    assert seq_block_footprint(ip.block_t, it.B, it.H,
                               gates=it.gates) <= SEQ_VMEM_BUDGET


def test_plan_only_items_are_flagged():
    rg = WorkItem.from_config(get_config("recurrentgemma-2b"), T=8, uid=0)
    p = plan([rg])
    assert not p.item(0).executable
    assert "[plan-only]" in p.item(0).describe()
    one = WorkItem(uid=1, family="rglru", B=1, T=8, H=64, L=1)
    assert plan([one]).item(1).executable


def test_cross_b_merge_is_scored_and_deterministic():
    """Mixed-width same-signature cells merge into padded slots only by
    perfmodel decision; the plan stays deterministic and every row's valid
    width is recorded for the in-kernel mask."""
    items = [WorkItem.from_config(lstm_config(64, layers=2), T=12, B=b,
                                  uid=i) for i, b in enumerate((2, 1))]
    p1, p2 = plan(items), plan(items)
    assert p1.describe() == p2.describe() and p1.slots == p2.slots
    for s in p1.slots:
        assert len(s.group_b) == s.g
        assert all(b <= s.B for b in s.group_b)
        assert max(s.group_b) == s.B  # padding never exceeds the widest row
    # B-widened here (small widths within one MXU row-tile): one slot per
    # wave, strictly fewer launches than the per-B-signature plan
    assert p1.launches < plan(items, cross_b=False).launches


def test_share_groups_require_matching_layers_only():
    """share-keyed items of different T still only concat where wave/layer
    align; all cells remain covered exactly once."""
    cfg = lstm_config(48, layers=3)
    items = [WorkItem.from_config(cfg, T=t, uid=i, share=0)
             for i, t in enumerate((12, 8))]
    p = plan(items)
    for ip in p.items:
        cells = [c for s in p.slots for c in s.cells if c.uid == ip.uid]
        assert len(cells) == len(set(cells)) == ip.item.L * ip.nk
    for s in p.slots:
        for grp in s.groups:
            assert len({c.layer for c in grp}) == 1  # one U per row
        for c in s.cells:
            assert c.layer + c.chunk == s.wave


def test_cross_b_concat_respects_vmem_budget():
    """Concat rows are wider than any width the per-item block_t was
    validated at — the packer must split a share group rather than emit a
    row whose working set blows the sequence kernels' VMEM bound."""
    from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint

    items = [WorkItem(uid=i, family="lstm", B=4, T=256, H=512, share=0,
                      L=1) for i in range(6)]
    p = plan(items)
    for s in p.slots:
        for b in s.group_b:
            assert seq_block_footprint(s.chunk_len, b, s.H,
                                       gates=4) <= SEQ_VMEM_BUDGET
    # ...while small shapes still concat into single rows
    small = plan([WorkItem(uid=i, family="lstm", B=1, T=8, H=32, L=2,
                           share=0) for i in range(3)])
    assert any(len(grp) == 3 for s in small.slots for grp in s.groups)


def test_stripe_alignment_respects_each_members_vmem_budget():
    """Regression: cross-B stripe alignment must not hand a large-B item a
    stripe that was only budget-valid at a small-B partner's width — every
    plan's (block_t, B) working set stays within the kernels' bound."""
    from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint

    items = [WorkItem(uid=0, family="lstm", B=1, T=512, H=512, L=2),
             WorkItem(uid=1, family="lstm", B=32, T=512, H=512, L=2)]
    p = plan(items)
    for ip in p.items:
        if ip.block_t > 1:
            assert seq_block_footprint(ip.block_t, ip.item.B, ip.item.H,
                                       gates=ip.item.gates) \
                <= SEQ_VMEM_BUDGET, ip.describe()


def test_decode_plan_is_one_chained_slot():
    items = [WorkItem(uid=i, family="gru", B=1, T=1, H=48, L=4, share=0)
             for i in range(3)]
    from repro.dispatch import plan_decode
    p = plan_decode(items)
    assert len(p.slots) == 1 and p.slots[0].chained
    assert p.launches == 1 and p.naive_launches == 3 * 4
    s = p.slots[0]
    assert s.g == 4  # one group per layer, in chain order
    assert [grp[0].layer for grp in s.groups] == [0, 1, 2, 3]
    assert s.B == 3 and set(s.group_b) == {3}
    assert "chained" in s.describe()


def test_gru_items_plan_with_three_gates():
    it = WorkItem(uid=0, family="gru", B=1, T=16, H=48, L=2)
    assert it.gates == 3
    p = plan([it, WorkItem(uid=1, family="gru", B=1, T=16, H=48, L=3)])
    assert all(s.family == "gru" for s in p.slots)
    assert p.launches < p.naive_launches
