"""Fault-injection (chaos) suite — ISSUE-6 acceptance.

Proves isolation end-to-end: with one poisoned/failed request in a packed
wave, every co-batched request completes bit-identically to the fault-free
run; the faulty request surfaces a structured ``status != "ok"``
completion; ``CompiledStack.stats`` reports the degraded/fallback
launches; and ``on_fault="raise"`` preserves fail-fast.  Run alone via
``make chaos`` (pytest marker ``chaos``)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sharp_lstm import lstm_config
from repro.core import schedules as sch
from repro.models.layers.lstm import init_lstm_stack
from repro.rnn import (ExecutionPolicy, LaunchError, NonFiniteStateError,
                       QueueFull, RequestTimeout, compile as rnn_compile)
from repro.serving import RecurrentRequest, RecurrentServingEngine

pytestmark = pytest.mark.chaos

CFG = lstm_config(32, layers=2)


def _params():
    return init_lstm_stack(jax.random.PRNGKey(0), CFG, jnp.float32)


def _xs(B=2, T=6, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((B, T, 32)), jnp.float32) * 0.5


def _engine(max_batch=3, **kw):
    return RecurrentServingEngine(CFG, _params(), max_batch=max_batch,
                                  interpret=True, **kw)


def _prompts(lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((t, 32)).astype(np.float32) * 0.5
            for t in lengths]


# ---------------------------------------------------------------------------
# guarded execution ladder (CompiledStack / executor)
# ---------------------------------------------------------------------------


def test_injected_fault_recovers_per_step_and_is_recorded():
    xs = _xs()
    healthy = rnn_compile(_params(), ExecutionPolicy(interpret=True))
    base = np.asarray(healthy.forward(xs))

    cs = rnn_compile(_params(),
                     ExecutionPolicy(interpret=True, on_fault="fallback"))
    cs.fault.arm([0])  # fused attempt of slot 0 raises; per-step recovers
    out = np.asarray(cs.forward(xs))
    np.testing.assert_allclose(base, out, atol=1e-5)
    assert cs.stats.degraded_launches == 1
    assert cs.stats.fallback_level == 1  # per_step
    assert cs.fault.fired == [(0, 0)]
    assert "fell back" in cs.stats.faults[0]
    assert "DEGRADED" in cs.describe()

    # healthy stacks report zero degradation
    assert healthy.stats.degraded_launches == 0
    assert healthy.stats.fallback_level == 0 and not healthy.stats.faults


def test_forced_reference_fallback_is_oracle_equal():
    xs = _xs()
    params = _params()
    cs = rnn_compile(params,
                     ExecutionPolicy(interpret=True, on_fault="fallback"))
    cs.fault.arm([0], through_level=1)  # fused AND per-step fail
    out = np.asarray(cs.forward(xs))
    oracle = np.asarray(sch.reference_stack(params, xs))
    np.testing.assert_allclose(out, oracle, atol=1e-4)
    assert cs.stats.fallback_level == 2  # reference rung


def test_on_fault_raise_preserves_fail_fast():
    cs = rnn_compile(_params(), ExecutionPolicy(interpret=True))
    assert cs.policy.on_fault == "raise"
    cs.fault.arm([0])
    with pytest.raises(LaunchError) as e:
        cs.forward(_xs())
    assert e.value.slot == 0 and e.value.injected
    assert e.value.level == "fused" and e.value.uids == (0,)
    assert cs.stats.degraded_launches == 0  # the call died, nothing folded
    # the injector fired once and disarmed (ft.failure_at_steps semantics):
    # the retry succeeds
    cs.forward(_xs())
    assert cs.stats.forward_calls == 1


def test_exhausted_ladder_escapes_even_under_fallback():
    cs = rnn_compile(_params(),
                     ExecutionPolicy(interpret=True, on_fault="fallback"))
    cs.fault.arm([0], through_level=2)  # every rung fails
    with pytest.raises(LaunchError, match="reference"):
        cs.forward(_xs())
    assert cs.fault.fired == [(0, 0), (0, 1), (0, 2)]


def test_decode_tick_ladder_recovers_chained_slot():
    xs = _xs(B=2, T=5)
    healthy = rnn_compile(_params(), ExecutionPolicy(interpret=True))
    cs = rnn_compile(_params(),
                     ExecutionPolicy(interpret=True, on_fault="fallback"))
    _, st_h = healthy.prefill(xs)
    _, st = cs.prefill(xs)
    y_h, st2_h = healthy.decode(xs[:, :1], st_h)
    for through in (0, 1):  # per-layer rung, then pure-jnp reference rung
        cs.fault.arm([0], through_level=through)
        y, st2 = cs.decode(xs[:, :1], st)
        np.testing.assert_allclose(np.asarray(y_h), np.asarray(y),
                                   atol=1e-5)
        np.testing.assert_allclose(np.asarray(st2_h["h"]),
                                   np.asarray(st2["h"]), atol=1e-5)
    assert cs.stats.degraded_launches == 2
    assert cs.stats.fallback_level == 2


def test_check_finite_raises_structured_error():
    cs = rnn_compile(_params(),
                     ExecutionPolicy(interpret=True, check_finite=True))
    L, B, H = 2, 2, 32
    bad = {"h": jnp.full((L, B, H), jnp.nan, jnp.float32),
           "c": jnp.zeros((L, B, H), jnp.float32)}
    with pytest.raises(NonFiniteStateError) as e:
        cs.decode(jnp.zeros((B, 1, 32), jnp.float32), bad)
    assert e.value.uids == (0,) and e.value.where == "decode tick"


# ---------------------------------------------------------------------------
# poisoned-slot quarantine (serving engine)
# ---------------------------------------------------------------------------


def _run(eng, prompts, max_new=3, **req_kw):
    for uid, p in enumerate(prompts):
        eng.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=max_new,
                                    **req_kw))
    return {c.uid: c for c in eng.run_to_completion()}


def test_prefill_launch_fault_fails_only_target_bit_identical():
    """An injected launch failure in the packed admission wave fails only
    the targeted request; the wave bisects and co-batched requests
    complete bit-identically to the fault-free run."""
    prompts = _prompts((8, 8, 6))
    clean = _run(_engine(), prompts)

    eng = _engine()
    eng.fail_prefill_of = {1}
    done = _run(eng, prompts)
    assert sorted(done) == [0, 1, 2]
    assert done[1].status == "failed"
    assert "launch fault" in done[1].error
    assert done[1].outputs.shape == (0, 32)  # prefill never finished
    assert eng.prefill_retries == 3 and eng.quarantined == 1
    for uid in (0, 2):
        assert done[uid].status == "ok" and done[uid].error is None
        np.testing.assert_array_equal(clean[uid].outputs, done[uid].outputs)
        np.testing.assert_array_equal(clean[uid].generated,
                                      done[uid].generated)


def test_prefill_fault_under_raise_mode_fails_fast():
    eng = _engine(on_fault="raise")
    eng.fail_prefill_of = {0}
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((6,))[0],
                                max_new_frames=1))
    with pytest.raises(LaunchError):
        eng.step()


def test_poisoned_prefill_state_quarantines_only_target():
    prompts = _prompts((7, 7, 5), seed=3)
    clean = _run(_engine(), prompts)

    eng = _engine()
    eng.poison_slot_at = {2: -1}  # poison uid 2's spliced prefill state
    done = _run(eng, prompts)
    assert done[2].status == "failed"
    assert "prefill state" in done[2].error
    for uid in (0, 1):
        assert done[uid].status == "ok"
        np.testing.assert_array_equal(clean[uid].outputs, done[uid].outputs)
        np.testing.assert_array_equal(clean[uid].generated,
                                      done[uid].generated)


def test_decode_poison_quarantines_mid_flight():
    """A NaN appearing in one request's recurrent state mid-decode fails
    only that request (partial frames preserved); the co-batched request
    finishes bit-identically to its fault-free run."""
    prompts = _prompts((6, 9), seed=5)
    clean = _run(_engine(max_batch=2), prompts, max_new=4)

    eng = _engine(max_batch=2)
    eng.poison_slot_at = {0: 2}  # uid 0's state goes NaN before tick 2
    done = _run(eng, prompts, max_new=4)
    assert done[0].status == "failed"
    assert "decode" in done[0].error
    assert done[0].generated.shape == (2, 32)  # ticks 0 and 1 preserved
    np.testing.assert_array_equal(clean[0].generated[:2], done[0].generated)
    assert done[1].status == "ok"
    assert done[1].generated.shape == (4, 32)
    np.testing.assert_array_equal(clean[1].outputs, done[1].outputs)
    np.testing.assert_array_equal(clean[1].generated, done[1].generated)
    assert eng.quarantined == 1


def test_submit_rejects_nonfinite_prompt():
    eng = _engine()
    bad = _prompts((5,))[0]
    bad[2, 7] = np.nan
    with pytest.raises(NonFiniteStateError) as e:
        eng.submit(RecurrentRequest(uid=42, frames=bad))
    assert e.value.uids == (42,) and e.value.where == "prompt"
    assert "42" in str(e.value)
    assert not eng.queue  # nothing admitted


# ---------------------------------------------------------------------------
# deadlines, backpressure, watchdog
# ---------------------------------------------------------------------------


def test_max_ticks_deadline_retires_with_timeout_status():
    eng = _engine(max_batch=2)
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((6,))[0],
                                max_new_frames=100, max_ticks=3))
    eng.submit(RecurrentRequest(uid=1, frames=_prompts((6,))[0],
                                max_new_frames=2))
    done = {c.uid: c for c in eng.run_to_completion()}
    assert done[0].status == "timeout"
    assert "max_ticks=3" in done[0].error
    assert done[0].generated.shape == (3, 32)  # partial work preserved
    assert done[1].status == "ok"


def test_wall_time_deadline_retires_with_timeout_status():
    eng = _engine(max_batch=1)
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((6,))[0],
                                max_new_frames=10_000, deadline_s=0.0))
    done = eng.run_to_completion()
    assert done[0].status == "timeout"
    assert "deadline" in done[0].error


def test_run_to_completion_overrun_carries_done():
    """ISSUE-6 satellite: an engine-level overrun raises RequestTimeout
    carrying the completions already finished — and the budget is per
    call, so a drained engine can be reused with a fresh budget."""
    eng = _engine(max_batch=1)
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((6,))[0],
                                max_new_frames=1))
    eng.submit(RecurrentRequest(uid=1, frames=_prompts((6,))[0],
                                max_new_frames=50))
    with pytest.raises(RequestTimeout) as e:
        eng.run_to_completion(max_ticks=5)
    assert [c.uid for c in e.value.done] == [0]  # finished work preserved
    assert e.value.uids == (1,)
    # the engine is still drainable — and the tick budget resets per call
    # (the old implementation compared a cumulative counter)
    done = eng.run_to_completion(max_ticks=60)
    assert sorted(c.uid for c in done) == [0, 1]

    eng.submit(RecurrentRequest(uid=2, frames=_prompts((6,))[0],
                                max_new_frames=50))
    done = eng.run_to_completion(max_ticks=60)  # would overrun cumulatively
    assert sorted(c.uid for c in done) == [0, 1, 2]


def test_bounded_queue_reject_backpressure():
    eng = _engine(max_batch=1, max_queue=2)
    for uid in (0, 1):
        eng.submit(RecurrentRequest(uid=uid, frames=_prompts((5,))[0],
                                    max_new_frames=1))
    with pytest.raises(QueueFull) as e:
        eng.submit(RecurrentRequest(uid=2, frames=_prompts((5,))[0],
                                    max_new_frames=1))
    assert e.value.uids == (2,)
    assert sorted(c.uid for c in eng.run_to_completion()) == [0, 1]


def test_bounded_queue_drop_oldest_backpressure():
    eng = _engine(max_batch=1, max_queue=2, backpressure="drop_oldest")
    for uid in (0, 1, 2):
        eng.submit(RecurrentRequest(uid=uid, frames=_prompts((5,))[0],
                                    max_new_frames=1))
    assert eng.dropped == 1
    done = {c.uid: c for c in eng.run_to_completion()}
    assert done[0].status == "failed"  # evicted head surfaces, never lost
    assert "evicted" in done[0].error
    assert done[1].status == "ok" and done[2].status == "ok"


def test_straggler_watchdog_observes_decode_ticks():
    eng = _engine(max_batch=2, watchdog_factor=1e6)  # never flags
    _run(eng, _prompts((6, 6)), max_new=3)
    assert eng.watchdog.ewma is not None  # ticks were observed
    assert eng.straggler_ticks == []


def test_engine_constructor_rejections_are_structured():
    from repro.rnn import PlanRejected

    params = _params()
    with pytest.raises(PlanRejected, match="rnn_family"):
        RecurrentServingEngine(CFG, params, rnn_family="tcn")
    import dataclasses as dc
    bidir = dc.replace(CFG, bidirectional=True)
    with pytest.raises(PlanRejected, match="streaming decode"):
        RecurrentServingEngine(bidir, params)
