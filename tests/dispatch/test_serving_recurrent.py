"""Recurrent serving engine: dispatcher-packed multi-request prefill ==
per-request serving, with launch accounting and edge-case guards."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.sharp_lstm import lstm_config
from repro.core import schedules as sch
from repro.models.layers.lstm import init_lstm_stack
from repro.serving import RecurrentRequest, RecurrentServingEngine

CFG = lstm_config(48, layers=3)


def _engine(max_batch=4, **kw):
    params = init_lstm_stack(jax.random.PRNGKey(0), CFG, jnp.float32)
    return params, RecurrentServingEngine(CFG, params, max_batch=max_batch,
                                          interpret=True, **kw)


def _prompts(lengths, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal((t, 48)).astype(np.float32) * 0.5
            for t in lengths]


def test_packed_prefill_matches_per_request_and_oracle():
    prompts = _prompts((12, 12, 8))
    params, eng = _engine()
    for uid, p in enumerate(prompts):
        eng.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=3))
    done = {c.uid: c for c in eng.run_to_completion()}
    assert sorted(done) == [0, 1, 2]
    assert eng.prefill_waves == 1  # one packed admission wave, not 3

    per_req_launches = 0
    for uid, p in enumerate(prompts):
        _, e1 = _engine(max_batch=1)
        e1.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=3))
        (c1,) = e1.run_to_completion()
        per_req_launches += e1.packed_launches
        np.testing.assert_allclose(done[uid].outputs, c1.outputs, atol=1e-5)
        np.testing.assert_allclose(done[uid].generated, c1.generated,
                                   atol=1e-5)
        oracle = sch.reference_stack(params, jnp.asarray(p)[None])
        np.testing.assert_allclose(done[uid].outputs,
                                   np.asarray(oracle[0]), atol=1e-4)
    # the dispatch claim in serving: packed admission launches strictly
    # fewer kernels than one-slot-at-a-time prefill
    assert eng.packed_launches < per_req_launches


def test_zero_new_frames_completes_at_prefill():
    prompts = _prompts((9,))
    _, eng = _engine(max_batch=2)
    eng.submit(RecurrentRequest(uid=0, frames=prompts[0], max_new_frames=0))
    (c,) = eng.run_to_completion()
    assert c.generated.shape == (0, 48)
    assert c.outputs.shape == (9, 48)
    assert eng.steps == 0  # never reached a decode tick


def test_empty_queue_mid_tick_is_a_noop():
    _, eng = _engine()
    eng.step()  # nothing queued, nothing active
    assert eng.steps == 0 and not eng.done
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((6,))[0],
                                max_new_frames=2))
    done = eng.run_to_completion()
    assert len(done) == 1
    eng.step()  # drained engine ticks are also no-ops
    assert len(eng.done) == 1


def test_invalid_prompts_rejected():
    _, eng = _engine()
    with pytest.raises(ValueError):
        eng.submit(RecurrentRequest(uid=0, frames=np.zeros((0, 48),
                                                           np.float32)))
    with pytest.raises(ValueError):
        eng.submit(RecurrentRequest(uid=1, frames=np.zeros((4, 7),
                                                           np.float32)))


def test_wide_input_prefill_only_requests_serve():
    """lstm_input != lstm_hidden: prefill-only requests must serve through
    whatever schedule the planner picks (regression: per_step fallback used
    to crash state collection)."""
    import dataclasses

    cfg = dataclasses.replace(lstm_config(48, layers=2), lstm_input=96)
    params = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    eng = RecurrentServingEngine(cfg, params, max_batch=2, interpret=True)
    rng = np.random.default_rng(7)
    prompts = {uid: rng.standard_normal((t, 96)).astype(np.float32)
               for uid, t in ((0, 1), (1, 5))}
    for uid, frames in prompts.items():
        eng.submit(RecurrentRequest(uid=uid, frames=frames,
                                    max_new_frames=0))
    done = {c.uid: c for c in eng.run_to_completion()}
    assert sorted(done) == [0, 1]
    for uid, frames in prompts.items():
        oracle = sch.reference_stack(params, jnp.asarray(frames)[None])
        np.testing.assert_allclose(done[uid].outputs,
                                   np.asarray(oracle[0]), atol=1e-4)


def test_duplicate_request_uids_are_served():
    """Request uids are caller-owned labels (the base engine accepts
    duplicates); the dispatcher keys plans by engine-internal ids."""
    prompts = _prompts((8, 8), seed=5)
    _, eng = _engine(max_batch=2)
    for p in prompts:
        eng.submit(RecurrentRequest(uid=7, frames=p, max_new_frames=1))
    done = eng.run_to_completion()
    assert [c.uid for c in done] == [7, 7]
    assert all(c.generated.shape == (1, 48) for c in done)


def test_per_step_launch_accounting_is_honest():
    """A per_step plan must issue exactly the L·T cell-kernel launches it
    reports (stateless path)."""
    from dataclasses import replace
    from repro.dispatch import plan as plan_fn, execute
    from repro.kernels.common import pallas_launch_count

    cfg = lstm_config(32, layers=2)
    params = {0: init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)}
    inputs = {0: jax.random.normal(jax.random.PRNGKey(1), (1, 5, 32)) * 0.5}
    from repro.dispatch import WorkItem
    p = plan_fn([WorkItem.from_config(cfg, T=5, uid=0)])
    forced = replace(p, items=tuple(replace(ip, schedule="per_step",
                                            naive_launches=2 * 5)
                                    for ip in p.items),
                     slots=(), external=(0,))
    n = pallas_launch_count(
        lambda pr, xs: execute(forced, pr, xs, interpret=True),
        params, inputs)
    assert n == forced.launches == 10
    outs = execute(forced, params, inputs, interpret=True)
    oracle = sch.reference_stack(params[0], inputs[0])
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(oracle),
                               atol=1e-4)


def test_decode_plans_k_row_cells_for_k_active_slots():
    """ISSUE-3 satellite: a tick with k active slots plans exactly k-row
    cells — empty slot columns are never computed (the old loop ran the
    full max_batch width every tick)."""
    prompts = _prompts((6, 9))
    _, eng = _engine(max_batch=4)
    for uid, p in enumerate(prompts):
        eng.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=2))
    eng.step()
    p = eng.last_decode_plan
    assert p is not None
    assert all(s.B == 2 and set(s.group_b) == {2} for s in p.slots)
    # ... and a planned tick is ONE chained launch, not L
    assert p.launches == 1 < eng.L
    done = eng.run_to_completion()
    assert sorted(c.uid for c in done) == [0, 1]


def test_decode_plan_cache_reuses_steady_state_plans():
    """Ticks with an unchanged active-slot signature reuse the cached plan;
    a changed signature (a request retiring) replans once."""
    prompts = _prompts((6, 6))
    _, eng = _engine(max_batch=2)
    eng.submit(RecurrentRequest(uid=0, frames=prompts[0], max_new_frames=5))
    eng.submit(RecurrentRequest(uid=1, frames=prompts[1], max_new_frames=2))
    eng.run_to_completion()
    # 5 ticks total: {0,1} active for 2, then {0} alone for 3 — two
    # distinct signatures, each planned exactly once
    assert eng.decode_ticks == 5
    assert eng.decode_plans_built == 2
    assert eng.decode_launches == 5  # one launch per tick
    # per-tick launches strictly below the old L-per-tick loop
    assert eng.decode_launches / eng.decode_ticks < eng.L


def test_admit_raises_clearly_when_state_unspliceable(monkeypatch):
    """If the compiled stack hands back no spliceable state (None — the
    rglru / bidirectional executor contract), admission must fail with a
    clear error, not a bare KeyError deep in the splice."""
    _, eng = _engine(max_batch=1)

    def no_state_prefill(seqs, priorities=None):
        eng.compiled._last_plan = eng.compiled.lower(1, 4)
        return [(jnp.zeros((1, xs.shape[1], 48), jnp.float32), None)
                for xs in seqs]

    monkeypatch.setattr(eng.compiled, "prefill", no_state_prefill)
    eng.submit(RecurrentRequest(uid=0, frames=_prompts((4,))[0]))
    with pytest.raises(RuntimeError, match="no spliceable"):
        eng.step()


def test_engine_has_no_direct_dispatch_calls():
    """ISSUE-4 acceptance: the engine is pure session management — every
    plan/execute goes through CompiledStack (one planned execution path
    shared with batch and single-call users)."""
    import ast
    import inspect

    import repro.serving.recurrent as rec

    src = inspect.getsource(rec)
    for name in ("plan", "plan_decode", "execute", "prepare_decode_stack"):
        assert f"{name}(" not in src.replace(f"compiled.{name}", ""), name
    tree = ast.parse(src)
    imported = {a.name for node in ast.walk(tree)
                if isinstance(node, ast.ImportFrom)
                and node.module and "dispatch" in node.module
                for a in node.names}
    assert imported <= {"DispatchPlan"}, imported  # type-only import


def test_gru_family_serves_end_to_end():
    """The engine's planned prefill + decode generalize to GRU stacks
    (rnn_family="gru"): outputs match the pure-jnp unfolded oracle and
    decode feeds back through the chained kernel."""
    from repro.core import gru

    params = gru.init_gru_stack(jax.random.PRNGKey(0), 48, 48, 3,
                                jnp.float32)
    eng = RecurrentServingEngine(CFG, params, max_batch=2, interpret=True,
                                 rnn_family="gru")
    prompts = _prompts((7, 5), seed=9)
    for uid, p in enumerate(prompts):
        eng.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=2))
    done = {c.uid: c for c in eng.run_to_completion()}
    assert sorted(done) == [0, 1]
    for uid, p in enumerate(prompts):
        y = jnp.asarray(p)[None]
        for layer in params["layers"]:
            y = gru.run_layer_unfolded(layer, y)
        np.testing.assert_allclose(done[uid].outputs, np.asarray(y[0]),
                                   atol=1e-4)
        assert done[uid].generated.shape == (2, 48)
    assert eng.decode_launches == eng.decode_ticks  # one launch per tick


def test_slots_are_reused_across_waves():
    prompts = _prompts((8, 8, 8, 8, 8), seed=3)
    _, eng = _engine(max_batch=2)
    for uid, p in enumerate(prompts):
        eng.submit(RecurrentRequest(uid=uid, frames=p, max_new_frames=2))
    done = eng.run_to_completion()
    assert sorted(c.uid for c in done) == [0, 1, 2, 3, 4]
    assert all(c.generated.shape == (2, 48) for c in done)
    assert eng.prefill_waves >= 2  # later arrivals admitted in later waves
