"""Launch-level tracing in one sitting: a traced forward + prefill + 3
feedback decode ticks, exported as chrome://tracing JSON + a metrics
snapshot + the predicted-vs-measured launch-cost table.

``ExecutionPolicy(trace=True)`` binds a live Tracer to the compiled
stack; every plan/hoist/slot_launch/decode_tick region becomes a fenced
wall-clock span tagged with its slot signature, and every measured
launch feeds the (signature -> µs) table the perfmodel's est_cycles are
checked against.  Open the trace in chrome://tracing or
https://ui.perfetto.dev.

    PYTHONPATH=src python examples/trace_demo.py   (or: make trace-demo)

Writes <out-dir>/trace.json, metrics_snapshot.json, launch_costs.json.
"""
import argparse
import json
import os

import jax
import jax.numpy as jnp

from repro import rnn
from repro.configs.sharp_lstm import lstm_config
from repro.models.layers.lstm import init_lstm_stack

H, T, L = 64, 24, 3


def main(out_dir: str = "artifacts") -> dict:
    stack = init_lstm_stack(jax.random.PRNGKey(0), lstm_config(H, layers=L),
                            jnp.float32)
    cs = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True, trace=True))
    xs = jax.random.normal(jax.random.PRNGKey(1), (1, T, H)) * 0.5

    cs.forward(xs)                      # whole-sequence span tree
    ys, state = cs.prefill(xs)          # prefill + exact t=T state
    y_t = ys[:, -1:]
    for _ in range(3):                  # serving steady state: chained ticks
        y_t, state = cs.decode(y_t, state)

    os.makedirs(out_dir, exist_ok=True)
    tr = cs.tracer
    paths = {
        "trace": tr.export_chrome_trace(os.path.join(out_dir, "trace.json")),
        "launch_costs": tr.launch_costs.save(
            os.path.join(out_dir, "launch_costs.json")),
        "snapshot": os.path.join(out_dir, "metrics_snapshot.json"),
    }
    with open(paths["snapshot"], "w") as f:
        json.dump(tr.snapshot(), f, indent=1, sort_keys=True)

    print(cs.describe())
    print()
    for k, p in sorted(paths.items()):
        print(f"wrote {k}: {p}")
    return paths


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="artifacts",
                    help="where trace.json + snapshots land")
    main(ap.parse_args().out_dir)
