"""Reproduce the paper's design-space exploration interactively.

Prints Fig. 9 (K-width), Fig. 10 (padding reconfiguration) and Table 6
(vs E-PUR) from the critical-path model for any hidden dim you pass.

    PYTHONPATH=src python examples/schedule_explorer.py --hidden 340
"""
import argparse

from repro.configs.sharp_lstm import MAC_BUDGETS, lstm_config
from repro.core import perfmodel as pm
from repro.core.tiling import K_CHOICES, TileConfig, mvm_cycles, select_tile


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hidden", type=int, default=340)
    ap.add_argument("--timesteps", type=int, default=25)
    args = ap.parse_args()
    H, T = args.hidden, args.timesteps
    cfg = lstm_config(H)

    print(f"=== K-width exploration (H={H}) — cycles per step ===")
    hdr = "macs      " + "".join(f"K={k:<8}" for k in K_CHOICES) + "best"
    print(hdr)
    for m in MAC_BUDGETS:
        row = f"{m:<10}"
        for k in K_CHOICES:
            if k > m:
                row += f"{'-':<10}"
                continue
            c = mvm_cycles(4 * H, H, TileConfig(k=k, macs=m), reconfigure=False)
            row += f"{c:<10}"
        row += f"K={select_tile(4 * H, H, m).k}"
        print(row)

    print(f"\n=== padding reconfiguration (H={H}) ===")
    pad = pm.fig10_padding_speedup(dims=[H])
    for m in MAC_BUDGETS:
        print(f"  {m:>6} MACs: {pad[(m, H)]:.3f}x")

    print(f"\n=== schedules (H={H}, T={T}) — time @each budget ===")
    for m in MAC_BUDGETS:
        times = {s: pm.network_time_s(cfg, T, pm.Design(macs=m, schedule=s)) * 1e6
                 for s in ("sequential", "batch", "intergate", "unfolded")}
        epur = pm.network_time_s(cfg, T, pm._epur(m)) * 1e6
        print(f"  {m:>6} MACs: " +
              "  ".join(f"{s}={v:8.1f}us" for s, v in times.items()) +
              f"  | epur={epur:8.1f}us -> sharp speedup "
              f"{epur / times['unfolded']:.2f}x")


if __name__ == "__main__":
    main()
