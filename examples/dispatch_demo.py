"""Tile-dispatcher demo: one DispatchPlan over a mixed batch of recurrent
workloads — three LSTM stacks with different H/L/T (repro.configs), one GRU
stack, and an RG-LRU item planned from the RecurrentGemma config — printed
slot by slot (the software analogue of watching SHARP reconfigure its tile
engine per model), then executed and verified against the pure-jnp oracle.

    PYTHONPATH=src python examples/dispatch_demo.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.sharp_lstm import lstm_config
from repro.core import gru, schedules as sch
from repro.dispatch import WorkItem, execute, plan
from repro.models.layers.lstm import init_lstm_stack

MIX = [  # different hidden width / depth / sequence length per request
    (lstm_config(64, layers=3), 24),
    (lstm_config(96, layers=2), 16),
    (lstm_config(64, layers=4), 12),
]


def main():
    items = [WorkItem.from_config(cfg, T=T, uid=i)
             for i, (cfg, T) in enumerate(MIX)]
    items.append(WorkItem(uid=3, family="gru", B=1, T=16, H=96, L=2))
    # plan-only rglru item: the dispatcher prices the recurrent core of a
    # hybrid config (latency / launch accounting feed admission control)
    items.append(WorkItem.from_config(get_config("recurrentgemma-2b"),
                                      T=32, uid=4, priority=1))

    p = plan(items)
    print(p.describe())

    params = {i: init_lstm_stack(jax.random.PRNGKey(i), cfg, jnp.float32)
              for i, (cfg, _) in enumerate(MIX)}
    params[3] = gru.init_gru_stack(jax.random.PRNGKey(3), 96, 96, 2,
                                   jnp.float32)
    inputs = {i: jax.random.normal(jax.random.PRNGKey(100 + i),
                                   (1, T, cfg.lstm_hidden)) * 0.5
              for i, (cfg, T) in enumerate(MIX)}
    inputs[3] = jax.random.normal(jax.random.PRNGKey(103), (1, 16, 96)) * 0.5

    runnable = [ip.item for ip in p.items if ip.executable]
    exec_plan = plan(runnable)
    outs = execute(exec_plan, params, inputs, interpret=True)

    print()
    for i, (cfg, T) in enumerate(MIX):
        oracle = sch.reference_stack(params[i], inputs[i])
        err = float(jnp.max(jnp.abs(outs[i] - oracle)))
        print(f"item {i}: {outs[i].shape}  max|err| vs oracle = {err:.2e}")
        assert err < 1e-4
    y = inputs[3]
    for layer in params[3]["layers"]:
        y = gru.run_layer_unfolded(layer, y)
    err = float(jnp.max(jnp.abs(outs[3] - y)))
    print(f"item 3: {outs[3].shape}  max|err| vs oracle = {err:.2e} (gru)")
    assert err < 1e-4
    print(f"\npacked launches: {exec_plan.launches}  "
          f"(per-item naive: {exec_plan.naive_launches})")


if __name__ == "__main__":
    main()
