"""End-to-end training driver example.

Trains an xLSTM LM (the assignment's recurrent arch — SHARP's first-class
target) on the synthetic Markov stream, with checkpointing and a mid-run
injected fault to demonstrate recovery.  Defaults are CI-sized; pass
--full for a ~140M-parameter run (the real xlstm-125m config) for a few
hundred steps.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""
import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="train the full xlstm-125m (~140M params)")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--arch", default="xlstm-125m")
    args = ap.parse_args()

    argv = ["--arch", args.arch, "--steps", str(args.steps),
            "--ckpt-every", "10", "--fail-at", str(args.steps // 2),
            "--ckpt-dir", "/tmp/repro_example_ckpt"]
    if args.full:
        argv += ["--batch", "8", "--seq", "256", "--microbatches", "2"]
    else:
        argv += ["--reduced", "--batch", "8", "--seq", "64"]
    loop = train_main(argv)
    print(f"\ndone: {len(loop.metrics_history)} steps, "
          f"{loop.restarts} restart(s) survived")


if __name__ == "__main__":
    main()
