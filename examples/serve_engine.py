"""Batched serving example: continuous batching over mixed-length requests
on the hybrid RecurrentGemma architecture (RG-LRU state + local-attention
ring caches exercised together).

    PYTHONPATH=src python examples/serve_engine.py
"""
from repro.launch.serve import main as serve_main


def main():
    serve_main(["--arch", "recurrentgemma-2b", "--reduced",
                "--requests", "10", "--max-batch", "4", "--max-seq", "96",
                "--max-new", "12"])


if __name__ == "__main__":
    main()
