"""Quickstart: SHARP's LSTM schedules on the paper's own model family.

Runs the GMAT-like LSTM layer under every schedule, verifies they are
numerically identical (the paper's premise), times them on CPU, and shows
the critical-path model's predicted ordering next to the measurement.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.sharp_lstm import lstm_config
from repro.core import perfmodel as pm
from repro.core import schedules as sch
from repro.kernels.lstm_cell.ops import as_cell_kernel
from repro.models.layers.lstm import init_lstm_layer


def main():
    H, T, B = 512, 25, 1
    key = jax.random.PRNGKey(0)
    params = init_lstm_layer(key, H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, T, H)) * 0.5

    print(f"LSTM layer H={H}, T={T}, batch={B} (inference)\n")
    ref = None
    print(f"{'schedule':<12} {'cpu_ms':>8} {'model_speedup@64K':>18}")
    for s in sch.SCHEDULES:
        fn = jax.jit(lambda p, x, s=s: sch.LAYER_FNS[s](p, x))
        out = jax.block_until_ready(fn(params, xs))
        if ref is None:
            ref = out
        assert jnp.allclose(out, ref, atol=1e-5), f"{s} diverged!"
        t0 = time.perf_counter()
        for _ in range(5):
            jax.block_until_ready(fn(params, xs))
        ms = (time.perf_counter() - t0) / 5 * 1e3
        model = pm.fig11_schedule_speedups(dims=[H], budgets=[65536])
        pred = model.get((65536, H, s))  # fused is a TPU path, not a paper
        pred_s = f"{pred:18.3f}" if pred is not None else f"{'-':>18}"
        print(f"{s:<12} {ms:8.2f} {pred_s}")

    # the fused Pallas cell drops into the unfolded scan
    out = sch.run_layer_unfolded(params, xs,
                                 cell_kernel=as_cell_kernel(interpret=True))
    assert jnp.allclose(out, ref, atol=1e-4)
    print("\nunfolded + Pallas lstm_cell kernel (interpret): matches reference ✓")

    # the unified front-end: the same layer through the planned path
    from repro import rnn

    cs = rnn.compile({"layers": [params]}, rnn.ExecutionPolicy())
    assert jnp.allclose(cs.forward(xs), ref, atol=1e-4)
    print(f"repro.rnn.compile(...).forward: matches reference ✓ "
          f"({cs.plan.launches} planned launches — "
          "see examples/rnn_api_demo.py)")

    # the paper's own bidirectional EESEN stack (Table 5), end to end
    # through the planned path: every layer's fwd and bwd walks interleave
    # into ONE wavefront timeline (each wave a single G-batched launch
    # merging both directions) — the per-layer bidirectional fallback is
    # retired, so this IS the execution the dispatcher plans
    from repro.configs.sharp_lstm import eesen_demo
    from repro.core.schedules import reference_stack

    eesen = eesen_demo()
    T_bi = 8
    cs_bi = rnn.compile(eesen, rnn.ExecutionPolicy(interpret=True))
    xs_bi = jax.random.normal(jax.random.PRNGKey(2),
                              (1, T_bi, eesen.lstm_input)) * 0.5
    ys_bi = cs_bi.forward(xs_bi)
    assert ys_bi.shape == (1, T_bi, 2 * eesen.lstm_hidden)
    assert jnp.array_equal(ys_bi,
                           reference_stack(cs_bi.params, xs_bi, "fused"))
    print(f"\nEESEN (bidirectional, H={eesen.lstm_hidden}, "
          f"L={eesen.n_layers}) through the interleaved wavefront: "
          f"{cs_bi.plan.launches} launches "
          f"(retired per-layer fallback: {2 * eesen.n_layers}), "
          "bit-identical to the per-layer fused reference ✓")
    print(cs_bi.plan.describe())

    d = pm.Design(macs=65536)
    cfg = lstm_config(H)
    print(f"\ncritical-path model @64K MACs: "
          f"{pm.network_time_s(cfg, T, d) * 1e6:.1f} us/sequence, "
          f"utilization {pm.utilization(cfg, T, d):.0%}")


if __name__ == "__main__":
    main()
