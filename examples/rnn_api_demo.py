"""The unified recurrent front-end in one sitting: compile -> forward ->
prefill -> decode, with the underlying DispatchPlan printed at every step.

Shows the ISSUE-4 headline: a heterogeneous lstm -> gru -> lstm stack runs
through ONE planned execution path — the planner wavefronts its
(layer, time-chunk) cells across families (same-family cells of a wave
merge into one G-batched launch), prefill leaves exact (h, c) state
behind, and decode resumes from it.  A homogeneous stack's decode tick is
a single chained kernel launch — the serving steady state.

    PYTHONPATH=src python examples/rnn_api_demo.py   (or: make api-demo)
"""
import jax
import jax.numpy as jnp

from repro import rnn
from repro.configs.sharp_lstm import lstm_config
from repro.core import gru, schedules as sch
from repro.models.layers.lstm import init_lstm_layer, init_lstm_stack

H, T = 48, 12


def main():
    pol = rnn.ExecutionPolicy(schedule="wavefront", block_t=4,
                              interpret=True)
    print(f"policy: {pol.describe()}\n")

    # -- a heterogeneous stack: lstm -> gru -> lstm ------------------------
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    mixed = {"layers": [init_lstm_layer(k1, H, H, jnp.float32),
                        gru.init_gru_layer(k2, H, H, jnp.float32),
                        init_lstm_layer(k3, H, H, jnp.float32)]}
    cs = rnn.compile(mixed, pol)
    print(f"compiled mixed stack: families={cs.families}")

    xs = jax.random.normal(jax.random.PRNGKey(1), (2, T, H)) * 0.5
    ys, state = cs.prefill(xs)
    err = float(jnp.max(jnp.abs(ys - sch.reference_stack(mixed, xs))))
    cells = cs.plan.item(0).item.L * cs.plan.item(0).nk
    print(f"prefill: out {ys.shape}, state h{tuple(state['h'].shape)} "
          f"c{tuple(state['c'].shape)}, max|err| vs oracle = {err:.1e}")
    print(f"cross-family wavefront: {cs.plan.launches} launches for "
          f"{cells} (layer, chunk) cells\n")
    print(cs.plan.describe())

    y_t, state = cs.decode(ys[:, -1], state)
    print(f"\ndecode (mixed: per-layer T=1 fallback): "
          f"{cs.last_decode_plan.launches} launches/tick")

    # -- a homogeneous stack: chained decode, one launch per tick ----------
    stack = init_lstm_stack(jax.random.PRNGKey(2), lstm_config(H, layers=3),
                            jnp.float32)
    ch = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    ys, state = ch.prefill(xs)
    y_t = ys[:, -1:]
    for _ in range(3):
        y_t, state = ch.decode(y_t, state)  # feedback: frame t -> input t+1
    print(f"\nhomogeneous lstm stack: decode = "
          f"{ch.last_decode_plan.launches} chained launch/tick "
          f"({ch.stats.decode_plans_built} decode plan built for "
          f"{ch.stats.decode_calls} ticks — cached)")
    print(f"\n{ch.describe().splitlines()[0]}")
    print(ch.describe().splitlines()[2])

    print("\nmigration: run_stack(stack, xs, 'wavefront', block_t=4)  ->  "
          "rnn.compile(stack, ExecutionPolicy(schedule='wavefront', "
          "block_t=4)).forward(xs)")


if __name__ == "__main__":
    main()
