"""Dispatch suite: packed-plan vs per-request launch counts + oracle latency.

A mixed batch of three LSTM configs (different H/L/T, all from
repro.configs.sharp_lstm) goes through the tile dispatcher as one
DispatchPlan; the baseline runs each request alone through its own
per-request wavefront plan (the shape the retired ``run_stack_wavefront``
used).  Rows record the structural launch counts (pallas_launch_count —
the dispatch claim) and the CPU-oracle wall time; outputs are verified
equal against the pure-jnp unfolded oracle before anything is emitted.

The decode sub-suite records the serving steady state: a planned tick (ONE
chained launch over the k active slots' layer chains, cross-B packed) vs
the pre-existing hand loop (L per-layer launches at the SAME k active rows
— retired pool columns are skipped, so the comparison prices launch
structure, not stale-column compute) — verified bit-equal before emission.  The cross-B sub-suite records a
mixed-B prefill mix packed (pad + in-kernel mask) vs the per-B-signature
plan of the same items.  The facade sub-suite (ISSUE-4) proves
``repro.rnn.compile().forward()`` adds ZERO launches over direct
dispatch.plan/execute on the same WorkItem — the front-end is the same
pipeline, not a wrapper with overhead.  The bidir sub-suite (ISSUE-5)
records a bidirectional admission wave through the interleaved fwd/bwd
wavefront vs the retired per-layer fused fallback (per request, per layer,
per direction — no packing), bit-equal gated.

The cost-model sub-suite (ISSUE-9) proves the measured cost model flips a
real planner decision: after an in-process calibration of both competing
plans' launch signatures, ``cost_model="measured"`` schedules the
canonical forward fused where ``"analytic"`` picks the G-merged
wavefront — bit-equal gated, and the flipped plan must win the wall
clock before its row is emitted.

The quant sub-suite (ISSUE-10) prices the int8 weight path at a matched
shape and records the VMEM headroom it buys: at the stripe-bound
H512/B8/T64 shape the fp32 resident U caps the time block at half of what
the int8 payload sustains (asserted >= 2x), and the int8 forward is gated
against its dequantized oracle within the documented rel-err bound.

The verify sub-suite (ISSUE-8) prices static plan verification:
``verify="plan"`` (the default) vs ``verify="off"`` on the steady-state
forward — bit-identity gated, smoke-checked < 5% — plus the one-time
plancheck proof cost itself on a plan-cache miss.

Rows report the MEDIAN of ``--repeats`` timed calls (after one warm-up);
raise ``--repeats`` for stabler medians.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import rnn
from repro.configs.sharp_lstm import lstm_config
from repro.core import schedules as sch
from repro.dispatch import (WorkItem, execute, plan, plan_decode,
                            prepare_decode_stack)
from repro.kernels.common import pallas_launch_count
from repro.kernels.lstm_cell.ops import lstm_seq
from repro.models.layers.lstm import init_lstm_stack
from repro.runtime.obs import measure_us

MIX = [  # (config, T): different H / L / T — the adaptability scenario
    (lstm_config(64, layers=3), 24),
    (lstm_config(96, layers=2), 16),
    (lstm_config(64, layers=4), 12),
]


def _time(fn: Callable, *args, repeat: int = 3) -> float:
    """One measurement discipline for the whole suite: the shared
    runtime timer (1 warm-up call excluded, every repeat fenced with
    block_until_ready, median reported) — the same code path traced
    span latencies come from, so bench rows and tracer histograms are
    directly comparable numbers."""
    return measure_us(fn, *args, repeats=repeat, warmup=1, reduce="median")


def dispatch(emit, repeats: int = 3) -> None:
    items = [WorkItem.from_config(cfg, T=T, uid=i)
             for i, (cfg, T) in enumerate(MIX)]
    params = {i: init_lstm_stack(jax.random.PRNGKey(i), cfg, jnp.float32)
              for i, (cfg, _) in enumerate(MIX)}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(100 + i),
                                   (1, T, cfg.lstm_hidden)) * 0.5
              for i, (cfg, T) in enumerate(MIX)}

    p = plan(items)
    solo = {i: plan([items[i]], schedule="wavefront",
                    block_t=min(items[i].T, 16)) for i in inputs}

    def packed(pr, xs):
        return execute(p, pr, xs, interpret=True)

    def per_request(pr, xs):
        return {i: execute(solo[i], {i: pr[i]}, {i: xs[i]},
                           interpret=True)[i] for i in xs}

    # -- correctness gate: packed == per-request == pure-jnp oracle -------
    outs = packed(params, inputs)
    naive = per_request(params, inputs)
    max_err = 0.0
    for i in inputs:
        oracle = sch.reference_stack(params[i], inputs[i])
        for got in (outs[i], naive[i]):
            err = float(jnp.max(jnp.abs(got - oracle)))
            max_err = max(max_err, err)
            assert err < 1e-4, (i, err)

    n_packed = pallas_launch_count(packed, params, inputs)
    n_naive = pallas_launch_count(per_request, params, inputs)
    assert n_packed < n_naive, (n_packed, n_naive)

    shapes = "+".join(f"H{c.lstm_hidden}L{c.n_layers}T{t}" for c, t in MIX)
    emit("dispatch/packed_prefill",
         _time(packed, params, inputs, repeat=repeats),
         f"{shapes} launches={n_packed} slots={len(p.slots)} "
         f"max_err={max_err:.1e}")
    emit("dispatch/per_request_wavefront",
         _time(per_request, params, inputs, repeat=repeats),
         f"{shapes} launches={n_naive}")
    emit("dispatch/oracle_unfolded",
         _time(lambda pr, xs: {i: sch.reference_stack(pr[i], xs[i])
                               for i in xs}, params, inputs,
               repeat=repeats), shapes)
    emit("dispatch/plan", 0.0,
         f"items={len(items)} launches={p.launches} "
         f"naive={p.naive_launches} est={p.est_cycles:.0f}cy")

    _decode_rows(emit, repeats)
    _cross_b_rows(emit, repeats)
    _facade_rows(emit, repeats)
    _bidir_rows(emit, repeats)
    _fault_rows(emit, repeats)
    _obs_rows(emit, repeats)
    _verify_rows(emit, repeats)
    _cost_model_rows(emit, repeats)
    _quant_rows(emit, repeats)


def _decode_rows(emit, repeats: int = 3) -> None:
    """Steady-state serving decode: planned (one chained launch over the k
    active slots) vs the per-layer loop at the same k active rows.  The
    loop used to pad to the full max_batch pool and compute its stale
    columns too — an unfair baseline that inflated the planned tick's
    win; it now skips retired rows, so the rows differ only in launch
    structure (1 chained vs L per-layer)."""
    H, L, k, max_batch = 64, 3, 3, 4
    cfg = lstm_config(H, layers=L)
    params = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    rng = np.random.default_rng(3)
    y = jnp.asarray(rng.standard_normal((k, 1, H)) * 0.5, jnp.float32)
    h = jnp.asarray(rng.standard_normal((L, k, H)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((L, k, H)) * 0.3, jnp.float32)

    items = [WorkItem(uid=i, family="lstm", B=1, T=1, H=H, L=L, share=0)
             for i in range(k)]
    p = plan_decode(items)
    prep = prepare_decode_stack(params, "lstm")  # once, like the engine

    def planned(y, h, c):
        inputs = {i: y[i:i + 1] for i in range(k)}
        init = {i: {"h": h[:, i:i + 1], "c": c[:, i:i + 1]}
                for i in range(k)}
        return execute(p, {i: params for i in range(k)}, inputs,
                       interpret=True, collect_state=True, init_state=init,
                       prepared={i: prep for i in range(k)})

    def loop(y, h, c):
        """The replaced _decode_tick, made fair: L per-layer launches at
        the k ACTIVE rows only (retired pool columns skipped, not padded
        in and computed stale)."""
        h_new, c_new = [], []
        yp = y
        for l, layer in enumerate(params["layers"]):
            xw = (jnp.einsum("btx,xg->btg", yp, layer["W"])
                  + layer["b"]).reshape(k, 1, 4, H)
            hs, h_n, c_n = lstm_seq(layer["U"].reshape(H, 4, H), xw, h[l],
                                    c[l], block_t=1, interpret=True)
            h_new.append(h_n)
            c_new.append(c_n)
            yp = hs.astype(jnp.float32)
        return yp, jnp.stack(h_new), jnp.stack(c_new)

    # -- correctness gate: planned tick == hand loop, bit-for-bit ---------
    outs, states = planned(y, h, c)
    y_ref, h_ref, c_ref = loop(y, h, c)
    for i in range(k):
        np.testing.assert_array_equal(np.asarray(outs[i][:, 0]),
                                      np.asarray(y_ref[i]))
        np.testing.assert_array_equal(np.asarray(states[i]["h"][:, 0]),
                                      np.asarray(h_ref[:, i]))
        np.testing.assert_array_equal(np.asarray(states[i]["c"][:, 0]),
                                      np.asarray(c_ref[:, i]))

    n_planned = pallas_launch_count(planned, y, h, c)
    n_loop = pallas_launch_count(loop, y, h, c)
    assert n_planned == p.launches == 1 < n_loop == L

    emit("dispatch/decode_planned_tick",
         _time(planned, y, h, c, repeat=repeats),
         f"H{H}L{L} active={k}/{max_batch} launches_per_tick={n_planned} "
         f"rows={sum(it.B for it in items)} chained")
    emit("dispatch/decode_loop_tick",
         _time(loop, y, h, c, repeat=repeats),
         f"H{H}L{L} launches_per_tick={n_loop} rows={k} "
         "(retired rows skipped)")


def _cross_b_rows(emit, repeats: int = 3) -> None:
    """Cross-B packed prefill (pad + in-kernel mask) vs the equal-signature
    unpacked (per-B-signature) plan of the same mixed-B items."""
    H, L, T = 64, 3, 12
    cfg = lstm_config(H, layers=L)
    items = [WorkItem.from_config(cfg, T=T, B=b, uid=i)
             for i, b in enumerate((2, 1, 1))]
    packed, unpacked = plan(items), plan(items, cross_b=False)
    assert packed.launches < unpacked.launches

    params = {i: init_lstm_stack(jax.random.PRNGKey(i), cfg, jnp.float32)
              for i in range(len(items))}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(50 + i),
                                   (it.B, T, H)) * 0.5
              for i, it in enumerate(items)}

    def run_packed(pr, xs):
        return execute(packed, pr, xs, interpret=True)

    def run_unpacked(pr, xs):
        return execute(unpacked, pr, xs, interpret=True)

    outs_p, outs_u = run_packed(params, inputs), run_unpacked(params, inputs)
    for i in inputs:
        np.testing.assert_array_equal(np.asarray(outs_p[i]),
                                      np.asarray(outs_u[i]))

    n_p = pallas_launch_count(run_packed, params, inputs)
    n_u = pallas_launch_count(run_unpacked, params, inputs)
    assert n_p == packed.launches < n_u == unpacked.launches

    shapes = "+".join(f"B{it.B}" for it in items) + f" H{H}L{L}T{T}"
    emit("dispatch/cross_b_packed_prefill",
         _time(run_packed, params, inputs, repeat=repeats),
         f"{shapes} launches={n_p} slots={len(packed.slots)}")
    emit("dispatch/cross_b_unpacked_prefill",
         _time(run_unpacked, params, inputs, repeat=repeats),
         f"{shapes} launches={n_u} slots={len(unpacked.slots)}")


def _facade_rows(emit, repeats: int = 3) -> None:
    """ISSUE-4 parity guard: the rnn facade is the SAME plan/execute
    pipeline — ``compile().forward()`` launches exactly the kernels of a
    direct dispatch.plan/execute of the same WorkItem (zero facade
    overhead), with plan caching amortizing the planner across calls."""
    cfg, T = lstm_config(64, layers=3), 24
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(100), (1, T, 64)) * 0.5

    direct_plan = plan([WorkItem.from_config(cfg, T=T, uid=0)])

    def direct(pr, x):
        return execute(direct_plan, {0: pr}, {0: x}, interpret=True)[0]

    pol = rnn.ExecutionPolicy(interpret=True)
    cs = rnn.compile(stack, pol)

    def facade(pr, x):
        return cs.forward(x)

    # -- parity gate: identical outputs, identical launch count ----------
    np.testing.assert_array_equal(np.asarray(facade(stack, xs)),
                                  np.asarray(direct(stack, xs)))
    n_direct = pallas_launch_count(direct, stack, xs)
    n_facade = pallas_launch_count(
        lambda pr, x: rnn.CompiledStack(pr, pol).forward(x), stack, xs)
    assert n_facade == n_direct == direct_plan.launches, \
        (n_facade, n_direct, direct_plan.launches)

    shapes = f"H{cfg.lstm_hidden}L{cfg.n_layers}T{T}"
    emit("dispatch/facade_forward",
         _time(facade, stack, xs, repeat=repeats),
         f"{shapes} launches={n_facade} (== direct; plan cached)")
    emit("dispatch/facade_direct_plan_execute",
         _time(direct, stack, xs, repeat=repeats),
         f"{shapes} launches={n_direct}")


def _bidir_rows(emit, repeats: int = 3) -> None:
    """ISSUE-5: a bidirectional admission wave (3 share-equal EESEN-style
    BiLSTM requests) through the interleaved fwd/bwd wavefront — cells of
    all requests and both directions packed into one slot timeline — vs
    the retired per-layer fused fallback, which launched every (request,
    layer, direction) alone.  Bit-equal gated before emission; the
    structural launch counts are the before/after of retiring the
    fallback."""
    import dataclasses

    H, L, T, bt, n_req = 64, 3, 12, 4, 3
    cfg = dataclasses.replace(lstm_config(H, layers=L), bidirectional=True)
    items = [WorkItem.from_config(cfg, T=T, uid=i, share=0)
             for i in range(n_req)]
    params = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    inputs = {i: jax.random.normal(jax.random.PRNGKey(200 + i),
                                   (1, T, H)) * 0.5 for i in range(n_req)}

    p = plan(items, schedule="wavefront", block_t=bt)

    def interleaved(pr, xs):
        return execute(p, {i: pr for i in xs}, xs, interpret=True)

    def fallback(pr, xs):
        """The retired path: per-layer fused launches, each direction of
        each layer of each request on its own (reference_stack 'fused' is
        exactly the code the old per_layer fallback ran)."""
        return {i: sch.reference_stack(pr, xs[i], "fused") for i in xs}

    # -- correctness gate: interleaved == retired fallback, bit-for-bit ---
    outs = interleaved(params, inputs)
    ref = fallback(params, inputs)
    for i in inputs:
        np.testing.assert_array_equal(np.asarray(outs[i]),
                                      np.asarray(ref[i]))

    n_packed = pallas_launch_count(interleaved, params, inputs)
    n_fallback = pallas_launch_count(fallback, params, inputs)
    nk = -(-T // bt)
    assert n_packed == p.launches == L * nk   # divisible T: full G-merge
    assert n_fallback == n_req * 2 * L
    assert n_packed < n_fallback
    assert n_packed < 2 * L * nk              # the acceptance bound

    shapes = f"B1x{n_req} H{H}L{L}T{T}bt{bt} bidirectional"
    emit("dispatch/bidir_interleaved_prefill",
         _time(interleaved, params, inputs, repeat=repeats),
         f"{shapes} launches={n_packed} slots={len(p.slots)} "
         f"waves=L*nk={L * nk}")
    emit("dispatch/bidir_per_layer_fallback",
         _time(fallback, params, inputs, repeat=repeats),
         f"{shapes} launches={n_fallback} (retired: 2 per layer per "
         "request)")


def _fault_rows(emit, repeats: int = 3) -> None:
    """ISSUE-6: the guarded execution ladder, priced.  The same forward
    under (a) the healthy fused path, (b) every slot's fused launch
    failing -> per-step re-execution, (c) fused AND per-step failing ->
    pure-jnp reference — the degraded serving modes a faulty device would
    run in.  Recovery is oracle-equal gated (against the healthy outputs)
    and the degradation counters are asserted before anything is
    emitted."""
    cfg, T = lstm_config(64, layers=3), 24
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(300), (1, T, 64)) * 0.5

    pol = rnn.ExecutionPolicy(interpret=True, on_fault="fallback")
    cs = rnn.compile(stack, pol)
    base = np.asarray(cs.forward(xs))
    n_slots = len(cs.plan.slots)

    def degraded(through_level):
        d = rnn.compile(stack, pol)
        # once=False: EVERY call's launches fail through the level, so the
        # timed repeats all run degraded (the soak shape, not one blip)
        d.fault.arm(range(n_slots), through_level=through_level, once=False)
        return d

    per_step, reference = degraded(0), degraded(1)
    for d in (per_step, reference):
        np.testing.assert_allclose(np.asarray(d.forward(xs)), base,
                                   atol=1e-5)
    assert per_step.stats.fallback_level == 1
    assert reference.stats.fallback_level == 2
    assert per_step.stats.degraded_launches == n_slots

    shapes = f"H{cfg.lstm_hidden}L{cfg.n_layers}T{T}"
    emit("dispatch/fault_healthy_forward",
         _time(cs.forward, xs, repeat=repeats),
         f"{shapes} slots={n_slots} fallback=fused (ladder level 0)")
    emit("dispatch/fault_per_step_fallback",
         _time(per_step.forward, xs, repeat=repeats),
         f"{shapes} slots={n_slots} fallback=per_step "
         f"degraded={n_slots}/call")
    emit("dispatch/fault_reference_fallback",
         _time(reference.forward, xs, repeat=repeats),
         f"{shapes} slots={n_slots} fallback=reference "
         f"degraded={n_slots}/call")


def _cost_model_rows(emit, repeats: int = 3) -> None:
    """ISSUE-9: the measured cost model, proved against the clock.  The
    suite's canonical forward (H64 L3 T24 B1) is planned both ways after
    an in-process calibration: ``repro.calib`` replays the launch
    signatures of BOTH competing plans — the fused per-layer slots and
    the wavefront's stripes including its G2-merged middle slots —
    through the shared obs clock into a throwaway table.  The analytic
    perfmodel picks the wavefront (its G-merge term assumes MXU rows run
    merged cells in parallel, so merging is nearly free); the measured
    table knows that under the interpreter a G2 launch costs ~2x a G1
    launch — the merge does NOT pay — and flips the schedule to fused,
    which wall-clocks ~2x faster.  Bit-equal gated, and both the flip
    and the wall-clock win are asserted before emission (the smoke test
    re-asserts them from the recorded rows)."""
    import os
    import tempfile

    from repro.calib import Candidate, calibrate

    H, L, T, B = 64, 3, 24, 1
    cfg = lstm_config(H, layers=L)
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(600), (B, T, H)) * 0.5

    # every signature either competing plan would launch, so the measured
    # scorer resolves each candidate by exact hit (no interpolation)
    cands = [Candidate(family="lstm", H=H, G=1, B=B, block_t=T),
             Candidate(family="lstm", H=H, G=1, B=B, block_t=T // 2),
             Candidate(family="lstm", H=H, G=2, B=B, block_t=T // 2),
             Candidate(family="lstm", H=H, G=1, B=B, block_t=1)]
    table = calibrate(cands, interpret=True, repeats=max(repeats, 3),
                      warmup=1)

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "measured_costs.json")
        table.save(path)
        analytic = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
        measured = rnn.compile(stack, rnn.ExecutionPolicy(
            interpret=True, cost_model="measured", cost_table=path))

        p_a, p_m = analytic.lower(B, T), measured.lower(B, T)
        sched_a, bt_a = p_a.items[0].schedule, p_a.items[0].block_t
        sched_m, bt_m = p_m.items[0].schedule, p_m.items[0].block_t
        assert (sched_a, bt_a) == ("wavefront", T // 2), (sched_a, bt_a)
        assert (sched_m, bt_m) == ("fused", T), (sched_m, bt_m)  # the flip
        assert p_m.launches < p_a.launches
        assert measured.stats.measured_hits > 0
        assert measured.stats.analytic_fallbacks == 0  # all exact hits

        # -- identity gate: the flipped plan computes the same forward ----
        np.testing.assert_array_equal(np.asarray(analytic.forward(xs)),
                                      np.asarray(measured.forward(xs)))

        t_a = _time(analytic.forward, xs, repeat=max(repeats, 5))
        t_m = _time(measured.forward, xs, repeat=max(repeats, 5))
        assert t_m <= t_a, (t_m, t_a)              # ...and won the clock

        shapes = f"H{H}L{L}T{T}B{B}"
        emit("dispatch/costmodel_analytic_forward", t_a,
             f"{shapes} schedule={sched_a} bt={bt_a} launches={p_a.launches}"
             " (analytic: the G-merge term prices merged cells as "
             "parallel)")
        emit("dispatch/costmodel_measured_forward", t_m,
             f"{shapes} schedule={sched_m} bt={bt_m} launches={p_m.launches}"
             f" (measured table flipped wavefront->fused; "
             f"hits={measured.stats.measured_hits} fallbacks=0)")


def _overhead(fn_off, fn_on, pairs: int = 11, trials: int = 3):
    """Traced-vs-untraced cost under machine noise: sequential A/B medians
    drift apart with background load, so each sample is an adjacent
    (off, on) PAIR (order alternating) through the shared timer, the
    trial's estimate is the median of the pairwise ratios (drift hits
    both halves of a pair equally), and the reported overhead is the best
    of ``trials`` — noise inflates a ratio far more easily than it
    deflates one, so the minimum is the tightest honest upper bound.
    Returns (off_us, on_us, ratio) from the best trial."""
    best = None
    for _ in range(max(1, trials)):
        offs, ons, ratios = [], [], []
        for i in range(pairs):
            if i % 2 == 0:
                a = measure_us(fn_off, repeats=1, warmup=0)
                b = measure_us(fn_on, repeats=1, warmup=0)
            else:
                b = measure_us(fn_on, repeats=1, warmup=0)
                a = measure_us(fn_off, repeats=1, warmup=0)
            offs.append(a)
            ons.append(b)
            ratios.append(b / a)
        est = (float(np.median(offs)), float(np.median(ons)),
               float(np.median(ratios)))
        if best is None or est[2] < best[2]:
            best = est
    return best


def _obs_rows(emit, repeats: int = 3) -> None:
    """ISSUE-7: the observability layer, priced.  The same compiled
    forward and chained decode tick with tracing OFF (the default
    shared no-op tracer) vs ON (spans, fenced launches, metrics,
    launch-cost table) — bit-identity gated first, because tracing must
    never alter numerics.  B=8 so kernel compute dominates and the
    per-slot fence's lost host/device overlap is a small fraction; the
    smoke test asserts the pairwise overhead estimate stays < 5%."""
    del repeats  # pair count is fixed by the estimator, not --repeats
    cfg, T, B = lstm_config(64, layers=3), 24, 8
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(400), (B, T, 64)) * 0.5

    off = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    on = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True, trace=True))

    # -- identity gate: tracing must be observation only, bit-for-bit -----
    np.testing.assert_array_equal(np.asarray(off.forward(xs)),
                                  np.asarray(on.forward(xs)))

    shapes = f"H{cfg.lstm_hidden}L{cfg.n_layers}T{T}B{B}"
    t_off, t_on, r = _overhead(lambda: off.forward(xs),
                               lambda: on.forward(xs))
    emit("dispatch/obs_untraced_forward", t_off,
         f"{shapes} trace=off (shared no-op tracer)")
    emit("dispatch/obs_traced_forward", t_on,
         f"{shapes} trace=on overhead={(r - 1) * 100:+.1f}% "
         "(pairwise median, best of 3 trials)")

    # decode tick from a FIXED prefilled state (pure tick timing, no
    # state feedback between repeats)
    _, st_off = off.prefill(xs)
    _, st_on = on.prefill(xs)
    x_t = xs[:, -1:]
    np.testing.assert_array_equal(
        np.asarray(off.decode(x_t, st_off)[0]),
        np.asarray(on.decode(x_t, st_on)[0]))
    t_off, t_on, r = _overhead(lambda: off.decode(x_t, st_off),
                               lambda: on.decode(x_t, st_on))
    emit("dispatch/obs_untraced_decode_tick", t_off,
         f"{shapes} trace=off chained")
    emit("dispatch/obs_traced_decode_tick", t_on,
         f"{shapes} trace=on chained overhead={(r - 1) * 100:+.1f}% "
         "(pairwise median, best of 3 trials)")


def _verify_rows(emit, repeats: int = 3) -> None:
    """ISSUE-8: static plan verification, priced.  The same compiled
    forward with ``verify="off"`` vs ``verify="plan"`` (the default) —
    bit-identity gated first, because a verifier must be observation
    only.  Verification runs once per plan-cache miss, so the steady
    state pays ~nothing (the smoke test asserts the pairwise estimate
    stays < 5%); the ``verify_plancheck`` row prices the one-time
    cache-miss cost itself — the full 13-rule proof over the mixed-batch
    plan of the suite's main scenario."""
    from repro.analysis.plancheck import check_plan

    cfg, T, B = lstm_config(64, layers=3), 24, 8
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(500), (B, T, 64)) * 0.5

    off = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                 verify="off"))
    on = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                verify="plan"))

    # -- identity gate: verification must never alter execution -----------
    np.testing.assert_array_equal(np.asarray(off.forward(xs)),
                                  np.asarray(on.forward(xs)))
    assert on.stats.plans_verified == 1 and off.stats.plans_verified == 0

    shapes = f"H{cfg.lstm_hidden}L{cfg.n_layers}T{T}B{B}"
    t_off, t_on, r = _overhead(lambda: off.forward(xs),
                               lambda: on.forward(xs))
    emit("dispatch/verify_off_forward", t_off,
         f"{shapes} verify=off")
    emit("dispatch/verify_on_forward", t_on,
         f"{shapes} verify=plan overhead={(r - 1) * 100:+.1f}% "
         "(pairwise median, best of 3 trials; verified once per "
         "plan-cache miss)")

    # the cache-miss cost itself: one full static proof of the suite's
    # mixed-batch plan (no execution involved)
    items = [WorkItem.from_config(c, T=t, uid=i)
             for i, (c, t) in enumerate(MIX)]
    p = plan(items)
    rep = check_plan(p)
    emit("dispatch/verify_plancheck",
         _time(check_plan, p, repeat=max(repeats, 5)),
         f"mixed batch: {rep.items} items {rep.slots} slots "
         f"{rep.cells} cells, {len(rep.rules)} rules proven")


def _quant_rows(emit, repeats: int = 3) -> None:
    """ISSUE-10: the int8 weight path, priced at a matched shape.  The
    stripe claim first: at H512/B8/T64 the fp32 resident U (4 MB of the
    8 MB sequence budget) caps ``select_time_block`` at bt=32, while the
    int8 payload (1 MB + per-gate scales) sustains the full bt=64 stripe
    — asserted >= 2x here (and in the autotune test) before anything is
    emitted.  The timed rows run the suite's canonical stack (H64 L3 T24
    B8, interpreter-friendly) compiled fp32 vs int8 through the SAME
    facade; the int8 output is gated against its dequantized oracle
    (pure-jnp reference over the fake-quant param view) within the
    documented rel-err bound, and that max rel-err rides in the row."""
    from repro.core.tiling import select_time_block
    from repro.kernels.quant import fake_quant_stack

    # -- the VMEM-headroom claim at the stripe-bound shape ----------------
    bt_fp32 = select_time_block(64, 8, 512)
    bt_int8 = select_time_block(64, 8, 512, precision="int8")
    assert bt_int8 >= 2 * bt_fp32, (bt_int8, bt_fp32)

    cfg, T, B = lstm_config(64, layers=3), 24, 8
    stack = init_lstm_stack(jax.random.PRNGKey(0), cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(700), (B, T, 64)) * 0.5

    fp = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True))
    q8 = rnn.compile(stack, rnn.ExecutionPolicy(interpret=True,
                                                precision="int8"))

    # -- oracle gates: fp32 vs exact reference, int8 vs dequantized -------
    err_fp = float(jnp.max(jnp.abs(fp.forward(xs)
                                   - sch.reference_stack(stack, xs))))
    assert err_fp < 1e-4, err_fp
    oracle = sch.reference_stack(fake_quant_stack(stack, "int8"), xs)
    rel = float(jnp.max(jnp.abs(q8.forward(xs) - oracle))
                / jnp.max(jnp.abs(oracle)))
    assert rel < 1e-5, rel  # L=3 depths of the ~2e-7/step distributivity gap

    shapes = f"H{cfg.lstm_hidden}L{cfg.n_layers}T{T}B{B}"
    emit("dispatch/quant_fp32_forward",
         _time(fp.forward, xs, repeat=repeats),
         f"{shapes} precision=fp32 stripe@H512B8T64: bt={bt_fp32}")
    emit("dispatch/quant_int8_forward",
         _time(q8.forward, xs, repeat=repeats),
         f"{shapes} precision=int8 stripe@H512B8T64: bt={bt_int8} "
         f"({bt_int8 // bt_fp32}x fp32) max_rel_err={rel:.1e}")
