"""Dispatch suite: packed-plan vs per-request launch counts + oracle latency.

A mixed batch of three LSTM configs (different H/L/T, all from
repro.configs.sharp_lstm) goes through the tile dispatcher as one
DispatchPlan; the baseline runs each request alone through the per-request
wavefront schedule (``run_stack(..., "wavefront")``).  Rows record the
structural launch counts (pallas_launch_count — the dispatch claim) and the
CPU-oracle wall time; outputs are verified equal against the pure-jnp
unfolded oracle before anything is emitted.
"""
from __future__ import annotations

import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.sharp_lstm import lstm_config
from repro.core import schedules as sch
from repro.dispatch import WorkItem, execute, plan
from repro.kernels.common import pallas_launch_count
from repro.models.layers.lstm import init_lstm_stack

MIX = [  # (config, T): different H / L / T — the adaptability scenario
    (lstm_config(64, layers=3), 24),
    (lstm_config(96, layers=2), 16),
    (lstm_config(64, layers=4), 12),
]


def _time(fn: Callable, *args, repeat: int = 3) -> float:
    fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6


def dispatch(emit) -> None:
    items = [WorkItem.from_config(cfg, T=T, uid=i)
             for i, (cfg, T) in enumerate(MIX)]
    params = {i: init_lstm_stack(jax.random.PRNGKey(i), cfg, jnp.float32)
              for i, (cfg, _) in enumerate(MIX)}
    inputs = {i: jax.random.normal(jax.random.PRNGKey(100 + i),
                                   (1, T, cfg.lstm_hidden)) * 0.5
              for i, (cfg, T) in enumerate(MIX)}

    p = plan(items)

    def packed(pr, xs):
        return execute(p, pr, xs, interpret=True)

    def per_request(pr, xs):
        return {i: sch.run_stack(pr[i], xs[i], "wavefront", interpret=True)
                for i in xs}

    # -- correctness gate: packed == per-request == pure-jnp oracle -------
    outs = packed(params, inputs)
    naive = per_request(params, inputs)
    max_err = 0.0
    for i in inputs:
        oracle = sch.run_stack(params[i], inputs[i], "unfolded")
        for got in (outs[i], naive[i]):
            err = float(jnp.max(jnp.abs(got - oracle)))
            max_err = max(max_err, err)
            assert err < 1e-4, (i, err)

    n_packed = pallas_launch_count(packed, params, inputs)
    n_naive = pallas_launch_count(per_request, params, inputs)
    assert n_packed < n_naive, (n_packed, n_naive)

    shapes = "+".join(f"H{c.lstm_hidden}L{c.n_layers}T{t}" for c, t in MIX)
    emit("dispatch/packed_prefill", _time(packed, params, inputs),
         f"{shapes} launches={n_packed} slots={len(p.slots)} "
         f"max_err={max_err:.1e}")
    emit("dispatch/per_request_wavefront",
         _time(per_request, params, inputs),
         f"{shapes} launches={n_naive}")
    emit("dispatch/oracle_unfolded",
         _time(lambda pr, xs: {i: sch.run_stack(pr[i], xs[i], "unfolded")
                               for i in xs}, params, inputs), shapes)
    emit("dispatch/plan", 0.0,
         f"items={len(items)} launches={p.launches} "
         f"naive={p.naive_launches} est={p.est_cycles:.0f}cy")
