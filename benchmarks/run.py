"""Benchmark harness: one function per paper table/figure + kernel
microbenches + the dry-run roofline table.  Prints ``name,us_per_call,
derived`` CSV (stdout is the artifact; tee it to bench_output.txt).

``--suite kernels`` runs only the kernel microbenches and persists the rows
to ``BENCH_kernels.json`` (override with ``--json``) so the perf trajectory
accumulates across PRs; the test tier smoke-runs this suite.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

SUITES = ("all", "kernels", "tables", "dispatch")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark names")
    ap.add_argument("--suite", default="all", choices=SUITES)
    ap.add_argument("--json", default=None,
                    help="write rows as JSON (default BENCH_<suite>.json "
                         "for non-'all' suites)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per row for suites that take it "
                         "(median reported; raise for stabler medians)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    rows = []

    def emit(name: str, us_per_call: float, derived: str = "") -> None:
        rows.append({"name": name, "us_per_call": round(us_per_call, 2),
                     "derived": derived})
        print(f"{name},{us_per_call:.2f},{derived}", flush=True)

    import dispatch_bench
    import kernel_bench
    import paper_tables

    print("name,us_per_call,derived")
    benches = []
    if args.suite in ("all", "tables"):
        benches += list(paper_tables.ALL)
    if args.suite in ("all", "kernels"):
        benches.append(kernel_bench.kernels)
    if args.suite in ("all", "dispatch"):
        benches.append(dispatch_bench.dispatch)
    import inspect

    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        if "repeats" in inspect.signature(fn).parameters:
            fn(emit, repeats=args.repeats)
        else:
            fn(emit)

    if (args.suite == "all" and not args.skip_roofline
            and (not args.only or "roofline" in args.only)):
        import roofline

        if os.path.isdir("artifacts/dryrun"):
            roofline.emit_rows(emit)
        else:
            emit("roofline/SKIPPED", 0.0, "run repro.launch.dryrun first")

    json_path = args.json
    if json_path is None and args.suite != "all" and not args.only:
        # default artifact only for FULL suite runs — a filtered run must
        # not clobber the committed trajectory file with partial rows
        json_path = f"BENCH_{args.suite}.json"
    if json_path:
        import jax

        payload = {"suite": args.suite, "backend": jax.default_backend(),
                   "rows": rows}
        with open(json_path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {json_path}", file=sys.stderr)


if __name__ == "__main__":
    main()
