"""Benchmark harness: one function per paper table/figure + kernel
microbenches + the dry-run roofline table.  Prints ``name,us_per_call,
derived`` CSV (stdout is the artifact; tee it to bench_output.txt)."""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter over benchmark names")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()

    import kernel_bench
    import paper_tables

    print("name,us_per_call,derived")
    benches = list(paper_tables.ALL) + [kernel_bench.kernels]
    for fn in benches:
        if args.only and args.only not in fn.__name__:
            continue
        fn(emit)

    if not args.skip_roofline and (not args.only or "roofline" in args.only):
        import roofline

        if os.path.isdir("artifacts/dryrun"):
            roofline.emit_rows(emit)
        else:
            emit("roofline/SKIPPED", 0.0, "run repro.launch.dryrun first")


if __name__ == "__main__":
    main()
