"""Render EXPERIMENTS.md tables (dry-run + roofline) from artifacts."""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from roofline import cell_terms  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, supports_shape  # noqa: E402


def dryrun_table(d="artifacts/dryrun"):
    print("| arch | shape | mesh | peak GiB/dev | compile s | micro |")
    print("|---|---|---|---:|---:|---:|")
    for arch in list_archs():
        for shape in SHAPES:
            for mesh in ("16x16", "2x16x16"):
                f = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(f):
                    continue
                r = json.load(open(f))
                if r["status"] == "skipped":
                    print(f"| {arch} | {shape} | {mesh} | SKIP (full attn @512k) | — | — |")
                    continue
                m = r["memory"]["peak_bytes_per_device"] / 2**30
                print(f"| {arch} | {shape} | {mesh} | {m:.2f} | "
                      f"{r['compile_s']} | {r.get('microbatches', 1)} |")


def roofline_table(d="artifacts/dryrun", mesh="16x16"):
    print("| cell | dominant | compute s | memory s | collective s | "
          "useful | roofline frac |")
    print("|---|---|---:|---:|---:|---:|---:|")
    for arch in list_archs():
        for shape in SHAPES:
            f = os.path.join(d, f"{arch}__{shape}__{mesh}.json")
            if not os.path.exists(f):
                continue
            r = json.load(open(f))
            if r["status"] != "ok":
                continue
            t = cell_terms(f)
            if not t:
                continue
            print(f"| {arch} {shape} | {t['dominant']} | "
                  f"{t['compute_s']:.3g} | {t['memory_s']:.3g} | "
                  f"{t['collective_s']:.3g} | {t['useful_ratio']:.2f} | "
                  f"{t['roofline_frac']:.3f} |")


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "both"
    if which in ("dryrun", "both"):
        dryrun_table()
        print()
    if which in ("roofline", "both"):
        roofline_table()
