"""One benchmark per paper table/figure.

Each function regenerates its artifact from the critical-path model
(core/perfmodel) and, where a functional counterpart exists, measures the
real JAX implementation on CPU.  Rows follow ``name,us_per_call,derived``.
"""
from __future__ import annotations

import statistics
import time
from typing import Callable, List

import jax
import jax.numpy as jnp

from repro.configs.sharp_lstm import (DEEPBENCH, MAC_BUDGETS,
                                      PAPER_NETWORKS, SWEEP_HIDDEN_DIMS,
                                      lstm_config)
from repro.core import perfmodel as pm
from repro.core import schedules as sch
from repro.models.layers.lstm import init_lstm_layer


def _time(fn: Callable, *args, repeat: int = 3) -> float:
    fn(*args)  # compile
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return min(ts) * 1e6  # us


def fig9_kwidth(emit) -> None:
    """Fig. 9: K-width exploration (model)."""
    sweep = pm.fig9_kwidth_sweep()
    for (m, k, h), v in sorted(sweep.items()):
        emit(f"fig9/macs{m}/k{k}/h{h}", 0.0, f"{v:.3f}")
    for m in MAC_BUDGETS:
        best = pm.fig9_best_k(m)
        emit(f"fig9/best_k/macs{m}", 0.0,
             ";".join(f"h{h}:K{k}" for h, k in best.items()))


def fig10_padding(emit) -> None:
    """Fig. 10: padding-reconfiguration speedup (paper: <=1.22x, 1.0@512)."""
    pad = pm.fig10_padding_speedup()
    for (m, h), v in sorted(pad.items()):
        emit(f"fig10/macs{m}/h{h}", 0.0, f"{v:.3f}")
    emit("fig10/max_speedup", 0.0, f"{max(pad.values()):.3f}")
    emit("fig10/at_512", 0.0,
         f"{statistics.mean(pad[(m, 512)] for m in MAC_BUDGETS):.3f}")


def fig11_schedules(emit) -> None:
    """Fig. 11: schedule comparison — model speedups AND measured CPU
    wall-time of the real JAX implementations (B=1 inference)."""
    sp = pm.fig11_schedule_speedups()
    for (m, h, s), v in sorted(sp.items()):
        emit(f"fig11/model/macs{m}/h{h}/{s}", 0.0, f"{v:.3f}")
    # measured: functional schedules on CPU (small dims so CI-friendly)
    H, T, B = 256, 25, 1
    params = init_lstm_layer(jax.random.PRNGKey(0), H, H, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (B, T, H))
    base_us = None
    for s in sch.SCHEDULES:
        fn = jax.jit(lambda p, x, s=s: sch.LAYER_FNS[s](p, x))
        us = _time(fn, params, xs)
        if s == "sequential":
            base_us = us
        emit(f"fig11/measured_cpu/h{H}/{s}", us, f"{base_us / us:.3f}x_vs_seq")


def fig12_latency_util(emit) -> None:
    f12 = pm.fig12_latency_utilization()
    for m in MAC_BUDGETS:
        lat = statistics.mean(f12[(m, h)]["latency_us"] for h in SWEEP_HIDDEN_DIMS)
        u = statistics.mean(f12[(m, h)]["utilization"] for h in SWEEP_HIDDEN_DIMS)
        ue = statistics.mean(f12[(m, h)]["epur_utilization"]
                             for h in SWEEP_HIDDEN_DIMS)
        emit(f"fig12/macs{m}", lat, f"util={u:.2f};epur_util={ue:.2f}")


def table4_brainwave(emit) -> None:
    k_bw, penalty, eff = pm.fit_brainwave()
    t4 = pm.table4_vs_brainwave(k_bw, penalty, eff)
    emit("table4/bw_model_fit", 0.0, f"k{k_bw};penalty{penalty};eff{eff}")
    for (h, steps), v in sorted(t4.items()):
        paper = pm.TABLE4_PAPER[(h, steps)]
        emit(f"table4/h{h}_t{steps}", 0.0,
             f"ours={v:.2f};paper={paper};relerr={abs(v - paper) / paper:.2f}")


def table6_epur(emit) -> None:
    t6 = pm.table6_vs_epur()
    paper = {"EESEN": [1.07, 1.25, 1.68, 1.9], "GMAT": [1.01, 1.51, 1.53, 1.66],
             "BYSDNE": [1.05, 1.24, 1.8, 2.22],
             "RLDRADSPR": [1.03, 1.11, 1.45, 2.3]}
    for name in paper:
        for i, m in enumerate(MAC_BUDGETS):
            emit(f"table6/{name}/macs{m}", 0.0,
                 f"ours={t6[(name, m)]:.2f};paper={paper[name][i]}")


def fig14_energy(emit) -> None:
    e = pm.fig14_energy()
    for m in MAC_BUDGETS:
        red = statistics.mean(e[(m, h)]["reduction"] for h in SWEEP_HIDDEN_DIMS)
        emit(f"fig14/macs{m}", 0.0, f"energy_reduction={red:.3f}")
    emit("fig14/gflops_per_watt_64k", 0.0, f"{pm.gflops_per_watt():.0f}")
    emit("fig14/gflops_per_watt_paper_util", 0.0,
         f"{pm.PEAK_TFLOPS[65536] * 0.5 / pm.POWER_W[65536] / 1e9:.0f}")


ALL = [fig9_kwidth, fig10_padding, fig11_schedules, fig12_latency_util,
       table4_brainwave, table6_epur, fig14_energy]
