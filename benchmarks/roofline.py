"""Roofline terms per (arch x shape x mesh) from the dry-run artifacts.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s        (197e12 bf16, v5e)
    memory     = HLO_bytes_per_chip / HBM_bw             (819e9 B/s)
    collective = collective_bytes_per_chip / link_bw     (50e9 B/s)

The HLO walker (repro.calib.hlo) parses the post-SPMD, per-device
optimized module, so its numbers are already per-chip.  Caveat recorded in
EXPERIMENTS.md: the CPU backend legalizes bf16 by upcasting to f32, which
inflates the bytes term ~2x vs a real TPU lowering; flops and collective
bytes are dtype-exact from shapes.

MODEL_FLOPS uses 6*N_active*D for train (fwd+bwd) and 2*N_active per token
for prefill/decode; the usefulness ratio MODEL/HLO catches remat recompute,
causal-masking waste and sharding replication.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional

from repro.calib.hlo import analyze_file
from repro.configs import SHAPES, get_config, V5E


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = cfg.num_active_params(include_embed=False)
    if shape.mode == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_act * tokens
    if shape.mode == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_act * tokens
    # decode: one token per sequence
    return 2.0 * n_act * shape.global_batch


def cell_terms(cell_json: str, hw=V5E) -> Optional[Dict]:
    r = json.load(open(cell_json))
    if r.get("status") != "ok":
        return None
    hlo = r.get("hlo")
    if not hlo or not os.path.exists(hlo):
        return None
    a = analyze_file(hlo)
    n_dev = r["n_devices"]
    compute_s = a["flops"] / hw.peak_flops_bf16
    memory_s = a["bytes"] / hw.hbm_bw
    collective_s = a["collective_bytes"] / hw.ici_bw
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(r["arch"], r["shape"])
    hlo_flops_total = a["flops"] * n_dev
    return {
        "cell": r["cell"], "arch": r["arch"], "shape": r["shape"],
        "mesh": r["mesh"], "n_devices": n_dev,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "bound_s": terms[dominant],
        "model_flops": mf, "hlo_flops_total": hlo_flops_total,
        "useful_ratio": mf / hlo_flops_total if hlo_flops_total else 0.0,
        # roofline fraction: how close the compute term is to being the
        # binding constraint (1.0 == perfectly compute-bound execution)
        "roofline_frac": (compute_s / terms[dominant]) if terms[dominant] else 0.0,
        "collectives": a["collectives"],
        "peak_gib": r["memory"]["peak_bytes_per_device"] / 2**30,
    }


def run(dryrun_dir: str = "artifacts/dryrun",
        out_csv: str = "artifacts/roofline.csv",
        mesh: str = "16x16") -> list:
    rows = []
    for f in sorted(os.listdir(dryrun_dir)):
        if not f.endswith(".json"):
            continue
        if mesh and not f.endswith(f"__{mesh}.json"):
            continue
        t = cell_terms(os.path.join(dryrun_dir, f))
        if t:
            rows.append(t)
    os.makedirs(os.path.dirname(out_csv), exist_ok=True)
    cols = ["cell", "dominant", "compute_s", "memory_s", "collective_s",
            "roofline_frac", "useful_ratio", "peak_gib"]
    with open(out_csv, "w") as fh:
        fh.write(",".join(cols) + "\n")
        for t in rows:
            fh.write(",".join(
                f"{t[c]:.6g}" if isinstance(t[c], float) else str(t[c])
                for c in cols) + "\n")
    return rows


def emit_rows(emit, mesh: str = "16x16") -> None:
    for t in run(mesh=mesh):
        emit(f"roofline/{t['cell']}", t["bound_s"] * 1e6,
             f"dom={t['dominant']};compute={t['compute_s']:.3e};"
             f"memory={t['memory_s']:.3e};coll={t['collective_s']:.3e};"
             f"useful={t['useful_ratio']:.2f}")


if __name__ == "__main__":
    rows = run()
    for t in rows:
        print(f"{t['cell']:58s} dom={t['dominant']:10s} "
              f"c={t['compute_s']:.2e} m={t['memory_s']:.2e} "
              f"x={t['collective_s']:.2e} useful={t['useful_ratio']:.2f}")
