"""Kernel microbenchmarks: Pallas (interpret) vs pure-jnp oracle on CPU.

Interpret-mode timings measure nothing about TPU speed — the point of
these rows is (a) proving the kernels execute end-to-end under jit and
(b) tracking the oracle's CPU cost, which IS the baseline the schedules
benchmark runs against.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.kernels.common import pallas_launch_count
from repro.kernels.decode_attention.ops import decode_attention, decode_attention_ref
from repro.kernels.lstm_cell.ops import (lstm_cell, lstm_cell_ref, lstm_seq,
                                         lstm_seq_ref)
from repro.kernels.mvm_tile.ops import mvm, mvm_ref
from repro.kernels.rglru.ops import rglru_scan, rglru_scan_ref
from repro.runtime.obs import measure_us


def _time(fn: Callable, *args, repeat: int = 3) -> float:
    """Shared runtime timer, min-of-repeats: microbenchmarks want the
    best case (least scheduler noise), unlike the dispatch suite's
    medians."""
    return measure_us(fn, *args, repeats=repeat, warmup=1, reduce="min")


def kernels(emit) -> None:
    key = jax.random.PRNGKey(0)
    B, H = 4, 256
    ks = jax.random.split(key, 4)
    U4 = jax.random.normal(ks[0], (H, 4, H), jnp.float32) * 0.1
    xw = jax.random.normal(ks[1], (B, 4, H), jnp.float32)
    h = jax.random.normal(ks[2], (B, H), jnp.float32)
    c = jax.random.normal(ks[3], (B, H), jnp.float32)
    emit("kernel/lstm_cell/pallas_interp", _time(lstm_cell, U4, xw, h, c),
         f"B{B}xH{H}")
    emit("kernel/lstm_cell/ref", _time(jax.jit(lstm_cell_ref), U4, xw, h, c),
         f"B{B}xH{H}")

    # ---- sequence-fused recurrence: 1 launch vs T (the PR's tentpole) ----
    T = 32
    xw_seq = jax.random.normal(ks[1], (B, T, 4, H), jnp.float32)
    h0 = jnp.zeros((B, H), jnp.float32)
    c0 = jnp.zeros((B, H), jnp.float32)

    @jax.jit
    def per_step_scan(U4, xw_seq, h0, c0):
        """The seed's path: lax.scan re-enters the cell kernel every step —
        T launches, (h, c) round-tripping between them."""
        def step(carry, xw_t):
            h, c = lstm_cell(U4, xw_t, carry[0], carry[1], interpret=True)
            return (h, c), h
        (_, _), hs = jax.lax.scan(step, (h0, c0), xw_seq.swapaxes(0, 1))
        return hs

    fused = jax.jit(lambda U4, xw, h, c: lstm_seq(U4, xw, h, c,
                                                  interpret=True)[0])
    n_per_step = pallas_launch_count(per_step_scan, U4, xw_seq, h0, c0)
    n_fused = pallas_launch_count(fused, U4, xw_seq, h0, c0)
    emit("kernel/lstm_seq/per_step_pallas",
         _time(per_step_scan, U4, xw_seq, h0, c0),
         f"B{B}xH{H}xT{T} launches={n_per_step}")
    emit("kernel/lstm_seq/fused_pallas", _time(fused, U4, xw_seq, h0, c0),
         f"B{B}xH{H}xT{T} launches={n_fused}")
    emit("kernel/lstm_seq/ref", _time(jax.jit(lstm_seq_ref), U4, xw_seq, h0, c0),
         f"B{B}xH{H}xT{T}")

    x = jax.random.normal(ks[0], (B, 512), jnp.float32)
    W = jax.random.normal(ks[1], (512, 1024), jnp.float32) * 0.05
    emit("kernel/mvm_tile/pallas_interp", _time(mvm, x, W), "512x1024")
    emit("kernel/mvm_tile/ref", _time(jax.jit(mvm_ref), x, W), "512x1024")

    la = -jnp.abs(jax.random.normal(ks[0], (B, 64, 256))) * 0.3
    gx = jax.random.normal(ks[1], (B, 64, 256))
    h0 = jax.random.normal(ks[2], (B, 256))
    emit("kernel/rglru/pallas_interp", _time(rglru_scan, la, gx, h0), "T64xW256")
    emit("kernel/rglru/ref", _time(jax.jit(rglru_scan_ref), la, gx, h0),
         "T64xW256")

    q = jax.random.normal(ks[0], (B, 8, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (B, 1024, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (B, 1024, 2, 64), jnp.float32)
    valid = jnp.full((B,), 1024, jnp.int32)
    emit("kernel/decode_attn/pallas_interp",
         _time(decode_attention, q, kc, vc, valid), "T1024")
    emit("kernel/decode_attn/ref",
         _time(jax.jit(decode_attention_ref), q, kc, vc, valid), "T1024")
