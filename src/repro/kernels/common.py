"""Shared kernel utilities."""
from __future__ import annotations

import jax


def default_interpret() -> bool:
    """Pallas TPU kernels run in interpret mode everywhere but real TPUs."""
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(a: int, b: int) -> int:
    return cdiv(a, b) * b


def ragged_b_mask(G: int, B: int, b_valid):
    """(G, B) int32 validity mask from per-cell valid row counts (ragged-B
    packing): mask[g, b] = 1 iff b < b_valid[g].  Shared by the sequence
    kernels' ``b_valid`` plumbing."""
    import jax
    import jax.numpy as jnp

    return (jax.lax.broadcasted_iota(jnp.int32, (G, B), 1)
            < jnp.asarray(b_valid, jnp.int32)[:, None]).astype(jnp.int32)


# ---------------------------------------------------------------------------
# structural launch accounting
# ---------------------------------------------------------------------------


def _subjaxprs(value):
    """Yield any jaxprs hiding inside an eqn param value."""
    if hasattr(value, "jaxpr"):          # ClosedJaxpr
        yield value.jaxpr
    elif hasattr(value, "eqns"):         # raw Jaxpr
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _subjaxprs(v)


def _count_launches(jaxpr, mult: int) -> int:
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            total += mult
        inner_mult = mult
        if eqn.primitive.name == "scan":
            inner_mult = mult * eqn.params.get("length", 1)
        for value in eqn.params.values():
            for sub in _subjaxprs(value):
                total += _count_launches(sub, inner_mult)
    return total


def pallas_launch_count(fn, *args, **kwargs) -> int:
    """Number of pallas_call launches ``fn(*args)`` issues at runtime.

    Traverses the jaxpr, multiplying launches under ``lax.scan`` by the trip
    count — the structural proof behind "1 launch vs T" claims (a scanned
    per-step kernel traces once but launches T times)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return _count_launches(closed.jaxpr, 1)
