"""Jitted wrapper for the RG-LRU scan kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.rglru.kernel import rglru_scan_pallas
from repro.kernels.rglru.ref import rglru_scan_ref


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def rglru_scan(log_a, gx, h0, *, block_w: int = 0, interpret: bool | None = None):
    W = log_a.shape[-1]
    if not block_w:
        block_w = min(512, W)
    if interpret is None:
        interpret = default_interpret()
    return rglru_scan_pallas(log_a, gx, h0, block_w=block_w, interpret=interpret)


__all__ = ["rglru_scan", "rglru_scan_ref"]
