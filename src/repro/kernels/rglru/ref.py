"""Pure-jnp oracle for the RG-LRU recurrence scan."""
import jax
import jax.numpy as jnp


def rglru_scan_ref(log_a, gx, h0):
    """log_a, gx (B, T, W) fp32; h0 (B, W) fp32 -> (hs (B,T,W), h_T)."""

    def step(h, inp):
        la, g = inp
        a = jnp.exp(la)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * g
        return h, h

    hT, hs = jax.lax.scan(step, h0, (log_a.swapaxes(0, 1), gx.swapaxes(0, 1)))
    return hs.swapaxes(0, 1), hT
