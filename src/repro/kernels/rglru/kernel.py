"""RG-LRU gated linear recurrence as a Pallas kernel.

The Unfolded split (DESIGN.md) leaves only this serial pointwise recurrence
inside the time loop — the analogue of SHARP's Cell-Updater stage.  The
kernel walks the grid (channel-block j, time t) with t innermost, carrying
the per-channel hidden state in a VMEM scratch register across time steps:
the whole T-step recurrence for a channel stripe runs without touching HBM
for the state (SHARP's double-buffered cell-state scratchpad, in VMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(la_ref, gx_ref, h0_ref, hs_ref, hT_ref, state_ref, *, n_t: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        state_ref[...] = h0_ref[...]

    a = jnp.exp(la_ref[..., 0, :])  # (B, bw)
    g = gx_ref[..., 0, :]
    h = a * state_ref[...] + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * g
    state_ref[...] = h
    hs_ref[...] = h[:, None, :]

    @pl.when(t == n_t - 1)
    def _final():
        hT_ref[...] = h


def rglru_scan_pallas(log_a, gx, h0, *, block_w: int, interpret: bool = True):
    """log_a, gx (B, T, W) fp32; h0 (B, W) fp32."""
    B, T, W = log_a.shape
    n_j = cdiv(W, block_w)
    kernel = functools.partial(_kernel, n_t=T)
    hs, hT = pl.pallas_call(
        kernel,
        grid=(n_j, T),
        in_specs=[
            pl.BlockSpec((B, 1, block_w), lambda j, t: (0, t, j)),
            pl.BlockSpec((B, 1, block_w), lambda j, t: (0, t, j)),
            pl.BlockSpec((B, block_w), lambda j, t: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((B, 1, block_w), lambda j, t: (0, t, j)),
            pl.BlockSpec((B, block_w), lambda j, t: (0, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, T, W), jnp.float32),
            jax.ShapeDtypeStruct((B, W), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, block_w), jnp.float32)],
        interpret=interpret,
    )(log_a, gx, h0)
    return hs, hT
