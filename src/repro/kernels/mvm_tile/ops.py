"""Jitted wrapper: tile shape resolved from the autotune table per (X, N)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import table
from repro.kernels.common import default_interpret
from repro.kernels.mvm_tile.kernel import mvm_pallas
from repro.kernels.mvm_tile.ref import mvm_ref


@functools.partial(jax.jit, static_argnames=("block_n", "block_k", "interpret"))
def mvm(x, W, b=None, *, block_n: int = 0, block_k: int = 0,
        interpret: bool | None = None):
    """Tiled y = x @ W (+ b).  x (B, X) or (X,); W (X, N)."""
    squeeze = x.ndim == 1
    if squeeze:
        x = x[None]
    X, N = W.shape
    if not block_n or not block_k:
        bk, bn = table().block(X, N, vmem_budget=2 * 2**20)
        block_k = block_k or min(bk, X)
        block_n = block_n or min(bn, N)
    if interpret is None:
        interpret = default_interpret()
    y = mvm_pallas(x, W, b, block_n=block_n, block_k=block_k,
                   interpret=interpret)
    return y[0] if squeeze else y


__all__ = ["mvm", "mvm_ref"]
