"""Pure-jnp oracle for the tiled MVM engine."""
import jax.numpy as jnp


def mvm_ref(x, W, b=None):
    """x (B, X) @ W (X, N) (+ b) with fp32 accumulation."""
    y = jnp.einsum("bx,xn->bn", x, W, preferred_element_type=jnp.float32)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)
