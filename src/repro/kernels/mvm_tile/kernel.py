"""Reconfigurable tiled MVM — the SHARP Compute-Unit/R-Add-Reduce analogue.

y = x @ W (+ b), with the (block_k x block_n) tile shape chosen per weight
matrix from the autotune table: SHARP's Config1..4 become BlockSpec
geometries, its R-Add-Reduce tap-point selection becomes the reduction
blocking, and its edge reconfiguration becomes the masked final stripes
(no MAC results are wasted past the matrix edge).

Grid: (j over N output cols, k over X reduction); the fp32 accumulator tile
lives in VMEM across the k stripes (revisiting), and the bias epilogue runs
on the last stripe — decode projections call this as their GEMV engine.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(x_ref, w_ref, b_ref, out_ref, acc_ref, *, n_k: int, X: int, bk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x_blk = x_ref[...]  # (B, bk)
    w_blk = w_ref[...]  # (bk, bn)
    base = k * bk
    cidx = base + jax.lax.broadcasted_iota(jnp.int32, x_blk.shape, 1)
    x_blk = jnp.where(cidx < X, x_blk, 0).astype(x_blk.dtype)
    ridx = base + jax.lax.broadcasted_iota(jnp.int32, w_blk.shape, 0)
    w_blk = jnp.where(ridx < X, w_blk, 0).astype(w_blk.dtype)
    acc_ref[...] += jax.lax.dot_general(
        x_blk, w_blk, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out_ref[...] = (acc_ref[...] + b_ref[...].astype(jnp.float32)
                        ).astype(out_ref.dtype)


def mvm_pallas(x, W, b=None, *, block_n: int, block_k: int,
               interpret: bool = True):
    """x (B, X); W (X, N); b (N,) optional."""
    B, X = x.shape
    N = W.shape[1]
    if b is None:
        b = jnp.zeros((N,), jnp.float32)
    b2 = b.reshape(1, N)
    n_j = cdiv(N, block_n)
    n_k = cdiv(X, block_k)
    kernel = functools.partial(_kernel, n_k=n_k, X=X, bk=block_k)
    out = pl.pallas_call(
        kernel,
        grid=(n_j, n_k),
        in_specs=[
            pl.BlockSpec((B, block_k), lambda j, k: (0, k)),
            pl.BlockSpec((block_k, block_n), lambda j, k: (k, j)),
            pl.BlockSpec((1, block_n), lambda j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((B, block_n), lambda j, k: (0, j)),
        out_shape=jax.ShapeDtypeStruct((B, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((B, block_n), jnp.float32)],
        interpret=interpret,
    )(x, W, b2)
    return out
