"""Shared quantization + structured-sparsity utilities for the cell kernels.

THE absmax int8 quantizer lives here — `optim.compression` (gradient
round-trip on the cross-pod axis) and the kernel-side per-gate weight
quantizer both import it, so there is exactly one scale convention in the
repo: ``scale = absmax / 127``, symmetric, clipped to [-127, 127].

Two weight transforms ride on it, both applied to the *recurrent* matrix U
only (the hoisted input GEMM keeps full-precision W — it runs once per
sequence outside the launch, so narrowing it buys no VMEM residency and
would add a second error term for free):

* **per-gate int8** (`quantize_per_gate` / `dequantize_per_gate`): one
  scale per gate slab of U (H, gates, H), int8 payload resident in VMEM,
  fp32 accumulate in-kernel, the (gates,) scale applied after the dot.
* **block-sparse row tiles** (`tile_bitmap` / `compact_rows`): U's input-row
  axis is cut into MXU_ROWS-row tiles; all-zero tiles are dropped and the
  kernel gathers only the surviving rows of h before the dot.  Padding
  rows (slot-uniform Ha across G cells) carry zero U rows and index 0, so
  their contribution is exactly 0.0 — the compaction is value-exact up to
  dot reduction order.

`fake_quant_stack` is the oracle-side twin: it maps a parameter stack to
the dequantized-f32 stack the kernels effectively compute with, so
`core.schedules.reference_stack(fake_quant_stack(params, p), xs)` is the
ground truth for any precision — error bounds then cover only the
distributivity gap between ``(h @ Uq) * s`` (kernel) and ``h @ (Uq * s)``
(oracle).
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.perfmodel import MXU_ROWS
from repro.kernels.common import cdiv


def absmax_scale(x, axis=None):
    """Symmetric int8 scale(s): absmax / 127, floored away from zero."""
    return jnp.maximum(jnp.max(jnp.abs(x), axis=axis), 1e-12) / 127.0


def quantize(x, scale):
    """Round x/scale to int8, clipped to the symmetric [-127, 127] range."""
    return jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)


def int8_roundtrip(g):
    """Per-tensor absmax int8 round-trip (quantize then dequantize) —
    what `optim.compression` ships over the cross-pod axis."""
    scale = absmax_scale(g)
    return quantize(g, scale).astype(jnp.float32) * scale


def bf16_roundtrip(x):
    """bf16 fake-quant: round values through bfloat16, stored as f32.
    bf16 -> f32 is exact, so kernels consuming the round-tripped weights
    match the dequantized oracle bit-for-bit."""
    return jnp.asarray(x).astype(jnp.bfloat16).astype(jnp.float32)


def quantize_per_gate(U):
    """Per-gate absmax int8 quantization of a recurrent matrix.

    U (H, gates, H) -> (q int8 (H, gates, H), scales (gates,) f32): one
    scale per gate slab, the granularity the fused kernels apply after
    their fp32-accumulated dot (a (gates,) broadcast over (B, gates, H))."""
    scales = absmax_scale(U, axis=(0, 2))
    return quantize(U, scales[None, :, None]), scales.astype(jnp.float32)


def dequantize_per_gate(q, scales):
    """Inverse of quantize_per_gate: int8 (H, gates, H) x (gates,) -> f32."""
    return q.astype(jnp.float32) * scales[None, :, None]


# ---------------------------------------------------------------------------
# structured block sparsity over U's input-row axis (tile = MXU_ROWS)
# ---------------------------------------------------------------------------


def tile_bitmap(U, tile: int = MXU_ROWS):
    """Occupancy bitmap of U's input-row tiles: a length-cdiv(H, tile)
    tuple of 0/1, 1 iff any element in rows [t*tile, (t+1)*tile) is
    nonzero.  U is (H, gates*H) or (H, gates, H); computed once per stack
    at compile time (host-synced ints — hashable, plan-cache friendly)."""
    U = jnp.asarray(U)
    H = U.shape[0]
    flat = U.reshape(H, -1)
    n = cdiv(H, tile)
    occupied = [bool(jnp.any(flat[t * tile:(t + 1) * tile] != 0))
                for t in range(n)]
    return tuple(int(b) for b in occupied)


def stack_tile_maps(stack_params, tile: int = MXU_ROWS):
    """Per-layer tile bitmaps for a whole parameter stack (the WorkItem
    ``tile_map`` payload).  Bidirectional layers take the OR-union of the
    fwd/bwd halves: both directions share one slot launch, so a tile is
    skippable only if BOTH halves zero it."""
    maps = []
    for layer in stack_params["layers"]:
        if "fwd" in layer:
            f = tile_bitmap(layer["fwd"]["U"], tile)
            b = tile_bitmap(layer["bwd"]["U"], tile)
            maps.append(tuple(int(x or y) for x, y in zip(f, b)))
        else:
            maps.append(tile_bitmap(layer["U"], tile))
    return tuple(maps)


def active_row_indices(bitmap, H: int, tile: int = MXU_ROWS):
    """The dense row indices covered by the bitmap's occupied tiles
    (partial last tile clipped to H)."""
    return [r for t, bit in enumerate(bitmap) if bit
            for r in range(t * tile, min((t + 1) * tile, H))]


def compact_rows(U, bitmap, tile: int = MXU_ROWS, pad_to: int | None = None):
    """Drop U's zero row-tiles.  U (H, gates, H) + bitmap ->
    (Uc (Ha, gates, H), rows (Ha,) int32) where Ha = pad_to (slot-uniform
    across G cells) or the active-row count.  Padding rows are zero U rows
    pointing at index 0 — the kernel's gather reads a live h value there,
    but the zero weight row annihilates it exactly."""
    U = jnp.asarray(U)
    H = U.shape[0]
    idx = active_row_indices(bitmap, H, tile)
    n_active = len(idx)
    Ha = n_active if pad_to is None else pad_to
    Ha = max(Ha, 1)  # an all-zero U still needs a non-empty dot operand
    if Ha < n_active:
        raise ValueError(f"pad_to={pad_to} < active rows {n_active}")
    rows = jnp.asarray(idx + [0] * (Ha - n_active), jnp.int32)
    Uc = jnp.zeros((Ha,) + U.shape[1:], U.dtype)
    if n_active:
        Uc = Uc.at[:n_active].set(U[jnp.asarray(idx, jnp.int32)])
    return Uc, rows


def expand_rows(Uc, rows, H: int):
    """Inverse of compact_rows for the fallback ladder's dense rungs:
    scatter-ADD the compacted rows back to (H, ...) — padding rows add
    0.0 to row 0, so duplicates are harmless and the round-trip is exact."""
    dense = jnp.zeros((H,) + tuple(Uc.shape[1:]), Uc.dtype)
    return dense.at[rows].add(Uc)


def density(bitmap) -> float:
    """Occupied-tile fraction of a bitmap (1.0 for None/empty — dense)."""
    if not bitmap:
        return 1.0
    return sum(bitmap) / len(bitmap)


def stack_density(tile_map) -> float:
    """Mean per-layer density of a stack tile_map (None -> dense 1.0)."""
    if not tile_map:
        return 1.0
    return sum(density(m) for m in tile_map) / len(tile_map)


# ---------------------------------------------------------------------------
# the oracle-side transform
# ---------------------------------------------------------------------------


def fake_quant_half(half, precision: str):
    """One layer half with U round-tripped through ``precision`` (W and b
    untouched — the input GEMM stays full precision by design)."""
    if precision == "fp32":
        return half
    U = jnp.asarray(half["U"])
    H = U.shape[0]
    if precision == "bf16":
        Uq = bf16_roundtrip(U)
    elif precision == "int8":
        gates = U.shape[-1] // H if U.ndim == 2 else U.shape[1]
        q, s = quantize_per_gate(U.reshape(H, gates, H))
        Uq = dequantize_per_gate(q, s).reshape(U.shape)
    else:
        raise ValueError(f"unknown precision {precision!r}")
    out = dict(half)
    out["U"] = Uq.astype(U.dtype) if U.dtype == jnp.float32 else Uq
    return out


def fake_quant_stack(stack_params, precision: str):
    """Dequantized-f32 view of a parameter stack: each layer's recurrent
    matrix is round-tripped through ``precision`` exactly as the kernels'
    hoist does it.  ``reference_stack(fake_quant_stack(p, prec), xs)`` is
    THE oracle for precision != fp32 (bidirectional halves round-trip
    independently, matching the per-direction hoist)."""
    if precision == "fp32":
        return stack_params
    layers = []
    for layer in stack_params["layers"]:
        if "fwd" in layer:
            out = dict(layer)
            out["fwd"] = fake_quant_half(layer["fwd"], precision)
            out["bwd"] = fake_quant_half(layer["bwd"], precision)
            layers.append(out)
        else:
            layers.append(fake_quant_half(layer, precision))
    out = dict(stack_params)
    out["layers"] = layers
    return out


__all__ = [
    "absmax_scale", "quantize", "int8_roundtrip", "bf16_roundtrip",
    "quantize_per_gate", "dequantize_per_gate",
    "tile_bitmap", "stack_tile_maps", "active_row_indices", "compact_rows",
    "expand_rows", "density", "stack_density",
    "fake_quant_half", "fake_quant_stack",
]
