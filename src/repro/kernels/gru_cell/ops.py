"""Jitted public wrapper for the sequence-fused GRU kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import table
from repro.kernels.common import default_interpret, ragged_b_mask
from repro.kernels.gru_cell.kernel import gru_decode_pallas, gru_seq_pallas
from repro.kernels.gru_cell.ref import gru_seq_ref, gru_step_ref


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def gru_seq(U3, xw, h0=None, *, b_valid=None, u_scales=None, u_rows=None,
            block_t: int = 0, interpret: bool | None = None):
    """Sequence-fused GRU recurrence: ONE pallas_call for the whole T walk.

    U3 (H,3,H) or, for a batch of G independent cells, (G,H,3,H); xw
    (B,T,3,H) / (G,B,T,3,H) precomputed input half; h0 optional (…B,H)
    initial state (zeros when omitted).  Returns (hs, h_T); ``hs`` is
    (…B,T,H).  ``block_t`` (the streamed T-stripe) defaults to the autotune
    table's VMEM-budget choice (gates=3).

    ``b_valid`` (stacked form only): (G,) int array of valid batch rows per
    cell under ragged-B packing — rows >= b_valid[g] are exact no-ops.

    Time-reversed walks (the bwd half of a bidirectional layer) use
    pre-launch reversal exactly like ``lstm_seq``: flip the xw stripe on
    the time axis and flip ``hs`` back — exact for any T (the T-edge mask
    only pads beyond T), with ``h_T`` then the state after the t=0 step
    (see kernels.lstm_cell.lstm_seq and
    tests/kernels/test_seq_reversed.py).

    ``u_scales`` (…3) f32 marks U3 as int8 per-gate quantized payload;
    ``u_rows`` (…Ha) int32 marks U3 as row-compacted (block-sparse) —
    see kernels.quant for both transforms and their exactness story."""
    stacked = xw.ndim == 5
    if not stacked:
        if b_valid is not None:
            raise ValueError("b_valid requires the stacked (G, ...) form")
        U3, xw = U3[None], xw[None]
        if h0 is not None:
            h0 = h0[None]
        if u_scales is not None:
            u_scales = u_scales[None]
        if u_rows is not None:
            u_rows = u_rows[None]
    G, B, T, _, H = xw.shape
    if h0 is None:
        h0 = jnp.zeros((G, B, H), xw.dtype)
    if T == 0:  # degenerate empty sequence: state passes through
        hs = jnp.zeros((G, B, 0, H), h0.dtype)
        return (hs, h0) if stacked else (hs[0], h0[0])
    if not block_t:
        precision = "int8" if u_scales is not None else "fp32"
        dens = 1.0 if u_rows is None else u_rows.shape[-1] / H
        block_t = table().seq_block(T, B, H, gates=3, precision=precision,
                                    density=dens)
    if interpret is None:
        interpret = default_interpret()
    b_mask = None if b_valid is None else ragged_b_mask(G, B, b_valid)
    hs, h_n = gru_seq_pallas(U3, xw, h0, block_t=block_t, interpret=interpret,
                             b_mask=b_mask, u_scales=u_scales, u_rows=u_rows)
    if not stacked:
        hs, h_n = hs[0], h_n[0]
    return hs, h_n


@functools.partial(jax.jit, static_argnames=("interpret",))
def gru_decode(xw0, Ws, bs, Us, h0, *, interpret: bool | None = None):
    """One T=1 decode tick through a whole L-layer GRU stack in ONE launch
    (the lstm_decode pattern on the 3-gate cell — see kernels.lstm_cell).

    xw0 (B,3,H) hoisted layer-0 input half; Ws (L,H,3,H) (entry 0 unused);
    bs (L,3,H); Us (L,H,3,H); h0 (L,B,H).  Returns h_n (L,B,H); the
    top-layer feedback frame is ``h_n[-1]``.  Bit-identical to L per-layer
    ``gru_seq(..., T=1)`` launches whenever the hoisted input GEMM
    promotes to f32 (see kernels.lstm_cell.lstm_decode for the fully-bf16
    caveat)."""
    if interpret is None:
        interpret = default_interpret()
    return gru_decode_pallas(xw0, Ws, bs, Us, h0, interpret=interpret)


__all__ = ["gru_seq", "gru_seq_ref", "gru_step_ref", "gru_decode"]
