"""Jitted public wrapper for the sequence-fused GRU kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import table
from repro.kernels.common import default_interpret
from repro.kernels.gru_cell.kernel import gru_seq_pallas
from repro.kernels.gru_cell.ref import gru_seq_ref, gru_step_ref


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def gru_seq(U3, xw, h0=None, *, block_t: int = 0,
            interpret: bool | None = None):
    """Sequence-fused GRU recurrence: ONE pallas_call for the whole T walk.

    U3 (H,3,H) or, for a batch of G independent cells, (G,H,3,H); xw
    (B,T,3,H) / (G,B,T,3,H) precomputed input half; h0 optional (…B,H)
    initial state (zeros when omitted).  Returns (hs, h_T); ``hs`` is
    (…B,T,H).  ``block_t`` (the streamed T-stripe) defaults to the autotune
    table's VMEM-budget choice (gates=3)."""
    stacked = xw.ndim == 5
    if not stacked:
        U3, xw = U3[None], xw[None]
        if h0 is not None:
            h0 = h0[None]
    G, B, T, _, H = xw.shape
    if h0 is None:
        h0 = jnp.zeros((G, B, H), xw.dtype)
    if T == 0:  # degenerate empty sequence: state passes through
        hs = jnp.zeros((G, B, 0, H), h0.dtype)
        return (hs, h0) if stacked else (hs[0], h0[0])
    if not block_t:
        block_t = table().seq_block(T, B, H, gates=3)
    if interpret is None:
        interpret = default_interpret()
    hs, h_n = gru_seq_pallas(U3, xw, h0, block_t=block_t, interpret=interpret)
    if not stacked:
        hs, h_n = hs[0], h_n[0]
    return hs, h_n


__all__ = ["gru_seq", "gru_seq_ref", "gru_step_ref"]
