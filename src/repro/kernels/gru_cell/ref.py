"""Pure-jnp oracles for the fused GRU sequence kernel."""
import jax
import jax.numpy as jnp


def gru_step_ref(U3, xw_t, h_prev):
    """U3 (H, 3, H); xw_t (B, 3, H) precomputed input half (+bias);
    h_prev (B, H).  Gate order along the 3-axis: (z, r, n).  Returns h."""
    hu = jnp.einsum("bx,xgj->bgj", h_prev, U3,
                    preferred_element_type=jnp.float32)
    xw32 = xw_t.astype(jnp.float32)
    z = jax.nn.sigmoid(xw32[:, 0] + hu[:, 0])
    r = jax.nn.sigmoid(xw32[:, 1] + hu[:, 1])
    n = jnp.tanh(xw32[:, 2] + r * hu[:, 2])
    h = (1 - z) * n + z * h_prev.astype(jnp.float32)
    return h.astype(h_prev.dtype)


def gru_seq_ref(U3, xw, h0):
    """Scan-based oracle for the sequence-fused GRU kernel.

    U3 (H,3,H) or (G,H,3,H); xw (B,T,3,H) or (G,B,T,3,H); h0 (…B,H).
    Returns (hs (…B,T,H), h_T (…B,H))."""
    if xw.ndim == 5:
        return jax.vmap(gru_seq_ref)(U3, xw, h0)

    def step(h, xw_t):
        h = gru_step_ref(U3, xw_t, h)
        return h, h

    h_n, hs = jax.lax.scan(step, h0, xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1), h_n
