"""Sequence-fused GRU recurrence as a Pallas TPU kernel.

The LSTM sequence kernel's T-stripe pattern (kernels.lstm_cell), ported to
the GRU cell: the time loop lives inside ONE ``pallas_call``, the hidden
state is VMEM-resident across the whole T walk, the precomputed input half
streams in T-block stripes via the BlockSpec index map, and a leading grid
dimension ``g`` batches independent recurrences (distinct U per cell) so
the dispatcher can pack GRU cells into shared wavefront slots.

The GRU is the harder Unfolded case (see core/gru.py): the reset gate
couples into the candidate's recurrent term *multiplicatively*, so the
epilogue is  n = tanh(xw_n + r·(U_n h))  rather than a pure pre-activation
sum — but the dependence structure (one recurrent MVM per step, pointwise
tail) is identical, and so is the fusion win: one launch instead of T, no
per-step HBM round-trip of h.

Gate order along the 3-axis: (z, r, n), matching core.gru.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _seq_kernel(xw_ref, u_ref, h0_ref, hs_ref, hn_ref, h_scr, *,
                block_t: int, T: int):
    """One grid step = one T-block of one recurrence ``g``.

    Grid is (G, n_t) with t innermost; h persists in VMEM scratch across
    the t walk and is re-seeded from h0 at each cell's first block.
    """
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    U = u_ref[0]                      # (H, 3, H) — resident across the walk
    H = U.shape[0]
    U2 = U.reshape(H, 3 * H)
    xw_blk = xw_ref[0]                # (B, block_t, 3, H) — streamed stripe
    B = xw_blk.shape[0]
    base = t * block_t

    def step(i, carry):
        h, ys = carry
        xw_t = jax.lax.dynamic_index_in_dim(xw_blk, i, axis=1,
                                            keepdims=False)  # (B, 3, H)
        hu = jax.lax.dot_general(
            h, U2, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(B, 3, H)
        xw32 = xw_t.astype(jnp.float32)
        z = jax.nn.sigmoid(xw32[:, 0] + hu[:, 0])
        r = jax.nn.sigmoid(xw32[:, 1] + hu[:, 1])
        n = jnp.tanh(xw32[:, 2] + r * hu[:, 2])
        h_new = (1 - z) * n + z * h
        # T-edge mask: the last block's tail reads BlockSpec padding
        # (undefined, NaN under interpret) — freeze the state there
        valid = base + i < T
        h = jnp.where(valid, h_new, h)
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, i, axis=1)
        return h, ys

    ys0 = jnp.zeros((B, block_t, H), jnp.float32)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h_scr[...], ys0))
    h_scr[...] = h
    hs_ref[0] = ys.astype(hs_ref.dtype)
    hn_ref[0] = h.astype(hn_ref.dtype)


def gru_seq_pallas(U3, xw, h0, *, block_t: int, interpret: bool = True):
    """Sequence-fused GRU recurrence — ONE kernel launch for all T steps.

    U3 (G,H,3,H); xw (G,B,T,3,H) precomputed input half (+bias);
    h0 (G,B,H).  Returns (hs (G,B,T,H), h_T (G,B,H)).  ``G`` batches
    independent recurrences (e.g. the GRU cells of one wavefront slot);
    pass G=1 for a single layer.
    """
    G, B, T, _, H = xw.shape
    bt = max(1, min(block_t, T))
    n_t = cdiv(T, bt)

    kernel = functools.partial(_seq_kernel, block_t=bt, T=T)
    hs, h_n = pl.pallas_call(
        kernel,
        grid=(G, n_t),
        in_specs=[
            pl.BlockSpec((1, B, bt, 3, H), lambda g, t: (g, 0, t, 0, 0)),  # xw
            pl.BlockSpec((1, H, 3, H), lambda g, t: (g, 0, 0, 0)),         # U3
            pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # h0
        ],
        out_specs=[
            pl.BlockSpec((1, B, bt, H), lambda g, t: (g, 0, t, 0)),        # hs
            pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # h_T
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, B, T, H), h0.dtype),
            jax.ShapeDtypeStruct((G, B, H), h0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),   # h — resident across t
        ],
        interpret=interpret,
    )(xw, U3, h0)
    return hs, h_n
