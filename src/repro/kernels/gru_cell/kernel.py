"""Sequence-fused GRU recurrence as a Pallas TPU kernel.

The LSTM sequence kernel's T-stripe pattern (kernels.lstm_cell), ported to
the GRU cell: the time loop lives inside ONE ``pallas_call``, the hidden
state is VMEM-resident across the whole T walk, the precomputed input half
streams in T-block stripes via the BlockSpec index map, and a leading grid
dimension ``g`` batches independent recurrences (distinct U per cell) so
the dispatcher can pack GRU cells into shared wavefront slots.

The GRU is the harder Unfolded case (see core/gru.py): the reset gate
couples into the candidate's recurrent term *multiplicatively*, so the
epilogue is  n = tanh(xw_n + r·(U_n h))  rather than a pure pre-activation
sum — but the dependence structure (one recurrent MVM per step, pointwise
tail) is identical, and so is the fusion win: one launch instead of T, no
per-step HBM round-trip of h.

Gate order along the 3-axis: (z, r, n), matching core.gru.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _seq_kernel(*refs, block_t: int, T: int, masked: bool,
                quant: bool = False, sparse: bool = False):
    """One grid step = one T-block of one recurrence ``g``.

    Grid is (G, n_t) with t innermost; h persists in VMEM scratch across
    the t walk and is re-seeded from h0 at each cell's first block.

    ``masked``: a per-row validity mask (ragged-B packing) rides along as
    an extra input; padded rows freeze their state exactly like the T-edge
    mask, so they are exact no-ops.

    ``quant`` / ``sparse``: the int8 per-gate and row-compacted U paths —
    see the LSTM twin in kernels.lstm_cell.kernel.  The GRU subtlety: the
    per-gate scale must multiply the full (B, 3, H) recurrent accumulate
    BEFORE the reset gate couples ``r * hu[:, 2]`` into the candidate, so
    the dequantized value the gates see matches the oracle's
    ``h @ (Uq * s)`` up to dot/scale distributivity.
    """
    refs = list(refs)
    xw_ref, u_ref = refs[:2]
    pos = 2
    s_ref = rows_ref = m_ref = None
    if quant:
        s_ref, pos = refs[pos], pos + 1
    if sparse:
        rows_ref, pos = refs[pos], pos + 1
    h0_ref = refs[pos]
    pos += 1
    if masked:
        m_ref, pos = refs[pos], pos + 1
    hs_ref, hn_ref, h_scr = refs[pos:]
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    U = u_ref[0]                 # (Hr, 3, H) — resident across the walk
    Hr, H = U.shape[0], U.shape[2]
    U2 = U.reshape(Hr, 3 * H)
    if quant:
        # scale-free int8 -> f32 upcast ONCE per grid step, outside the
        # t loop; the per-gate scale rides on the accumulate below
        U2 = U2.astype(jnp.float32)
    xw_blk = xw_ref[0]                # (B, block_t, 3, H) — streamed stripe
    B = xw_blk.shape[0]
    base = t * block_t

    def step(i, carry):
        h, ys = carry
        xw_t = jax.lax.dynamic_index_in_dim(xw_blk, i, axis=1,
                                            keepdims=False)  # (B, 3, H)
        h_in = h if not sparse else jnp.take(h, rows_ref[0], axis=1)
        hu = jax.lax.dot_general(
            h_in, U2, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(B, 3, H)
        if quant:
            hu = hu * s_ref[0][None, :, None]
        xw32 = xw_t.astype(jnp.float32)
        z = jax.nn.sigmoid(xw32[:, 0] + hu[:, 0])
        r = jax.nn.sigmoid(xw32[:, 1] + hu[:, 1])
        n = jnp.tanh(xw32[:, 2] + r * hu[:, 2])
        h_new = (1 - z) * n + z * h
        # T-edge mask: the last block's tail reads BlockSpec padding
        # (undefined, NaN under interpret) — freeze the state there
        valid = base + i < T
        if m_ref is not None:
            valid = jnp.logical_and(valid, m_ref[0] != 0)[:, None]  # (B, 1)
        h = jnp.where(valid, h_new, h)
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, i, axis=1)
        return h, ys

    ys0 = jnp.zeros((B, block_t, H), jnp.float32)
    h, ys = jax.lax.fori_loop(0, block_t, step, (h_scr[...], ys0))
    h_scr[...] = h
    hs_ref[0] = ys.astype(hs_ref.dtype)
    hn_ref[0] = h.astype(hn_ref.dtype)


def gru_seq_pallas(U3, xw, h0, *, block_t: int, interpret: bool = True,
                   b_mask=None, u_scales=None, u_rows=None):
    """Sequence-fused GRU recurrence — ONE kernel launch for all T steps.

    U3 (G,H,3,H); xw (G,B,T,3,H) precomputed input half (+bias);
    h0 (G,B,H).  Returns (hs (G,B,T,H), h_T (G,B,H)).  ``G`` batches
    independent recurrences (e.g. the GRU cells of one wavefront slot);
    pass G=1 for a single layer.  ``b_mask`` (G,B) int32 marks valid batch
    rows under ragged-B packing: zero rows are exact no-ops.

    ``u_scales`` (G,3) f32: U3 is int8 per-gate quantized; ``u_rows``
    (G,Ha) int32: U3 is row-compacted to (G,Ha,3,H) (see kernels.quant).
    """
    G, B, T, _, H = xw.shape
    Hr = U3.shape[1]
    bt = max(1, min(block_t, T))
    n_t = cdiv(T, bt)

    masked = b_mask is not None
    quant = u_scales is not None
    sparse = u_rows is not None
    kernel = functools.partial(_seq_kernel, block_t=bt, T=T, masked=masked,
                               quant=quant, sparse=sparse)
    in_specs = [
        pl.BlockSpec((1, B, bt, 3, H), lambda g, t: (g, 0, t, 0, 0)),  # xw
        pl.BlockSpec((1, Hr, 3, H), lambda g, t: (g, 0, 0, 0)),        # U3
    ]
    args = (xw, U3)
    if quant:
        in_specs.append(pl.BlockSpec((1, 3), lambda g, t: (g, 0)))     # scales
        args += (u_scales,)
    if sparse:
        Ha = u_rows.shape[1]
        in_specs.append(pl.BlockSpec((1, Ha), lambda g, t: (g, 0)))    # rows
        args += (u_rows,)
    in_specs.append(pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)))   # h0
    args += (h0,)
    if masked:
        in_specs.append(pl.BlockSpec((1, B), lambda g, t: (g, 0)))     # mask
        args += (b_mask,)
    hs, h_n = pl.pallas_call(
        kernel,
        grid=(G, n_t),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, B, bt, H), lambda g, t: (g, 0, t, 0)),        # hs
            pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # h_T
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, B, T, H), h0.dtype),
            jax.ShapeDtypeStruct((G, B, H), h0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),   # h — resident across t
        ],
        interpret=interpret,
    )(*args)
    return hs, h_n


# ===========================================================================
# chained decode kernel: a whole T=1 stack tick inside ONE pallas_call
# ===========================================================================


def _decode_kernel(xw0_ref, w_ref, b_ref, u_ref, h0_ref, hn_ref, y_scr,
                   xw_scr, *, out_dtype, xw_dtype):
    """One grid step = one layer of a T=1 GRU decode tick (see the LSTM
    twin in kernels.lstm_cell.kernel for the full story): the layer chain
    serializes through ``y_scr``, layer 0 uses the pre-hoisted ``xw0``
    (its in-kernel input GEMM pl.when-guarded away), deeper layers compute
    their input GEMM in-kernel — one launch per tick instead of L."""
    l = pl.program_id(0)
    H = u_ref.shape[-1]
    B = xw0_ref.shape[0]

    @pl.when(l == 0)
    def _first():
        xw_scr[...] = xw0_ref[...].astype(jnp.float32)

    @pl.when(l > 0)
    def _deeper():
        # round GEMM + bias through the per-layer hoist's result dtype
        # (``xw_dtype``) — see the LSTM twin for why this keeps
        # low-precision weight stacks bit-identical too
        xw = jax.lax.dot_general(
            y_scr[...], w_ref[0].reshape(H, 3 * H).astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(xw_dtype).reshape(B, 3, H)
        xw_scr[...] = (xw + b_ref[0].astype(xw_dtype)).astype(jnp.float32)

    xw = xw_scr[...]
    hu = jax.lax.dot_general(
        h0_ref[0].astype(jnp.float32), u_ref[0].reshape(H, 3 * H),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(B, 3, H)
    z = jax.nn.sigmoid(xw[:, 0] + hu[:, 0])
    r = jax.nn.sigmoid(xw[:, 1] + hu[:, 1])
    n = jnp.tanh(xw[:, 2] + r * hu[:, 2])
    h = (1 - z) * n + z * h0_ref[0].astype(jnp.float32)
    y_scr[...] = h.astype(out_dtype).astype(jnp.float32)
    hn_ref[0] = h.astype(hn_ref.dtype)


def gru_decode_pallas(xw0, Ws, bs, Us, h0, *, interpret: bool = True):
    """One T=1 decode tick through an L-layer GRU stack — ONE launch.

    xw0 (B,3,H) hoisted layer-0 input half (+bias); Ws (L,H,3,H) (entry 0
    unused); bs (L,3,H); Us (L,H,3,H); h0 (L,B,H).  Returns h_n (L,B,H);
    the top-layer feedback frame is ``h_n[-1]``.
    """
    L, B, H = h0.shape
    kernel = functools.partial(
        _decode_kernel, out_dtype=h0.dtype,
        xw_dtype=jnp.promote_types(h0.dtype, Ws.dtype))
    (h_n,) = pl.pallas_call(
        kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((B, 3, H), lambda l: (0, 0, 0)),        # xw0
            pl.BlockSpec((1, H, 3, H), lambda l: (l, 0, 0, 0)),  # Ws
            pl.BlockSpec((1, 3, H), lambda l: (l, 0, 0)),        # bs
            pl.BlockSpec((1, H, 3, H), lambda l: (l, 0, 0, 0)),  # Us
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),        # h0
        ],
        out_specs=[
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),        # h_n
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, B, H), h0.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),     # y — the layer chain's wire
            pltpu.VMEM((B, 3, H), jnp.float32),  # xw — this layer's input half
        ],
        interpret=interpret,
    )(xw0, Ws, bs, Us, h0)
    return h_n
