"""Pure-jnp oracle for the fused LSTM recurrent step."""
import jax
import jax.numpy as jnp


def lstm_cell_ref(U4, xw_t, h_prev, c_prev):
    """U4 (H, 4, H); xw_t (B, 4, H) precomputed input half (+bias);
    h_prev (B, H); c_prev (B, H) fp32.  Returns (h, c)."""
    gates = xw_t.astype(jnp.float32) + jnp.einsum(
        "bx,xgj->bgj", h_prev, U4, preferred_element_type=jnp.float32)
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    g = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c = f * c_prev.astype(jnp.float32) + i * g
    h = o * jnp.tanh(c)
    return h.astype(h_prev.dtype), c
