"""Pure-jnp oracles for the fused LSTM kernels (single step + sequence)."""
import jax
import jax.numpy as jnp


def lstm_cell_ref(U4, xw_t, h_prev, c_prev):
    """U4 (H, 4, H); xw_t (B, 4, H) precomputed input half (+bias);
    h_prev (B, H); c_prev (B, H) fp32.  Returns (h, c)."""
    gates = xw_t.astype(jnp.float32) + jnp.einsum(
        "bx,xgj->bgj", h_prev, U4, preferred_element_type=jnp.float32)
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    g = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c = f * c_prev.astype(jnp.float32) + i * g
    h = o * jnp.tanh(c)
    return h.astype(h_prev.dtype), c


def lstm_seq_ref(U4, xw, h0, c0):
    """Scan-based oracle for the sequence-fused kernel.

    U4 (H,4,H) or (G,H,4,H); xw (B,T,4,H) or (G,B,T,4,H); h0/c0 (…B,H).
    Returns (hs (…B,T,H), h_T (…B,H), c_T (…B,H))."""
    if xw.ndim == 5:
        return jax.vmap(lstm_seq_ref)(U4, xw, h0, c0)

    def step(carry, xw_t):
        h, c = carry
        h, c = lstm_cell_ref(U4, xw_t, h, c)
        return (h, c), h

    (h_n, c_n), hs = jax.lax.scan(
        step, (h0, c0.astype(jnp.float32)), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1), h_n, c_n
