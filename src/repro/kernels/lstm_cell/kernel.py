"""Fused LSTM recurrent step as a Pallas TPU kernel.

SHARP's three pipeline stages (Compute Unit -> A-MFU -> Cell Updater)
collapse into one VMEM-resident kernel: the recurrent MVM U·h accumulates in
a VMEM scratch tile, and on the last reduction step the gate activations and
the cell/hidden update run as the epilogue on the same tile — the TPU
analogue of SHARP's "output-based tiling" (no HBM round-trip between the
MVM, activation and update stages).

Grid: (j over H output columns, k over H reduction rows); k innermost so the
accumulator tile is revisited.  Block shapes come from the autotune table
(core.tiling.select_block_shape), mirroring the paper's per-model K-width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(h_ref, u_ref, xw_ref, c_ref, h_out_ref, c_out_ref, acc_ref, *,
            n_k: int, H: int, bk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- Compute Unit: one reduction stripe of U·h ----------------------
    h_blk = h_ref[...]  # (B, bk)
    u_blk = u_ref[...]  # (bk, 4, bh)
    # mask the reduction tail (matrix edge -> SHARP's padding handling);
    # both operands, since out-of-bounds pads are undefined (NaN in interpret)
    base = k * bk
    idx = base + jax.lax.broadcasted_iota(jnp.int32, h_blk.shape, 1)
    h_blk = jnp.where(idx < H, h_blk, 0).astype(h_blk.dtype)
    ridx = base + jax.lax.broadcasted_iota(jnp.int32, u_blk.shape, 0)
    u_blk = jnp.where(ridx < H, u_blk, 0).astype(u_blk.dtype)
    acc_ref[...] += jax.lax.dot_general(
        h_blk, u_blk.reshape(u_blk.shape[0], -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(acc_ref.shape)

    # ---- A-MFU + Cell Updater epilogue on the last stripe ---------------
    @pl.when(k == n_k - 1)
    def _epilogue():
        gates = acc_ref[...] + xw_ref[...].astype(jnp.float32)  # (B, 4, bh)
        i = jax.nn.sigmoid(gates[:, 0])
        f = jax.nn.sigmoid(gates[:, 1])
        g = jnp.tanh(gates[:, 2])
        o = jax.nn.sigmoid(gates[:, 3])
        c = f * c_ref[...].astype(jnp.float32) + i * g
        c_out_ref[...] = c
        h_out_ref[...] = (o * jnp.tanh(c)).astype(h_out_ref.dtype)


def lstm_cell_pallas(U4, xw_t, h_prev, c_prev, *, block_h: int, block_k: int,
                     interpret: bool = True):
    """U4 (H,4,H); xw_t (B,4,H); h_prev (B,H); c_prev (B,H) fp32."""
    H = U4.shape[0]
    B = h_prev.shape[0]
    n_j = cdiv(H, block_h)
    n_k = cdiv(H, block_k)

    kernel = functools.partial(_kernel, n_k=n_k, H=H, bk=block_k)
    h_out, c_out = pl.pallas_call(
        kernel,
        grid=(n_j, n_k),
        in_specs=[
            pl.BlockSpec((B, block_k), lambda j, k: (0, k)),          # h_prev
            pl.BlockSpec((block_k, 4, block_h), lambda j, k: (k, 0, j)),  # U4
            pl.BlockSpec((B, 4, block_h), lambda j, k: (0, 0, j)),    # xw_t
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # c_prev
        ],
        out_specs=[
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # h
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # c
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h_prev.dtype),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, 4, block_h), jnp.float32)],
        interpret=interpret,
    )(h_prev, U4, xw_t, c_prev)
    return h_out, c_out
