"""Fused LSTM recurrence as Pallas TPU kernels.

Two granularities live here:

``lstm_cell_pallas`` — ONE recurrent step.  SHARP's three pipeline stages
(Compute Unit -> A-MFU -> Cell Updater) collapse into one VMEM-resident
kernel: the recurrent MVM U·h accumulates in a VMEM scratch tile, and on the
last reduction step the gate activations and the cell/hidden update run as
the epilogue on the same tile — the TPU analogue of SHARP's "output-based
tiling" (no HBM round-trip between the MVM, activation and update stages).
Grid: (j over H output columns, k over H reduction rows); k innermost so the
accumulator tile is revisited.  Block shapes come from the autotune table
(core.tiling.select_block_shape), mirroring the paper's per-model K-width.

``lstm_seq_pallas`` — the WHOLE sequence.  The per-step kernel still pays T
kernel launches and T HBM round-trips of (h, c) when driven by ``lax.scan``;
SHARP's point (§5, Fig. 8.d) is that the recurrent state should stay
resident while timesteps stream through the datapath.  Here the time loop
moves *inside* a single ``pallas_call``: the grid's innermost dimension
walks T-blocks, the precomputed input half ``xw[:, t]`` streams in stripe by
stripe via the BlockSpec index map, and (h, c) live in VMEM scratch that
persists across grid steps — state never touches HBM between timesteps.  A
leading grid dimension ``g`` batches independent recurrences (distinct U per
cell), which is what the wavefront multi-layer schedule packs an
anti-diagonal of (layer, time-chunk) cells into.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv


def _kernel(h_ref, u_ref, xw_ref, c_ref, h_out_ref, c_out_ref, acc_ref, *,
            n_k: int, H: int, bk: int):
    k = pl.program_id(1)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # ---- Compute Unit: one reduction stripe of U·h ----------------------
    h_blk = h_ref[...]  # (B, bk)
    u_blk = u_ref[...]  # (bk, 4, bh)
    # mask the reduction tail (matrix edge -> SHARP's padding handling);
    # both operands, since out-of-bounds pads are undefined (NaN in interpret)
    base = k * bk
    idx = base + jax.lax.broadcasted_iota(jnp.int32, h_blk.shape, 1)
    h_blk = jnp.where(idx < H, h_blk, 0).astype(h_blk.dtype)
    ridx = base + jax.lax.broadcasted_iota(jnp.int32, u_blk.shape, 0)
    u_blk = jnp.where(ridx < H, u_blk, 0).astype(u_blk.dtype)
    acc_ref[...] += jax.lax.dot_general(
        h_blk, u_blk.reshape(u_blk.shape[0], -1),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(acc_ref.shape)

    # ---- A-MFU + Cell Updater epilogue on the last stripe ---------------
    @pl.when(k == n_k - 1)
    def _epilogue():
        gates = acc_ref[...] + xw_ref[...].astype(jnp.float32)  # (B, 4, bh)
        i = jax.nn.sigmoid(gates[:, 0])
        f = jax.nn.sigmoid(gates[:, 1])
        g = jnp.tanh(gates[:, 2])
        o = jax.nn.sigmoid(gates[:, 3])
        c = f * c_ref[...].astype(jnp.float32) + i * g
        c_out_ref[...] = c
        h_out_ref[...] = (o * jnp.tanh(c)).astype(h_out_ref.dtype)


def lstm_cell_pallas(U4, xw_t, h_prev, c_prev, *, block_h: int, block_k: int,
                     interpret: bool = True):
    """U4 (H,4,H); xw_t (B,4,H); h_prev (B,H); c_prev (B,H) fp32."""
    H = U4.shape[0]
    B = h_prev.shape[0]
    n_j = cdiv(H, block_h)
    n_k = cdiv(H, block_k)

    kernel = functools.partial(_kernel, n_k=n_k, H=H, bk=block_k)
    h_out, c_out = pl.pallas_call(
        kernel,
        grid=(n_j, n_k),
        in_specs=[
            pl.BlockSpec((B, block_k), lambda j, k: (0, k)),          # h_prev
            pl.BlockSpec((block_k, 4, block_h), lambda j, k: (k, 0, j)),  # U4
            pl.BlockSpec((B, 4, block_h), lambda j, k: (0, 0, j)),    # xw_t
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # c_prev
        ],
        out_specs=[
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # h
            pl.BlockSpec((B, block_h), lambda j, k: (0, j)),          # c
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H), h_prev.dtype),
            jax.ShapeDtypeStruct((B, H), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((B, 4, block_h), jnp.float32)],
        interpret=interpret,
    )(h_prev, U4, xw_t, c_prev)
    return h_out, c_out


# ===========================================================================
# sequence-fused kernel: time loop inside ONE pallas_call
# ===========================================================================


def _seq_kernel(*refs, block_t: int, T: int, masked: bool,
                quant: bool = False, sparse: bool = False):
    """One grid step = one T-block of one recurrence ``g``.

    Grid is (G, n_t) with t innermost; (h, c) persist in VMEM scratch across
    the t walk and are re-seeded from (h0, c0) at each cell's first block.

    ``masked``: a per-row validity mask (ragged-B packing — cells of
    different batch widths padded to a common B) rides along as an extra
    input; padded rows freeze their state exactly like the T-edge mask, so
    they are exact no-ops and h_T/c_T of valid rows are bit-exact.

    ``quant``: U arrives int8 with a (4,) per-gate scale operand; the int8
    payload is what sits resident in VMEM (4x smaller), the dot
    accumulates in fp32 over the scale-free upcast, and the scale is
    applied to the (B, 4, H) accumulate after the dot — so the only error
    vs the dequantized oracle is the distributivity of ``(h @ Uq) * s``.

    ``sparse``: U arrives row-compacted (Ha <= H input rows) with an
    (Ha,) int32 row-index operand; h is gathered to the surviving rows
    before the dot.  Padding rows are zero U rows at index 0 — exact
    no-ops (see kernels.quant.compact_rows).
    """
    refs = list(refs)
    xw_ref, u_ref = refs[:2]
    pos = 2
    s_ref = rows_ref = m_ref = None
    if quant:
        s_ref, pos = refs[pos], pos + 1
    if sparse:
        rows_ref, pos = refs[pos], pos + 1
    h0_ref, c0_ref = refs[pos:pos + 2]
    pos += 2
    if masked:
        m_ref, pos = refs[pos], pos + 1
    hs_ref, hn_ref, cn_ref, h_scr, c_scr = refs[pos:]
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _seed():
        h_scr[...] = h0_ref[0].astype(jnp.float32)
        c_scr[...] = c0_ref[0].astype(jnp.float32)

    U = u_ref[0]                 # (Hr, 4, H) — resident across the walk
    Hr, H = U.shape[0], U.shape[2]
    U2 = U.reshape(Hr, 4 * H)
    if quant:
        # scale-free int8 -> f32 upcast ONCE per grid step, outside the
        # t loop; the per-gate scale rides on the accumulate below
        U2 = U2.astype(jnp.float32)
    xw_blk = xw_ref[0]                # (B, block_t, 4, H) — streamed stripe
    B = xw_blk.shape[0]
    base = t * block_t

    def step(i, carry):
        h, c, ys = carry
        xw_t = jax.lax.dynamic_index_in_dim(xw_blk, i, axis=1,
                                            keepdims=False)  # (B, 4, H)
        h_in = h if not sparse else jnp.take(h, rows_ref[0], axis=1)
        acc = jax.lax.dot_general(
            h_in, U2, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).reshape(B, 4, H)
        if quant:
            acc = acc * s_ref[0][None, :, None]
        gates = xw_t.astype(jnp.float32) + acc
        ig = jax.nn.sigmoid(gates[:, 0])
        fg = jax.nn.sigmoid(gates[:, 1])
        gg = jnp.tanh(gates[:, 2])
        og = jax.nn.sigmoid(gates[:, 3])
        c_new = fg * c + ig * gg
        h_new = og * jnp.tanh(c_new)
        # T-edge mask: the last block's tail reads BlockSpec padding
        # (undefined, NaN under interpret) — freeze the state there
        valid = base + i < T
        if m_ref is not None:
            valid = jnp.logical_and(valid, m_ref[0] != 0)[:, None]  # (B, 1)
        h = jnp.where(valid, h_new, h)
        c = jnp.where(valid, c_new, c)
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, i, axis=1)
        return h, c, ys

    ys0 = jnp.zeros((B, block_t, H), jnp.float32)
    h, c, ys = jax.lax.fori_loop(
        0, block_t, step, (h_scr[...], c_scr[...], ys0))
    h_scr[...] = h
    c_scr[...] = c
    hs_ref[0] = ys.astype(hs_ref.dtype)
    hn_ref[0] = h.astype(hn_ref.dtype)
    cn_ref[0] = c


def lstm_seq_pallas(U4, xw, h0, c0, *, block_t: int, interpret: bool = True,
                    b_mask=None, u_scales=None, u_rows=None):
    """Sequence-fused LSTM recurrence — ONE kernel launch for all T steps.

    U4 (G,H,4,H); xw (G,B,T,4,H) precomputed input half (+bias);
    h0 (G,B,H); c0 (G,B,H).  Returns (hs (G,B,T,H), h_T (G,B,H),
    c_T (G,B,H)).  ``G`` batches independent recurrences (e.g. the cells of
    one wavefront slot); pass G=1 for a single layer.  ``b_mask`` (G,B)
    int32 marks valid batch rows when cells of different B were padded to a
    common width (ragged-B packing): zero rows are exact no-ops.

    ``u_scales`` (G,4) f32: U4 is int8 per-gate quantized; fp32
    accumulate, scale applied post-dot (see kernels.quant).  ``u_rows``
    (G,Ha) int32: U4 is row-compacted to (G,Ha,4,H) — the kernel gathers
    h to the surviving rows (block-sparse row tiles).
    """
    G, B, T, _, H = xw.shape
    Hr = U4.shape[1]
    bt = max(1, min(block_t, T))
    n_t = cdiv(T, bt)

    masked = b_mask is not None
    quant = u_scales is not None
    sparse = u_rows is not None
    kernel = functools.partial(_seq_kernel, block_t=bt, T=T, masked=masked,
                               quant=quant, sparse=sparse)
    in_specs = [
        pl.BlockSpec((1, B, bt, 4, H), lambda g, t: (g, 0, t, 0, 0)),  # xw
        pl.BlockSpec((1, Hr, 4, H), lambda g, t: (g, 0, 0, 0)),        # U4
    ]
    args = (xw, U4)
    if quant:
        in_specs.append(pl.BlockSpec((1, 4), lambda g, t: (g, 0)))     # scales
        args += (u_scales,)
    if sparse:
        Ha = u_rows.shape[1]
        in_specs.append(pl.BlockSpec((1, Ha), lambda g, t: (g, 0)))    # rows
        args += (u_rows,)
    in_specs += [
        pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # h0
        pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # c0
    ]
    args += (h0, c0)
    if masked:
        in_specs.append(pl.BlockSpec((1, B), lambda g, t: (g, 0)))     # mask
        args += (b_mask,)
    hs, h_n, c_n = pl.pallas_call(
        kernel,
        grid=(G, n_t),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, B, bt, H), lambda g, t: (g, 0, t, 0)),        # hs
            pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # h_T
            pl.BlockSpec((1, B, H), lambda g, t: (g, 0, 0)),               # c_T
        ],
        out_shape=[
            jax.ShapeDtypeStruct((G, B, T, H), h0.dtype),
            jax.ShapeDtypeStruct((G, B, H), h0.dtype),
            jax.ShapeDtypeStruct((G, B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),   # h — resident across t
            pltpu.VMEM((B, H), jnp.float32),   # c — resident across t
        ],
        interpret=interpret,
    )(*args)
    return hs, h_n, c_n


# ===========================================================================
# chained decode kernel: a whole T=1 stack tick inside ONE pallas_call
# ===========================================================================


def _decode_kernel(xw0_ref, w_ref, b_ref, u_ref, h0_ref, c0_ref,
                   hn_ref, cn_ref, y_scr, xw_scr, *, out_dtype, xw_dtype):
    """One grid step = one layer of a T=1 decode tick.

    Grid is (L,).  The layer cells of a decode tick are serially dependent
    (layer l eats layer l-1's output *at the same timestep*), so no
    wavefront exists — but the TPU grid walks its steps in order, which is
    exactly a dependence-respecting schedule: the inter-layer value flows
    through ``y_scr`` (VMEM scratch), the same persistence trick the
    sequence kernels use for (h, c) across t-blocks.  Layer 0 uses the
    hoisted input half ``xw0`` (its input exists before launch; the in-
    kernel input GEMM is pl.when-guarded so layer 0 pays no dead MXU
    work); deeper layers compute their input GEMM *in-kernel* from
    y_scr — one launch per tick instead of L.

    The inter-layer value is rounded through ``out_dtype`` and the input
    GEMM through ``xw_dtype`` (the hoist's promotion dtype) before use, so
    a chained tick reproduces the per-layer launches' rounding points —
    bit-identical whenever the hoist promotes to f32 (see lstm_decode).
    """
    l = pl.program_id(0)
    H = u_ref.shape[-1]
    B = xw0_ref.shape[0]

    @pl.when(l == 0)
    def _first():
        xw_scr[...] = xw0_ref[...].astype(jnp.float32)

    @pl.when(l > 0)
    def _deeper():
        # round GEMM + bias through the per-layer hoist's result dtype
        # (``xw_dtype``: einsum promotes activations x weights, then the
        # seq kernel casts to f32) — this keeps a chained tick
        # bit-identical for low-precision weight stacks too, not just f32
        # params
        xw = jax.lax.dot_general(
            y_scr[...], w_ref[0].reshape(H, 4 * H).astype(jnp.float32),
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(xw_dtype).reshape(B, 4, H)
        xw_scr[...] = (xw + b_ref[0].astype(xw_dtype)).astype(jnp.float32)

    gates = xw_scr[...] + jax.lax.dot_general(
        h0_ref[0].astype(jnp.float32), u_ref[0].reshape(H, 4 * H),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(B, 4, H)
    i = jax.nn.sigmoid(gates[:, 0])
    f = jax.nn.sigmoid(gates[:, 1])
    g = jnp.tanh(gates[:, 2])
    o = jax.nn.sigmoid(gates[:, 3])
    c = f * c0_ref[0].astype(jnp.float32) + i * g
    h = o * jnp.tanh(c)
    y_scr[...] = h.astype(out_dtype).astype(jnp.float32)
    hn_ref[0] = h.astype(hn_ref.dtype)
    cn_ref[0] = c


def lstm_decode_pallas(xw0, Ws, bs, Us, h0, c0, *, interpret: bool = True):
    """One T=1 decode tick through an L-layer LSTM stack — ONE launch.

    xw0 (B,4,H) hoisted layer-0 input half (+bias); Ws (L,H,4,H) input
    weights per layer, gate axis unpacked (entry 0 is unused — layer 0 is
    pre-hoisted, so X may differ from H); bs (L,4,H); Us (L,H,4,H);
    h0/c0 (L,B,H) the per-layer recurrent state.  Returns (h_n (L,B,H),
    c_n (L,B,H) fp32): layer l's new hidden state IS its T=1 output, so the
    top-layer feedback frame is ``h_n[-1]``.
    """
    L, B, H = h0.shape
    kernel = functools.partial(
        _decode_kernel, out_dtype=h0.dtype,
        xw_dtype=jnp.promote_types(h0.dtype, Ws.dtype))
    h_n, c_n = pl.pallas_call(
        kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((B, 4, H), lambda l: (0, 0, 0)),      # xw0
            pl.BlockSpec((1, H, 4, H), lambda l: (l, 0, 0, 0)),  # Ws
            pl.BlockSpec((1, 4, H), lambda l: (l, 0, 0)),      # bs
            pl.BlockSpec((1, H, 4, H), lambda l: (l, 0, 0, 0)),  # Us
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),      # h0
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),      # c0
        ],
        out_specs=[
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),      # h_n
            pl.BlockSpec((1, B, H), lambda l: (l, 0, 0)),      # c_n
        ],
        out_shape=[
            jax.ShapeDtypeStruct((L, B, H), h0.dtype),
            jax.ShapeDtypeStruct((L, B, H), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((B, H), jnp.float32),     # y — the layer chain's wire
            pltpu.VMEM((B, 4, H), jnp.float32),  # xw — this layer's input half
        ],
        interpret=interpret,
    )(xw0, Ws, bs, Us, h0, c0)
    return h_n, c_n
