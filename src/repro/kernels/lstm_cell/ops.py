"""Jitted public wrappers for the fused LSTM kernels (cell + sequence)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import table
from repro.kernels.common import default_interpret, ragged_b_mask, round_up
from repro.kernels.lstm_cell.kernel import (lstm_cell_pallas,
                                            lstm_decode_pallas,
                                            lstm_seq_pallas)
from repro.kernels.lstm_cell.ref import lstm_cell_ref, lstm_seq_ref


@functools.partial(jax.jit, static_argnames=("block_h", "block_k", "interpret"))
def lstm_cell(U4, xw_t, h_prev, c_prev, *, block_h: int = 0, block_k: int = 0,
              interpret: bool | None = None):
    """Fused recurrent LSTM step.  U4 (H,4,H); xw_t (B,4,H) precomputed
    input half; h (B,H); c (B,H) fp32 -> (h, c)."""
    H = U4.shape[0]
    if not block_h or not block_k:
        bk, bh = table().block(H, H, vmem_budget=2 * 2**20)
        block_h = block_h or min(bh, H)
        block_k = block_k or min(bk, H)
    if interpret is None:
        interpret = default_interpret()
    return lstm_cell_pallas(U4, xw_t, h_prev, c_prev, block_h=block_h,
                            block_k=block_k, interpret=interpret)


def as_cell_kernel(interpret: bool | None = None):
    """Adapter for core.schedules.run_layer_unfolded(cell_kernel=...).

    Schedules store U as (H, 4H) gate-major; the kernel wants (H, 4, H)."""

    def cell(U, xw_t, h, c):
        H = U.shape[0]
        U4 = U.reshape(H, 4, H)
        xw4 = xw_t.reshape(xw_t.shape[0], 4, H)
        return lstm_cell(U4, xw4, h, c, interpret=interpret)

    return cell


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def lstm_seq(U4, xw, h0=None, c0=None, *, b_valid=None, u_scales=None,
             u_rows=None, block_t: int = 0, interpret: bool | None = None):
    """Sequence-fused recurrence: ONE pallas_call for the whole T walk.

    U4 (H,4,H) or, for a batch of G independent cells, (G,H,4,H); xw
    (B,T,4,H) / (G,B,T,4,H) precomputed input half; h0/c0 optional (…B,H)
    initial state (each defaults to zeros when omitted, independently).
    Returns (hs, h_T, c_T); ``hs`` is (…B,T,H).  ``block_t`` (the streamed
    T-stripe) defaults to the autotune table's VMEM-budget choice.

    ``b_valid`` (stacked form only): (G,) int array of valid batch rows per
    cell when ragged-B cells were padded to a common B — rows >= b_valid[g]
    are exact no-ops (state passes through), so valid rows' t=T state is
    bit-exact regardless of padding.

    Time-reversed walks (the bwd half of a bidirectional layer) use
    pre-launch reversal: feed ``jnp.flip(xw, time_axis)`` and flip ``hs``
    back — the kernel walks whatever order the stripe carries, its T-edge
    mask only ever pads *beyond* T, so the reversed walk is exact for any
    T, ragged remainder chunks included, and ``h_T``/``c_T`` are then the
    state after the t=0 step (the end of the reversed walk).  The dispatch
    executor flips per cell, so one G-batched launch can mix fwd and bwd
    cells (tests/kernels/test_seq_reversed.py property-tests the
    contract).

    ``u_scales`` (…4) f32 marks U4 as int8 per-gate quantized payload;
    ``u_rows`` (…Ha) int32 marks U4 as row-compacted (block-sparse) —
    see kernels.quant for both transforms and their exactness story."""
    stacked = xw.ndim == 5
    if not stacked:
        if b_valid is not None:
            raise ValueError("b_valid requires the stacked (G, ...) form")
        U4, xw = U4[None], xw[None]
        if h0 is not None:
            h0 = h0[None]
        if c0 is not None:
            c0 = c0[None]
        if u_scales is not None:
            u_scales = u_scales[None]
        if u_rows is not None:
            u_rows = u_rows[None]
    G, B, T, _, H = xw.shape
    if h0 is None:
        h0 = jnp.zeros((G, B, H), xw.dtype)
    if c0 is None:
        c0 = jnp.zeros((G, B, H), jnp.float32)
    if T == 0:  # degenerate empty sequence: state passes through
        hs = jnp.zeros((G, B, 0, H), h0.dtype)
        return (hs, h0, c0.astype(jnp.float32)) if stacked else \
            (hs[0], h0[0], c0[0].astype(jnp.float32))
    if not block_t:
        precision = "int8" if u_scales is not None else "fp32"
        dens = 1.0 if u_rows is None else u_rows.shape[-1] / H
        block_t = table().seq_block(T, B, H, precision=precision,
                                    density=dens)
    if interpret is None:
        interpret = default_interpret()
    b_mask = None if b_valid is None else ragged_b_mask(G, B, b_valid)
    hs, h_n, c_n = lstm_seq_pallas(U4, xw, h0, c0, block_t=block_t,
                                   interpret=interpret, b_mask=b_mask,
                                   u_scales=u_scales, u_rows=u_rows)
    if not stacked:
        hs, h_n, c_n = hs[0], h_n[0], c_n[0]
    return hs, h_n, c_n


@functools.partial(jax.jit, static_argnames=("interpret",))
def lstm_decode(xw0, Ws, bs, Us, h0, c0, *, interpret: bool | None = None):
    """One T=1 decode tick through a whole L-layer stack in ONE launch.

    The L layer cells of a decode tick are serially dependent, so they
    cannot wavefront — but they CAN share a single kernel launch: the grid
    walks layers in order and the inter-layer value chains through VMEM
    scratch (ROADMAP: "a T=1 wavefront over layers is a single slot").

    xw0 (B,4,H) hoisted layer-0 input half; Ws (L,H,4,H) (entry 0 unused,
    so layer 0's input width may differ from H); bs (L,4,H); Us (L,H,4,H);
    h0/c0 (L,B,H).  Returns (h_n (L,B,H), c_n (L,B,H) fp32); the top-layer
    feedback frame is ``h_n[-1]`` and each layer's new h IS its T=1 output.
    Bit-identical to L per-layer ``lstm_seq(..., T=1)`` launches whenever
    the hoisted input GEMM promotes to f32 (f32 weights with any
    activation dtype, or f32 activations with any weight dtype); fully-
    bf16 stacks agree to one bf16 ulp per deeper layer under interpret
    mode, which emulates in-kernel bf16 dots in f32."""
    if interpret is None:
        interpret = default_interpret()
    return lstm_decode_pallas(xw0, Ws, bs, Us, h0, c0, interpret=interpret)


def as_seq_kernel(interpret: bool | None = None, block_t: int = 0):
    """Adapter for core.schedules.run_layer_fused / core.unfolded.unfold.

    Schedules store U as (H, 4H) gate-major and the hoisted input half as
    (B, T, 4H); the kernel wants the gate axis unpacked to (4, H)."""

    def seq(U, xw, h0=None, c0=None):
        H = U.shape[0]
        B, T = xw.shape[0], xw.shape[1]
        return lstm_seq(U.reshape(H, 4, H), xw.reshape(B, T, 4, H), h0, c0,
                        block_t=block_t, interpret=interpret)

    return seq


__all__ = ["lstm_cell", "lstm_cell_ref", "as_cell_kernel",
           "lstm_seq", "lstm_seq_ref", "as_seq_kernel", "lstm_decode"]
