"""Jitted public wrapper for the fused LSTM cell kernel."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.autotune import table
from repro.kernels.common import default_interpret, round_up
from repro.kernels.lstm_cell.kernel import lstm_cell_pallas
from repro.kernels.lstm_cell.ref import lstm_cell_ref


@functools.partial(jax.jit, static_argnames=("block_h", "block_k", "interpret"))
def lstm_cell(U4, xw_t, h_prev, c_prev, *, block_h: int = 0, block_k: int = 0,
              interpret: bool | None = None):
    """Fused recurrent LSTM step.  U4 (H,4,H); xw_t (B,4,H) precomputed
    input half; h (B,H); c (B,H) fp32 -> (h, c)."""
    H = U4.shape[0]
    if not block_h or not block_k:
        bk, bh = table().block(H, H, vmem_budget=2 * 2**20)
        block_h = block_h or min(bh, H)
        block_k = block_k or min(bk, H)
    if interpret is None:
        interpret = default_interpret()
    return lstm_cell_pallas(U4, xw_t, h_prev, c_prev, block_h=block_h,
                            block_k=block_k, interpret=interpret)


def as_cell_kernel(interpret: bool | None = None):
    """Adapter for core.schedules.run_layer_unfolded(cell_kernel=...).

    Schedules store U as (H, 4H) gate-major; the kernel wants (H, 4, H)."""

    def cell(U, xw_t, h, c):
        H = U.shape[0]
        U4 = U.reshape(H, 4, H)
        xw4 = xw_t.reshape(xw_t.shape[0], 4, H)
        return lstm_cell(U4, xw4, h, c, interpret=interpret)

    return cell


__all__ = ["lstm_cell", "lstm_cell_ref", "as_cell_kernel"]
