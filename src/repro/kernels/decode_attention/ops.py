"""Jitted wrapper for flash-decode attention."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.common import default_interpret
from repro.kernels.decode_attention.kernel import decode_attention_pallas
from repro.kernels.decode_attention.ref import decode_attention_ref


@functools.partial(jax.jit, static_argnames=("block_t", "interpret"))
def decode_attention(q, k_cache, v_cache, valid, *, block_t: int = 0,
                     interpret: bool | None = None):
    """q (B, Hq, D) or (B, 1, Hq, D); caches (B, T, Hk, D); valid (B,)."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    T = k_cache.shape[1]
    if not block_t:
        block_t = min(512, T)
        while T % block_t:
            block_t //= 2
    if interpret is None:
        interpret = default_interpret()
    o = decode_attention_pallas(q, k_cache, v_cache, valid, block_t=block_t,
                                interpret=interpret)
    return o[:, None] if squeeze else o


__all__ = ["decode_attention", "decode_attention_ref"]
