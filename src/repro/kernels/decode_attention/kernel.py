"""Flash-decode GQA attention: one query token vs. a long KV cache.

The decode step is the transformer analogue of SHARP's serial recurrent
tail: it must finish before the next token can start, so its latency sets
the serving rate.  The kernel streams the KV cache block-by-block
(HBM -> VMEM) with an online-softmax accumulator in VMEM scratch — one pass
over the cache, no (B, T) score materialization.

Grid: (b over batch, t over KV blocks), t innermost so (m, l, acc) scratch
carries across cache blocks for a fixed request.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import cdiv

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, m_ref, l_ref, acc_ref, *,
            n_t: int, bt: int, G: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]            # (Hq, D)
    k = k_ref[0]            # (bt, Hk, D)
    v = v_ref[0]            # (bt, Hk, D)
    valid = valid_ref[0, 0]  # scalar int32
    Hq, D = q.shape
    Hk = k.shape[1]
    qg = q.reshape(Hk, G, D).astype(jnp.float32)
    s = jnp.einsum("hgd,thd->hgt", qg, k.astype(jnp.float32))
    s = s / jnp.sqrt(D).astype(jnp.float32)
    pos = t * bt + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
    s = jnp.where(pos < valid, s, NEG_INF)

    m_prev = m_ref[...]                      # (Hk, G)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])        # (Hk, G, bt)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    pv = jnp.einsum("hgt,thd->hgd", p, v.astype(jnp.float32))
    acc_ref[...] = acc_ref[...] * alpha[..., None] + pv
    m_ref[...] = m_new

    @pl.when(t == n_t - 1)
    def _final():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = out.reshape(1, Hq, D).astype(o_ref.dtype)


def decode_attention_pallas(q, k_cache, v_cache, valid, *, block_t: int,
                            interpret: bool = True):
    """q (B, Hq, D); caches (B, T, Hk, D); valid (B,) int32."""
    B, Hq, D = q.shape
    T, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    assert T % block_t == 0, (T, block_t)
    n_t = T // block_t
    valid2 = valid.reshape(B, 1).astype(jnp.int32)
    kernel = functools.partial(_kernel, n_t=n_t, bt=block_t, G=G)
    out = pl.pallas_call(
        kernel,
        grid=(B, n_t),
        in_specs=[
            pl.BlockSpec((1, Hq, D), lambda b, t: (b, 0, 0)),
            pl.BlockSpec((1, block_t, Hk, D), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, block_t, Hk, D), lambda b, t: (b, t, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, t: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, Hq, D), lambda b, t: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((Hk, G), jnp.float32),
            pltpu.VMEM((Hk, G), jnp.float32),
            pltpu.VMEM((Hk, G, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache, valid2)
    return out
