"""Pure-jnp oracle for single-token GQA decode attention."""
import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(q, k_cache, v_cache, valid):
    """q (B, Hq, D); k/v_cache (B, T, Hk, D); valid (B,) int32 live slots.

    Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    T, Hk = k_cache.shape[1], k_cache.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, Hk, G, D)
    s = jnp.einsum("bhgd,bthd->bhgt", qg, k_cache,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(D).astype(jnp.float32)
    ok = jnp.arange(T)[None, :] < valid[:, None]  # (B, T)
    s = jnp.where(ok[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgt,bthd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Hq, D).astype(q.dtype)
