"""MeasuredCostTable + MeasuredCostModel: measured µs per slot signature.

The planner's analytic ``perfmodel`` formulas rank launch shapes by cycle
estimates that BENCH_dispatch shows diverging from wall-clock on the one
backend we measure.  This module is the ground-truth side of the
measured-launch cost model:

``MeasuredCostTable``
    ``signature -> {med_us, p90_us, n, est_cycles, runs, stamp}`` per
    backend, persisted to ``artifacts/measured_costs.json``.  Entries are
    *backend-tagged* (``interpret(cpu)``, ``tpu``, ...) because a µs
    measured under the interpreter says nothing about MXU wall-clock —
    lookups only see the table's bound backend.  ``save()`` merges across
    runs: a conflicting signature takes the NEWER run's med/p90/est
    (monotonic ``stamp``), while sample and run counts accumulate.  The
    file carries a schema ``version``; a mismatched version is stale and
    loads as empty (re-calibrate rather than trust old semantics).

``MeasuredCostModel``
    The planner-facing scorer (``ExecutionPolicy(cost_model="measured")``).
    ``slot_us(...)`` resolves a candidate launch shape in three steps:
    exact signature hit -> measured median; near miss -> the nearest
    measured neighbor (same family/dtype/dirs/chained, every shape dim
    within ``NEIGHBOR_MAX_RATIO``) scaled by the analytic cycle ratio of
    the two shapes; otherwise -> the analytic estimate converted to µs by
    the table's mean ``cycles_per_us`` calibration constant.  Each
    resolution is counted (``hits``/``interpolated``/``fallbacks`` — the
    numbers ``CompiledStack.stats`` surfaces).  An EMPTY table reports
    ``active == False`` and the planner never consults it, so cold-start
    measured mode is bit-identical to analytic mode by construction.

Timing never happens here — replay.py measures through
``runtime.obs.measure_samples`` (the repo's one clock, repolint RL003).
"""
from __future__ import annotations

import json
import math
import os
from typing import Dict, List, Optional, Sequence

from repro.core.perfmodel import (Design, decode_plan_cycles,
                                  slot_launch_cycles)
from repro.runtime.obs import slot_signature

#: persisted calibration table, next to artifacts/launch_costs.json (the
#: executed-slot measurement PR 7 records; this one holds *replayed
#: candidate* shapes, which is what the planner needs to score roads not
#: taken)
MEASURED_COSTS_PATH = os.path.join("artifacts", "measured_costs.json")

#: schema version — bump whenever entry semantics change; older files are
#: stale and load as empty (staleness versioning: never score plans
#: against a table whose fields mean something else)
TABLE_VERSION = 1


def current_backend(interpret: Optional[bool] = None) -> str:
    """The backend tag measured entries carry: the jax backend name,
    wrapped in ``interpret(...)`` when Pallas kernels run interpreted
    (``None`` = the executor's auto rule: interpret everywhere but real
    TPUs) — interpreter µs and MXU µs must never score each other's
    plans."""
    import jax

    from repro.kernels.common import default_interpret

    base = jax.default_backend()
    interp = default_interpret() if interpret is None else interpret
    return f"interpret({base})" if interp else base


def parse_signature(sig: str) -> Optional[dict]:
    """Invert ``runtime.obs.slot_signature``: ``"lstm|H64|G3|B1|bt1|
    float32|fwd|chained"`` -> field dict, or None for a malformed string
    (foreign keys in a hand-edited table are skipped, not fatal).  The
    optional trailing tokens — ``p<precision>`` (absent = fp32, so
    pre-precision tables parse unchanged) then ``chained`` — land in the
    ``precision`` / ``chained`` fields."""
    parts = sig.split("|")
    if len(parts) < 7:
        return None
    try:
        out = {"family": parts[0], "H": int(parts[1][1:]),
               "G": int(parts[2][1:]), "B": int(parts[3][1:]),
               "chunk_len": int(parts[4][2:]), "dtype": parts[5],
               "dirs": parts[6], "precision": "fp32", "chained": False}
        for tok in parts[7:]:
            if tok == "chained":
                out["chained"] = True
            elif tok.startswith("p"):
                out["precision"] = tok[1:]
            else:
                return None
        return out
    except (ValueError, IndexError):
        return None


def analytic_shape_cycles(family: str, H: int, G: int, B: int,
                          chunk_len: int, design: Design, *,
                          chained: bool = False,
                          precision: str = "fp32") -> float:
    """The perfmodel's estimate for one launch of this shape — the same
    formulas the executor's launch-cost table records as its predicted
    half (chained slots: G is the layer count L; decode ignores precision
    — its ticks run the dense dequantized weights)."""
    if chained:
        return decode_plan_cycles(family, H, H, G, design)
    return slot_launch_cycles(family, H, chunk_len, [B] * G, design,
                              precision=precision)


class MeasuredCostTable:
    """Backend-tagged ``signature -> measured µs`` with run-merge and
    staleness semantics (module doc).  One instance is bound to ONE
    backend (lookups and ``record`` use it); entries for other backends
    are carried opaquely so ``save`` never drops a machine's calibration
    just because this run measured a different one."""

    def __init__(self, backend: str,
                 entries: Optional[Dict[str, Dict[str, dict]]] = None,
                 stamp: int = 0):
        self.backend = backend
        #: backend -> signature -> entry dict
        self.entries: Dict[str, Dict[str, dict]] = entries or {}
        #: the highest run stamp merged into ``entries`` (this run's new
        #: records are stamped ``stamp + 1`` at save time)
        self.stamp = stamp

    # -- recording ------------------------------------------------------
    def record(self, sig: str, med_us: float, p90_us: float, n: int,
               est_cycles: float) -> None:
        """File one replayed signature under the bound backend.  A repeat
        within one run overwrites (the replay harness dedupes upstream).
        The ``None`` stamp marks a not-yet-persisted record — always newest
        in ``save``'s merge, then replaced by the real run stamp."""
        self.entries.setdefault(self.backend, {})[sig] = {
            "med_us": float(med_us), "p90_us": float(p90_us),
            "n": int(n), "est_cycles": float(est_cycles),
            "runs": 1, "stamp": None,
        }

    # -- lookup ---------------------------------------------------------
    def lookup(self, sig: str) -> Optional[dict]:
        """The bound backend's entry for ``sig``, or None — entries
        measured under any other backend are invisible here."""
        return self.entries.get(self.backend, {}).get(sig)

    def signatures(self) -> List[str]:
        return sorted(self.entries.get(self.backend, {}))

    def __len__(self) -> int:
        return len(self.entries.get(self.backend, {}))

    def mean_cycles_per_us(self) -> float:
        """The calibration constant analytic fallbacks divide by: the mean
        est_cycles/med_us over the bound backend's entries (0.0 when the
        table is empty — callers must not convert against nothing)."""
        ratios = [e["est_cycles"] / e["med_us"]
                  for e in self.entries.get(self.backend, {}).values()
                  if e["med_us"] > 0 and e["est_cycles"] > 0]
        return sum(ratios) / len(ratios) if ratios else 0.0

    # -- persistence ----------------------------------------------------
    def save(self, path: str = MEASURED_COSTS_PATH) -> str:
        """Merge this table into ``path`` and write it.

        Merge contract (regression-tested): the on-disk table is loaded
        first; for a signature both sides carry, the side with the newer
        ``stamp`` wins med/p90/est while ``n`` and ``runs`` ACCUMULATE
        (the sample history is real even when the summary is refreshed);
        signatures only one side carries pass through.  This run's records
        are stamped one past the highest stamp ever merged, so "newer"
        is well-defined across interleaved machines sharing one file."""
        disk = self.load(path, backend=self.backend) \
            if os.path.exists(path) else MeasuredCostTable(self.backend)
        stamp = max(self.stamp, disk.stamp) + 1
        merged: Dict[str, Dict[str, dict]] = {
            b: dict(sigs) for b, sigs in disk.entries.items()}
        for b, sigs in self.entries.items():
            tgt = merged.setdefault(b, {})
            for sig, e in sigs.items():
                # a None stamp is a record made this run — always newest
                mine = {**e, "stamp": stamp} if e["stamp"] is None \
                    else dict(e)
                old = tgt.get(sig)
                if old is None:
                    tgt[sig] = mine
                    continue
                if e["stamp"] is not None and e["stamp"] == old["stamp"]:
                    continue  # same lineage (we loaded it from this file)
                newer, older = (mine, old) if mine["stamp"] >= old["stamp"] \
                    else (old, mine)
                tgt[sig] = {**newer,
                            "n": newer["n"] + older["n"],
                            "runs": newer["runs"] + older["runs"]}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"version": TABLE_VERSION, "stamp": stamp,
                       "backends": merged}, f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str = MEASURED_COSTS_PATH, *,
             backend: Optional[str] = None) -> "MeasuredCostTable":
        """Load a table bound to ``backend`` (default: the current one).
        A missing file or a stale schema ``version`` loads as EMPTY — the
        planner then runs pure-analytic (cold start) instead of scoring
        against entries whose meaning may have changed."""
        backend = backend if backend is not None else current_backend()
        if not os.path.exists(path):
            return cls(backend)
        with open(path) as f:
            raw = json.load(f)
        if raw.get("version") != TABLE_VERSION:
            return cls(backend)
        return cls(backend, entries=raw.get("backends", {}),
                   stamp=int(raw.get("stamp", 0)))

    def describe(self) -> str:
        rows = self.entries.get(self.backend, {})
        if not rows:
            return f"measured costs [{self.backend}]: (empty)"
        lines = [f"measured costs [{self.backend}]: {len(rows)} signatures"
                 f" (mean {self.mean_cycles_per_us():.2f}cy/us)"]
        for sig in sorted(rows):
            e = rows[sig]
            lines.append(
                f"  {sig}: med={e['med_us']:.1f}us p90={e['p90_us']:.1f}us "
                f"n={e['n']} runs={e['runs']} est={e['est_cycles']:.0f}cy")
        return "\n".join(lines)


class MeasuredCostModel:
    """The planner's measured scorer (module doc): exact hit ->
    interpolated neighbor -> analytic-converted fallback, with counters.

    All returns are µs; the planner only compares these against each
    other, never against raw cycles.  ``active`` is False over an empty
    table, in which case the planner never calls ``slot_us`` at all —
    cold-start measured mode IS analytic mode."""

    #: a neighbor is trustworthy only when every shape dim (H, G, B,
    #: chunk_len) is within this factor of the query — beyond that the
    #: analytic scaling ratio is extrapolating, not interpolating
    NEIGHBOR_MAX_RATIO = 4.0

    def __init__(self, table: MeasuredCostTable, macs: int = 16384):
        self.table = table
        self.design = Design(macs=macs, schedule="unfolded")
        self.hits = 0           # exact signature lookups
        self.interpolated = 0   # neighbor-scaled lookups
        self.fallbacks = 0      # analytic-converted (no close neighbor)
        self._cpu: Optional[float] = None

    @property
    def active(self) -> bool:
        return len(self.table) > 0

    def cycles_to_us(self, cycles: float) -> float:
        """Analytic cycles -> µs via the table's mean calibration constant
        (keeps every candidate in ONE unit when some shapes have no
        measured neighbor)."""
        if self._cpu is None:
            self._cpu = self.table.mean_cycles_per_us()
        return cycles / self._cpu if self._cpu > 0 else cycles

    def slot_us(self, family: str, H: int, G: int, B: int, chunk_len: int,
                dtype: str, dirs: Sequence[str] = ("fwd",),
                chained: bool = False, precision: str = "fp32") -> float:
        """Measured µs for one candidate launch shape (resolution ladder
        in the module doc).  ``precision`` is categorical: an int8 query
        only ever resolves against int8 entries (exact or neighbor) — a
        quantized launch's µs says nothing about the fp32 one's."""
        sig = slot_signature(family, H, G, B, chunk_len, dtype,
                             directions=dirs, chained=chained,
                             precision=precision)
        hit = self.table.lookup(sig)
        if hit is not None:
            self.hits += 1
            return hit["med_us"]
        est = analytic_shape_cycles(family, H, G, B, chunk_len, self.design,
                                    chained=chained, precision=precision)
        nb = self._nearest(family, dtype, dirs, chained, precision,
                           H, G, B, chunk_len)
        if nb is not None:
            n, e = nb
            self.interpolated += 1
            n_est = analytic_shape_cycles(
                n["family"], n["H"], n["G"], n["B"], n["chunk_len"],
                self.design, chained=n["chained"],
                precision=n["precision"])
            return e["med_us"] * (est / n_est) if n_est > 0 else e["med_us"]
        self.fallbacks += 1
        return self.cycles_to_us(est)

    def _nearest(self, family, dtype, dirs, chained, precision,
                 H, G, B, chunk_len):
        """The closest measured shape sharing the categorical fields
        (family, dtype, dirs, chained, precision), by summed |log ratio|
        over (H, G, B, chunk_len); None when no entry is within
        ``NEIGHBOR_MAX_RATIO`` on every dim."""
        want_dirs = "+".join(sorted(set(dirs)))
        best = None
        for sig in self.table.signatures():
            n = parse_signature(sig)
            if n is None or n["family"] != family or n["dtype"] != dtype \
                    or n["dirs"] != want_dirs or n["chained"] != chained \
                    or n["precision"] != precision:
                continue
            ratios = [max(a, b) / min(a, b) for a, b in
                      ((n["H"], H), (n["G"], G), (n["B"], B),
                       (n["chunk_len"], chunk_len)) if min(a, b) > 0]
            if not ratios or max(ratios) > self.NEIGHBOR_MAX_RATIO:
                continue
            dist = sum(math.log(r) for r in ratios)
            if best is None or dist < best[0]:
                best = (dist, n, self.table.lookup(sig))
        return None if best is None else (best[1], best[2])

    def describe(self) -> str:
        state = (f"{len(self.table)} table entries "
                 f"[{self.table.backend}], {self.hits} hits, "
                 f"{self.interpolated} interpolated, "
                 f"{self.fallbacks} analytic fallbacks")
        if not self.active:
            return f"measured (cold start — empty table, scoring analytic; " \
                   f"{state})"
        return f"measured ({state})"
