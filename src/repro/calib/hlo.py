"""Optimized-HLO cost analyzer (static side of the calibration subsystem).

Part of ``repro.calib``: where replay.py MEASURES a candidate launch on
the live backend, this walker statically prices a dumped optimized-HLO
module (flops/bytes/collectives) — the roofline's input (§Roofline of
EXPERIMENTS.md, benchmarks/roofline.py).  CLI:
``python -m repro.calib.hlo <module.txt[.gz]>``.

Why not ``compiled.cost_analysis()``: XLA's analysis counts a while-loop
body ONCE, so anything under ``lax.scan`` (our layer stacks, microbatch
accumulation, attention chunk loops) is undercounted by its trip count.
This walker parses ``compiled.as_text()``, recovers each while loop's trip
count from its condition computation, and propagates execution multipliers
through the call graph (entry -> while bodies -> fusions -> ...).

Per module it reports:
  flops             dot/convolution FLOPs (2*M*N*K), multiplier-weighted
  bytes             fusion-boundary traffic (operands+results of top-level
                    ops, skipping free ops) — an HBM-traffic proxy
  collective_bytes  per collective kind, using link-traffic conventions:
                    all-gather/all-to-all/collective-permute: result bytes;
                    all-reduce: 2x bytes (reduce-scatter + all-gather phases);
                    reduce-scatter: input bytes
  transcendentals   exp/tanh/log/... element counts (MFU pressure)

All numbers are WHOLE-MODULE (all devices); divide by device count for
per-chip terms.  Parsing is best-effort: unknown shapes contribute zero
rather than raising.
"""
from __future__ import annotations

import gzip
import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1, "token": 0, "opaque": 0,
}

SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*"
                   r"([\w\-]+)\((.*)$")
COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(\([^{]*\))?\s*->")
FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
            "after-all", "partition-id", "replica-id", "iota", "reshape",
            "custom-call", "get-dimension-size", "opt-barrier"}
TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                  "logistic", "exponential-minus-one", "log-plus-one", "cosine",
                  "sine", "erf"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start"}


def _shapes_bytes(text: str) -> int:
    total = 0
    for dt, dims in SHAPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def _shape_dims(text: str) -> List[int]:
    m = SHAPE_RE.search(text)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclass
class Op:
    name: str
    kind: str
    result: str          # result shape text
    rest: str            # operand list + attributes
    operands: List[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # symbol -> shape txt


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    comment_re = re.compile(r"/\*.*?\*/")
    for line in text.splitlines():
        line = comment_re.sub("", line)  # /*index=5*/ comments break parsing
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{"):
                m = COMP_HDR_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1))
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
                    # parameter shapes from the header
                    hdr = m.group(2) or ""
                    for pm in re.finditer(r"([\w\.\-]+):\s*([^,()]+(?:\([^)]*\))?)",
                                          hdr):
                        cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = OP_RE.match(line)
        if not m:
            continue
        name, result, kind, rest = m.groups()
        # operand names: %tokens up to the closing paren of the op call
        depth = 1
        args = []
        buf = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    args.append(buf)
                    break
            if depth >= 1 and ch != "," or depth > 1:
                buf += ch
            elif ch == "," and depth == 1:
                args.append(buf)
                buf = ""
        operand_names = []
        for a in args:
            mm = re.search(r"%([\w\.\-]+)\s*$", a.strip())
            if mm:
                operand_names.append(mm.group(1))
        op = Op(name=name, kind=kind, result=result, rest=rest,
                operands=operand_names)
        cur.ops.append(op)
        cur.shapes[name] = result
    return comps, entry


def _while_trip_count(cond: Computation) -> int:
    """jax scans lower to `compare(iter, constant(N)), direction=LT`."""
    consts: List[int] = []
    for op in cond.ops:
        if op.kind == "constant" and "s32" in op.result:
            m = re.search(r"constant\((-?\d+)\)", "constant(" + op.rest)
            if m:
                consts.append(int(m.group(1)))
    pos = [c for c in consts if c > 0]
    return max(pos) if pos else 1


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(op.result):
        out_elems *= d
    lhs_shape = comp.shapes.get(op.operands[0], "") if op.operands else ""
    dims = _shape_dims(lhs_shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.rest)
    k = 1
    if m and dims:
        for idx in m.group(1).split(","):
            if idx and int(idx) < len(dims):
                k *= dims[int(idx)]
    return 2.0 * out_elems * k


def _collective_bytes(op: Op, comp: Computation) -> float:
    res = _shapes_bytes(op.result)
    kind = op.kind.replace("-start", "")
    if kind == "all-reduce":
        return 2.0 * res
    if kind == "reduce-scatter":
        in_bytes = sum(_shapes_bytes(comp.shapes.get(o, ""))
                       for o in op.operands)
        return float(in_bytes or res)
    return float(res)  # all-gather / all-to-all / permute: result bytes


def analyze(text: str) -> Dict[str, float]:
    comps, entry = parse_hlo(text)
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "transcendental_elems": 0.0,
                "collective_bytes": 0.0, "collectives": {}}

    # execution multiplier per computation, propagated through calls
    mult: Dict[str, float] = defaultdict(float)
    mult[entry] = 1.0
    order = [entry]
    seen = {entry}
    # BFS in call order; while bodies get multiplier * trip_count
    i = 0
    while i < len(order):
        cname = order[i]
        i += 1
        comp = comps.get(cname)
        if comp is None:
            continue
        for op in comp.ops:
            callees: List[Tuple[str, float]] = []
            if op.kind == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                mc = re.search(r"condition=%?([\w\.\-]+)", op.rest)
                trip = _while_trip_count(comps[mc.group(1)]) if (
                    mc and mc.group(1) in comps) else 1
                if mb:
                    callees.append((mb.group(1), float(trip)))
            elif op.kind in ("fusion", "call", "map", "reduce", "reduce-window",
                             "scatter", "sort", "select-and-scatter",
                             "all-reduce", "reduce-scatter"):
                mcalls = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", op.rest)
                if mcalls:
                    callees.append((mcalls.group(1), 1.0))
            elif op.kind == "conditional":
                for mm in re.finditer(r"(?:branch_computations=\{([^}]*)\}|"
                                      r"(?:true|false)_computation=%?([\w\.\-]+))",
                                      op.rest):
                    names = (mm.group(1) or mm.group(2) or "")
                    for nm in names.replace("%", "").split(","):
                        if nm.strip():
                            callees.append((nm.strip(), 1.0))
            for nm, factor in callees:
                mult[nm] += mult[cname] * factor
                if nm not in seen:
                    seen.add(nm)
                    order.append(nm)

    flops = 0.0
    byte_traffic = 0.0
    transcendental = 0.0
    coll: Dict[str, float] = defaultdict(float)

    for cname in seen:
        comp = comps.get(cname)
        if comp is None:
            continue
        w = mult[cname]
        if w == 0:
            continue
        for op in comp.ops:
            if op.kind in ("dot", "convolution"):
                flops += w * _dot_flops(op, comp)
            kindbase = op.kind.replace("-start", "")
            if kindbase in {"all-reduce", "all-gather", "reduce-scatter",
                            "all-to-all", "collective-permute"}:
                coll[kindbase] += w * _collective_bytes(op, comp)
            if op.kind in TRANSCENDENTAL:
                elems = 1
                for d in _shape_dims(op.result):
                    elems *= d
                transcendental += w * elems

    # fusion-boundary bytes: only ENTRY + while bodies count as "top level"
    top_level = {entry}
    for cname in seen:
        comp = comps.get(cname)
        if not comp:
            continue
        for op in comp.ops:
            if op.kind == "while":
                mb = re.search(r"body=%?([\w\.\-]+)", op.rest)
                if mb:
                    top_level.add(mb.group(1))
    for cname in top_level:
        comp = comps.get(cname)
        if comp is None:
            continue
        w = mult[cname]
        in_body = cname != entry
        for op in comp.ops:
            if op.kind in FREE_OPS or op.kind == "while":
                continue
            rb = _shapes_bytes(op.result)
            obs = [_shapes_bytes(comp.shapes.get(o, "")) for o in op.operands]
            ob = sum(obs)
            # in-place credit: a loop-body op producing a result the same
            # size as one operand (>=64 KiB) is an in-place update of a
            # loop-carried buffer (scan ys dynamic-update-slice, gradient
            # accumulators): XLA aliases it, so the buffer is not re-read
            # and re-written wholesale every iteration.
            if in_body and rb >= 65536 and rb in obs:
                ob -= rb
                rb = 0
            byte_traffic += w * (rb + ob)

    return {
        "flops": flops,
        "bytes": byte_traffic,
        "transcendental_elems": transcendental,
        "collective_bytes": float(sum(coll.values())),
        "collectives": dict(coll),
    }


def analyze_file(path: str) -> Dict[str, float]:
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        return analyze(f.read())


if __name__ == "__main__":
    import json
    import sys

    print(json.dumps(analyze_file(sys.argv[1]), indent=1))
