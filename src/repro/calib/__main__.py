"""CLI: replay a calibration grid into artifacts/measured_costs.json.

    python -m repro.calib [--grid smoke|small] [--repeats N] [--warmup N]
                          [--out PATH] [--check TOL] [--no-save]

``--check TOL`` re-replays every calibrated signature once after the
table is built and exits nonzero if any fresh measurement disagrees with
the stored median by more than TOL x either way — the `make calibrate`
gate (generous default: it catches unit/lowering errors, not scheduler
jitter; 0 disables).
"""
from __future__ import annotations

import argparse
import sys

from repro.calib.candidates import SMOKE_GRID, sweep_grid
from repro.calib.replay import calibrate, check_table
from repro.calib.table import MEASURED_COSTS_PATH, current_backend

#: --grid small: the smoke axes widened one notch per dim (still minutes,
#: not hours, under the interpreter)
SMALL_GRID = dict(families=("lstm", "gru"), Hs=(64, 128), Gs=(1, 2, 3),
                  Bs=(1, 3, 8), block_ts=(1, 8), dtypes=("float32",),
                  chained_Ls=(2, 3))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.calib",
        description="compile-and-replay calibration -> measured cost table")
    ap.add_argument("--grid", choices=("smoke", "small"), default="smoke")
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--warmup", type=int, default=1)
    ap.add_argument("--out", default=MEASURED_COSTS_PATH)
    ap.add_argument("--check", type=float, default=0.0, metavar="TOL",
                    help="re-replay each signature and fail beyond TOLx "
                         "disagreement (0 = skip)")
    ap.add_argument("--no-save", action="store_true",
                    help="replay and report without touching --out")
    args = ap.parse_args(argv)

    grid = SMOKE_GRID if args.grid == "smoke" else SMALL_GRID
    cands = sweep_grid(**grid)
    print(f"calibrating {len(cands)} candidate shapes "
          f"[{current_backend()}] ({args.grid} grid, "
          f"repeats={args.repeats})")
    table = calibrate(cands, repeats=args.repeats, warmup=args.warmup,
                      progress=print)
    if not args.no_save:
        path = table.save(args.out)
        print(f"saved -> {path}")
    if args.check > 0:
        print(f"verifying replay vs table (tolerance {args.check:g}x):")
        bad = check_table(table, tolerance=args.check, progress=print)
        if bad:
            print(f"FAIL: {len(bad)} signature(s) disagree beyond "
                  f"{args.check:g}x: {', '.join(bad)}")
            return 1
        print("ok: replay and table agree within tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
