"""Replay harness: lower a Candidate to the executor's launch and time it.

Each candidate becomes the SAME kernel call the executor's planned rung
issues for that signature — ``lstm_seq``/``gru_seq`` with (G, B, bt)
batched operands for sequence slots, ``lstm_decode``/``gru_decode`` with
(L, ...) stacked weights for chained decode slots — on synthetic operands
of the candidate's shapes and dtype.  Timing goes through
``runtime.obs.measure_samples`` (warmup-excluded, ``block_until_ready``
fenced — the repo's one clock, repolint RL003), and the per-signature
median + p90 land in a ``MeasuredCostTable`` beside the perfmodel's
analytic estimate for the same shape, so every entry carries its own
``cycles_per_us`` calibration signal.

The input hoist (the X-GEMM) is deliberately NOT replayed: the executor
issues it outside the ``slot_launch`` span (it overlaps the serial tail),
so the measured µs here and PR 7's traced launch costs describe the same
region.
"""
from __future__ import annotations

import statistics
from typing import Iterable, Optional, Sequence

from repro.calib.candidates import Candidate, dedupe
from repro.calib.table import (MeasuredCostTable, analytic_shape_cycles,
                               current_backend)
from repro.core.perfmodel import Design
from repro.dispatch.workitem import GATES
from repro.runtime.obs import measure_samples


def _operands(cand: Candidate, interpret: Optional[bool]):
    """Synthetic operands matching the executor's call for this shape,
    and the launch thunk over them."""
    import jax.numpy as jnp

    gates = GATES[cand.family]
    H, G, B, bt = cand.H, cand.G, cand.B, cand.block_t
    dt = jnp.dtype(cand.dtype)
    lstm = cand.family == "lstm"

    def filled(shape, dtype=dt):
        # deterministic non-trivial values (no PRNG dependency, nothing
        # that can saturate the gates' nonlinearities to a constant)
        n = 1
        for s in shape:
            n *= s
        return (jnp.arange(n, dtype=jnp.float32).reshape(shape)
                % 7.0 * 0.03 - 0.1).astype(dtype)

    if cand.chained:
        # a decode tick: G is the layer count L (executor's chained rung)
        from repro.kernels.gru_cell.ops import gru_decode
        from repro.kernels.lstm_cell.ops import lstm_decode

        L = G
        xw0 = filled((B, gates, H))
        Ws = filled((L, H, gates, H))
        bs = filled((L, gates, H))
        Us = filled((L, H, gates, H))
        h0 = filled((L, B, H))
        if lstm:
            c0 = filled((L, B, H), jnp.float32)
            return lambda: lstm_decode(xw0, Ws, bs, Us, h0, c0,
                                       interpret=interpret)
        return lambda: gru_decode(xw0, Ws, bs, Us, h0, interpret=interpret)

    from repro.kernels.gru_cell.ops import gru_seq
    from repro.kernels.lstm_cell.ops import lstm_seq

    U = filled((G, H, gates, H))
    xw = filled((G, B, bt, gates, H))
    h0 = filled((G, B, H))
    u_scales = None
    if cand.precision == "int8":
        # the executor's quantized hoist: int8 payload + per-gate scales,
        # so the measured µs is the quantized launch's, not the fp32 one's
        from repro.kernels.quant import quantize_per_gate

        qs = [quantize_per_gate(U[g]) for g in range(G)]
        U = jnp.stack([q for q, _ in qs])
        u_scales = jnp.stack([s for _, s in qs])
    elif cand.precision == "bf16":
        from repro.kernels.quant import bf16_roundtrip

        U = bf16_roundtrip(U)
    if lstm:
        c0 = filled((G, B, H), jnp.float32)
        return lambda: lstm_seq(U, xw, h0, c0, u_scales=u_scales,
                                block_t=bt, interpret=interpret)
    return lambda: gru_seq(U, xw, h0, u_scales=u_scales, block_t=bt,
                           interpret=interpret)


def replay_candidate(cand: Candidate, *, interpret: Optional[bool] = None,
                     repeats: int = 5, warmup: int = 1) -> dict:
    """Replay one candidate: {med_us, p90_us, n} over ``repeats`` fenced
    runs (nearest-rank p90, exact at these sample sizes)."""
    fn = _operands(cand, interpret)
    ts = sorted(measure_samples(fn, repeats=repeats, warmup=warmup))
    rank = max(1, -(-len(ts) * 9 // 10))  # ceil(0.9 * n), nearest-rank
    return {"med_us": statistics.median(ts),
            "p90_us": ts[min(rank, len(ts)) - 1], "n": len(ts)}


def calibrate(cands: Iterable[Candidate], *,
              table: Optional[MeasuredCostTable] = None,
              interpret: Optional[bool] = None,
              repeats: int = 5, warmup: int = 1,
              macs: int = 16384,
              progress=None) -> MeasuredCostTable:
    """Replay every (deduped) candidate into a MeasuredCostTable bound to
    the current backend.  ``progress`` is an optional ``str -> None`` line
    sink (the CLI passes print)."""
    if table is None:
        table = MeasuredCostTable(current_backend(interpret))
    design = Design(macs=macs, schedule="unfolded")
    for cand in dedupe(cands):
        r = replay_candidate(cand, interpret=interpret, repeats=repeats,
                             warmup=warmup)
        est = analytic_shape_cycles(cand.family, cand.H, cand.G, cand.B,
                                    cand.block_t, design,
                                    chained=cand.chained,
                                    precision=cand.precision)
        table.record(cand.signature(), r["med_us"], r["p90_us"], r["n"],
                     est)
        if progress is not None:
            progress(f"  {cand.signature()}: med={r['med_us']:.1f}us "
                     f"p90={r['p90_us']:.1f}us n={r['n']} est={est:.0f}cy")
    return table


def check_table(table: MeasuredCostTable, *,
                interpret: Optional[bool] = None,
                tolerance: float = 25.0, repeats: int = 2,
                progress=None) -> Sequence[str]:
    """Re-replay every signature in the table's bound backend once and
    compare against the stored median; returns the signatures whose fresh
    measurement disagrees by more than ``tolerance``x either way (the
    `make calibrate` gate — generous by default: it exists to catch unit
    and lowering errors, not scheduler jitter)."""
    from repro.calib.table import parse_signature

    bad = []
    for sig in table.signatures():
        f = parse_signature(sig)
        if f is None:
            continue
        cand = Candidate(family=f["family"], H=f["H"], G=f["G"], B=f["B"],
                         block_t=f["chunk_len"], dtype=f["dtype"],
                         dirs=tuple(f["dirs"].split("+")),
                         chained=f["chained"], precision=f["precision"])
        fresh = replay_candidate(cand, interpret=interpret,
                                 repeats=repeats)["med_us"]
        stored = table.lookup(sig)["med_us"]
        ratio = max(fresh, stored) / max(min(fresh, stored), 1e-9)
        line = f"  {sig}: stored={stored:.1f}us fresh={fresh:.1f}us " \
               f"ratio={ratio:.2f}x"
        if progress is not None:
            progress(line)
        if ratio > tolerance:
            bad.append(sig)
    return bad
