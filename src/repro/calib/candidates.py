"""Candidate launch shapes for the compile-and-replay calibration harness.

A ``Candidate`` is one concrete launch shape the executor could issue —
the same axes ``runtime.obs.slot_signature`` keys on (family x H x G x B x
block_t x dtype x dirs x chained) — and replay.py lowers it to the exact
kernel call the executor's planned rung makes for that signature.

Two enumeration modes, both deduped by signature:

``candidates_for``
    Walk a ``ModelConfig`` / ``CompiledStack`` through the REAL planner at
    the given (B, T) shapes and emit one candidate per distinct slot of
    the resulting plans, plus — for homogeneous lstm/gru stacks — both
    decode-tick alternatives (the chained single launch AND the per-layer
    loop) at each B, so the chained-vs-loop decision has measured costs on
    BOTH sides.  This is "calibrate what this model will actually launch".

``sweep_grid``
    The cartesian product of explicit axis values — the offline grid mode
    (``python -m repro.calib``), for populating a table ahead of any
    particular model.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Iterable, List, Sequence, Tuple, Union

from repro.configs.base import ModelConfig
from repro.dispatch.planner import DispatchPlan, plan, plan_decode
from repro.dispatch.workitem import WorkItem
from repro.runtime.obs import slot_signature


@dataclass(frozen=True)
class Candidate:
    """One replayable launch shape.  For ``chained`` candidates (a decode
    tick), ``G`` doubles as the layer count L — the chained slot's groups
    ARE the L serially dependent layer cells."""
    family: str
    H: int
    G: int
    B: int
    block_t: int
    dtype: str = "float32"
    dirs: Tuple[str, ...] = ("fwd",)
    chained: bool = False
    precision: str = "fp32"  # replayed with the matching kernel operands
    #                          (int8 payload + per-gate scales), so the
    #                          measured µs prices the quantized launch

    def signature(self) -> str:
        return slot_signature(self.family, self.H, self.G, self.B,
                              self.block_t, self.dtype,
                              directions=self.dirs, chained=self.chained,
                              precision=self.precision)


def _from_plan(p: DispatchPlan) -> List[Candidate]:
    return [Candidate(family=s.family, H=s.H, G=s.g, B=s.B,
                      block_t=s.chunk_len, dtype=s.dtype,
                      dirs=tuple(c.direction for c in s.cells),
                      chained=s.chained, precision=s.precision)
            for s in p.slots]


def dedupe(cands: Iterable[Candidate]) -> List[Candidate]:
    """Signature-keyed dedupe, first occurrence wins, order preserved."""
    seen, out = set(), []
    for c in cands:
        sig = c.signature()
        if sig not in seen:
            seen.add(sig)
            out.append(c)
    return out


def candidates_for(model: Union[ModelConfig, "object"], *,
                   shapes: Sequence[Tuple[int, int]] = ((1, 32),),
                   dtype: str = "float32",
                   macs: int = 16384,
                   decode: bool = True,
                   precision: str = "fp32") -> List[Candidate]:
    """Candidates a model would actually launch: plan it at each (B, T)
    shape and harvest the slots; for homogeneous lstm/gru stacks add the
    decode tick's chained AND per-layer alternatives at each B.
    ``precision`` plans (and therefore prices) the quantized-weight
    variant of the same stack.

    ``model`` is a ModelConfig (family "rnn") or any object with the
    CompiledStack shape surface (``families``/``H``/``X``/``L``/
    ``bidirectional``) — the enumeration needs shapes only, never
    parameters."""
    if isinstance(model, ModelConfig):
        fams = ("lstm",) * model.n_layers
        H, X, L = model.lstm_hidden, model.lstm_input, model.n_layers
        bidir = bool(getattr(model, "bidirectional", False))
    else:
        fams = tuple(model.families)
        H, X, L = model.H, model.X, model.L
        bidir = bool(model.bidirectional)

    def item(uid: int, B: int, T: int, share=None) -> WorkItem:
        return WorkItem(uid=uid, family=fams[0], B=B, T=T, H=H, L=L, X=X,
                        dtype=dtype, bidirectional=bidir, share=share,
                        families=fams, precision=precision)

    out: List[Candidate] = []
    for B, T in shapes:
        out += _from_plan(plan([item(0, B, T)], macs=macs))
    if decode and not bidir and len(set(fams)) == 1 \
            and fams[0] in ("lstm", "gru"):
        for B in sorted({b for b, _ in shapes}):
            # both sides of the chained-vs-loop decode decision
            out += _from_plan(plan_decode([item(0, B, 1, share=0)],
                                          macs=macs))
            out += _from_plan(plan([item(0, B, 1, share=0)], macs=macs,
                                   schedule="wavefront", block_t=1))
    return dedupe(out)


def sweep_grid(*, families: Sequence[str] = ("lstm", "gru"),
               Hs: Sequence[int] = (64,),
               Gs: Sequence[int] = (1, 3),
               Bs: Sequence[int] = (1, 3),
               block_ts: Sequence[int] = (1,),
               dtypes: Sequence[str] = ("float32",),
               chained_Ls: Sequence[int] = (3,),
               precisions: Sequence[str] = ("fp32",)) -> List[Candidate]:
    """The cartesian grid: sequence-slot shapes over family x H x G x B x
    block_t x dtype x precision, plus chained decode shapes (one per
    family x H x B x dtype x L in ``chained_Ls`` — decode ticks run the
    dense dequantized weights, so they carry no precision axis)."""
    out = [Candidate(family=f, H=h, G=g, B=b, block_t=bt, dtype=dt,
                     precision=p)
           for f, h, g, b, bt, dt, p in itertools.product(
               families, Hs, Gs, Bs, block_ts, dtypes, precisions)]
    out += [Candidate(family=f, H=h, G=l, B=b, block_t=1, dtype=dt,
                      chained=True)
            for f, h, b, dt, l in itertools.product(
                families, Hs, Bs, dtypes, chained_Ls)]
    return dedupe(out)


#: the `make calibrate` / CI smoke grid: small enough to replay in
#: seconds under the interpreter, yet covering both sides of the
#: chained-vs-loop decode decision at the benchmarked H64/L3 shape AND
#: both sides of the int8-vs-fp32 pricing split (precision-tagged
#: signatures keep the two populations separate in the table)
SMOKE_GRID = dict(families=("lstm", "gru"), Hs=(64,), Gs=(1, 3),
                  Bs=(1, 3), block_ts=(1,), dtypes=("float32",),
                  chained_Ls=(3,), precisions=("fp32", "int8"))
