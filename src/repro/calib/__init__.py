"""repro.calib: the compile-and-replay calibration subsystem.

The planner's analytic ``perfmodel`` ranks launch shapes by hand-written
cycle formulas; this package measures the same shapes on the real backend
and gives the planner ground truth to score against
(``ExecutionPolicy(cost_model="measured")``):

``candidates``  enumerate candidate slot shapes — from a model through
                the real planner, or an explicit grid — deduped by
                ``Slot.signature()``
``replay``      lower each candidate to the executor's exact kernel call
                and time it through ``runtime.obs.measure_samples``
``table``       ``MeasuredCostTable`` (persisted, backend-tagged,
                merge-across-runs, staleness-versioned) and
                ``MeasuredCostModel`` (exact hit -> interpolated neighbor
                -> analytic fallback, the planner's measured scorer)
``hlo``         the static optimized-HLO cost walker (roofline input)

CLI: ``python -m repro.calib`` replays the smoke grid into
``artifacts/measured_costs.json`` (see ``make calibrate``).
"""
from repro.calib.candidates import (Candidate, SMOKE_GRID, candidates_for,
                                    dedupe, sweep_grid)
from repro.calib.replay import calibrate, check_table, replay_candidate
from repro.calib.table import (MEASURED_COSTS_PATH, MeasuredCostModel,
                               MeasuredCostTable, TABLE_VERSION,
                               analytic_shape_cycles, current_backend,
                               parse_signature)

__all__ = [
    "Candidate", "SMOKE_GRID", "candidates_for", "dedupe", "sweep_grid",
    "calibrate", "check_table", "replay_candidate",
    "MEASURED_COSTS_PATH", "TABLE_VERSION", "MeasuredCostModel",
    "MeasuredCostTable", "analytic_shape_cycles", "current_backend",
    "parse_signature",
]
