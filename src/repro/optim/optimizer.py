"""AdamW with global-norm clipping and warmup-cosine schedule.

Implemented from scratch (no optax dependency).  Moments are fp32 regardless
of param dtype; the optimizer state inherits the param sharding (ZeRO-style:
when params are FSDP-sharded over 'data', so are m/v — GSPMD keeps the
update local).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    warm = cfg.lr * (step + 1) / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac + (1 - cfg.min_lr_frac)
                    * 0.5 * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def apply_updates(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    grads_f, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    count = state["count"] + 1
    cf = count.astype(jnp.float32)
    lr = lr_at(cfg, state["count"])
    bc1 = 1 - cfg.b1 ** cf
    bc2 = 1 - cfg.b2 ** cf

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        step = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads_f)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_state = {
        "m": jax.tree.unflatten(treedef, [o[1] for o in out]),
        "v": jax.tree.unflatten(treedef, [o[2] for o in out]),
        "count": count,
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
