"""Gradient compression for the slow cross-pod axis, with error feedback.

Two schemes:
  * int8: per-tensor absmax scaling; the cross-pod all-reduce then moves 4x
    fewer bytes (the int8 payload is what a deployment ships over DCN).
  * topk: keep the largest-|g| fraction per tensor, zero the rest.

Both carry an error-feedback residual e_t (Karimireddy et al., 2019):
    c_t = C(g_t + e_{t-1});  e_t = (g_t + e_{t-1}) - c_t
which restores convergence despite the lossy operator — property-tested on
a quadratic in tests/test_optim.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

# THE absmax int8 round-trip lives in kernels.quant (shared with the
# kernel-side per-gate weight quantizer — one scale convention repo-wide)
from repro.kernels.quant import int8_roundtrip as _int8_roundtrip


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    scheme: str = "none"  # none | int8 | topk
    topk_frac: float = 0.01


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_roundtrip(g, frac: float):
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    return kept.reshape(g.shape)


def compress(cfg: CompressionConfig, grads, err_state):
    """Lossy-compress grads (fp32) with error feedback.

    Returns (decompressed_grads, new_err_state).  The decompressed value is
    exactly what every pod reconstructs after the compressed all-reduce.
    """
    if cfg.scheme == "none":
        return grads, err_state

    def one(g, e):
        x = g.astype(jnp.float32) + e
        if cfg.scheme == "int8":
            c = _int8_roundtrip(x)
        elif cfg.scheme == "topk":
            c = _topk_roundtrip(x, cfg.topk_frac)
        else:
            raise ValueError(cfg.scheme)
        return c, x - c

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(treedef, [o[0] for o in out]),
            jax.tree.unflatten(treedef, [o[1] for o in out]))


def compressed_bytes(cfg: CompressionConfig, params) -> int:
    """Bytes crossing the pod axis per step under the scheme (for roofline)."""
    n = sum(p.size for p in jax.tree.leaves(params))
    if cfg.scheme == "int8":
        return n  # 1 byte/param (+ negligible scales)
    if cfg.scheme == "topk":
        return int(n * cfg.topk_frac) * 8  # value + index
    return n * 4
