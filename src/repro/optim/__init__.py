from repro.optim.compression import CompressionConfig, compress, init_error_state  # noqa: F401
from repro.optim.optimizer import (  # noqa: F401
    AdamWConfig, apply_updates, clip_by_global_norm, global_norm, init_state, lr_at,
)
