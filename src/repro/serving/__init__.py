from repro.serving.engine import Completion, Request, ServingEngine  # noqa: F401
