from repro.serving.engine import Completion, Request, ServingEngine  # noqa: F401
from repro.serving.recurrent import (  # noqa: F401
    RecurrentCompletion, RecurrentRequest, RecurrentServingEngine)
