"""Recurrent-stack serving: session management over the unified front-end.

The transformer engine (serving.engine) admits requests one prefill at a
time; recurrent stacks can do strictly better, because *prefill itself is a
recurrence* — an (L layers x T steps) dependency grid.  This engine admits
every free slot's request in one wave and hands the batch to ONE
``repro.rnn.CompiledStack.prefill`` call: the requests' (layer, time-chunk)
cells share wavefront slots, so G-batched sequence-kernel launches hide the
per-request serial dependencies behind each other (ROADMAP item "Wavefront
in serving").  The compiled stack leaves behind each request's exact t=T
per-layer (h, c), which splices into the engine's batched decode state
exactly like the transformer engine splices KV-cache rows.

Decode is planned, not hand-rolled: one tick = one ``CompiledStack.decode``
call over the *active* slots only — their T=1 layer chains B-concatenate
into a single chained slot, ONE kernel launch per tick instead of L, with
each new top-layer output frame fed back as the next step's input (requires
X == H, which the paper's stacks satisfy).  Ticks in steady state reuse the
compiled stack's cached decode plans instead of replanning — the Zhao et
al. steady-state serving story (PAPERS.md).  Requests are *frame* streams,
not token streams — the serving analogue of an RNN acoustic/regression
service (cf. the MASR-style per-shape serving story, PAPERS.md).

Post-ISSUE-4 the engine is ONLY the session layer — admission, slot pool,
state splicing, retirement.  It holds no planner/executor calls of its
own: serving, batch, and single-call users all exercise the identical
planned pipeline and plan caching through ``CompiledStack``.

Fault isolation (ISSUE-6): requests share packed launches, never failure
domains.  Every completion carries ``status`` ("ok" | "failed" |
"timeout") plus error detail, and the engine quarantines per request:

  * a non-finite prompt is rejected at ``submit`` (structured
    ``NonFiniteStateError`` naming the uid) before it can poison a slot;
  * a launch fault inside a packed prefill wave (surfaced as the guarded
    ladder's ``LaunchError``) bisects the wave — each request re-admits
    solo, so exactly the faulty one fails and the co-batched ones proceed
    bit-identically (packed rows are independent by the cross-B masking
    contract, asserted in the dispatch bench);
  * a non-finite spliced prefill state or decode frame fails ONLY the
    offending request's slot — the row check runs per request, the slot
    frees, co-batched rows keep their (independent) values;
  * admission is bounded (``max_queue`` + ``backpressure``: "reject"
    raises ``QueueFull``, "drop_oldest" evicts the queue head as a
    ``status="failed"`` completion — no request is ever silently lost);
  * deadlines retire: per-request ``max_ticks`` (decode ticks) and
    ``deadline_s`` (wall time from admission) produce ``status="timeout"``
    completions carrying the frames generated so far, and
    ``run_to_completion`` raises ``RequestTimeout`` carrying ``.done``
    so an engine-level overrun never loses finished work;
  * a ``runtime.ft.StragglerWatchdog`` (the training loop's EWMA
    detector) optionally flags slow decode ticks in ``straggler_ticks``.

A decode-tick ``LaunchError`` that survives the whole guarded ladder is
re-raised (both on_fault modes): the tick is one chained launch over all
active rows, and a fault that the reference rung cannot absorb has no
per-request attribution to quarantine on.

Fault-injection hooks mirror ``runtime.ft.TrainLoop.failure_at_steps``:
``fail_prefill_of`` (uids whose admission wave's launch raises, through
the full ladder) and ``poison_slot_at`` (uid -> decode tick whose state
turns NaN; -1 poisons the spliced prefill state) make every quarantine
path provable in CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set

import jax.numpy as jnp
import numpy as np

from repro.analysis.plancheck import check_decode_tick
from repro.configs.base import ModelConfig
from repro.dispatch.planner import DispatchPlan
from repro.rnn import CompiledStack, ExecutionPolicy, compile as rnn_compile
from repro.runtime import obs
from repro.runtime.errors import (LaunchError, NonFiniteStateError,
                                  PlanRejected, QueueFull, RequestTimeout)
from repro.runtime.ft import StragglerWatchdog

#: completion statuses: "ok" = ran to its frame budget; "failed" = faulted
#: (launch fault, poisoned state, backpressure eviction) and quarantined;
#: "timeout" = a per-request deadline retired it mid-flight.
STATUSES = ("ok", "failed", "timeout")

#: bounded-admission policies: "reject" raises QueueFull at the submit
#: call; "drop_oldest" evicts the queue head as a failed completion.
BACKPRESSURE = ("reject", "drop_oldest")


@dataclasses.dataclass
class RecurrentRequest:
    uid: int
    frames: np.ndarray          # (T, X) prompt feature frames
    max_new_frames: int = 0     # autoregressive continuation steps
    priority: int = 0
    max_ticks: Optional[int] = None     # decode-tick deadline (per request)
    deadline_s: Optional[float] = None  # wall-time budget from admission


@dataclasses.dataclass
class RecurrentCompletion:
    uid: int
    prompt_len: int
    outputs: np.ndarray         # (T, H) top-layer prefill outputs
    generated: np.ndarray       # (n, H) fed-back continuation (n may be
                                # short of max_new_frames when status != ok)
    status: str = "ok"          # one of STATUSES
    error: Optional[str] = None  # fault detail when status != "ok"


class RecurrentServingEngine:
    """Continuous batching over a fixed slot pool, recurrent edition."""

    def __init__(self, cfg: ModelConfig, stack_params, max_batch: int = 4,
                 macs: int = 16384, interpret: Optional[bool] = None,
                 rnn_family: str = "lstm", *, on_fault: str = "fallback",
                 max_queue: Optional[int] = None,
                 backpressure: str = "reject",
                 watchdog_factor: Optional[float] = None,
                 watchdog_alpha: float = 0.3,
                 trace: bool = False):
        if cfg.family != "rnn":
            raise PlanRejected(
                f"recurrent engine serves rnn stacks, got config "
                f"{cfg.name!r} (family {cfg.family!r})")
        if cfg.bidirectional:
            raise PlanRejected(
                "bidirectional stacks have no streaming decode — serve "
                "whole sequences through CompiledStack.forward instead")
        if rnn_family not in ("lstm", "gru"):
            raise PlanRejected(f"rnn_family={rnn_family!r} invalid; "
                               "allowed: lstm, gru")
        if backpressure not in BACKPRESSURE:
            raise ValueError(f"backpressure={backpressure!r} invalid; "
                             f"allowed: {', '.join(BACKPRESSURE)}")
        self.cfg = cfg
        self.family = rnn_family
        self.max_batch = max_batch
        L, H = cfg.n_layers, cfg.lstm_hidden
        self.L, self.H = L, H

        # the planned execution path: every prefill wave and decode tick
        # goes through this one CompiledStack (shared plan cache included);
        # the engine defaults to on_fault="fallback" — a serving process
        # wants the guarded ladder, library callers keep fail-fast
        self.on_fault = on_fault
        self.compiled: CompiledStack = rnn_compile(
            stack_params, ExecutionPolicy(interpret=interpret, macs=macs,
                                          on_fault=on_fault, trace=trace))
        #: the compiled stack's tracer (runtime.obs) — the engine folds its
        #: serving events (admit spans, per-request admit->retire spans on
        #: the "requests" track, queue/occupancy histograms, watchdog
        #: instants) into the SAME trace the executor's launch spans land
        #: in; the shared no-op tracer when ``trace=False``
        self.tracer = self.compiled.tracer
        if self.compiled.families != (rnn_family,) * L:
            raise PlanRejected(
                f"stack families {self.compiled.families} do not match "
                f"rnn_family={rnn_family!r} x {L} layers")

        # batched recurrent state: one column per slot (the recurrent
        # analogue of the transformer engine's batch cache)
        self.h = jnp.zeros((L, max_batch, H), jnp.float32)
        self.c = (jnp.zeros((L, max_batch, H), jnp.float32)
                  if rnn_family == "lstm" else None)
        self.last_y = jnp.zeros((max_batch, 1, H), jnp.float32)

        self.queue: List[RecurrentRequest] = []
        self.max_queue = max_queue
        self.backpressure = backpressure
        self.slots: List[Optional[RecurrentRequest]] = [None] * max_batch
        self.prefill_out: List[Optional[np.ndarray]] = [None] * max_batch
        self.generated: List[List[np.ndarray]] = [[] for _ in range(max_batch)]
        self.slot_ticks: List[int] = [0] * max_batch
        self.admitted_at: List[Optional[float]] = [None] * max_batch
        # per-slot admission timestamps on the TRACER clock (µs), so
        # retirement can file the retroactive request span
        self._admit_us: List[Optional[float]] = [None] * max_batch
        self.done: List[RecurrentCompletion] = []
        self.steps = 0
        # dispatch accounting (inspected by tests/benchmarks); plan-cache
        # counters live on compiled.stats — see the properties below
        self.prefill_waves = 0
        self.packed_launches = 0
        self.naive_launches = 0
        self.last_plan: Optional[DispatchPlan] = None
        self.decode_ticks = 0
        self.decode_launches = 0
        self.last_decode_plan: Optional[DispatchPlan] = None
        # fault accounting + optional straggler detection
        self.quarantined = 0         # requests failed/evicted in isolation
        self.prefill_retries = 0     # solo re-admissions after a wave fault
        self.dropped = 0             # backpressure evictions
        self.watchdog = (StragglerWatchdog(watchdog_factor, watchdog_alpha)
                         if watchdog_factor is not None else None)
        self.straggler_ticks: List[int] = []
        # fault-injection hooks (the ft.failure_at_steps analogue):
        # uids whose admission wave's launch raises through the full ladder
        self.fail_prefill_of: Set[int] = set()
        # uid -> decode tick whose pre-tick state turns NaN (-1 = poison
        # the spliced prefill state instead)
        self.poison_slot_at: Dict[int, int] = {}

    @property
    def decode_plans_built(self) -> int:
        """Decode plans constructed (cache misses in the compiled stack):
        stays flat across steady-state ticks while decode_ticks grows."""
        return self.compiled.stats.decode_plans_built

    # ------------------------------------------------------------------
    def submit(self, req: RecurrentRequest):
        frames = np.asarray(req.frames)
        if frames.ndim != 2 or frames.shape[0] == 0:
            raise PlanRejected(f"request {req.uid}: prompt must be (T>0, X)",
                               uids=(req.uid,))
        if frames.shape[1] != self.cfg.lstm_input:
            raise PlanRejected(
                f"request {req.uid}: X={frames.shape[1]} != "
                f"lstm_input={self.cfg.lstm_input}", uids=(req.uid,))
        if req.max_new_frames > 0 and self.cfg.lstm_input != self.H:
            raise PlanRejected("feedback decode requires lstm_input == "
                               "hidden", uids=(req.uid,))
        if not np.isfinite(frames).all():
            # reject at the door: an admitted NaN frame propagates through
            # the prompt recurrence and poisons the slot's spliced state
            raise NonFiniteStateError(
                f"request {req.uid}: prompt frames contain NaN/Inf — "
                "rejected at submit", uids=(req.uid,), where="prompt")
        if self.max_queue is not None and len(self.queue) >= self.max_queue:
            if self.backpressure == "reject":
                raise QueueFull(
                    f"admission queue at capacity ({self.max_queue}); "
                    f"request {req.uid} rejected", uids=(req.uid,))
            evicted = self.queue.pop(0)  # drop_oldest: head is stalest
            self.dropped += 1
            self.quarantined += 1
            self.done.append(RecurrentCompletion(
                uid=evicted.uid, prompt_len=len(evicted.frames),
                outputs=np.zeros((0, self.H), np.float32),
                generated=np.zeros((0, self.H), np.float32),
                status="failed",
                error=f"evicted by backpressure='drop_oldest' "
                      f"(queue bound {self.max_queue})"))
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        """One admission wave -> one packed CompiledStack.prefill over ALL
        newly admitted prompts (the requests' cells share one
        DispatchPlan's wavefront slots and cross-B rows)."""
        pairs = []
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                pairs.append((slot, self.queue.pop(0)))
        if not pairs:  # queue drained mid-tick: nothing to dispatch
            return
        self._prefill_wave(pairs)
        self._retire()  # zero-new-frame requests complete right here

    def _prefill_wave(self, pairs):
        seqs = [jnp.asarray(req.frames, jnp.float32)[None]
                for _, req in pairs]
        armed = self._arm_injected_prefill_fault(pairs)
        with self.tracer.span("admit", n_requests=len(pairs),
                              uids=[req.uid for _, req in pairs]):
            try:
                results = self.compiled.prefill(
                    seqs, priorities=[req.priority for _, req in pairs])
            except LaunchError as err:
                if self.on_fault != "fallback":
                    raise  # fail-fast mode: preserve pre-ISSUE-6 behaviour
                self._quarantine_wave(pairs, err)
                return
            finally:
                if armed:
                    self.compiled.fault.disarm()
            p = self.compiled.plan
            self.prefill_waves += 1
            self.packed_launches += p.launches
            self.naive_launches += p.naive_launches
            self.last_plan = p
            for (slot, req), (out_b, st) in zip(pairs, results):
                self._splice(slot, req, out_b, st)

    def _arm_injected_prefill_fault(self, pairs) -> bool:
        """``fail_prefill_of`` hook: for waves containing a targeted uid,
        arm the compiled stack's injector through the WHOLE ladder, so the
        resulting ``LaunchError`` reaches the engine's quarantine even
        under on_fault="fallback" (a shallower arm would just be absorbed
        by the per-step rung)."""
        if not any(req.uid in self.fail_prefill_of for _, req in pairs):
            return False
        self.compiled.fault.arm([0], through_level=2)
        return True

    def _quarantine_wave(self, pairs, err: LaunchError):
        """Launch-fault bisection.  A single-request wave names its
        culprit: fail exactly that request.  A multi-request wave
        re-admits each request as its own solo wave — packed rows are
        independent by the cross-B masking contract (the dispatch bench
        asserts bit-equality of packed vs unpacked rows), so the healthy
        requests' solo outputs are bit-identical to the packed ones."""
        if len(pairs) == 1:
            _, req = pairs[0]
            self._fail_unadmitted(req, f"prefill launch fault: {err}")
            return
        self.prefill_retries += len(pairs)
        for pair in pairs:
            self._prefill_wave([pair])

    def _fail_unadmitted(self, req: RecurrentRequest, error: str):
        """A request that faulted before occupying a slot: surface a
        failed completion (empty outputs — prefill never finished)."""
        self.quarantined += 1
        if self.tracer.enabled:
            self.tracer.instant("request_failed", track="requests",
                                uid=req.uid, error=error)
            self.tracer.metrics.counter("requests_failed").add()
        self.done.append(RecurrentCompletion(
            uid=req.uid, prompt_len=len(req.frames),
            outputs=np.zeros((0, self.H), np.float32),
            generated=np.zeros((0, self.H), np.float32),
            status="failed", error=error))

    def _splice(self, slot: int, req: RecurrentRequest, out_b, st):
        """Splice one request's prefill result into its slot — or
        quarantine it (non-finite state/outputs fail ONLY this request)."""
        if st is None or "h" not in st:
            # the executor returns None (rglru, stateless schedules) or a
            # per-direction dict (bidirectional) for items with no single
            # t=T state — nothing to splice, and silently proceeding would
            # serve garbage decode frames.  A config-level mismatch, not a
            # per-request fault: raise (PlanRejected is a RuntimeError).
            raise PlanRejected(
                f"request {req.uid}: prefill returned no spliceable "
                f"recurrent state (family {self.family!r}); the engine "
                "can only serve stacks whose executor surfaces exact "
                "t=T (h[, c]) state", uids=(req.uid,))
        h_col = np.asarray(st["h"][:, 0], np.float32)
        c_col = (np.asarray(st["c"][:, 0], np.float32)
                 if self.c is not None else None)
        if self.poison_slot_at.get(req.uid) == -1:
            # injected fault: the quarantine below sees a REAL poisoned
            # splice, not a simulated flag
            h_col = np.full_like(h_col, np.nan)
        out = np.asarray(out_b[0])                  # (T, H)
        finite = (np.isfinite(h_col).all() and np.isfinite(out).all()
                  and (c_col is None or np.isfinite(c_col).all()))
        if not finite:
            self._fail_unadmitted(req, str(NonFiniteStateError(
                f"request {req.uid}: non-finite spliced prefill state — "
                "quarantined, slot stays free", uids=(req.uid,),
                where="prefill state")))
            return
        self.h = self.h.at[:, slot].set(jnp.asarray(h_col))
        if self.c is not None:
            self.c = self.c.at[:, slot].set(jnp.asarray(c_col))
        self.prefill_out[slot] = out
        self.last_y = self.last_y.at[slot, 0].set(
            jnp.asarray(out[-1], jnp.float32))
        self.slots[slot] = req
        self.generated[slot] = []
        self.slot_ticks[slot] = 0
        self.admitted_at[slot] = obs.monotonic_s()
        if self.tracer.enabled:
            self._admit_us[slot] = self.tracer.now_us()

    # ------------------------------------------------------------------
    def _decode_tick(self):
        """One planned decode step across the *active* slots only: their
        T=1 layer chains B-concatenate into a single chained slot — ONE
        kernel launch per tick instead of L — with each request's last
        top-layer frame fed back as its next input.  Plans are cached per
        active-slot signature inside the CompiledStack (plans are
        shape-only: WHICH slots are active changes the gather, not the
        plan).  Per-row finiteness quarantine after the launch fails only
        poisoned requests; the co-batched rows are independent and keep
        their values."""
        active = [s for s in range(self.max_batch)
                  if self.slots[s] is not None]
        # poison_slot_at hook: corrupt the targeted request's live state
        # just before its poisoned tick, so quarantine handles real NaN
        # propagation through the kernels
        for s in active:
            if self.poison_slot_at.get(
                    self.slots[s].uid) == self.slot_ticks[s]:
                self.h = self.h.at[:, s].set(jnp.nan)
        idx = jnp.asarray(active)
        state = {"h": self.h[:, idx]}
        if self.c is not None:
            state["c"] = self.c[:, idx]
        t0 = obs.monotonic_s()
        y, st = self.compiled.decode(self.last_y[idx], state)
        p = self.compiled.last_decode_plan
        # the dispatch claim, verified every tick: k active slots plan
        # exactly k-row cells — empty slots are never computed
        check_decode_tick(p, len(active))
        self.decode_ticks += 1
        self.decode_launches += p.launches
        self.last_decode_plan = p
        if self.tracer.enabled:
            # serving-level distributions: how full the pool runs and how
            # deep admissions back up, one observation per tick
            self.tracer.metrics.histogram("slot_occupancy").observe(
                len(active))
            self.tracer.metrics.histogram("queue_depth").observe(
                len(self.queue))
        if self.watchdog is not None and self.watchdog.observe(
                self.decode_ticks, obs.monotonic_s() - t0):
            self.straggler_ticks.append(self.decode_ticks)
            if self.tracer.enabled:
                self.tracer.instant("straggler", tick=self.decode_ticks)
                self.tracer.metrics.counter("straggler_ticks").add()

        self.h = self.h.at[:, idx].set(st["h"].astype(jnp.float32))
        if self.c is not None:
            self.c = self.c.at[:, idx].set(st["c"])
        frames = y[:, 0].astype(jnp.float32)            # (k, H)
        self.last_y = self.last_y.at[idx, 0].set(frames)
        frames_np = np.asarray(frames)
        new_h = np.asarray(st["h"])
        new_c = np.asarray(st["c"]) if self.c is not None else None
        poisoned = []
        for i, s in enumerate(active):
            row_ok = (np.isfinite(new_h[:, i]).all()
                      and np.isfinite(frames_np[i]).all()
                      and (new_c is None or np.isfinite(new_c[:, i]).all()))
            if row_ok:
                self.generated[s].append(frames_np[i])
                self.slot_ticks[s] += 1
            else:
                poisoned.append(s)
        for s in poisoned:
            uid = self.slots[s].uid
            self.quarantined += 1
            self._finish(s, status="failed", error=str(NonFiniteStateError(
                f"request {uid}: non-finite decode state/frame at tick "
                f"{self.slot_ticks[s]} — quarantined, slot freed",
                uids=(uid,), slot=s, where="decode frame")))

    def _finish(self, slot: int, status: str = "ok",
                error: Optional[str] = None):
        """Retire one slot into a completion (whatever frames it got)."""
        req = self.slots[slot]
        gen = (np.stack(self.generated[slot]) if self.generated[slot]
               else np.zeros((0, self.H), np.float32))
        if self.tracer.enabled:
            # the request's whole admit->retire lifetime as ONE retroactive
            # span on the "requests" track, beside the exec track's launches
            now = self.tracer.now_us()
            start = self._admit_us[slot]
            self.tracer.span_at(
                "request", start if start is not None else now, now,
                track="requests", uid=req.uid, slot=slot, status=status,
                ticks=self.slot_ticks[slot], frames=len(gen))
            self.tracer.metrics.counter(f"requests_{status}").add()
        self.done.append(RecurrentCompletion(
            uid=req.uid, prompt_len=len(req.frames),
            outputs=self.prefill_out[slot], generated=gen,
            status=status, error=error))
        self.slots[slot] = None
        self.generated[slot] = []
        self.admitted_at[slot] = None
        self._admit_us[slot] = None

    def _retire(self):
        """Deadline-aware retirement: frame-budget completion ("ok"),
        decode-tick deadline (``max_ticks``), and wall-time deadline
        (``deadline_s``, measured from admission) — expired requests
        retire as ``status="timeout"`` carrying their partial output."""
        now = obs.monotonic_s()
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if len(self.generated[slot]) >= req.max_new_frames:
                self._finish(slot)
            elif (req.max_ticks is not None
                  and self.slot_ticks[slot] >= req.max_ticks):
                self._finish(slot, status="timeout", error=(
                    f"request {req.uid}: max_ticks={req.max_ticks} expired "
                    f"with {len(self.generated[slot])}/"
                    f"{req.max_new_frames} frames"))
            elif (req.deadline_s is not None
                  and self.admitted_at[slot] is not None
                  and now - self.admitted_at[slot] > req.deadline_s):
                self._finish(slot, status="timeout", error=(
                    f"request {req.uid}: wall-time deadline "
                    f"{req.deadline_s}s expired with "
                    f"{len(self.generated[slot])}/"
                    f"{req.max_new_frames} frames"))

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit (packed prefill) -> planned decode ->
        retire."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        self._decode_tick()
        self.steps += 1
        self._retire()

    def run_to_completion(self, max_ticks: int = 10_000
                          ) -> List[RecurrentCompletion]:
        """Drive until queue and slots drain; ``max_ticks`` bounds THIS
        call (a local counter — repeated calls each get the full budget).
        On overrun, raises ``RequestTimeout`` carrying the completions
        already finished in ``.done`` — an engine-level deadline never
        loses completed work."""
        ticks = 0
        while self.queue or any(s is not None for s in self.slots):
            self.step()
            ticks += 1
            if ticks > max_ticks:
                stuck = sorted({r.uid for r in self.queue}
                               | {r.uid for r in self.slots
                                  if r is not None})
                raise RequestTimeout(
                    f"engine did not drain within {max_ticks} ticks; "
                    f"in-flight request uids {stuck} (finished "
                    "completions preserved in .done)",
                    uids=stuck, done=self.done)
        return self.done
