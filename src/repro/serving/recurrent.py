"""Recurrent-stack serving: dispatcher-backed continuous batching for the
paper's own LSTM family.

The transformer engine (serving.engine) admits requests one prefill at a
time; recurrent stacks can do strictly better, because *prefill itself is a
recurrence* — an (L layers x T steps) dependency grid.  This engine admits
every free slot's request in one wave, describes each prompt as a
``dispatch.WorkItem``, and runs ONE packed ``DispatchPlan``: the requests'
(layer, time-chunk) cells share wavefront slots, so G-batched sequence-
kernel launches hide the per-request serial dependencies behind each other
(ROADMAP item "Wavefront in serving").  The executor leaves behind each
request's exact t=T per-layer (h, c), which splices into the engine's
batched decode state exactly like the transformer engine splices KV-cache
rows.

Decode then proceeds engine-style: one tick = one batched step across all
active slots (L sequence-kernel launches at T=1), each new top-layer output
frame fed back as the next step's input (requires X == H, which the paper's
stacks satisfy).  Requests are *frame* streams, not token streams — the
serving analogue of an RNN acoustic/regression service (cf. the MASR-style
per-shape serving story, PAPERS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dispatch import WorkItem, execute, plan


@dataclasses.dataclass
class RecurrentRequest:
    uid: int
    frames: np.ndarray          # (T, X) prompt feature frames
    max_new_frames: int = 0     # autoregressive continuation steps
    priority: int = 0


@dataclasses.dataclass
class RecurrentCompletion:
    uid: int
    prompt_len: int
    outputs: np.ndarray         # (T, H) top-layer prefill outputs
    generated: np.ndarray       # (max_new_frames, H) fed-back continuation


class RecurrentServingEngine:
    """Continuous batching over a fixed slot pool, recurrent edition."""

    def __init__(self, cfg: ModelConfig, stack_params, max_batch: int = 4,
                 macs: int = 16384, interpret: Optional[bool] = None):
        assert cfg.family == "rnn", "recurrent engine serves rnn stacks"
        assert not cfg.bidirectional, \
            "bidirectional stacks have no streaming decode"
        self.cfg = cfg
        self.params = stack_params
        self.max_batch = max_batch
        self.macs = macs
        self.interpret = interpret
        L, H = cfg.n_layers, cfg.lstm_hidden
        self.L, self.H = L, H

        # batched recurrent state: one column per slot (the recurrent
        # analogue of the transformer engine's batch cache)
        self.h = jnp.zeros((L, max_batch, H), jnp.float32)
        self.c = jnp.zeros((L, max_batch, H), jnp.float32)
        self.last_y = jnp.zeros((max_batch, 1, H), jnp.float32)

        self.queue: List[RecurrentRequest] = []
        self.slots: List[Optional[RecurrentRequest]] = [None] * max_batch
        self.prefill_out: List[Optional[np.ndarray]] = [None] * max_batch
        self.generated: List[List[np.ndarray]] = [[] for _ in range(max_batch)]
        self.done: List[RecurrentCompletion] = []
        self.steps = 0
        self._admit_seq = 0  # WorkItem ids: engine-internal, so duplicate
        #                      request uids never collide inside a plan
        # dispatch accounting (inspected by tests/benchmarks)
        self.prefill_waves = 0
        self.packed_launches = 0
        self.naive_launches = 0
        self.last_plan = None

    # ------------------------------------------------------------------
    def submit(self, req: RecurrentRequest):
        frames = np.asarray(req.frames)
        if frames.ndim != 2 or frames.shape[0] == 0:
            raise ValueError(f"request {req.uid}: prompt must be (T>0, X)")
        if frames.shape[1] != self.cfg.lstm_input:
            raise ValueError(
                f"request {req.uid}: X={frames.shape[1]} != "
                f"lstm_input={self.cfg.lstm_input}")
        if req.max_new_frames > 0 and self.cfg.lstm_input != self.H:
            raise ValueError("feedback decode requires lstm_input == hidden")
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        """One admission wave -> one packed DispatchPlan for ALL newly
        admitted prompts (replacing one-slot-at-a-time prefill)."""
        pairs = []
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                pairs.append((slot, self.queue.pop(0)))
        if not pairs:  # queue drained mid-tick: nothing to dispatch
            return

        wids = {}
        for slot, req in pairs:
            wids[slot] = self._admit_seq
            self._admit_seq += 1
        items = [WorkItem.from_config(
            self.cfg, T=len(req.frames), B=1, uid=wids[slot],
            priority=req.priority) for slot, req in pairs]
        p = plan(items, macs=self.macs)
        params = {wids[slot]: self.params for slot, _ in pairs}
        inputs = {wids[slot]: jnp.asarray(req.frames, jnp.float32)[None]
                  for slot, req in pairs}
        outs, states = execute(p, params, inputs, interpret=self.interpret,
                               collect_state=True)
        self.prefill_waves += 1
        self.packed_launches += p.launches
        self.naive_launches += p.naive_launches
        self.last_plan = p

        for slot, req in pairs:
            st = states[wids[slot]]
            self.h = self.h.at[:, slot].set(st["h"][:, 0].astype(jnp.float32))
            self.c = self.c.at[:, slot].set(st["c"][:, 0])
            out = np.asarray(outs[wids[slot]][0])       # (T, H)
            self.prefill_out[slot] = out
            self.last_y = self.last_y.at[slot, 0].set(
                jnp.asarray(out[-1], jnp.float32))
            self.slots[slot] = req
            self.generated[slot] = []
        self._retire()  # zero-new-frame requests complete right here

    # ------------------------------------------------------------------
    def _decode_tick(self):
        """One batched decode step across all slots: the last output frame
        of every active request feeds back through the stack (L sequence-
        kernel launches at T=1, batched over the slot axis)."""
        from repro.kernels.lstm_cell.ops import lstm_seq

        y = self.last_y                                  # (S, 1, H)
        h_new, c_new = [], []
        for l, layer in enumerate(self.params["layers"]):
            H = self.H
            xw = (jnp.einsum("btx,xg->btg", y, layer["W"])
                  + layer["b"]).reshape(self.max_batch, 1, 4, H)
            hs, h_n, c_n = lstm_seq(layer["U"].reshape(H, 4, H), xw,
                                    self.h[l], self.c[l], block_t=1,
                                    interpret=self.interpret)
            h_new.append(h_n.astype(jnp.float32))
            c_new.append(c_n)
            y = hs.astype(jnp.float32)
        self.h = jnp.stack(h_new)
        self.c = jnp.stack(c_new)
        self.last_y = y
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.generated[slot].append(np.asarray(y[slot, 0]))

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if len(self.generated[slot]) >= req.max_new_frames:
                gen = (np.stack(self.generated[slot])
                       if self.generated[slot]
                       else np.zeros((0, self.H), np.float32))
                self.done.append(RecurrentCompletion(
                    uid=req.uid, prompt_len=len(req.frames),
                    outputs=self.prefill_out[slot], generated=gen))
                self.slots[slot] = None
                self.generated[slot] = []

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit (packed prefill) -> batched decode ->
        retire."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        self._decode_tick()
        self.steps += 1
        self._retire()

    def run_to_completion(self, max_ticks: int = 10_000
                          ) -> List[RecurrentCompletion]:
        while self.queue or any(s is not None for s in self.slots):
            self.step()
            if self.steps > max_ticks:
                raise RuntimeError("engine did not drain")
        return self.done
