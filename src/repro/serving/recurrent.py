"""Recurrent-stack serving: dispatcher-backed continuous batching for the
paper's own LSTM family.

The transformer engine (serving.engine) admits requests one prefill at a
time; recurrent stacks can do strictly better, because *prefill itself is a
recurrence* — an (L layers x T steps) dependency grid.  This engine admits
every free slot's request in one wave, describes each prompt as a
``dispatch.WorkItem``, and runs ONE packed ``DispatchPlan``: the requests'
(layer, time-chunk) cells share wavefront slots, so G-batched sequence-
kernel launches hide the per-request serial dependencies behind each other
(ROADMAP item "Wavefront in serving").  The executor leaves behind each
request's exact t=T per-layer (h, c), which splices into the engine's
batched decode state exactly like the transformer engine splices KV-cache
rows.

Decode is planned, not hand-rolled: one tick = one ``plan_decode``
DispatchPlan over the *active* slots only — their T=1 layer chains
B-concatenate (cross-B packing; every request binds the same stack) into a
single chained slot, ONE kernel launch per tick instead of L, with each new
top-layer output frame fed back as the next step's input (requires X == H,
which the paper's stacks satisfy).  Ticks in steady state (unchanged
active-slot signature) reuse a cached plan instead of replanning — the Zhao
et al. steady-state serving story (PAPERS.md).  Requests are *frame*
streams, not token streams — the serving analogue of an RNN
acoustic/regression service (cf. the MASR-style per-shape serving story,
PAPERS.md).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dispatch import DispatchPlan, WorkItem, execute, plan, plan_decode


@dataclasses.dataclass
class RecurrentRequest:
    uid: int
    frames: np.ndarray          # (T, X) prompt feature frames
    max_new_frames: int = 0     # autoregressive continuation steps
    priority: int = 0


@dataclasses.dataclass
class RecurrentCompletion:
    uid: int
    prompt_len: int
    outputs: np.ndarray         # (T, H) top-layer prefill outputs
    generated: np.ndarray       # (max_new_frames, H) fed-back continuation


class RecurrentServingEngine:
    """Continuous batching over a fixed slot pool, recurrent edition."""

    def __init__(self, cfg: ModelConfig, stack_params, max_batch: int = 4,
                 macs: int = 16384, interpret: Optional[bool] = None,
                 rnn_family: str = "lstm"):
        assert cfg.family == "rnn", "recurrent engine serves rnn stacks"
        assert not cfg.bidirectional, \
            "bidirectional stacks have no streaming decode"
        assert rnn_family in ("lstm", "gru"), rnn_family
        self.cfg = cfg
        self.family = rnn_family
        self.params = stack_params
        self.max_batch = max_batch
        self.macs = macs
        self.interpret = interpret
        L, H = cfg.n_layers, cfg.lstm_hidden
        self.L, self.H = L, H

        # batched recurrent state: one column per slot (the recurrent
        # analogue of the transformer engine's batch cache)
        self.h = jnp.zeros((L, max_batch, H), jnp.float32)
        self.c = (jnp.zeros((L, max_batch, H), jnp.float32)
                  if rnn_family == "lstm" else None)
        self.last_y = jnp.zeros((max_batch, 1, H), jnp.float32)

        self.queue: List[RecurrentRequest] = []
        self.slots: List[Optional[RecurrentRequest]] = [None] * max_batch
        self.prefill_out: List[Optional[np.ndarray]] = [None] * max_batch
        self.generated: List[List[np.ndarray]] = [[] for _ in range(max_batch)]
        self.done: List[RecurrentCompletion] = []
        self.steps = 0
        self._admit_seq = 0  # WorkItem ids: engine-internal, so duplicate
        #                      request uids never collide inside a plan
        # dispatch accounting (inspected by tests/benchmarks)
        self.prefill_waves = 0
        self.packed_launches = 0
        self.naive_launches = 0
        self.last_plan = None
        # decode accounting: per-tick plans are cached per active-slot
        # signature (the active count — plans are shape-only), so a
        # steady-state tick reuses its plan (plans_built stays flat while
        # ticks grow)
        self.decode_ticks = 0
        self.decode_launches = 0
        self.decode_plans_built = 0
        self.last_decode_plan: Optional[DispatchPlan] = None
        self._decode_plans: Dict[int, DispatchPlan] = {}
        self._decode_prepared: Optional[dict] = None  # stacked (Ws, bs, Us)

    # ------------------------------------------------------------------
    def submit(self, req: RecurrentRequest):
        frames = np.asarray(req.frames)
        if frames.ndim != 2 or frames.shape[0] == 0:
            raise ValueError(f"request {req.uid}: prompt must be (T>0, X)")
        if frames.shape[1] != self.cfg.lstm_input:
            raise ValueError(
                f"request {req.uid}: X={frames.shape[1]} != "
                f"lstm_input={self.cfg.lstm_input}")
        if req.max_new_frames > 0 and self.cfg.lstm_input != self.H:
            raise ValueError("feedback decode requires lstm_input == hidden")
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        """One admission wave -> one packed DispatchPlan for ALL newly
        admitted prompts (replacing one-slot-at-a-time prefill)."""
        pairs = []
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                pairs.append((slot, self.queue.pop(0)))
        if not pairs:  # queue drained mid-tick: nothing to dispatch
            return

        wids = {}
        for slot, req in pairs:
            wids[slot] = self._admit_seq
            self._admit_seq += 1
        items = [WorkItem.from_config(
            self.cfg, T=len(req.frames), B=1, uid=wids[slot],
            priority=req.priority, rnn_family=self.family,
            share=0) for slot, req in pairs]  # share: one stack serves all
        #   requests, so the planner may cross-B pack their cells
        p = plan(items, macs=self.macs)
        params = {wids[slot]: self.params for slot, _ in pairs}
        inputs = {wids[slot]: jnp.asarray(req.frames, jnp.float32)[None]
                  for slot, req in pairs}
        outs, states = execute(p, params, inputs, interpret=self.interpret,
                               collect_state=True)
        self.prefill_waves += 1
        self.packed_launches += p.launches
        self.naive_launches += p.naive_launches
        self.last_plan = p

        for slot, req in pairs:
            st = states[wids[slot]]
            if st is None or "h" not in st:
                # the executor returns None for items with no single t=T
                # state (rglru / bidirectional) — nothing to splice, and
                # silently proceeding would serve garbage decode frames
                raise RuntimeError(
                    f"request {req.uid}: prefill returned no spliceable "
                    f"recurrent state (family {self.family!r}); the engine "
                    "can only serve stacks whose executor surfaces exact "
                    "t=T (h[, c]) state")
            self.h = self.h.at[:, slot].set(st["h"][:, 0].astype(jnp.float32))
            if self.c is not None:
                self.c = self.c.at[:, slot].set(st["c"][:, 0])
            out = np.asarray(outs[wids[slot]][0])       # (T, H)
            self.prefill_out[slot] = out
            self.last_y = self.last_y.at[slot, 0].set(
                jnp.asarray(out[-1], jnp.float32))
            self.slots[slot] = req
            self.generated[slot] = []
        self._retire()  # zero-new-frame requests complete right here

    # ------------------------------------------------------------------
    def _decode_plan(self, active: List[int]) -> DispatchPlan:
        """The tick's DispatchPlan, cached by active-slot signature: a
        steady-state tick reuses its plan.  Plans are shape-only (uids are
        positions in the active list, inputs/state bound at execute), so
        the signature is just the active count — WHICH slots are active
        changes the gather, not the plan."""
        key = len(active)
        p = self._decode_plans.get(key)
        if p is None:
            items = [WorkItem(uid=i, family=self.family, B=1, T=1, H=self.H,
                              L=self.L, X=self.H, share=0)
                     for i in range(len(active))]
            p = plan_decode(items, macs=self.macs)
            self._decode_plans[key] = p
            self.decode_plans_built += 1
        return p

    def _decode_tick(self):
        """One planned decode step across the *active* slots only: their
        T=1 layer chains B-concatenate into a single chained slot — ONE
        kernel launch per tick instead of L — with each request's last
        top-layer frame fed back as its next input (the layer-0 input GEMM
        is hoisted inside the slot; deeper layers' run in-kernel)."""
        active = [s for s in range(self.max_batch)
                  if self.slots[s] is not None]
        p = self._decode_plan(active)
        # the dispatch claim, asserted every tick: k active slots plan
        # exactly k-row cells — empty slots are never computed
        assert all(s.B == len(active) and all(b == len(active)
                                              for b in s.group_b)
                   for s in p.slots), p.describe()

        if self._decode_prepared is None:
            from repro.dispatch.executor import prepare_decode_stack

            self._decode_prepared = prepare_decode_stack(self.params,
                                                         self.family)
        inputs = {i: self.last_y[slot][None]            # (1, 1, H)
                  for i, slot in enumerate(active)}
        init_state = {}
        for i, slot in enumerate(active):
            st = {"h": self.h[:, slot:slot + 1]}
            if self.c is not None:
                st["c"] = self.c[:, slot:slot + 1]
            init_state[i] = st
        outs, states = execute(
            p, {i: self.params for i in inputs}, inputs,
            interpret=self.interpret, collect_state=True,
            init_state=init_state,
            prepared={i: self._decode_prepared for i in inputs})
        self.decode_ticks += 1
        self.decode_launches += p.launches
        self.last_decode_plan = p

        for i, slot in enumerate(active):
            self.h = self.h.at[:, slot].set(
                states[i]["h"][:, 0].astype(jnp.float32))
            if self.c is not None:
                self.c = self.c.at[:, slot].set(states[i]["c"][:, 0])
            y = jnp.asarray(outs[i][0, 0], jnp.float32)  # top-layer frame
            self.last_y = self.last_y.at[slot, 0].set(y)
            self.generated[slot].append(np.asarray(y))

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if len(self.generated[slot]) >= req.max_new_frames:
                gen = (np.stack(self.generated[slot])
                       if self.generated[slot]
                       else np.zeros((0, self.H), np.float32))
                self.done.append(RecurrentCompletion(
                    uid=req.uid, prompt_len=len(req.frames),
                    outputs=self.prefill_out[slot], generated=gen))
                self.slots[slot] = None
                self.generated[slot] = []

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit (packed prefill) -> planned decode ->
        retire."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        self._decode_tick()
        self.steps += 1
        self._retire()

    def run_to_completion(self, max_ticks: int = 10_000
                          ) -> List[RecurrentCompletion]:
        while self.queue or any(s is not None for s in self.slots):
            self.step()
            if self.steps > max_ticks:
                raise RuntimeError("engine did not drain")
        return self.done
