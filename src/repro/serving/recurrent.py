"""Recurrent-stack serving: session management over the unified front-end.

The transformer engine (serving.engine) admits requests one prefill at a
time; recurrent stacks can do strictly better, because *prefill itself is a
recurrence* — an (L layers x T steps) dependency grid.  This engine admits
every free slot's request in one wave and hands the batch to ONE
``repro.rnn.CompiledStack.prefill`` call: the requests' (layer, time-chunk)
cells share wavefront slots, so G-batched sequence-kernel launches hide the
per-request serial dependencies behind each other (ROADMAP item "Wavefront
in serving").  The compiled stack leaves behind each request's exact t=T
per-layer (h, c), which splices into the engine's batched decode state
exactly like the transformer engine splices KV-cache rows.

Decode is planned, not hand-rolled: one tick = one ``CompiledStack.decode``
call over the *active* slots only — their T=1 layer chains B-concatenate
into a single chained slot, ONE kernel launch per tick instead of L, with
each new top-layer output frame fed back as the next step's input (requires
X == H, which the paper's stacks satisfy).  Ticks in steady state reuse the
compiled stack's cached plan instead of replanning — the Zhao et al.
steady-state serving story (PAPERS.md).  Requests are *frame* streams, not
token streams — the serving analogue of an RNN acoustic/regression service
(cf. the MASR-style per-shape serving story, PAPERS.md).

Post-ISSUE-4 the engine is ONLY the session layer — admission, slot pool,
state splicing, retirement.  It holds no planner/executor calls of its own:
serving, batch, and single-call users all exercise the identical
plan→pack→execute pipeline and plan caching through ``CompiledStack``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dispatch.planner import DispatchPlan
from repro.rnn import CompiledStack, ExecutionPolicy, compile as rnn_compile


@dataclasses.dataclass
class RecurrentRequest:
    uid: int
    frames: np.ndarray          # (T, X) prompt feature frames
    max_new_frames: int = 0     # autoregressive continuation steps
    priority: int = 0


@dataclasses.dataclass
class RecurrentCompletion:
    uid: int
    prompt_len: int
    outputs: np.ndarray         # (T, H) top-layer prefill outputs
    generated: np.ndarray       # (max_new_frames, H) fed-back continuation


class RecurrentServingEngine:
    """Continuous batching over a fixed slot pool, recurrent edition."""

    def __init__(self, cfg: ModelConfig, stack_params, max_batch: int = 4,
                 macs: int = 16384, interpret: Optional[bool] = None,
                 rnn_family: str = "lstm"):
        assert cfg.family == "rnn", "recurrent engine serves rnn stacks"
        assert not cfg.bidirectional, \
            "bidirectional stacks have no streaming decode"
        assert rnn_family in ("lstm", "gru"), rnn_family
        self.cfg = cfg
        self.family = rnn_family
        self.max_batch = max_batch
        L, H = cfg.n_layers, cfg.lstm_hidden
        self.L, self.H = L, H

        # the planned execution path: every prefill wave and decode tick
        # goes through this one CompiledStack (shared plan cache included)
        self.compiled: CompiledStack = rnn_compile(
            stack_params, ExecutionPolicy(interpret=interpret, macs=macs))
        assert self.compiled.families == (rnn_family,) * L, \
            (self.compiled.families, rnn_family)

        # batched recurrent state: one column per slot (the recurrent
        # analogue of the transformer engine's batch cache)
        self.h = jnp.zeros((L, max_batch, H), jnp.float32)
        self.c = (jnp.zeros((L, max_batch, H), jnp.float32)
                  if rnn_family == "lstm" else None)
        self.last_y = jnp.zeros((max_batch, 1, H), jnp.float32)

        self.queue: List[RecurrentRequest] = []
        self.slots: List[Optional[RecurrentRequest]] = [None] * max_batch
        self.prefill_out: List[Optional[np.ndarray]] = [None] * max_batch
        self.generated: List[List[np.ndarray]] = [[] for _ in range(max_batch)]
        self.done: List[RecurrentCompletion] = []
        self.steps = 0
        # dispatch accounting (inspected by tests/benchmarks); plan-cache
        # counters live on compiled.stats — see the properties below
        self.prefill_waves = 0
        self.packed_launches = 0
        self.naive_launches = 0
        self.last_plan: Optional[DispatchPlan] = None
        self.decode_ticks = 0
        self.decode_launches = 0
        self.last_decode_plan: Optional[DispatchPlan] = None

    @property
    def decode_plans_built(self) -> int:
        """Decode plans constructed (cache misses in the compiled stack):
        stays flat across steady-state ticks while decode_ticks grows."""
        return self.compiled.stats.decode_plans_built

    # ------------------------------------------------------------------
    def submit(self, req: RecurrentRequest):
        frames = np.asarray(req.frames)
        if frames.ndim != 2 or frames.shape[0] == 0:
            raise ValueError(f"request {req.uid}: prompt must be (T>0, X)")
        if frames.shape[1] != self.cfg.lstm_input:
            raise ValueError(
                f"request {req.uid}: X={frames.shape[1]} != "
                f"lstm_input={self.cfg.lstm_input}")
        if req.max_new_frames > 0 and self.cfg.lstm_input != self.H:
            raise ValueError("feedback decode requires lstm_input == hidden")
        self.queue.append(req)

    # ------------------------------------------------------------------
    def _admit(self):
        """One admission wave -> one packed CompiledStack.prefill over ALL
        newly admitted prompts (the requests' cells share one
        DispatchPlan's wavefront slots and cross-B rows)."""
        pairs = []
        for slot in range(self.max_batch):
            if self.slots[slot] is None and self.queue:
                pairs.append((slot, self.queue.pop(0)))
        if not pairs:  # queue drained mid-tick: nothing to dispatch
            return

        seqs = [jnp.asarray(req.frames, jnp.float32)[None]
                for _, req in pairs]
        results = self.compiled.prefill(
            seqs, priorities=[req.priority for _, req in pairs])
        p = self.compiled.plan
        self.prefill_waves += 1
        self.packed_launches += p.launches
        self.naive_launches += p.naive_launches
        self.last_plan = p

        for (slot, req), (out_b, st) in zip(pairs, results):
            if st is None or "h" not in st:
                # the executor returns None (rglru, stateless schedules)
                # or a per-direction dict (bidirectional) for items with
                # no single t=T state — nothing to splice, and silently
                # proceeding would serve garbage decode frames
                raise RuntimeError(
                    f"request {req.uid}: prefill returned no spliceable "
                    f"recurrent state (family {self.family!r}); the engine "
                    "can only serve stacks whose executor surfaces exact "
                    "t=T (h[, c]) state")
            self.h = self.h.at[:, slot].set(st["h"][:, 0].astype(jnp.float32))
            if self.c is not None:
                self.c = self.c.at[:, slot].set(st["c"][:, 0])
            out = np.asarray(out_b[0])                  # (T, H)
            self.prefill_out[slot] = out
            self.last_y = self.last_y.at[slot, 0].set(
                jnp.asarray(out[-1], jnp.float32))
            self.slots[slot] = req
            self.generated[slot] = []
        self._retire()  # zero-new-frame requests complete right here

    # ------------------------------------------------------------------
    def _decode_tick(self):
        """One planned decode step across the *active* slots only: their
        T=1 layer chains B-concatenate into a single chained slot — ONE
        kernel launch per tick instead of L — with each request's last
        top-layer frame fed back as its next input.  Plans are cached per
        active-slot signature inside the CompiledStack (plans are
        shape-only: WHICH slots are active changes the gather, not the
        plan)."""
        active = [s for s in range(self.max_batch)
                  if self.slots[s] is not None]
        idx = jnp.asarray(active)
        state = {"h": self.h[:, idx]}
        if self.c is not None:
            state["c"] = self.c[:, idx]
        y, st = self.compiled.decode(self.last_y[idx], state)
        p = self.compiled.last_decode_plan
        # the dispatch claim, asserted every tick: k active slots plan
        # exactly k-row cells — empty slots are never computed
        assert all(s.B == len(active) and all(b == len(active)
                                              for b in s.group_b)
                   for s in p.slots), p.describe()
        self.decode_ticks += 1
        self.decode_launches += p.launches
        self.last_decode_plan = p

        self.h = self.h.at[:, idx].set(st["h"].astype(jnp.float32))
        if self.c is not None:
            self.c = self.c.at[:, idx].set(st["c"])
        frames = y[:, 0].astype(jnp.float32)            # (k, H)
        self.last_y = self.last_y.at[idx, 0].set(frames)
        for i, slot in enumerate(active):
            self.generated[slot].append(np.asarray(frames[i]))

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            if len(self.generated[slot]) >= req.max_new_frames:
                gen = (np.stack(self.generated[slot])
                       if self.generated[slot]
                       else np.zeros((0, self.H), np.float32))
                self.done.append(RecurrentCompletion(
                    uid=req.uid, prompt_len=len(req.frames),
                    outputs=self.prefill_out[slot], generated=gen))
                self.slots[slot] = None
                self.generated[slot] = []

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit (packed prefill) -> planned decode ->
        retire."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        self._decode_tick()
        self.steps += 1
        self._retire()

    def run_to_completion(self, max_ticks: int = 10_000
                          ) -> List[RecurrentCompletion]:
        while self.queue or any(s is not None for s in self.slots):
            self.step()
            if self.steps > max_ticks:
                raise RuntimeError("engine did not drain")
        return self.done
