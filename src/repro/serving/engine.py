"""Batched serving engine: continuous batching over a fixed slot pool.

The decode step is the transformer's analogue of SHARP's serial recurrent
tail (one step per token, state-dependent), so the engine's job mirrors the
paper's scheduling story: keep the parallel work (prefill of incoming
requests) flowing around the serial work (batched decode) without stalling
it.

Mechanics:
  * ``max_batch`` slots share one batched cache (allocated once).
  * Admission: a free slot gets the next queued request; its prompt runs as
    a single-request prefill whose cache rows are spliced into the batch
    cache (slot-local positions via the per-slot ``idx`` cursor).
  * Prefill is *bucketed*: the jitted prefill only ever sees power-of-two
    prompt lengths (the largest bucket <= the prompt), so XLA compiles once
    per bucket instead of once per unique prompt length; the remainder
    tokens run through the single-token decode step (compiled once for the
    batch-1 admission shape, separate from the batched tick's compile).
    Chunked prefill + decode is positionally identical to a full prefill
    (causal attention / per-step recurrent updates), so results are exact.
  * Every engine tick decodes ALL active slots in one batched serve_step;
    finished slots (EOS or max_new_tokens) free immediately.
Greedy sampling by default; temperature optional.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as tf
from repro.runtime.errors import PlanRejected, RequestTimeout


@dataclasses.dataclass
class Request:
    uid: int
    tokens: np.ndarray  # (prompt_len,)
    max_new_tokens: int = 16
    eos_id: int = -1  # -1: never


@dataclasses.dataclass
class Completion:
    uid: int
    tokens: List[int]
    prompt_len: int


class ServingEngine:
    def __init__(self, cfg: ModelConfig, params, max_batch: int = 4,
                 max_seq: int = 256, temperature: float = 0.0, seed: int = 0):
        if cfg.embed_stub:
            raise PlanRejected(
                "stub-frontend archs serve via the embeds API, not the "
                "token engine")
        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)

        self.cache = tf.init_cache(cfg, max_batch, max_seq)
        self._decode = jax.jit(
            lambda p, c, t: tf.decode_step(cfg, p, c, {"tokens": t}))
        self._prefill = jax.jit(
            lambda p, t: tf.prefill(cfg, p, {"tokens": t}, seq_len=max_seq))

        self.prefill_lengths: set = set()  # distinct jitted prefill shapes

        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * max_batch
        self.generated: List[List[int]] = [[] for _ in range(max_batch)]
        self.last_token = np.zeros((max_batch, 1), np.int32)
        self.done: List[Completion] = []
        self.steps = 0

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        if len(req.tokens) == 0:
            raise ValueError(f"request {req.uid}: empty prompt")
        self.queue.append(req)

    def _splice_cache(self, slot: int, req_cache):
        # scan-stacked caches are (L, B, ...): the slot lives on axis 1;
        # per-layer list caches are (B, ...): axis 0
        axis = 1 if self.cfg.scan_layers else 0

        def one(big, small):
            if axis == 1:
                return big.at[:, slot:slot + 1].set(small.astype(big.dtype))
            return big.at[slot:slot + 1].set(small.astype(big.dtype))

        layers = jax.tree.map(one, self.cache["layers"], req_cache["layers"])
        idx = self.cache["idx"].at[slot].set(req_cache["idx"][0])
        self.cache = {"layers": layers, "idx": idx}

    def _prefill_bucketed(self, tokens):
        """Prefill a (1, L) prompt with a bucketed compile footprint.

        The jitted prefill runs on the largest power-of-two prefix b <= L
        (one compile per bucket, ever); the L - b remainder tokens advance
        through the single-token decode path (one extra compile for the
        batch-1 shape).  Returns (last_token_logits (1, V), cache)."""
        L = tokens.shape[1]
        bucket = 1 << (L.bit_length() - 1)  # largest power of two <= L
        self.prefill_lengths.add(bucket)
        logits, cache = self._prefill(self.params, tokens[:, :bucket])
        last = logits[:, -1]
        for t in range(bucket, L):
            step_logits, cache = self._decode(
                self.params, cache, tokens[:, t:t + 1])
            last = step_logits[:, -1]
        return last, cache

    def _admit(self):
        """Admission wave: claim every free slot for the queue's head, then
        hand the whole wave to ``_prefill_admitted`` at once (the base
        engine prefills per request; the recurrent engine overrides this
        with one dispatcher-packed wavefront execution)."""
        pairs = []
        for slot in range(self.max_batch):
            while self.slots[slot] is None and self.queue:
                req = self.queue.pop(0)
                if req.max_new_tokens <= 0:
                    # zero-token request: complete immediately — never
                    # occupies a slot, never reaches prefill/decode
                    self.done.append(Completion(req.uid, [], len(req.tokens)))
                    continue
                pairs.append((slot, req))
                self.slots[slot] = req
                break
        if not pairs:  # queue drained mid-tick (or only zero-token reqs)
            return
        self._prefill_admitted(pairs)

    def _prefill_admitted(self, pairs):
        """Prefill one admission wave.  Base engine: per-request bucketed
        prefill spliced into the batch cache."""
        for slot, req in pairs:
            tokens = jnp.asarray(req.tokens, jnp.int32)[None]
            logits, req_cache = self._prefill_bucketed(tokens)
            self._splice_cache(slot, req_cache)
            nxt = self._sample(logits)
            self.generated[slot] = [int(nxt[0])]
            self.last_token[slot, 0] = int(nxt[0])

    def _sample(self, logits):
        if self.temperature <= 0:
            return np.asarray(jnp.argmax(logits, axis=-1))
        self._key, sub = jax.random.split(self._key)
        return np.asarray(jax.random.categorical(sub, logits / self.temperature))

    def _retire(self):
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            gen = self.generated[slot]
            if len(gen) >= req.max_new_tokens or (gen and gen[-1] == req.eos_id):
                self.done.append(Completion(req.uid, gen, len(req.tokens)))
                self.slots[slot] = None

    # ------------------------------------------------------------------
    def step(self):
        """One engine tick: admit -> batched decode -> retire."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        logits, self.cache = self._decode(
            self.params, self.cache, jnp.asarray(self.last_token))
        nxt = self._sample(logits[:, 0])
        for slot, req in enumerate(self.slots):
            if req is None:
                continue
            self.generated[slot].append(int(nxt[slot]))
            self.last_token[slot, 0] = int(nxt[slot])
        self.steps += 1
        self._retire()

    def run_to_completion(self, max_ticks: int = 10_000) -> List[Completion]:
        while (self.queue or any(s is not None for s in self.slots)):
            self.step()
            if self.steps > max_ticks:
                in_flight = [s.uid for s in self.slots if s is not None]
                raise RequestTimeout(
                    f"engine did not drain within {max_ticks} ticks "
                    f"({len(self.queue)} queued, uids {in_flight} in "
                    "flight)", uids=in_flight, done=self.done)
        return self.done
