"""ExecutionPolicy: the validated, frozen replacement for the stringly-typed
``schedule="unfolded"`` / ``**kw`` surface of the pre-facade dispatch
wrappers.

A policy is *how* to run, never *what* to run — it carries no shapes and no
parameters, so one policy object serves every stack and every call, and a
``CompiledStack`` can hash plan-cache keys without inspecting it twice.
Every field is validated at construction with an error that names the
offending field and the allowed values (the old surface let an unknown
schedule string travel all the way into ``core.gru.run_layer``'s function
table and die as a bare KeyError).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.dispatch.planner import DEFAULT_MACS

#: "auto" lets the planner score wavefront/fused/per_step per shape;
#: the rest force one execution shape (the research schedules
#: sequential/batch/intergate/unfolded run the pure reference
#: implementations through the planner's external path).
SCHEDULES = ("auto", "wavefront", "fused", "per_step",
             "sequential", "batch", "intergate", "unfolded")

DTYPES = ("float32", "bfloat16", "float16")

#: "raise" = fail fast (pre-ISSUE-6 behaviour): the first launch failure
#: unwinds the caller.  "fallback" = the guarded execution ladder: a failed
#: fused/chained launch re-executes per-step and, failing that, through the
#: non-deprecated pure-jnp reference (oracle-equal by construction), with
#: the degradation recorded in ``CompiledStack.stats``.
ON_FAULT = ("raise", "fallback")

#: "plan" (the default) statically verifies every DispatchPlan the stack
#: builds — coverage, wavefront readiness, packing legality, VMEM budget
#: (``analysis.plancheck``) — raising a structured ``PlanInvariantError``
#: before any launch; runs once per plan-cache build, under an obs
#: ``verify`` span.  "off" skips verification (the benchmark baseline).
VERIFY = ("off", "plan")


def _bad(field: str, value, allowed) -> ValueError:
    return ValueError(
        f"ExecutionPolicy.{field}={value!r} is invalid; allowed: "
        f"{', '.join(str(a) for a in allowed)}")


@dataclass(frozen=True)
class ExecutionPolicy:
    """How a CompiledStack executes.

    schedule:  "auto" (planner-scored) or a forced schedule — one of
               ``SCHEDULES``.
    block_t:   wavefront T-stripe override, honored under "auto" too (the
               scorer then only weighs the pinned stripe against
               per_step); 0 = autotuned (VMEM-budgeted).
    interpret: force Pallas interpret mode (None = auto: interpret
               everywhere but real TPUs).
    dtype:     cast inputs before execution; None = keep the caller's.
    packing:   cross-B packing + stripe alignment on/off (off = every cell
               its own launch row; the benchmark baseline).
    macs:      planner tile-engine budget (the paper's K-width exploration
               space; DEFAULT_MACS = 16K, the paper's reference design).
    on_fault:  "raise" (fail fast) or "fallback" (guarded execution
               ladder: failed launches re-execute per-step, then through
               the pure-jnp reference, recorded in ``.stats`` — see
               ``ON_FAULT``).
    check_finite: verify each launch's recurrent state is finite and raise
               a structured ``NonFiniteStateError`` naming the poisoned
               items (fallback cannot fix a NaN — it re-derives
               deterministically — so this raises under either on_fault).
    verify:    "plan" (default) statically verifies every plan the stack
               builds against the dispatch invariants — exact coverage,
               wavefront readiness, packing legality, stripe/VMEM budgets
               (``analysis.plancheck``) — raising ``PlanInvariantError``
               before anything launches; "off" skips the check.  Runs
               once per plan-cache build (amortizes to zero across cache
               hits) and is counted in ``.stats.plans_verified``.
    trace:     record wall-clock spans + metrics for every plan/launch/
               decode tick on ``CompiledStack.tracer`` (a
               ``runtime.obs.Tracer`` — Chrome-trace export, latency
               histograms, predicted-vs-measured launch costs).  Off (the
               default) binds the shared no-op tracer: no events, no
               ``block_until_ready`` fencing, outputs bit-identical to
               the untraced path.
    """

    schedule: str = "auto"
    block_t: int = 0
    interpret: Optional[bool] = None
    dtype: Optional[str] = None
    packing: bool = True
    macs: int = DEFAULT_MACS
    on_fault: str = "raise"
    check_finite: bool = False
    verify: str = "plan"
    trace: bool = False

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise _bad("schedule", self.schedule, SCHEDULES)
        if (not isinstance(self.block_t, int) or isinstance(self.block_t, bool)
                or self.block_t < 0):
            raise _bad("block_t", self.block_t,
                       ("a non-negative int (0 = autotuned)",))
        if not (self.interpret is None or isinstance(self.interpret, bool)):
            raise _bad("interpret", self.interpret, (None, True, False))
        if self.dtype is not None and self.dtype not in DTYPES:
            raise _bad("dtype", self.dtype, (None,) + DTYPES)
        if not isinstance(self.packing, bool):
            raise _bad("packing", self.packing, (True, False))
        if (not isinstance(self.macs, int) or isinstance(self.macs, bool)
                or self.macs < 1):
            raise _bad("macs", self.macs, ("a positive int (MAC budget)",))
        if self.on_fault not in ON_FAULT:
            raise _bad("on_fault", self.on_fault, ON_FAULT)
        if not isinstance(self.check_finite, bool):
            raise _bad("check_finite", self.check_finite, (True, False))
        if self.verify not in VERIFY:
            raise _bad("verify", self.verify, VERIFY)
        if not isinstance(self.trace, bool):
            raise _bad("trace", self.trace, (True, False))

    def describe(self) -> str:
        return (f"ExecutionPolicy(schedule={self.schedule}, "
                f"block_t={self.block_t or 'auto'}, "
                f"interpret={self.interpret}, dtype={self.dtype or 'keep'}, "
                f"packing={self.packing}, macs={self.macs}, "
                f"on_fault={self.on_fault}, "
                f"check_finite={self.check_finite}, "
                f"verify={self.verify}, trace={self.trace})")
