"""ExecutionPolicy: the validated, frozen replacement for the stringly-typed
``schedule="unfolded"`` / ``**kw`` surface of the pre-facade dispatch
wrappers.

A policy is *how* to run, never *what* to run — it carries no shapes and no
parameters, so one policy object serves every stack and every call, and a
``CompiledStack`` can hash plan-cache keys without inspecting it twice.
Every field is validated at construction with an error that names the
offending field and the allowed values (the old surface let an unknown
schedule string travel all the way into ``core.gru.run_layer``'s function
table and die as a bare KeyError).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.dispatch.planner import DEFAULT_MACS
from repro.dispatch.workitem import PRECISIONS, SPARSITIES

#: "auto" lets the planner score wavefront/fused/per_step per shape;
#: the rest force one execution shape (the research schedules
#: sequential/batch/intergate/unfolded run the pure reference
#: implementations through the planner's external path).
SCHEDULES = ("auto", "wavefront", "fused", "per_step",
             "sequential", "batch", "intergate", "unfolded")

DTYPES = ("float32", "bfloat16", "float16")

#: "raise" = fail fast (pre-ISSUE-6 behaviour): the first launch failure
#: unwinds the caller.  "fallback" = the guarded execution ladder: a failed
#: fused/chained launch re-executes per-step and, failing that, through the
#: non-deprecated pure-jnp reference (oracle-equal by construction), with
#: the degradation recorded in ``CompiledStack.stats``.
ON_FAULT = ("raise", "fallback")

#: "plan" (the default) statically verifies every DispatchPlan the stack
#: builds — coverage, wavefront readiness, packing legality, VMEM budget
#: (``analysis.plancheck``) — raising a structured ``PlanInvariantError``
#: before any launch; runs once per plan-cache build, under an obs
#: ``verify`` span.  "off" skips verification (the benchmark baseline).
VERIFY = ("off", "plan")

# PRECISIONS / SPARSITIES (imported above, shared with the planner's
# WorkItems): "fp32" is bit-exact; "bf16" round-trips U through bfloat16
# (exact vs its dequantized oracle); "int8" quantizes U per-gate (4x
# smaller VMEM residency, fp32 accumulate) with a BOUNDED-error contract
# vs the dequantized oracle — the first policy surface that is not
# bit-equal (see rnn/README.md).  Sparsity: "none" (dense) or "block"
# (skip zero MXU row-tiles of U, value-exact up to dot reduction order).

#: "analytic" scores plans with the perfmodel's cycle formulas (the
#: default, zero-IO).  "measured" loads the replay-calibrated table
#: (``repro.calib``, ``artifacts/measured_costs.json``) for the bound
#: backend and scores merge/schedule/chained decisions in measured µs,
#: falling back to analytic scaling for unmeasured shapes; an empty or
#: missing table degrades to plans bit-identical to "analytic".
COST_MODELS = ("analytic", "measured")


def _bad(field: str, value, allowed) -> ValueError:
    return ValueError(
        f"ExecutionPolicy.{field}={value!r} is invalid; allowed: "
        f"{', '.join(str(a) for a in allowed)}")


@dataclass(frozen=True)
class ExecutionPolicy:
    """How a CompiledStack executes.

    schedule:  "auto" (planner-scored) or a forced schedule — one of
               ``SCHEDULES``.
    block_t:   wavefront T-stripe override, honored under "auto" too (the
               scorer then only weighs the pinned stripe against
               per_step); 0 = autotuned (VMEM-budgeted).
    interpret: force Pallas interpret mode (None = auto: interpret
               everywhere but real TPUs).
    dtype:     cast inputs before execution; None = keep the caller's.
    precision: recurrent-weight precision — "fp32" (bit-exact default),
               "bf16" (U round-tripped through bfloat16; exact vs its
               dequantized oracle), or "int8" (per-gate absmax int8
               payload resident in VMEM, fp32 accumulate; BOUNDED error
               vs the dequantized oracle, not bit-equality — see
               rnn/README.md "Precision & sparsity").  The input GEMM
               (W) always stays full precision.
    sparsity:  "none" (dense) or "block" — skip all-zero MXU row-tiles
               of each layer's recurrent matrix (tile bitmap derived from
               the bound parameters at compile; value-exact up to dot
               reduction order).
    packing:   cross-B packing + stripe alignment on/off (off = every cell
               its own launch row; the benchmark baseline).
    macs:      planner tile-engine budget (the paper's K-width exploration
               space; DEFAULT_MACS = 16K, the paper's reference design).
    on_fault:  "raise" (fail fast) or "fallback" (guarded execution
               ladder: failed launches re-execute per-step, then through
               the pure-jnp reference, recorded in ``.stats`` — see
               ``ON_FAULT``).
    check_finite: verify each launch's recurrent state is finite and raise
               a structured ``NonFiniteStateError`` naming the poisoned
               items (fallback cannot fix a NaN — it re-derives
               deterministically — so this raises under either on_fault).
    verify:    "plan" (default) statically verifies every plan the stack
               builds against the dispatch invariants — exact coverage,
               wavefront readiness, packing legality, stripe/VMEM budgets
               (``analysis.plancheck``) — raising ``PlanInvariantError``
               before anything launches; "off" skips the check.  Runs
               once per plan-cache build (amortizes to zero across cache
               hits) and is counted in ``.stats.plans_verified``.
    cost_model: "analytic" (perfmodel cycle formulas, the default) or
               "measured" (score planner decisions — merge-vs-split,
               schedule choice, chained-vs-loop decode — against the
               replay-calibrated ``repro.calib`` table for this backend;
               unmeasured shapes interpolate from the nearest measured
               neighbor or fall back to analytic, and an empty table
               plans bit-identically to "analytic").
    cost_table: path to the measured-cost JSON; None = the default
               ``artifacts/measured_costs.json``.  Only read when
               ``cost_model="measured"``.
    trace:     record wall-clock spans + metrics for every plan/launch/
               decode tick on ``CompiledStack.tracer`` (a
               ``runtime.obs.Tracer`` — Chrome-trace export, latency
               histograms, predicted-vs-measured launch costs).  Off (the
               default) binds the shared no-op tracer: no events, no
               ``block_until_ready`` fencing, outputs bit-identical to
               the untraced path.
    """

    schedule: str = "auto"
    block_t: int = 0
    interpret: Optional[bool] = None
    dtype: Optional[str] = None
    precision: str = "fp32"
    sparsity: str = "none"
    packing: bool = True
    macs: int = DEFAULT_MACS
    on_fault: str = "raise"
    check_finite: bool = False
    verify: str = "plan"
    cost_model: str = "analytic"
    cost_table: Optional[str] = None
    trace: bool = False

    def __post_init__(self):
        if self.schedule not in SCHEDULES:
            raise _bad("schedule", self.schedule, SCHEDULES)
        if (not isinstance(self.block_t, int) or isinstance(self.block_t, bool)
                or self.block_t < 0):
            raise _bad("block_t", self.block_t,
                       ("a non-negative int (0 = autotuned)",))
        if not (self.interpret is None or isinstance(self.interpret, bool)):
            raise _bad("interpret", self.interpret, (None, True, False))
        if self.dtype is not None and self.dtype not in DTYPES:
            raise _bad("dtype", self.dtype, (None,) + DTYPES)
        if self.precision not in PRECISIONS:
            raise _bad("precision", self.precision, PRECISIONS)
        if self.sparsity not in SPARSITIES:
            raise _bad("sparsity", self.sparsity, SPARSITIES)
        if not isinstance(self.packing, bool):
            raise _bad("packing", self.packing, (True, False))
        if (not isinstance(self.macs, int) or isinstance(self.macs, bool)
                or self.macs < 1):
            raise _bad("macs", self.macs, ("a positive int (MAC budget)",))
        if self.on_fault not in ON_FAULT:
            raise _bad("on_fault", self.on_fault, ON_FAULT)
        if not isinstance(self.check_finite, bool):
            raise _bad("check_finite", self.check_finite, (True, False))
        if self.verify not in VERIFY:
            raise _bad("verify", self.verify, VERIFY)
        if self.cost_model not in COST_MODELS:
            raise _bad("cost_model", self.cost_model, COST_MODELS)
        if not (self.cost_table is None or isinstance(self.cost_table, str)):
            raise _bad("cost_table", self.cost_table,
                       (None, "a path to a measured-cost JSON"))
        if not isinstance(self.trace, bool):
            raise _bad("trace", self.trace, (True, False))

    def describe(self) -> str:
        return (f"ExecutionPolicy(schedule={self.schedule}, "
                f"block_t={self.block_t or 'auto'}, "
                f"interpret={self.interpret}, dtype={self.dtype or 'keep'}, "
                f"precision={self.precision}, sparsity={self.sparsity}, "
                f"packing={self.packing}, macs={self.macs}, "
                f"on_fault={self.on_fault}, "
                f"check_finite={self.check_finite}, "
                f"verify={self.verify}, cost_model={self.cost_model}, "
                f"trace={self.trace})")
