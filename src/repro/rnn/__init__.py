"""repro.rnn — the unified recurrent-stack front-end.

One planned execution path from ``compile()`` to serving: every call
lowers to ``repro.dispatch`` WorkItems and executes through the tile
dispatcher (SHARP §5–6 — one dispatch mechanism that reconfigures to any
model shape, instead of per-shape code paths).  See README.md in this
directory for the API tour and the migration table from the deprecated
``core.schedules.run_layer/run_stack`` surface.

    from repro import rnn

    cs = rnn.compile(stack_params, rnn.ExecutionPolicy(schedule="auto"))
    ys = cs.forward(xs)                  # (B, T, H)
    ys, state = cs.prefill(xs)           # + exact t=T (h[, c])
    y_t, state = cs.decode(x_t, state)   # one chained launch per tick
    print(cs.plan.describe(), cs.stats)
"""
from repro.rnn.compiled import CompiledStack, StackStats, compile  # noqa: F401
from repro.rnn.policy import (COST_MODELS, DTYPES, ON_FAULT,  # noqa: F401
                              SCHEDULES, VERIFY, ExecutionPolicy)
from repro.runtime.errors import (FALLBACK_LEVELS, FaultInjector,  # noqa: F401
                                  LaunchError, NonFiniteStateError,
                                  PlanInvariantError, PlanRejected,
                                  QueueFull, RequestTimeout, ServingFault)

__all__ = ["compile", "CompiledStack", "StackStats", "ExecutionPolicy",
           "SCHEDULES", "DTYPES", "ON_FAULT", "VERIFY", "COST_MODELS",
           "FALLBACK_LEVELS",
           "ServingFault", "LaunchError", "NonFiniteStateError",
           "PlanRejected", "PlanInvariantError", "QueueFull",
           "RequestTimeout", "FaultInjector"]
