"""compile() -> CompiledStack: the one planned execution path.

``compile`` takes either a ``repro.configs`` ModelConfig (family "rnn") or
a parameter stack ``{"layers": [...]}`` (LSTM, GRU, or a mixed stack —
families are inferred per layer from the gate-axis width) plus an
``ExecutionPolicy``, and returns a ``CompiledStack`` whose every entry
point lowers to ``dispatch.WorkItem``s and executes through the tile
dispatcher's planner/executor:

    forward(xs)          whole-sequence evaluation (one stack; batch B)
    prefill(xs | [xs..]) forward + exact t=T recurrent state; a list packs
                         all requests into ONE DispatchPlan (the serving
                         admission wave)
    decode(x_t, state)   one T=1 tick resumed from ``state`` — a single
                         chained kernel launch for homogeneous lstm/gru
                         stacks (the serving steady state), a per-layer
                         T=1 plan for mixed stacks
    plan                 the most recent DispatchPlan (``.describe()``
                         prints every launch the executor will make)
    stats                launches / est_cycles / plans_built accounting

Plans are shape-only and cached per (direction, B, T, dtype) signature, so
repeated calls at one shape replan nothing — batch users, the serving
engine, and the deprecated ``core.schedules.run_stack`` shim all share
this exact pipeline, which is the point: dispatcher wins (wavefront
packing, cross-B merges, chained decode) reach every entry surface, a
mixed lstm/gru stack wavefronts across families with no special casing
(the planner groups cells into launches by their own layer's family), and
a bidirectional stack runs the interleaved fwd/bwd wavefront (ISSUE-5) —
forward returns the (B, T, 2H) fwd‖bwd concat, prefill per-direction
end-of-walk state, and decode raises (no streaming decode exists).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedules import stack_families
from repro.dispatch import (DispatchPlan, WorkItem, execute, plan,
                            plan_decode, prepare_decode_stack)
from repro.rnn.policy import ExecutionPolicy
from repro.runtime.errors import ExecutionReport, FaultInjector
from repro.runtime.obs import NULL_TRACER, Tracer


@dataclasses.dataclass
class StackStats:
    """Execution accounting of one CompiledStack (all counters cumulative).

    ``launches``/``est_cycles`` include decode ticks; ``plans_built``
    counts plan-cache misses (flat counters across steady-state reuse are
    the plan-cache proof the serving tests assert).

    ``degraded_launches`` counts slots the guarded execution ladder had to
    re-execute below their planned rung (policy ``on_fault="fallback"``);
    ``fallback_level`` is the deepest rung ever used (index into
    ``runtime.errors.FALLBACK_LEVELS``: 0 planned, 1 per-step, 2 pure-jnp
    reference); ``faults`` is the human-readable fault trail — a ring
    buffer keeping the ``MAX_FAULT_TRAIL`` most recent entries
    (``faults_total`` counts every fault ever, so a long-lived serving
    stack under chronic degradation holds bounded memory without losing
    the signal).  All of these stay zero/empty on a healthy stack — they
    are the degradation signal the serving layer watches.

    ``measured_hits``/``analytic_fallbacks`` (policy
    ``cost_model="measured"``) count the measured cost model's lookup
    resolutions across every plan this stack built: hits include
    interpolated neighbors; fallbacks are shapes the calibration table
    could not price (scored analytically instead).  Both stay zero under
    ``cost_model="analytic"``."""

    #: ring-buffer bound on ``faults`` — the trail keeps this many most
    #: recent entries; ``faults_total`` keeps the true count
    MAX_FAULT_TRAIL = 64

    forward_calls: int = 0
    decode_calls: int = 0
    launches: int = 0
    est_cycles: float = 0.0
    plans_built: int = 0
    plans_verified: int = 0
    decode_launches: int = 0
    decode_plans_built: int = 0
    degraded_launches: int = 0
    fallback_level: int = 0
    faults: List[str] = dataclasses.field(default_factory=list)
    faults_total: int = 0
    measured_hits: int = 0
    analytic_fallbacks: int = 0

    def record_faults(self, entries: Sequence[str]) -> None:
        """Append to the fault trail, keeping only the last
        ``MAX_FAULT_TRAIL`` entries (ring-buffer semantics)."""
        self.faults_total += len(entries)
        self.faults.extend(entries)
        if len(self.faults) > self.MAX_FAULT_TRAIL:
            del self.faults[:len(self.faults) - self.MAX_FAULT_TRAIL]


def _as_policy(policy) -> ExecutionPolicy:
    if policy is None:
        return ExecutionPolicy()
    if not isinstance(policy, ExecutionPolicy):
        raise TypeError(
            f"compile(..., policy=...) takes an ExecutionPolicy, got "
            f"{type(policy).__name__} — schedule strings moved into "
            "ExecutionPolicy(schedule=...)")
    return policy


def compile(model, policy: Optional[ExecutionPolicy] = None, *,
            params: Optional[dict] = None, rnn_family: str = "lstm",
            seed: int = 0) -> "CompiledStack":
    """Compile a recurrent stack into the planned execution path.

    ``model``: a ModelConfig (family "rnn") or a parameter stack
    ``{"layers": [...]}``.  For a config, ``params`` binds existing
    parameters; otherwise they are initialized from ``seed``
    (``rnn_family`` picks lstm or the paper §8 GRU variant).  For a
    parameter stack, families are inferred per layer from the gate widths
    — mixed lstm/gru stacks are first-class.
    """
    policy = _as_policy(policy)
    if isinstance(model, ModelConfig):
        if model.family != "rnn":
            raise ValueError(
                f"compile: config {model.name!r} (family {model.family!r}) "
                "is not a recurrent stack; the rnn facade compiles "
                "family='rnn' configs or {'layers': [...]} parameter stacks")
        if params is None:
            if rnn_family == "lstm":
                from repro.models.layers.lstm import init_lstm_stack

                params = init_lstm_stack(jax.random.PRNGKey(seed), model,
                                         jnp.dtype(model.dtype))
            elif rnn_family == "gru":
                if model.bidirectional:
                    raise ValueError(
                        "compile: no bidirectional GRU initializer; pass "
                        "params= explicitly")
                from repro.core.gru import init_gru_stack

                params = init_gru_stack(jax.random.PRNGKey(seed),
                                        model.lstm_input, model.lstm_hidden,
                                        model.n_layers,
                                        jnp.dtype(model.dtype))
            else:
                raise ValueError(
                    f"compile: rnn_family={rnn_family!r} invalid; "
                    "allowed: lstm, gru")
    elif isinstance(model, dict) and "layers" in model:
        if params is not None:
            raise ValueError(
                "compile: pass EITHER a parameter stack as model OR a "
                "config plus params=, not both")
        params = model
    else:
        raise TypeError(
            f"compile: expected a ModelConfig or a {{'layers': [...]}} "
            f"parameter stack, got {type(model).__name__}")
    return CompiledStack(params, policy)


class CompiledStack:
    """One recurrent stack bound to one ExecutionPolicy; see module doc."""

    def __init__(self, params: dict, policy: ExecutionPolicy):
        if not params.get("layers"):
            raise ValueError("CompiledStack: empty parameter stack")
        self.policy = policy
        if policy.precision != "fp32":
            # bind the fake-quant view ONCE: every execution surface —
            # packed kernels (which re-quantize it, an exact idempotent
            # round-trip), decode ticks, and the external reference
            # schedules — then computes with the SAME dequantized values,
            # so one oracle (reference_stack over these params) covers all
            # of them (see rnn/README.md "Precision & sparsity")
            from repro.kernels.quant import fake_quant_stack
            params = fake_quant_stack(params, policy.precision)
        self.params = params
        self.families: Tuple[str, ...] = stack_families(params)
        self.bidirectional = any("fwd" in l for l in params["layers"])
        if self.bidirectional and not all("fwd" in l
                                          for l in params["layers"]):
            raise ValueError(
                "CompiledStack: mixed uni/bidirectional layers unsupported")
        if self.bidirectional and len(set(self.families)) > 1:
            # fail at compile() like every other stack-shape error, not at
            # the first forward() from WorkItem validation
            raise ValueError(
                "CompiledStack: mixed-family stacks cannot be bidirectional")
        layer0 = params["layers"][0]
        half0 = layer0.get("fwd", layer0)
        self.H = int(half0["U"].shape[0])
        self.X = int(half0["W"].shape[0])
        self.L = len(params["layers"])
        widths = {int(l.get("fwd", l)["U"].shape[0])
                  for l in params["layers"]}
        if widths != {self.H}:
            raise ValueError(
                f"CompiledStack: layers must share one hidden width, got "
                f"{sorted(widths)}")
        self.stats = StackStats()
        #: the observability surface (policy ``trace=True``): a
        #: runtime.obs.Tracer recording plan/hoist/launch/decode-tick spans
        #: + metrics; the shared no-op tracer when tracing is off (zero
        #: events, no fencing — the untraced path is bit-identical)
        self.tracer = Tracer() if policy.trace else NULL_TRACER
        #: test/chaos hook: arm with plan slot indices to make launches
        #: raise (see runtime.errors.FaultInjector); disarmed = no-op
        self.fault = FaultInjector()
        #: the planner's cost scorer (policy ``cost_model="measured"``): a
        #: repro.calib.MeasuredCostModel over the persisted calibration
        #: table for THIS backend; None under "analytic".  A missing or
        #: empty table leaves the model inactive — the planner then takes
        #: the analytic paths untouched (cold-start bit-identity).
        self.cost_model = None
        if policy.cost_model == "measured":
            from repro.calib import (MEASURED_COSTS_PATH, MeasuredCostModel,
                                     MeasuredCostTable, current_backend)
            path = policy.cost_table or MEASURED_COSTS_PATH
            table = MeasuredCostTable.load(
                path, backend=current_backend(policy.interpret))
            self.cost_model = MeasuredCostModel(table, macs=policy.macs)
        #: block-sparsity occupancy of the bound parameters, derived ONCE
        #: at compile (policy ``sparsity="block"``): per-layer MXU
        #: row-tile bitmaps the planner prices and the executor
        #: row-compacts against.  None = dense.
        self._tile_map: Optional[tuple] = None
        if policy.sparsity == "block":
            from repro.kernels.quant import stack_tile_maps
            self._tile_map = stack_tile_maps(params)
        #: per-plan memo of quantized / row-compacted weight operands —
        #: valid for this stack's lifetime (the bound parameters never
        #: change), so each layer quantizes at most once across every
        #: forward/prefill/decode call
        self._quant_cache: dict = {}
        self.last_decode_plan: Optional[DispatchPlan] = None
        self._last_plan: Optional[DispatchPlan] = None
        self._plans: Dict[tuple, DispatchPlan] = {}
        self._prepared: Optional[dict] = None

    # ------------------------------------------------------------------
    @property
    def heterogeneous(self) -> bool:
        return len(set(self.families)) > 1

    @property
    def plan(self) -> Optional[DispatchPlan]:
        """The most recent forward/prefill DispatchPlan (decode keeps its
        own ``last_decode_plan``); None before the first call — use
        ``lower(B, T)`` to build one without executing."""
        return self._last_plan

    # ------------------------------------------------------------------
    def _item(self, uid: int, B: int, T: int, dtype: str,
              priority: int = 0) -> WorkItem:
        return WorkItem(uid=uid, family=self.families[0], B=B, T=T,
                        H=self.H, L=self.L, X=self.X, dtype=dtype,
                        priority=priority, bidirectional=self.bidirectional,
                        share=0, families=self.families,
                        precision=self.policy.precision,
                        tile_map=self._tile_map)

    @property
    def _dir_key(self) -> str:
        """Direction component of every plan-cache key: a bidirectional
        stack's plans are interleaved fwd/bwd timelines, never
        interchangeable with a unidirectional stack's at the same shape."""
        return "bi" if self.bidirectional else "uni"

    #: plan-cache bound: decode keys are bounded by the batch widths seen,
    #: but a long-running serving process with ragged prompt lengths almost
    #: never repeats an admission-wave signature — without a cap the cache
    #: is an unbounded leak.  LRU: re-hits refresh recency.
    MAX_CACHED_PLANS = 128

    def _cached(self, key, build) -> DispatchPlan:
        p = self._plans.get(key)
        if p is None:
            p = build()
            if self.policy.verify == "plan":
                # verify ONCE per cache miss, before the plan is ever
                # executable from the cache — steady-state reuse pays
                # nothing, and the verify span prices the miss cost
                from repro.analysis.plancheck import check_plan
                with self.tracer.span("verify", slots=len(p.slots)):
                    check_plan(p)
                self.stats.plans_verified += 1
            while len(self._plans) >= self.MAX_CACHED_PLANS:
                self._plans.pop(next(iter(self._plans)))
            self._plans[key] = p
            self.stats.plans_built += 1
            if key[0] == "dec":
                self.stats.decode_plans_built += 1
            if self.cost_model is not None:
                cm = self.cost_model
                self.stats.measured_hits = cm.hits + cm.interpolated
                self.stats.analytic_fallbacks = cm.fallbacks
        else:
            self._plans[key] = self._plans.pop(key)  # LRU refresh
        return p

    def lower(self, B: int, T: int, dtype: str = "float32",
              priority: int = 0) -> DispatchPlan:
        """Build (or fetch) the DispatchPlan for a shape without executing
        — the introspection entry point (``lower(...).describe()``).
        Shares its cache key with forward() and single-request prefill()."""
        return self._lower_many(((B, T, dtype),), (priority,))

    def _lower_many(self, shapes: Tuple[Tuple[int, int, str], ...],
                    prios: Tuple[int, ...]) -> DispatchPlan:
        """One plan over per-request (B, T, dtype) signatures — the single
        cache-key shape every entry point funnels through (a lone request
        and a one-element admission wave are the same plan)."""
        pol = self.policy
        force = None if pol.schedule == "auto" else pol.schedule
        key = ("fwd", self._dir_key, shapes, prios)
        return self._cached(key, lambda: plan(
            [self._item(i, b, t, dt, priority=p)
             for i, ((b, t, dt), p) in enumerate(zip(shapes, prios))],
            macs=pol.macs, cross_b=pol.packing, align_stripes=pol.packing,
            schedule=force, block_t=pol.block_t, tracer=self.tracer,
            cost_model=self.cost_model))

    # ------------------------------------------------------------------
    def _prep(self, xs, name: str):
        xs = jnp.asarray(xs)
        squeeze = xs.ndim == 2
        if squeeze:
            xs = xs[None]
        if xs.ndim != 3 or xs.shape[-1] != self.X:
            raise ValueError(
                f"CompiledStack.{name}: expected xs of shape "
                f"(B, T, {self.X}) or (T, {self.X}), got {tuple(xs.shape)}")
        if self.policy.dtype is not None:
            xs = xs.astype(self.policy.dtype)
        return xs, squeeze

    def _guard(self) -> Tuple[ExecutionReport, dict]:
        """Per-call guarded-ladder kwargs for execute(): the policy's fault
        knobs, this stack's injector, and a fresh degradation report that
        ``_account`` folds into ``.stats`` after a successful call."""
        rep = ExecutionReport()
        return rep, {"on_fault": self.policy.on_fault,
                     "check_finite": self.policy.check_finite,
                     "inject": self.fault, "report": rep,
                     "tracer": self.tracer}

    def _account(self, p: DispatchPlan, decode: bool = False,
                 report: Optional[ExecutionReport] = None) -> None:
        self.stats.launches += p.launches
        self.stats.est_cycles += p.est_cycles
        if report is not None and report.degraded_launches:
            self.stats.degraded_launches += report.degraded_launches
            self.stats.fallback_level = max(self.stats.fallback_level,
                                            report.fallback_level)
            self.stats.record_faults(report.faults)
        if decode:
            self.stats.decode_calls += 1
            self.stats.decode_launches += p.launches
            self.last_decode_plan = p
        else:
            self.stats.forward_calls += 1
            self._last_plan = p

    # ------------------------------------------------------------------
    def forward(self, xs):
        """Whole-sequence evaluation: (B, T, X) -> (B, T, H·dirs) (2-D
        input auto-batches and squeezes back)."""
        xs, squeeze = self._prep(xs, "forward")
        B, T, _ = xs.shape
        if T == 0:
            raise ValueError("CompiledStack.forward: T=0 sequence")
        tr = self.tracer
        with tr.span("forward", B=B, T=T) as sp:
            p = self.lower(B, T, str(xs.dtype))
            rep, guard = self._guard()
            outs = execute(p, {0: self.params}, {0: xs},
                           interpret=self.policy.interpret,
                           quant_cache=self._quant_cache, **guard)
            outs = tr.fence(outs)
            if tr.enabled:
                sp.tag(plan=tr.plan_id(p), launches=p.launches)
        self._account(p, report=rep)
        ys = outs[0]
        return ys[0] if squeeze else ys

    def prefill(self, xs, priorities: Optional[Sequence[int]] = None):
        """forward + exact t=T recurrent state.

        One array -> ``(ys, state)`` with state {"h": (L, B, H)[, "c"]}
        ("c" rows of a mixed stack's gru layers are zeros).  A SEQUENCE of
        arrays (the serving admission wave) packs every request into ONE
        DispatchPlan — their (layer, time-chunk) cells share wavefront
        slots and cross-B rows — and returns a list of (ys, state).

        Bidirectional stacks return per-direction state
        ``{"fwd": {"h"[, "c"]}, "bwd": {...}}`` — fwd's walk ends at t=T,
        bwd's at t=0, so there is no single t=T state to splice into a
        decode (the serving engine checks for a plain {"h": ...} dict).
        """
        if self.policy.schedule in ("sequential", "batch", "intergate",
                                    "unfolded", "per_step"):
            # these schedules have no state surface: the executor would
            # silently reroute state collection through the per-layer
            # fused path, executing a different schedule (with different
            # launches) than the plan's accounting reports
            raise ValueError(
                f"ExecutionPolicy.schedule={self.policy.schedule!r} has no "
                "t=T state surface; prefill requires a dispatcher schedule "
                "(auto, wavefront, fused) — use forward() for "
                "reference-schedule evaluation")
        single = not isinstance(xs, (list, tuple))
        seqs = [xs] if single else list(xs)
        if not seqs:
            raise ValueError("CompiledStack.prefill: empty request list")
        prios = list(priorities) if priorities is not None else [0] * len(seqs)
        if len(prios) != len(seqs):
            raise ValueError(
                f"CompiledStack.prefill: {len(prios)} priorities for "
                f"{len(seqs)} requests")
        prepped = [self._prep(x, "prefill") for x in seqs]
        inputs = {i: x for i, (x, _) in enumerate(prepped)}
        if any(x.shape[1] == 0 for x in inputs.values()):
            raise ValueError("CompiledStack.prefill: T=0 sequence")
        tr = self.tracer
        with tr.span("prefill", n_requests=len(seqs)) as sp:
            # per-request dtype: a mixed-precision wave must not share
            # launch signatures (the planner keys slots on dtype per item)
            p = self._lower_many(
                tuple((x.shape[0], x.shape[1], str(x.dtype))
                      for x in inputs.values()), tuple(prios))
            rep, guard = self._guard()
            outs, states = execute(p, {i: self.params for i in inputs},
                                   inputs,
                                   interpret=self.policy.interpret,
                                   collect_state=True,
                                   quant_cache=self._quant_cache, **guard)
            outs, states = tr.fence((outs, states))
            if tr.enabled:
                sp.tag(plan=tr.plan_id(p), launches=p.launches)
        self._account(p, report=rep)
        res = []
        for i, (_, squeeze) in enumerate(prepped):
            ys = outs[i][0] if squeeze else outs[i]
            res.append((ys, states[i]))
        return res[0] if single else res

    def decode(self, x_t, state):
        """One planned T=1 tick resumed from ``state`` ({"h": (L, B, H)
        [, "c"]}); returns (y_t (B, 1, H), new_state).

        Homogeneous lstm/gru stacks run the whole tick as ONE chained
        kernel launch (the serving steady state: the L dependent layer
        cells chain through VMEM scratch); mixed stacks fall back to a
        per-layer T=1 plan (L launches).  The policy's schedule preference
        does not apply here — decode is always state-resumed, which only
        the dispatcher paths support.
        """
        if self.bidirectional:
            raise ValueError(
                f"CompiledStack.decode: bidirectional stacks ({self.L} "
                "layers, both directions) have no streaming decode — the "
                "backward walk consumes the full sequence; run whole "
                "sequences through forward()/prefill() (the interleaved-"
                "wavefront path) instead")
        x_t = jnp.asarray(x_t)
        if x_t.ndim == 2:
            x_t = x_t[:, None, :]
        if x_t.ndim != 3 or x_t.shape[1] != 1 or x_t.shape[-1] != self.X:
            raise ValueError(
                f"CompiledStack.decode: expected x_t of shape (B, 1, "
                f"{self.X}) or (B, {self.X}), got {tuple(x_t.shape)}")
        if self.policy.dtype is not None:
            x_t = x_t.astype(self.policy.dtype)
        B = x_t.shape[0]
        dtype = str(x_t.dtype)
        tr = self.tracer
        with tr.span("decode_tick", B=B) as sp:
            if not self.heterogeneous:
                key = ("dec", B, dtype)
                p = self._cached(key, lambda: plan_decode(
                    [self._item(0, B, 1, dtype)], macs=self.policy.macs,
                    tracer=tr, cost_model=self.cost_model))
                if p.items[0].schedule == "decode":
                    if self._prepared is None:
                        # self.params already carries the fake-quant view,
                        # so the precision round-trip here is an exact
                        # idempotent no-op — passed anyway to keep the
                        # surfaces honest about what decode computes with
                        self._prepared = prepare_decode_stack(
                            self.params, self.families[0],
                            precision=self.policy.precision)
                    prepared = {0: self._prepared}
                else:
                    # measured cost model flipped this tick to the
                    # per-layer plan (L small launches beat one chained
                    # launch on this backend) — the mixed-stack path,
                    # which needs no hoisted decode operands
                    prepared = None
            else:
                # mixed stacks: per-layer T=1 plan — FORCED onto the packed
                # timeline (schedule="wavefront" at bt=1 collapses to
                # packable per-layer cells), because only packed items
                # resume from init_state; at T=1 the auto scorer's fused
                # and per_step estimates tie to within rounding, and a
                # per_step pick would route external, where execute()
                # rejects init_state
                key = ("dec", B, dtype)
                p = self._cached(key, lambda: plan(
                    [self._item(0, B, 1, dtype)], macs=self.policy.macs,
                    cross_b=self.policy.packing, schedule="wavefront",
                    block_t=1, tracer=tr, cost_model=self.cost_model))
                prepared = None
            rep, guard = self._guard()
            outs, states = execute(p, {0: self.params}, {0: x_t},
                                   interpret=self.policy.interpret,
                                   collect_state=True,
                                   init_state={0: state},
                                   prepared=prepared,
                                   quant_cache=self._quant_cache, **guard)
            outs, states = tr.fence((outs, states))
            if tr.enabled:
                sp.tag(plan=tr.plan_id(p), launches=p.launches)
        if tr.enabled:
            tr.metrics.histogram("decode_tick_us").observe(sp.dur_us)
        self._account(p, decode=True, report=rep)
        return outs[0], states[0]

    # ------------------------------------------------------------------
    def describe(self) -> str:
        fams = "/".join(self.families) if self.heterogeneous \
            else self.families[0]
        bi = " bidirectional" if self.bidirectional else ""
        s = self.stats
        cm_line = ("analytic (perfmodel cycle formulas)"
                   if self.cost_model is None
                   else self.cost_model.describe())
        lines = [
            f"CompiledStack: {fams} L{self.L} H{self.H} X{self.X}{bi}",
            f"  {self.policy.describe()}",
            f"  cost model: {cm_line}",
            f"  stats: {s.forward_calls} forward / {s.decode_calls} decode "
            f"calls, {s.launches} launches ({s.decode_launches} decode), "
            f"{s.plans_built} plans built ({s.decode_plans_built} decode, "
            f"{s.plans_verified} verified), "
            f"est {s.est_cycles:.0f}cy",
            f"  plan cache: {len(self._plans)} shapes",
        ]
        if s.degraded_launches:
            from repro.runtime.errors import FALLBACK_LEVELS
            lines.append(
                f"  DEGRADED: {s.degraded_launches} launches fell back "
                f"(deepest rung: {FALLBACK_LEVELS[s.fallback_level]}; "
                f"{s.faults_total} faults, trail keeps last "
                f"{s.MAX_FAULT_TRAIL})")
        if self.tracer.enabled:
            lines.append("  observability:")
            lines += ["    " + ln
                      for ln in self.tracer.describe().splitlines()]
        if self._last_plan is not None:
            lines.append("  last plan:")
            lines += ["    " + ln
                      for ln in self._last_plan.describe().splitlines()]
        return "\n".join(lines)
