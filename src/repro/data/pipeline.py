"""Deterministic synthetic token pipeline (sharded, checkpointable).

Two sources:
  * ``random``  — uniform tokens; for dry-runs and throughput benches.
  * ``markov``  — a fixed random bigram chain; has learnable structure so the
    end-to-end training examples show a real loss drop.

Determinism: batch ``i`` is a pure function of (seed, i) — restarting from a
checkpoint at step ``i`` reproduces the exact stream (no hidden iterator
state), which is what makes the fault-tolerance story exact.  Per-host
sharding: each data-parallel host materializes only its slice
[host_id * per_host : (host_id+1) * per_host) of the global batch.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    source: str = "markov"  # markov | random
    embed_dim: int = 0      # >0: emit precomputed embeddings (stub frontends)
    num_hosts: int = 1
    host_id: int = 0


class SyntheticPipeline:
    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.per_host = cfg.global_batch // cfg.num_hosts
        if cfg.source == "markov":
            rng = np.random.default_rng(cfg.seed)
            # peaked bigram transition table -> learnable next-token structure
            logits = rng.normal(size=(cfg.vocab_size, cfg.vocab_size)) * 2.0
            self._trans = _softmax(logits)
        self._embed_rng_seed = cfg.seed + 17

    # ------------------------------------------------------------------
    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """The (host-local) batch for global step ``step`` — pure function."""
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed, step, cfg.host_id, 0xB10C))
        if cfg.source == "random":
            tokens = rng.integers(0, cfg.vocab_size,
                                  size=(self.per_host, cfg.seq_len),
                                  dtype=np.int32)
        else:
            tokens = np.empty((self.per_host, cfg.seq_len), np.int32)
            tokens[:, 0] = rng.integers(0, cfg.vocab_size, size=self.per_host)
            for t in range(1, cfg.seq_len):
                u = rng.random((self.per_host, 1))
                cdf = np.cumsum(self._trans[tokens[:, t - 1]], axis=-1)
                tokens[:, t] = (u > cdf).sum(axis=-1)
        out: Dict[str, np.ndarray] = {"tokens": tokens}
        if cfg.embed_dim:
            erng = np.random.default_rng((self._embed_rng_seed, step, cfg.host_id))
            out = {
                "embeds": erng.normal(size=(self.per_host, cfg.seq_len,
                                            cfg.embed_dim)).astype(np.float32),
                "labels": tokens,
            }
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def _softmax(x):
    e = np.exp(x - x.max(axis=-1, keepdims=True))
    return e / e.sum(axis=-1, keepdims=True)
