"""End-to-end training driver (CPU-runnable; mesh-ready).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m --reduced \
        --steps 50 --batch 8 --seq 64
    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-3b --reduced \
        --steps 100 --compression int8 --fail-at 30   # FT demo
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.configs import get_config, get_reduced
from repro.data import DataConfig, SyntheticPipeline
from repro.launch.mesh import host_mesh
from repro.launch.steps import (TrainSettings, init_opt_state, make_train_step)
from repro.models import transformer as tf
from repro.models.layers.common import sharding_ctx
from repro.optim import AdamWConfig, CompressionConfig
from repro.runtime import FTConfig, TrainLoop
from repro.sharding.partition import batch_spec, param_specs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "int8", "topk"])
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a fault at this step (FT demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    mesh = host_mesh(model=args.mesh_model) if len(jax.devices()) > 1 else None

    settings = TrainSettings(
        adamw=AdamWConfig(lr=args.lr, total_steps=args.steps,
                          warmup_steps=max(args.steps // 20, 5)),
        compression=CompressionConfig(scheme=args.compression),
        microbatches=args.microbatches,
    )

    key = jax.random.PRNGKey(args.seed)
    data = SyntheticPipeline(DataConfig(
        vocab_size=max(cfg.vocab_size, 2), seq_len=args.seq,
        global_batch=args.batch, seed=args.seed,
        embed_dim=cfg.d_model if cfg.embed_stub else 0))

    ctx = sharding_ctx(mesh) if mesh is not None else _nullctx()
    with ctx:
        params = tf.init_params(cfg, key)
        opt_state = init_opt_state(cfg, params, settings)
        train_step = make_train_step(cfg, settings)
        p_sh = o_sh = None
        if mesh is not None:
            p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                param_specs(params, mesh))
            o_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                                param_specs(opt_state, mesh))
            params = jax.device_put(params, p_sh)
            opt_state = jax.device_put(opt_state, o_sh)
            step_fn = jax.jit(train_step, in_shardings=(p_sh, o_sh, None),
                              out_shardings=(p_sh, o_sh, None),
                              donate_argnums=(0, 1))
        else:
            step_fn = jax.jit(train_step, donate_argnums=(0, 1))

        def batch_fn(step):
            return {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}

        loop = TrainLoop(step_fn, batch_fn,
                         FTConfig(ckpt_dir=f"{args.ckpt_dir}/{cfg.name}",
                                  ckpt_every=args.ckpt_every),
                         shardings=(p_sh, o_sh))
        if args.fail_at >= 0:
            loop.failure_at_steps.add(args.fail_at)

        t0 = time.time()
        params, opt_state, step = loop.run(params, opt_state, 0, args.steps)
        wall = time.time() - t0

    hist = loop.metrics_history
    first = np.mean([h["loss"] for h in hist[:5]])
    last = np.mean([h["loss"] for h in hist[-5:]])
    tok_s = args.batch * args.seq * len(hist) / wall
    print(json.dumps({
        "arch": cfg.name, "steps": step, "wall_s": round(wall, 1),
        "tokens_per_s": round(tok_s, 1),
        "loss_first5": round(float(first), 4),
        "loss_last5": round(float(last), 4),
        "restarts": loop.restarts,
        "stragglers": loop.watchdog.flagged,
    }, indent=1))
    if args.steps >= 20:
        assert last < first, "training did not reduce loss"
    return loop


class _nullctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
