"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n_axes: int):
    # jax.sharding.AxisType (and make_mesh's axis_types kwarg) arrived after
    # 0.4.x; Auto is the default there anyway, so omit when unavailable.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod 'pod' axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Elastic helper: whatever topology the (restarted) job got."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_mesh_kwargs(len(axes)))


def host_mesh(n: int = 0, model: int = 1):
    """Small debug mesh over host platform devices."""
    n = n or len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))
