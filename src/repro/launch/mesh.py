"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds the 2-pod 'pod' axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_mesh(shape, axes):
    """Elastic helper: whatever topology the (restarted) job got."""
    return jax.make_mesh(
        tuple(shape), tuple(axes),
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def host_mesh(n: int = 0, model: int = 1):
    """Small debug mesh over host platform devices."""
    n = n or len(jax.devices())
    data = n // model
    return make_mesh((data, model), ("data", "model"))
