"""Step functions (train / prefill / serve) + abstract input specs.

These are the exact computations the dry-run lowers and the drivers run;
there is no separate "dry-run model".
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer as tf
from repro.models.layers.common import param_dtype
from repro.optim import (AdamWConfig, CompressionConfig, apply_updates,
                         compress, init_state)


@dataclasses.dataclass(frozen=True)
class TrainSettings:
    adamw: AdamWConfig = AdamWConfig()
    compression: CompressionConfig = CompressionConfig()
    microbatches: int = 1


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, settings: TrainSettings = TrainSettings()):
    n_micro = settings.microbatches

    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: tf.loss_fn(cfg, p, batch), has_aux=True)(params)

    def train_step(params, opt_state, batch):
        if n_micro == 1:
            (loss, metrics), grads = grad_fn(params, batch)
        else:
            # gradient accumulation: scan over microbatches
            def split(x):
                return x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (loss, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = jax.lax.scan(acc_step, (g0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss = loss_sum / n_micro
            metrics = {"loss": loss, "aux_loss": jnp.zeros((), jnp.float32)}

        if settings.compression.scheme != "none":
            grads, new_err = compress(settings.compression, grads,
                                      opt_state["err"])
        new_params, new_opt, om = apply_updates(
            settings.adamw, params, grads, opt_state["adam"])
        out_state = {"adam": new_opt}
        if settings.compression.scheme != "none":
            out_state["err"] = new_err
        elif "err" in opt_state:
            out_state["err"] = opt_state["err"]
        return new_params, out_state, {**metrics, **om}

    return train_step


def init_opt_state(cfg: ModelConfig, params,
                   settings: TrainSettings = TrainSettings()):
    state: Dict[str, Any] = {"adam": init_state(params)}
    if settings.compression.scheme != "none":
        from repro.optim import init_error_state

        state["err"] = init_error_state(params)
    return state


# ---------------------------------------------------------------------------
# inference
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, seq_len: int):
    def prefill_step(params, batch):
        logits, cache = tf.prefill(cfg, params, batch, seq_len=seq_len)
        return logits[:, -1:], cache  # serving returns next-token logits only

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        logits, new_cache = tf.decode_step(cfg, params, cache, batch)
        return logits, new_cache

    return serve_step


# ---------------------------------------------------------------------------
# abstract inputs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def batch_struct(cfg: ModelConfig, batch: int, seq: int) -> Dict[str, Any]:
    if cfg.embed_stub:
        return {
            "embeds": jax.ShapeDtypeStruct((batch, seq, cfg.d_model),
                                           jnp.bfloat16),
            "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32)}


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                settings: TrainSettings = TrainSettings(), key=None
                ) -> Dict[str, Any]:
    """Abstract (ShapeDtypeStruct) arguments for the step of ``shape.mode``."""
    if key is None:
        key = jax.random.PRNGKey(0)
    params = jax.eval_shape(lambda: tf.init_params(cfg, key))
    if shape.mode == "train":
        opt = jax.eval_shape(lambda: init_opt_state(
            cfg, params, settings))
        batch = batch_struct(cfg, shape.global_batch, shape.seq_len)
        return {"params": params, "opt_state": opt, "batch": batch}
    if shape.mode == "prefill":
        batch = batch_struct(cfg, shape.global_batch, shape.seq_len)
        return {"params": params, "batch": batch}
    if shape.mode == "decode":
        cache = jax.eval_shape(lambda: tf.init_cache(
            cfg, shape.global_batch, shape.seq_len))
        if cfg.embed_stub:
            batch = {"embeds": jax.ShapeDtypeStruct(
                (shape.global_batch, 1, cfg.d_model), jnp.bfloat16)}
        else:
            batch = {"tokens": jax.ShapeDtypeStruct(
                (shape.global_batch, 1), jnp.int32)}
        return {"params": params, "cache": cache, "batch": batch}
    raise ValueError(shape.mode)
