import os
_SMALL = bool(os.environ.get("REPRO_DRYRUN_SMALL"))  # test mode: 16 devices
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + ("16" if _SMALL else "512"))
# ^ MUST precede any jax-importing import: jax locks the device count at init.

import argparse  # noqa: E402
import gzip  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, get_config, list_archs, supports_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (TrainSettings, init_opt_state, input_specs,  # noqa: E402
                                make_prefill_step, make_serve_step,
                                make_train_step)
from repro.models import transformer as tf  # noqa: E402
from repro.models.layers.common import sharding_ctx  # noqa: E402
from repro.sharding.partition import batch_spec, cache_specs, param_specs  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on placeholder devices exactly what a launch on a
real 256-chip pod (or 2-pod slice) would exercise: the sharding rules are
coherent, the collectives XLA inserts are supported, and the per-device
memory footprint is printed from ``compiled.memory_analysis()``.  Artifacts
(memory stats, cost analysis, gzipped optimized HLO for the roofline pass)
land in artifacts/dryrun/.
"""


def settings_for(cfg, shape) -> TrainSettings:
    if shape.mode != "train":
        return TrainSettings()
    # bound activation memory: <= ~64k global tokens per microbatch
    tokens = shape.global_batch * shape.seq_len
    micro = max(1, tokens // 65536)
    while shape.global_batch % micro:
        micro -= 1
    return TrainSettings(microbatches=micro)


def shardings_for(cfg, shape, mesh, specs, settings):
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)
    # Decode is latency-bound serial work (the paper's recurrent tail):
    # keep weights STATIONARY (TP-only) instead of FSDP-gathering them every
    # step — unless the model is too big to be 16-way resident (arctic,
    # qwen2).  PREFILL keeps FSDP: with ~1M tokens in flight, per-layer
    # weight gathers (1.4 GB) beat TP activation psums (17 GB); measured
    # difference is ~neutral because prefill's collective term is dominated
    # by attention-head resharding instead (EXPERIMENTS.md §Perf).
    tp_only = shape.mode == "decode" and cfg.num_params() <= 70e9
    p_spec = param_specs(specs["params"], mesh,
                         multi_pod_fsdp=True, fsdp=not tp_only)
    if shape.mode == "train":
        o_spec = param_specs(specs["opt_state"], mesh)
        b_spec = batch_spec(mesh, specs["batch"])
        in_sh = (ns(p_spec), ns(o_spec), ns(b_spec))
        out_sh = (ns(p_spec), ns(o_spec), None)
        donate = (0, 1)
    elif shape.mode == "prefill":
        b_spec = batch_spec(mesh, specs["batch"])
        cache_shape = jax.eval_shape(
            lambda: tf.init_cache(cfg, shape.global_batch, shape.seq_len))
        c_spec = cache_specs(cache_shape, mesh)
        in_sh = (ns(p_spec), ns(b_spec))
        out_sh = (None, ns(c_spec))
        donate = ()
    else:  # decode
        c_spec = cache_specs(specs["cache"], mesh)
        b_spec = batch_spec(mesh, specs["batch"])
        in_sh = (ns(p_spec), ns(c_spec), ns(b_spec))
        out_sh = (None, ns(c_spec))
        donate = (1,)
    return in_sh, out_sh, donate


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: str,
             save_hlo: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = f"{arch}__{shape_name}__{mesh_name}"
    if not supports_shape(cfg, shape):
        return {"cell": cell, "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention"}

    t0 = time.time()
    if _SMALL:
        from repro.launch.mesh import make_mesh
        mesh = (make_mesh((2, 2, 4), ("pod", "data", "model")) if multi_pod
                else make_mesh((4, 4), ("data", "model")))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    settings = settings_for(cfg, shape)
    with sharding_ctx(mesh):
        specs = input_specs(cfg, shape, settings)
        in_sh, out_sh, donate = shardings_for(cfg, shape, mesh, specs, settings)
        if shape.mode == "train":
            step = make_train_step(cfg, settings)
            args = (specs["params"], specs["opt_state"], specs["batch"])
        elif shape.mode == "prefill":
            step = make_prefill_step(cfg, shape.seq_len)
            args = (specs["params"], specs["batch"])
        else:
            step = make_serve_step(cfg)
            args = (specs["params"], specs["cache"], specs["batch"])
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # 0.4.x: one dict per module
        cost = cost[0] if cost else {}
    n_dev = mesh.devices.size
    result = {
        "cell": cell,
        "status": "ok",
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "mode": shape.mode,
        "microbatches": settings.microbatches,
        "compile_s": round(time.time() - t0, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_bytes_per_device": (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes
                                      - mem.alias_size_in_bytes),
        },
        "cost_analysis": {k: v for k, v in (cost or {}).items()
                          if isinstance(v, (int, float)) and
                          k in ("flops", "bytes accessed", "transcendentals")},
    }
    os.makedirs(outdir, exist_ok=True)
    if save_hlo:
        hlo_path = os.path.join(outdir, f"{cell}.hlo.gz")
        with gzip.open(hlo_path, "wt") as f:
            f.write(compiled.as_text())
        result["hlo"] = hlo_path
    with open(os.path.join(outdir, f"{cell}.json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    r = run_cell(arch, shape, mp, args.out,
                                 save_hlo=not args.no_hlo)
                except Exception as e:  # a failing cell is a bug: surface it
                    r = {"cell": f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}",
                         "status": "FAILED", "error": f"{type(e).__name__}: {e}",
                         "trace": traceback.format_exc()[-2000:]}
                    with open(os.path.join(args.out, r["cell"] + ".json"), "w") as f:
                        json.dump(r, f, indent=1)
                results.append(r)
                status = r["status"]
                extra = ""
                if status == "ok":
                    gb = r["memory"]["peak_bytes_per_device"] / 2**30
                    extra = f"peak {gb:6.2f} GiB/dev  {r['compile_s']}s"
                elif status == "FAILED":
                    extra = r["error"][:120]
                print(f"[{status:7s}] {r['cell']:55s} {extra}", flush=True)

    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_fail = sum(r["status"] == "FAILED" for r in results)
    print(f"\n== dry-run: {n_ok} ok, {n_skip} skipped (documented), {n_fail} FAILED ==")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
