"""Batched serving driver: synthetic request stream through the engine.

    PYTHONPATH=src python -m repro.launch.serve --arch recurrentgemma-2b \
        --reduced --requests 12 --max-new 24
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config, get_reduced
from repro.models import transformer as tf
from repro.serving import Request, ServingEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="recurrentgemma-2b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = tf.init_params(cfg, key)
    engine = ServingEngine(cfg, params, max_batch=args.max_batch,
                           max_seq=args.max_seq, temperature=args.temperature)

    rng = np.random.default_rng(args.seed)
    total_prompt = 0
    for uid in range(args.requests):
        plen = int(rng.integers(4, args.max_seq // 4))
        total_prompt += plen
        engine.submit(Request(
            uid=uid,
            tokens=rng.integers(0, cfg.vocab_size, size=plen).astype(np.int32),
            max_new_tokens=args.max_new))

    t0 = time.time()
    done = engine.run_to_completion()
    wall = time.time() - t0
    gen_tokens = sum(len(c.tokens) for c in done)
    print(json.dumps({
        "arch": cfg.name,
        "requests": len(done),
        "engine_ticks": engine.steps,
        "prompt_tokens": total_prompt,
        "generated_tokens": gen_tokens,
        "wall_s": round(wall, 2),
        "decode_tok_per_s": round(gen_tokens / wall, 1),
    }, indent=1))
    assert len(done) == args.requests
    return done


if __name__ == "__main__":
    main()
