"""The paper's own LSTM benchmark networks (Table 5) + DeepBench dims (Table 4).

These drive the faithful reproduction: core/schedules.py executes them under
all four schedules, and core/perfmodel.py regenerates the paper's figures.
"""
from repro.configs.base import ModelConfig

# Table 5 of the paper.
EESEN = ModelConfig(
    name="sharp-eesen", family="rnn", n_layers=5, d_model=340, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=0, lstm_hidden=340, bidirectional=True,
    scan_layers=False,
)
GMAT = ModelConfig(
    name="sharp-gmat", family="rnn", n_layers=17, d_model=1024, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=0, lstm_hidden=1024, scan_layers=False,
)
BYSDNE = ModelConfig(
    name="sharp-bysdne", family="rnn", n_layers=5, d_model=340, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=0, lstm_hidden=340, scan_layers=False,
)
RLDRADSPR = ModelConfig(
    name="sharp-rldradspr", family="rnn", n_layers=10, d_model=1024, n_heads=1,
    n_kv_heads=1, d_ff=0, vocab_size=0, lstm_hidden=1024, scan_layers=False,
)

PAPER_NETWORKS = {
    "EESEN": (EESEN, 300),       # (model, representative time steps)
    "GMAT": (GMAT, 75),
    "BYSDNE": (BYSDNE, 30),
    "RLDRADSPR": (RLDRADSPR, 400),
}

# Table 4: DeepBench LSTM inference dims (hidden, time_steps).
DEEPBENCH = [(256, 150), (512, 25), (1024, 25), (1536, 50)]

# Fig. 9/10/11/12 sweep: hidden dims spanning the paper's application space
# (EESEN/BYSDNE are 340-dim; GMAT/RLDRADSPR 1024; DeepBench adds 1536 — a mix
# of padding-hostile and padding-friendly sizes, which is the point of Fig 10).
SWEEP_HIDDEN_DIMS = [100, 256, 340, 512, 1000, 1024, 1536, 2048]
MAC_BUDGETS = [1024, 4096, 16384, 65536]  # 1K, 4K, 16K, 64K
K_WIDTHS = [32, 64, 128, 256, 512]


def lstm_config(hidden: int, layers: int = 1) -> ModelConfig:
    return ModelConfig(
        name=f"sharp-lstm-{hidden}", family="rnn", n_layers=layers,
        n_heads=1, n_kv_heads=1, d_model=hidden, d_ff=0, vocab_size=0,
        lstm_hidden=hidden, scan_layers=False,
    )


def config() -> ModelConfig:
    """Default paper model for the quickstart (GMAT-like single layer)."""
    return lstm_config(1024, layers=1)


def eesen_demo(dtype: str = "float32") -> ModelConfig:
    """The paper's bidirectional EESEN stack (Table 5) in a demo-friendly
    dtype: what examples/quickstart.py compiles end-to-end through the
    dispatcher's interleaved bidirectional wavefront (`rnn.compile`).

    ASR-style BiLSTMs like this are the workloads SHARP's adaptiveness
    claim is evaluated on — the whole point of retiring the per-layer
    bidirectional fallback (ISSUE-5)."""
    import dataclasses

    return dataclasses.replace(EESEN, dtype=dtype)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="sharp-lstm-reduced", family="rnn", n_layers=2, n_heads=1,
        n_kv_heads=1, d_model=48, d_ff=0, vocab_size=0, lstm_hidden=48,
        scan_layers=False, dtype="float32",
    )
