"""Configuration dataclasses for models, shapes and meshes.

Every assigned architecture is expressed as a ``ModelConfig``; the four
assigned input shapes are ``ShapeConfig`` instances.  Configs are frozen
(hashable) so they can key autotune/dry-run artifact tables.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description.

    family:
      dense   -- decoder-only transformer (GQA, optional SWA)
      moe     -- decoder-only transformer with MoE FFN (optional dense residual)
      ssm     -- recurrent blocks only (xLSTM: sLSTM + mLSTM)
      hybrid  -- recurrent + local-attention mix (RecurrentGemma)
      audio   -- transformer backbone over precomputed codec-frame embeddings
      vlm     -- transformer backbone with M-RoPE over precomputed patch embeds
      rnn     -- the paper's own LSTM stacks (SHARP benchmarks)
    """

    name: str
    family: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_dense_ff: int = 0  # arctic-style parallel dense residual branch
    capacity_factor: float = 1.25

    # --- attention ---
    window: int = 0  # sliding-window size; 0 = full causal attention
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits

    # --- recurrent / hybrid ---
    # cycle of per-layer block kinds; () means all 'attn'
    block_pattern: Tuple[str, ...] = ()
    rglru_width: int = 0  # RG-LRU recurrence width (0 -> d_model)
    conv1d_width: int = 4  # temporal conv in recurrent blocks

    # --- paper RNN (LSTM) ---
    lstm_hidden: int = 0
    lstm_input: int = 0  # 0 -> lstm_hidden (paper assumes equal sizes)
    bidirectional: bool = False

    # --- behaviour ---
    scan_layers: bool = True
    remat_policy: str = "dots"  # none | dots | full
    remat_group: int = 1  # layers per remat unit (sqrt-L checkpointing);
    #                       >1 stores one residual per GROUP during training
    dtype: str = "bfloat16"
    embed_stub: bool = False  # audio/vlm: inputs are precomputed embeddings
    tie_embeddings: bool = False
    norm_eps: float = 1e-6

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.family == "rnn" and self.lstm_input == 0:
            object.__setattr__(self, "lstm_input", self.lstm_hidden)
        if self.family in ("ssm", "hybrid") and self.rglru_width == 0:
            object.__setattr__(self, "rglru_width", self.d_model)

    # -- layer pattern -------------------------------------------------
    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kinds, cycling ``block_pattern``."""
        if not self.block_pattern:
            return ("attn",) * self.n_layers
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    # -- parameter counting (analytical; used for 6ND roofline) ---------
    def num_params(self, include_embed: bool = True) -> int:
        if self.family == "rnn":
            h, x = self.lstm_hidden, self.lstm_input
            per_dir = 4 * h * (x + h) + 8 * h
            per_layer = per_dir * (2 if self.bidirectional else 1)
            return per_layer * self.n_layers

        d = self.d_model
        total = 0
        for kind in self.layer_kinds():
            if kind == "attn":
                total += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                total += self._ffn_params()
            elif kind == "rglru":
                w = self.rglru_width
                # in/out projections + gates (a, input gate) + conv
                total += d * w * 2 + w * d + 3 * w + self.conv1d_width * w
                total += self._ffn_params()
            elif kind == "mlstm":
                # up-proj x2 (gate+value), qkv projections at 2d, down-proj
                dh = 2 * d
                total += d * dh * 2 + 3 * dh * dh // 4 + dh * d
            elif kind == "slstm":
                dh = d
                total += 4 * dh * (d + dh) + 8 * dh + d * d
            total += 2 * d  # norms
        if include_embed:
            total += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return total

    def _ffn_params(self) -> int:
        d = self.d_model
        if self.n_experts:
            expert = 3 * d * self.d_ff  # gated MLP
            dense = 3 * d * self.moe_dense_ff if self.moe_dense_ff else 0
            router = d * self.n_experts
            return expert * self.n_experts + dense + router
        if self.d_ff == 0:
            return 0
        return 3 * d * self.d_ff  # gated (SwiGLU-style) MLP

    def num_active_params(self, include_embed: bool = False) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if not self.n_experts:
            return self.num_params(include_embed=include_embed)
        full = self.num_params(include_embed=include_embed)
        expert_all = 3 * self.d_model * self.d_ff * self.n_experts
        expert_active = 3 * self.d_model * self.d_ff * self.experts_per_token
        n_moe_layers = sum(1 for k in self.layer_kinds() if k == "attn")
        # every attn layer carries the MoE FFN in our assemblies
        return full - (expert_all - expert_active) * n_moe_layers

    def model_flops_per_token(self) -> int:
        """Standard 6*N_active*D-style estimate (per token, fwd+bwd=6N, fwd=2N)."""
        return 2 * self.num_active_params(include_embed=False)


# ---------------------------------------------------------------------------
# Input shapes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def supports_shape(model: ModelConfig, shape: ShapeConfig) -> bool:
    """Applicability per assignment: long_500k needs sub-quadratic attention."""
    if shape.name != "long_500k":
        return True
    if model.family in ("ssm",):
        return True
    kinds = set(model.layer_kinds())
    if "attn" in kinds and model.window == 0:
        return False  # pure full attention at 512k context: skip (documented)
    return True


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e-class, per instructions)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class HardwareConfig:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12  # per chip
    hbm_bw: float = 819e9  # bytes/s per chip
    ici_bw: float = 50e9  # bytes/s per link
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20


V5E = HardwareConfig()
