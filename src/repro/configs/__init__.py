"""Architecture registry: ``--arch <id>`` resolution.

Ten assigned architectures + the paper's own LSTM family.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (  # noqa: F401 (re-export)
    HardwareConfig,
    ModelConfig,
    ShapeConfig,
    SHAPES,
    V5E,
    supports_shape,
)

_ARCH_MODULES: Dict[str, str] = {
    "arctic-480b": "repro.configs.arctic_480b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "starcoder2-3b": "repro.configs.starcoder2_3b",
    "deepseek-67b": "repro.configs.deepseek_67b",
    "h2o-danube-3-4b": "repro.configs.h2o_danube3_4b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "musicgen-large": "repro.configs.musicgen_large",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "sharp-lstm": "repro.configs.sharp_lstm",
}


def list_archs(include_paper: bool = False) -> List[str]:
    names = [n for n in _ARCH_MODULES if n != "sharp-lstm"]
    if include_paper:
        names.append("sharp-lstm")
    return names


def get_config(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).config()


def get_reduced(name: str) -> ModelConfig:
    if name not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[name]).reduced()
