"""musicgen-large [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf].  Backbone only: the EnCodec frontend is a stub —
``input_specs`` provides precomputed frame embeddings (B, S, d_model).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large",
        family="audio",
        n_layers=48,
        d_model=2048,
        n_heads=32,
        n_kv_heads=32,
        d_ff=8192,
        vocab_size=2048,
        embed_stub=True,
        scan_layers=True,
        remat_policy="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="musicgen-large-reduced",
        family="audio",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=128,
        vocab_size=128,
        embed_stub=True,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
