"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, pattern 1:2.

[arXiv:2402.19427; hf].  Two recurrent (RG-LRU) blocks followed by one
local-attention block (window 2048), cycling over 26 layers.  The RG-LRU
recurrence is the second first-class target of the Unfolded schedule.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b",
        family="hybrid",
        n_layers=26,
        d_model=2560,
        n_heads=10,
        n_kv_heads=1,
        d_ff=7680,
        vocab_size=256000,
        window=2048,
        block_pattern=("rglru", "rglru", "attn"),
        rglru_width=2560,
        scan_layers=False,  # heterogeneous pattern; unrolled
        remat_policy="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-2b-reduced",
        family="hybrid",
        n_layers=3,
        d_model=64,
        n_heads=2,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        window=16,
        block_pattern=("rglru", "rglru", "attn"),
        rglru_width=64,
        scan_layers=False,
        remat_policy="none",
        dtype="float32",
    )
