"""xlstm-125m [ssm] — alternating sLSTM + mLSTM blocks.

[arXiv:2405.04517; unverified].  d_ff=0: xLSTM blocks carry their own
up/down projections instead of a residual MLP.  This family is the
first-class target of the paper's Unfolded schedule (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m",
        family="ssm",
        n_layers=12,
        d_model=768,
        n_heads=4,
        n_kv_heads=4,
        d_ff=0,
        vocab_size=50304,
        block_pattern=("mlstm", "slstm"),
        scan_layers=False,  # heterogeneous blocks; 12 layers unrolled is cheap
        remat_policy="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="xlstm-125m-reduced",
        family="ssm",
        n_layers=2,
        d_model=64,
        n_heads=2,
        n_kv_heads=2,
        d_ff=0,
        vocab_size=256,
        block_pattern=("mlstm", "slstm"),
        scan_layers=False,
        remat_policy="none",
        dtype="float32",
    )
