"""h2o-danube-3-4b [dense] — llama+mistral mix, SWA.

[arXiv:2401.16818; unverified] — window size not pinned by the source;
we assume a mistral-style 4096 sliding window (recorded in DESIGN.md).
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b",
        family="dense",
        n_layers=24,
        d_model=3840,
        n_heads=32,
        n_kv_heads=8,
        d_ff=10240,
        vocab_size=32000,
        window=4096,
        scan_layers=True,
        remat_policy="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        window=16,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
