"""qwen2-vl-72b [vlm] — M-RoPE, dynamic resolution.

[arXiv:2409.12191; hf].  Backbone only: the vision tower is a stub —
``input_specs`` provides precomputed patch embeddings.  M-RoPE splits the
head_dim rotary bands into (temporal, height, width) sections.
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        n_layers=80,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=29568,
        vocab_size=152064,
        mrope_sections=(16, 24, 24),  # sums to head_dim//2 = 64
        embed_stub=True,
        scan_layers=True,
        remat_policy="full",
        remat_group=4,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-reduced",
        family="vlm",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mrope_sections=(2, 3, 3),  # head_dim 16 -> half=8
        embed_stub=True,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
