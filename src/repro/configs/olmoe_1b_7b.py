"""olmoe-1b-7b [moe] — 64 experts top-8. [arXiv:2409.02060; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        n_layers=16,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        n_experts=64,
        experts_per_token=8,
        capacity_factor=1.25,
        scan_layers=True,
        remat_policy="full",  # MoE dispatch buffers are too large to save
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=4,
        d_ff=32,
        vocab_size=256,
        n_experts=8,
        experts_per_token=4,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
