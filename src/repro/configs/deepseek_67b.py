"""deepseek-67b [dense] — llama-arch, GQA kv=8. [arXiv:2401.02954; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b",
        family="dense",
        n_layers=95,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        d_ff=22016,
        vocab_size=102400,
        scan_layers=True,
        remat_policy="full",
        remat_group=5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="deepseek-67b-reduced",
        family="dense",
        n_layers=3,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=160,
        vocab_size=256,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
