"""starcoder2-3b [dense] — GQA kv=2, RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b",
        family="dense",
        n_layers=30,
        d_model=3072,
        n_heads=24,
        n_kv_heads=2,
        d_ff=12288,
        vocab_size=49152,
        scan_layers=True,
        remat_policy="full",
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-3b-reduced",
        family="dense",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
