"""arctic-480b [moe] — 128 experts top-2 + dense residual.

[hf:Snowflake/snowflake-arctic-base; hf]
"""
from repro.configs.base import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        d_ff=4864,
        vocab_size=32000,
        n_experts=128,
        experts_per_token=2,
        moe_dense_ff=4864,
        capacity_factor=1.25,
        scan_layers=True,
        remat_policy="full",
        remat_group=5,
    )


def reduced() -> ModelConfig:
    return ModelConfig(
        name="arctic-480b-reduced",
        family="moe",
        n_layers=2,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=96,
        vocab_size=256,
        n_experts=8,
        experts_per_token=2,
        moe_dense_ff=96,
        scan_layers=True,
        remat_policy="none",
        dtype="float32",
    )
