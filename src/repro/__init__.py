"""repro: a growing jax_pallas reproduction of SHARP (arXiv:1911.01258).

The one obvious import for users is the unified recurrent front-end:

    from repro import rnn
    compiled = rnn.compile(stack_or_config, rnn.ExecutionPolicy(...))

Submodules load lazily (``repro.kernels``, ``repro.dispatch``, ...) so
``import repro`` stays cheap — nothing below pulls jax until touched.
"""
from importlib import import_module

_SUBMODULES = ("calib", "checkpoint", "configs", "core", "data", "dispatch",
               "kernels", "launch", "models", "optim", "rnn", "runtime",
               "serving", "sharding")

__all__ = list(_SUBMODULES)


def __getattr__(name):
    if name in _SUBMODULES:
        mod = import_module(f"repro.{name}")
        globals()[name] = mod  # cache: next access skips __getattr__
        return mod
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(list(globals()) + list(_SUBMODULES)))
