"""Parameter / activation / cache partitioning rules (DP + FSDP + TP + EP).

Rules are path+shape based and divisibility-checked against the actual mesh,
so a single rule set serves every assigned architecture on any mesh shape
(the 1000-node posture: bigger meshes only change the shape tuple).

Scheme (logical -> physical):
  batch         ('pod', 'data')     data parallel across pods and hosts
  fsdp          ('pod', 'data')     param/optimizer-state sharding (ZeRO-3
                                    style: gathered per-layer at use)
  tensor        'model'             TP: heads / ffn / experts / vocab / gate-4H

Per-tensor policy (matching dims checked for divisibility, else replicated):
  embedding table (V, d)        -> (model, fsdp)
  unembed (d, V)                -> (fsdp, model)
  in-projections  (.., d, out)  -> (.., fsdp, model)   w_q, w_kv, w_gate, w_up,
                                                        W, w_in, w_a, w_x, w_up_*
  out-projections (.., in, d)   -> (.., model, fsdp)   w_o, w_down, w_out
  MoE experts (E, d, f) / (E, f, d) -> (model=EP, fsdp, -) / (model, -, fsdp)
  router (d, E)                 -> (-, model)
  everything 1-D (norms, biases, Lambda) -> replicated
Scan-stacked params carry a leading L dim, always unsharded.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

OUT_PROJ_NAMES = {"w_o", "w_down", "w_out"}
IN_PROJ_NAMES = {"w_q", "w_kv", "w_gate", "w_up", "W", "w_in", "w_a", "w_x",
                 "w_up_v", "w_up_g", "w_q2", "w_k", "w_v", "U", "R"}


def _axes_in(mesh: Mesh, axes) -> Optional[Tuple[str, ...]]:
    present = set(mesh.axis_names)
    if isinstance(axes, str):
        axes = (axes,)
    axes = tuple(a for a in axes if a in present)
    return axes or None


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in (axes,) if isinstance(axes, str) else axes:
        n *= sizes[a]
    return n


def _fit(mesh: Mesh, dim: int, axes):
    """axes if present-in-mesh and dim divides evenly, else None."""
    axes = _axes_in(mesh, axes) if axes is not None else None
    if axes is None:
        return None
    if dim % _size(mesh, axes) != 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def _param_spec(path_names, leaf, mesh: Mesh, fsdp_axes) -> P:
    name = path_names[-1] if path_names else ""
    shape = leaf.shape
    nd = len(shape)
    in_moe = "moe" in path_names
    spec: list = [None] * nd
    if nd <= 1:
        return P(*spec)
    # small tensors replicate: sharding them buys no memory and costs
    # per-use collectives.  Exception: the sLSTM recurrent matrix R — its
    # per-step dR accumulation must stay sharded with the gate axis or the
    # backward pass all-reduces it every timestep (§Perf, xlstm iter 2).
    size = 1
    for s in shape:
        size *= s
    if size < 2**22 and name != "R":
        return P(*spec)
    if name == "R":  # (H, dh, 4dh): gate axis over 'model'
        spec[-1] = _fit(mesh, shape[-1], "model")
        return P(*spec)

    # which trailing dims are the "real" matrix (strip scan-L / expert dims)
    if name in ("router",):
        spec[-1] = _fit(mesh, shape[-1], "model")
        return P(*spec)

    if in_moe and name in ("w_gate", "w_up", "w_down") and nd >= 3:
        # (..., E, d, f) or (..., E, f, d): EP on E; FSDP on the ff dim.
        # NOT on d: d is the dispatch-buffer contraction dim, and sharding
        # it forces a weight regather (or an (E,C,ff) partial-sum
        # all-reduce) inside every microbatch iteration — measured 4x
        # collective blowup on arctic (EXPERIMENTS.md §Perf, refuted).
        e_dim = nd - 3
        spec[e_dim] = _fit(mesh, shape[e_dim], "model")
        if name == "w_down":  # (E, f, d): fsdp on f... also contraction;
            # use d (output dim): output (E,C,d@fsdp) reshards once/layer
            spec[-1] = _fit(mesh, shape[-1], fsdp_axes)
        else:  # (E, d, f): fsdp on f (non-contracting)
            spec[-1] = _fit(mesh, shape[-1], fsdp_axes)
        return P(*spec)

    if name == "table":  # (V, d)
        spec[-2] = _fit(mesh, shape[-2], "model")
        spec[-1] = _fit(mesh, shape[-1], fsdp_axes)
        return P(*spec)
    if name == "unembed":  # (d, V)
        spec[-2] = _fit(mesh, shape[-2], fsdp_axes)
        spec[-1] = _fit(mesh, shape[-1], "model")
        return P(*spec)

    if name in OUT_PROJ_NAMES:
        spec[-2] = _fit(mesh, shape[-2], "model")
        spec[-1] = _fit(mesh, shape[-1], fsdp_axes)
        return P(*spec)

    # default / in-projection: (.., d_in, d_out) -> (fsdp, model)
    spec[-2] = _fit(mesh, shape[-2], fsdp_axes)
    spec[-1] = _fit(mesh, shape[-1], "model")
    # avoid double-booking an axis if both dims resolved to overlapping axes
    if spec[-2] is not None and spec[-1] is not None:
        a = {spec[-2]} if isinstance(spec[-2], str) else set(spec[-2])
        b = {spec[-1]} if isinstance(spec[-1], str) else set(spec[-1])
        if a & b:
            spec[-2] = None
    return P(*spec)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params_shape, mesh: Mesh, multi_pod_fsdp: bool = True,
                fsdp: bool = True):
    """Pytree of PartitionSpec matching ``params_shape`` (shapes or arrays).

    ``fsdp=False``: weight-stationary (TP-only) layout — no per-use gathers;
    the serving/decode configuration (see DESIGN.md §5)."""
    if not fsdp:
        fsdp_axes = ()
    else:
        fsdp_axes = ("pod", "data") if multi_pod_fsdp else ("data",)

    def one(path, leaf):
        return _param_spec(_path_names(path), leaf, mesh, fsdp_axes)

    return jax.tree_util.tree_map_with_path(one, params_shape)


def param_shardings(params_shape, mesh: Mesh, **kw):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params_shape, mesh, **kw))


# ---------------------------------------------------------------------------
# batch / cache
# ---------------------------------------------------------------------------


def batch_spec(mesh: Mesh, batch_shape_tree):
    """tokens/embeds/labels: batch dim over (pod, data) when divisible."""

    def one(leaf):
        dp = _fit(mesh, leaf.shape[0], ("pod", "data"))
        spec = [dp] + [None] * (len(leaf.shape) - 1)
        if len(leaf.shape) >= 3:  # embeds (B, S, d)
            spec[-1] = _fit(mesh, leaf.shape[-1], "model")
        return P(*spec)

    return jax.tree.map(one, batch_shape_tree)


def cache_specs(cache_shape, mesh: Mesh):
    """KV caches (L?, B, T, KV): batch over dp, flattened kv over model;
    recurrent states (B, W): width over model."""

    def one(path, leaf):
        names = _path_names(path)
        nd = len(leaf.shape)
        name = names[-1]
        if name == "idx":
            return P(_fit(mesh, leaf.shape[0], ("pod", "data")))
        # stacked (scan) caches carry a leading L dim; list caches have a
        # numeric layer index in their path instead
        has_idx = any(n.isdigit() for n in names)
        scan_l = 0 if has_idx else (1 if nd >= 3 else 0)
        spec = [None] * nd
        b_dim = scan_l
        if b_dim < nd:
            spec[b_dim] = _fit(mesh, leaf.shape[b_dim], ("pod", "data"))
        if name in ("k", "v") and nd >= b_dim + 3:
            # sequence-parallel KV cache: shard the T dim over 'model' so
            # decode attention reduces softmax stats (KBs) across shards
            # instead of all-gathering cache rows (MBs) — see EXPERIMENTS.md
            # §Perf (recurrentgemma decode hillclimb, iteration 2)
            spec[b_dim + 1] = _fit(mesh, leaf.shape[b_dim + 1], "model")
        elif name in ("state", "h", "c", "n", "m") and nd == b_dim + 2:
            spec[-1] = _fit(mesh, leaf.shape[-1], "model")
        elif name == "conv" and nd == b_dim + 3:
            spec[-1] = _fit(mesh, leaf.shape[-1], "model")
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, cache_shape)
