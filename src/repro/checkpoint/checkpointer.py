"""Sharded, async, elastic checkpointing (numpy backend).

Layout per step:
    <dir>/step_<N>/manifest.json       pytree structure + dtypes + step
    <dir>/step_<N>/arr_<i>.npy         one file per leaf
    <dir>/step_<N>/.complete           commit marker (atomic rename)

Design points for the 1000-node posture:
  * async: ``save`` snapshots leaves to host RAM and writes on a worker
    thread; training continues immediately (double-buffered — a new save
    waits for the previous one).
  * atomic: readers only trust directories with the commit marker, so a
    worker dying mid-write can never corrupt restore.
  * elastic: ``restore`` takes the *current* mesh/shardings and device_puts
    each leaf accordingly — the restoring job may have a different topology
    than the saving job.
  * GC: keep the newest ``keep`` checkpoints.

On a real multi-host pod each host writes only its addressable shards; the
single-process CPU container degenerates to full arrays, but the layout and
commit protocol are the deployment ones.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None

    # -- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False):
        self.wait()  # double-buffer: at most one in-flight save
        leaves, treedef = jax.tree.flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # snapshot now
        paths = jax.tree_util.tree_flatten_with_path(tree)[0]
        names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in p)
                 for p, _ in paths]

        def _write():
            tmp = os.path.join(self.directory, f".tmp_step_{step}")
            final = os.path.join(self.directory, f"step_{step}")
            shutil.rmtree(tmp, ignore_errors=True)
            os.makedirs(tmp, exist_ok=True)
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            manifest = {
                "step": step,
                "names": names,
                "num_leaves": len(host_leaves),
                "treedef": str(treedef),
                "time": time.time(),
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, ".complete"), "w") as f:
                f.write("ok")
            shutil.rmtree(final, ignore_errors=True)
            os.rename(tmp, final)
            self._gc()

        if blocking:
            _write()
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- restore ---------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and os.path.exists(
                    os.path.join(self.directory, name, ".complete")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; device_put per
        ``shardings`` (elastic: any mesh works)."""
        d = os.path.join(self.directory, f"step_{step}")
        if not os.path.exists(os.path.join(d, ".complete")):
            raise FileNotFoundError(f"no complete checkpoint at {d}")
        leaves, treedef = jax.tree.flatten(like)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        assert manifest["num_leaves"] == len(leaves), "structure mismatch"
        arrs = [np.load(os.path.join(d, f"arr_{i}.npy"))
                for i in range(len(leaves))]
        # ml_dtypes (bfloat16, ...) round-trip through .npy as raw void
        # records; view them back before casting
        arrs = [a.view(np.dtype(l.dtype)) if a.dtype.kind == "V" else a
                for a, l in zip(arrs, leaves)]
        arrs = [a.astype(l.dtype) for a, l in zip(arrs, leaves)]
        if shardings is not None:
            shard_leaves = jax.tree.leaves(
                shardings, is_leaf=lambda x: x is None)
            out = [jax.device_put(a, s) if s is not None else jax.device_put(a)
                   for a, s in zip(arrs, shard_leaves)]
        else:
            out = [jax.device_put(a) for a in arrs]
        return jax.tree.unflatten(treedef, out)

    # -- gc ----------------------------------------------------------------
    def _gc(self):
        steps = sorted(s for s in (
            int(n.split("_")[1]) for n in os.listdir(self.directory)
            if n.startswith("step_")))
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s}"),
                          ignore_errors=True)
