"""Static verification of ``DispatchPlan``s: prove dispatch invariants
before launch, with no execution.

SHARP's core claim is that a tiled dispatch mechanism handles RNN data
dependencies safely across arbitrary model shapes.  The planner constructs
plans it *believes* satisfy that claim, and the property tests *sample* it
— this module closes the loop by checking every emitted plan against the
formal rules, turning "the wavefront readiness rule holds" from a tested
hope into a machine-checked theorem per plan (the compile-time dataflow
check MASR-style accelerators bake into their schedulers — PAPERS.md).

``check_plan(plan)`` proves, per plan:

Coverage (``coverage-missing`` / ``coverage-duplicate`` /
``coverage-unknown``)
    Every packed item's ``(uid, layer, chunk, direction)`` cell is
    scheduled exactly once; no slot carries a cell of an unknown item, an
    external-fallback item, or an out-of-range layer/chunk/direction.

Chunk tiling (``chunk-tiling``)
    Each covered walk's chunk boundaries tile ``[0, T)`` with no gap or
    overlap: the item's ``nk`` chunks are exactly ``_chunk_lens(T,
    block_t)`` and every slot launches its cells at the chunk's true
    length (remainders included) — together with coverage this is the
    executor's layer-0 slicing contract.

Dependency safety (``readiness-chunk`` / ``readiness-layer`` /
``wave-monotone``)
    The race/hazard check over the wavefront timeline.  Each cell's wave
    index is *strictly* after all its producers': the previous chunk of
    the same (layer, direction) walk (for "bwd" cells, walking descending
    time, that is chunk ``k+1``); and layer ``l-1``'s chunk ``k`` — BOTH
    directions of it for bidirectional items (the fwd‖bwd concat
    barrier).  Strictness also rules out producer/consumer sharing one
    launch.  ``wave-monotone`` ties the executor's slot-tuple order to
    the wave timeline (non-decreasing wave along ``plan.slots``); the two
    rules together prove execution-order safety: producer wave < consumer
    wave and waves non-decreasing in tuple order imply the producer's
    launch really happens first.

Chained decode order (``decode-chain``)
    A chained slot's groups ARE the serial layer chain: group ``g`` holds
    exactly layer ``g``'s cells, chunk 0, direction "fwd", with one cell
    per item in the identical row order at every layer (the in-kernel
    VMEM chain scatters by fixed row offsets).

Packing legality (``pack-row-mix`` / ``pack-width`` / ``pack-signature``)
    No cross-B row mixes directions, layers, dtypes, or non-``share``
    items (a concatenated row binds ONE recurrent matrix U — the
    ``WorkItem.share`` contract); ``group_b`` widths are the exact sums
    of member batch rows, none exceeding the slot's padded ``B``, whose
    value is the widest row; every cell's own layer family / H / dtype
    matches the slot signature it shares.

Tiling provenance (``stripe-align``)
    The slot's ``tile_k`` / ``mvm_block`` are what the autotune table
    prescribes for (family, H) at the plan's MAC budget — a slot cannot
    smuggle in a launch shape the offline exploration never validated.

Resource budget (``vmem-budget``)
    The per-slot VMEM footprint from tile shapes × dtype — the sequence
    kernels' working set for packed slots, the per-layer resident set for
    chained decode slots — fits a configurable budget (default: the
    autotune table's own ``SEQ_VMEM_BUDGET``).  Precision-aware: an int8
    slot is budgeted at its 1-byte resident payload plus per-gate scales
    (bf16 at 2 bytes), and a block-sparse slot at its densest member
    layer's occupied row-tiles plus the gather index.

Any violation raises a structured ``runtime.errors.PlanInvariantError``
naming the rule, slot, and cell; a clean pass returns a
``PlanCheckReport``.  Wired in as ``ExecutionPolicy(verify="plan")`` (the
default): the rnn facade verifies each plan ONCE at build time, under an
obs ``verify`` span so the overhead is measured (it amortizes to zero
across plan-cache hits; ``BENCH_dispatch.json`` prices it).
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import numpy as np

from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint
from repro.dispatch.planner import (DispatchPlan, ItemPlan, Slot,
                                    _chunk_lens, _slot_config,
                                    validate_unique_uids)
from repro.dispatch.workitem import GATES
from repro.runtime.errors import PlanInvariantError

#: every invariant rule ``check_plan`` proves, in check order
RULES = (
    "vmem-budget",        # per-slot VMEM footprint under the budget
    "stripe-align",       # tile_k / mvm_block match the autotune table
    "pack-width",         # group_b arithmetic: sums, bounds, slot B
    "pack-row-mix",       # no row mixes direction/layer/dtype/non-share
    "pack-signature",     # cell family/H/dtype match the slot signature
    "chunk-tiling",       # chunks tile [0, T) exactly, true lengths
    "coverage-unknown",   # no cell outside the plan's covered spec
    "coverage-missing",   # every expected cell scheduled
    "coverage-duplicate", # ... exactly once
    "decode-chain",       # chained slots walk layers in-order, fixed rows
    "readiness-chunk",    # wave strictly after previous chunk same walk
    "readiness-layer",    # wave strictly after layer l-1 (concat barrier)
    "wave-monotone",      # slot tuple order consistent with wave order
)


@dataclass(frozen=True)
class PlanCheckReport:
    """A clean verification outcome (violations raise instead)."""

    items: int            # covered (packed-timeline) items
    slots: int            # slots walked (packed + chained)
    cells: int            # cells proven covered + hazard-free
    chained: int          # chained decode slots among them

    @property
    def rules(self) -> Tuple[str, ...]:
        return RULES

    def describe(self) -> str:
        tag = f", {self.chained} chained" if self.chained else ""
        return (f"plancheck: OK — {self.items} items, {self.slots} slots, "
                f"{self.cells} cells{tag}; {len(RULES)} rules proven")


def _fail(rule: str, msg: str, *, slot: Optional[Slot] = None,
          cell=None, uids=()) -> PlanInvariantError:
    where = f" (slot {slot.index}" + (f", cell {cell}" if cell else "") + ")" \
        if slot is not None else (f" (cell {cell})" if cell else "")
    return PlanInvariantError(
        f"plan invariant {rule!r} violated{where}: {msg}", rule=rule,
        slot=None if slot is None else slot.index, cell=cell, uids=uids)


def _covered_items(plan: DispatchPlan) -> Dict[int, ItemPlan]:
    """The items whose cells the slot timeline must cover: everything the
    planner did not route external (reference schedules, per_step, rglru,
    T=0 all land in ``plan.external`` and execute off-timeline)."""
    return {ip.uid: ip for ip in plan.items if ip.uid not in plan.external}


def _item_spec(ip: ItemPlan):
    """(expected cell set, chunk length per k, directions) of one covered
    item — the ground truth its scheduled cells are checked against."""
    it = ip.item
    if ip.schedule == "decode":
        lens = [1]
        dirs = ("fwd",)
    else:
        lens = _chunk_lens(it.T, ip.block_t)
        dirs = ("fwd", "bwd") if it.bidirectional else ("fwd",)
    if len(lens) != ip.nk or sum(lens) != it.T or (lens and min(lens) < 1):
        raise _fail(
            "chunk-tiling",
            f"item {it.uid}: nk={ip.nk} chunks at block_t={ip.block_t} "
            f"cannot tile T={it.T} (lens {lens})", uids=(it.uid,))
    expected = {(it.uid, l, k, d)
                for l in range(it.L) for k in range(len(lens)) for d in dirs}
    return expected, lens, dirs


def _decode_footprint(slot: Slot) -> int:
    """Per-layer resident VMEM of a chained decode launch: the layer's W
    and U tiles, its bias, the chained xw row, and the (h, c) state rows
    (fp32).  The decode kernel grid streams layers, so the budget is
    per-layer, not the whole (L, ...) stack."""
    gates = GATES[slot.family]
    itemsize = np.dtype(slot.dtype).itemsize
    weights = 2 * slot.H * gates * slot.H * itemsize + gates * slot.H * itemsize
    rows = slot.B * gates * slot.H * itemsize + 4 * slot.B * slot.H * 4
    return weights + rows


def _check_slot_budget(slot: Slot, budget: int,
                       covered: Dict[int, ItemPlan]) -> None:
    """The footprint is precision-aware (an int8 slot's resident U is the
    1-byte payload + per-gate scales) and sparsity-aware: a row-compacted
    launch keeps only the densest member layer's occupied row-tiles
    resident (slot-uniform Ha), so that density bounds the true set.
    Unknown uids fall back dense — ``coverage-unknown`` fires right after.
    """
    if slot.chained:
        used = _decode_footprint(slot)
    else:
        dens = max((covered[grp[0].uid].item.layer_density(grp[0].layer)
                    for grp in slot.groups
                    if grp and grp[0].uid in covered), default=1.0)
        used = seq_block_footprint(slot.chunk_len, slot.B, slot.H,
                                   gates=GATES[slot.family],
                                   precision=slot.precision,
                                   density=dens)
    if used > budget:
        raise _fail("vmem-budget",
                    f"footprint {used}B exceeds budget {budget}B "
                    f"({slot.family} H{slot.H} B{slot.B} "
                    f"bt{slot.chunk_len} {slot.dtype} "
                    f"p{slot.precision})", slot=slot)


def _check_slot_tiling(slot: Slot, macs: int) -> None:
    tile_k, mvm_block = _slot_config(slot.family, slot.H, macs)
    if slot.tile_k != tile_k or tuple(slot.mvm_block) != tuple(mvm_block):
        raise _fail(
            "stripe-align",
            f"tile config K{slot.tile_k} blk{tuple(slot.mvm_block)} is not "
            f"the autotune table's K{tile_k} blk{tuple(mvm_block)} for "
            f"{slot.family} H{slot.H} at macs={macs}", slot=slot)


def _check_slot_rows(slot: Slot, covered: Dict[int, ItemPlan]) -> None:
    """Packing legality: group_b arithmetic + cross-B row homogeneity +
    per-cell signature match (also rejects cells of unknown/external
    items before any width arithmetic trusts their B)."""
    if len(slot.groups) != len(slot.group_b):
        raise _fail("pack-width",
                    f"{len(slot.groups)} rows but {len(slot.group_b)} "
                    "group_b widths", slot=slot)
    if not slot.groups or any(not grp for grp in slot.groups):
        raise _fail("pack-width", "empty launch row", slot=slot)
    for grp, b in zip(slot.groups, slot.group_b):
        for cell in grp:
            ip = covered.get(cell.uid)
            if ip is None:
                raise _fail(
                    "coverage-unknown",
                    f"cell of item {cell.uid} which is not on the packed "
                    "timeline (unknown or external-fallback uid)",
                    slot=slot, cell=cell, uids=(cell.uid,))
            it = ip.item
            if not (0 <= cell.layer < it.L) or cell.direction not in (
                    ("fwd", "bwd") if it.bidirectional else ("fwd",)):
                raise _fail(
                    "coverage-unknown",
                    f"layer {cell.layer} / direction {cell.direction!r} "
                    f"outside item {cell.uid}'s walk (L={it.L})",
                    slot=slot, cell=cell, uids=(cell.uid,))
        if len(grp) > 1 and not slot.chained:
            # row homogeneity first: a merged row of mismatched cells is
            # a packing error even when one of them matches the slot
            shares = {covered[c.uid].item.share for c in grp}
            if (len(shares) != 1 or None in shares
                    or len({c.layer for c in grp}) != 1
                    or len({c.direction for c in grp}) != 1
                    or len({covered[c.uid].item.dtype for c in grp}) != 1):
                raise _fail(
                    "pack-row-mix",
                    "cross-B row mixes directions, layers, dtypes, or "
                    f"non-share items: {grp}", slot=slot,
                    uids=sorted({c.uid for c in grp}))
        for cell in grp:
            it = covered[cell.uid].item
            if (it.families[cell.layer] != slot.family or it.H != slot.H
                    or it.dtype != slot.dtype):
                raise _fail(
                    "pack-signature",
                    f"cell binds {it.families[cell.layer]} H{it.H} "
                    f"{it.dtype}, slot signature is {slot.family} "
                    f"H{slot.H} {slot.dtype}",
                    slot=slot, cell=cell, uids=(cell.uid,))
        width = sum(covered[c.uid].item.B for c in grp)
        if width != b or b > slot.B:
            raise _fail(
                "pack-width",
                f"row of {len(grp)} cell(s) holds {width} batch rows but "
                f"group_b says {b} (slot B={slot.B})", slot=slot,
                uids=sorted({c.uid for c in grp}))
    if slot.B != max(slot.group_b):
        raise _fail("pack-width",
                    f"slot B={slot.B} is not the widest row "
                    f"({max(slot.group_b)})", slot=slot)


def _check_chained(slot: Slot, covered: Dict[int, ItemPlan]) -> None:
    """A chained slot's groups are the serial layer walk of one decode
    tick: group g == layer g, chunk 0, "fwd", one cell per item in the
    same row order at every layer."""
    rows0 = tuple(c.uid for c in slot.groups[0])
    for g, grp in enumerate(slot.groups):
        bad = [c for c in grp
               if c.layer != g or c.chunk != 0 or c.direction != "fwd"]
        if bad:
            raise _fail(
                "decode-chain",
                f"group {g} must hold exactly layer {g}'s chunk-0 fwd "
                f"cells, got {bad[0]}", slot=slot, cell=bad[0],
                uids=(bad[0].uid,))
        if tuple(c.uid for c in grp) != rows0:
            raise _fail(
                "decode-chain",
                f"group {g} row order {[c.uid for c in grp]} differs from "
                f"layer 0's {list(rows0)} — the in-kernel chain scatters "
                "by fixed row offsets", slot=slot,
                uids=sorted(set(rows0)))
    for ip in (covered[u] for u in rows0):
        if ip.schedule != "decode":
            raise _fail(
                "decode-chain",
                f"item {ip.uid} (schedule {ip.schedule!r}) inside a "
                "chained slot; only decode items chain", slot=slot,
                uids=(ip.uid,))


def _check_readiness(cell_wave: Dict[tuple, int],
                     covered: Dict[int, ItemPlan],
                     specs: Dict[int, tuple]) -> None:
    """The wavefront hazard detector: every producer strictly earlier."""
    for (uid, l, k, d), w in cell_wave.items():
        nk = len(specs[uid][1])
        it = covered[uid].item
        prev = (uid, l, k - 1, d) if d == "fwd" else (uid, l, k + 1, d)
        if (d == "fwd" and k > 0) or (d == "bwd" and k < nk - 1):
            if cell_wave[prev] >= w:
                raise _fail(
                    "readiness-chunk",
                    f"cell {(uid, l, k, d)} at wave {w} but its walk's "
                    f"previous chunk {prev} is at wave {cell_wave[prev]} "
                    "(must be strictly earlier)", cell=(uid, l, k, d),
                    uids=(uid,))
        if l > 0:
            for dep_d in specs[uid][2]:
                dep = (uid, l - 1, k, dep_d)
                if cell_wave[dep] >= w:
                    barrier = (" — the fwd‖bwd concat barrier"
                               if it.bidirectional else "")
                    raise _fail(
                        "readiness-layer",
                        f"cell {(uid, l, k, d)} at wave {w} but its "
                        f"layer-{l - 1} producer {dep} is at wave "
                        f"{cell_wave[dep]} (must be strictly earlier"
                        f"{barrier})", cell=(uid, l, k, d), uids=(uid,))


def check_plan(plan: DispatchPlan, *,
               vmem_budget: Optional[int] = None) -> PlanCheckReport:
    """Statically verify ``plan`` against every rule in ``RULES``.

    Pure inspection — no kernel launches, no parameters, no inputs.
    Raises ``PlanInvariantError`` (naming rule, slot, cell) on the first
    violation; returns a ``PlanCheckReport`` on a clean pass.

    ``vmem_budget`` overrides the per-slot footprint bound (default:
    ``core.tiling.SEQ_VMEM_BUDGET``, the same working-set budget the
    autotune table stripes against).
    """
    budget = SEQ_VMEM_BUDGET if vmem_budget is None else vmem_budget
    validate_unique_uids([ip.item for ip in plan.items])
    covered = _covered_items(plan)
    specs = {uid: _item_spec(ip) for uid, ip in covered.items()}

    scheduled: Counter = Counter()
    cell_wave: Dict[tuple, int] = {}
    chained = 0
    for slot in plan.slots:
        _check_slot_budget(slot, budget, covered)
        _check_slot_tiling(slot, plan.macs)
        _check_slot_rows(slot, covered)
        if slot.chained:
            chained += 1
            _check_chained(slot, covered)
        for cell in slot.cells:
            key = (cell.uid, cell.layer, cell.chunk, cell.direction)
            lens = specs[cell.uid][1]
            if cell.chunk >= len(lens):
                raise _fail(
                    "coverage-unknown",
                    f"chunk {cell.chunk} outside item {cell.uid}'s "
                    f"{len(lens)}-chunk walk", slot=slot, cell=cell,
                    uids=(cell.uid,))
            if slot.chunk_len != lens[cell.chunk]:
                raise _fail(
                    "chunk-tiling",
                    f"slot launches chunk {cell.chunk} at length "
                    f"{slot.chunk_len}, but item {cell.uid}'s tiling of "
                    f"[0, {covered[cell.uid].item.T}) "
                    f"makes it {lens[cell.chunk]}", slot=slot, cell=cell,
                    uids=(cell.uid,))
            scheduled[key] += 1
            if not slot.chained:
                cell_wave[key] = slot.wave

    expected = set().union(*(s[0] for s in specs.values())) if specs else set()
    extra = sorted(set(scheduled) - expected)
    if extra:
        raise _fail("coverage-unknown",
                    f"scheduled cell {extra[0]} is outside every covered "
                    "item's walk", cell=extra[0], uids=(extra[0][0],))
    missing = sorted(expected - set(scheduled))
    if missing:
        raise _fail("coverage-missing",
                    f"cell {missing[0]} is never scheduled "
                    f"({len(missing)} missing in total)", cell=missing[0],
                    uids=(missing[0][0],))
    dup = sorted(k for k, n in scheduled.items() if n > 1)
    if dup:
        raise _fail("coverage-duplicate",
                    f"cell {dup[0]} scheduled {scheduled[dup[0]]} times",
                    cell=dup[0], uids=(dup[0][0],))

    _check_readiness(cell_wave, covered, specs)

    waves = [s.wave for s in plan.slots if not s.chained]
    if any(a > b for a, b in zip(waves, waves[1:])):
        raise _fail("wave-monotone",
                    f"slot tuple order contradicts the wave timeline "
                    f"(waves {waves}): the executor runs slots in tuple "
                    "order, so a later-wave slot before an earlier-wave "
                    "one reorders dependencies")

    return PlanCheckReport(items=len(covered), slots=len(plan.slots),
                           cells=sum(scheduled.values()), chained=chained)


def check_decode_tick(plan: DispatchPlan, n_active: int) -> None:
    """The serving engine's per-tick dispatch claim, as a plan invariant:
    a decode tick over ``n_active`` active slots plans exactly
    ``n_active``-row cells in every slot — empty pool slots are never
    computed.  Raises ``PlanInvariantError`` (rule "decode-active-rows");
    replaces the engine's former bare ``assert``."""
    for slot in plan.slots:
        if slot.B != n_active or any(b != n_active for b in slot.group_b):
            raise PlanInvariantError(
                f"decode tick planned {slot.B} batch rows (group_b "
                f"{slot.group_b}) for {n_active} active slots — empty "
                "slots must never be computed:\n" + plan.describe(),
                rule="decode-active-rows", slot=slot.index)


__all__ = ["check_plan", "check_decode_tick", "PlanCheckReport", "RULES"]
