"""Static analysis over the repo's own artifacts.

Two layers, no execution involved in either:

  * ``plancheck`` — a static verifier over ``DispatchPlan``: proves
    coverage, wavefront readiness (the race/hazard rules), packing
    legality, and resource budgets per plan, raising structured
    ``runtime.errors.PlanInvariantError`` on any violation.  Wired into
    the rnn facade as ``ExecutionPolicy(verify="plan")`` (the default).
  * ``repolint`` — an AST lint enforcing the repo's codebase contracts
    (no deprecated shims, no bare asserts on the serving path, one
    fenced clock, no slot-internals coupling); ``make lint-repro``.
"""
from repro.analysis.plancheck import (PlanCheckReport, RULES,
                                      check_decode_tick, check_plan)

__all__ = ["check_plan", "check_decode_tick", "PlanCheckReport", "RULES"]
