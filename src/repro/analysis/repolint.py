"""AST-based repository lint: enforce repro's own codebase contracts.

The repo accumulated a handful of conventions that keep the serving path
debuggable and the benchmarks honest — each previously enforced only by
review or by a runtime gate that needs test execution.  This lint checks
them statically (``make lint-repro``, a CI step), so a violation fails
the build before any test runs:

``RL001`` deprecated-shim
    No internal call to the deprecated ``core.schedules.run_layer`` /
    ``run_stack`` shims (or ``core.gru.run_layer``).  Complements the
    ``-W error::DeprecationWarning:repro\\.`` pytest gate with a static
    check: the pytest gate only fires on code paths the suite happens to
    execute; this one reads every file.  (The suffix-named per-schedule
    entry points — ``run_layer_fused`` etc. — are the supported API and
    are not flagged.)

``RL002`` serving-assert
    No bare ``assert`` statement, and no ``raise RuntimeError(...)`` /
    ``raise AssertionError(...)``, on the serving path (``dispatch/``,
    ``rnn/``, ``serving/``).  Faults there must use the structured
    ``runtime.errors`` taxonomy so callers can quarantine by slot/uid —
    and ``assert`` vanishes under ``python -O``, which would silently
    drop the check in an optimized deployment.

``RL003`` timing-outside-obs
    No ``time.*`` calls and no ``jax.block_until_ready`` outside
    ``runtime/obs.py`` (scope: ``calib/``, ``dispatch/``, ``rnn/``,
    ``serving/``, ``runtime/``).  Timing and fencing go through the obs
    module's ``measure_samples`` / ``measure_us`` / ``monotonic_s`` /
    ``fence`` so every measurement in the repo shares one fenced clock
    (the PR-4 "one benchmark timer" rule, now machine-checked) — the
    calibration replay harness included: its measured tables are only
    comparable to the tracer's launch costs because both come off the
    same clock.  Launch-side modules (``launch/``, ``checkpoint/``)
    legitimately stamp wall-clock epoch metadata and are out of scope.

``RL004`` slot-field-read
    ``Slot.signature()``-relevant fields (``wave``, ``chunk_len``,
    ``group_b``, ``chained``, ``tile_k``, ``mvm_block``) are read only by
    the planner, the executor, the verifier (``analysis/``),
    ``runtime/obs.py``, and the calibration subsystem (``calib/`` replays
    exactly the launches those fields describe — it is the measurement
    side of the same contract).  Any other module pattern-matching on
    slot internals is coupling to the packing layout, which the planner
    is free to change under the same ``signature()``; such code must go
    through ``DispatchPlan``'s public accessors or the verifier.

Usage::

    python -m repro.analysis.repolint src/repro     # CI / make lint-repro
    violations = collect(Path("src/repro"))         # programmatic

Paths are keyed by their suffix after the last ``repro`` path component,
so the rules apply identically from a checkout root, an installed
site-packages tree, or a test's tmp dir.
"""
from __future__ import annotations

import ast
import sys
from dataclasses import dataclass
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

#: the deprecated entry points RL001 bans (exact names; the per-schedule
#: ``run_layer_*`` functions are the supported replacements)
DEPRECATED_SHIMS = ("run_layer", "run_stack")

#: exception constructors RL002 bans on the serving path
BANNED_RAISES = ("RuntimeError", "AssertionError")

#: Slot fields whose reads RL004 confines to planner/executor/analysis.
#: ("groups" is signature-relevant too but collides with ``m.groups()``
#: on regex matches — the planner's own property tests cover it.)
SLOT_FIELDS = frozenset(
    {"wave", "chunk_len", "group_b", "chained", "tile_k", "mvm_block"})

#: rule -> (path prefixes in scope, path suffixes exempt).  "" = repo-wide.
_SCOPES = {
    "RL001": (("",), ("core/schedules.py", "core/gru.py")),
    "RL002": (("dispatch/", "rnn/", "serving/"), ()),
    "RL003": (("calib/", "dispatch/", "rnn/", "serving/", "runtime/"),
              ("runtime/obs.py",)),
    "RL004": (("",), ("dispatch/planner.py", "dispatch/executor.py",
                      "runtime/obs.py", "analysis/", "calib/")),
}


@dataclass(frozen=True)
class Violation:
    rule: str       # "RL001".."RL004"
    path: str       # repo-relative path of the offending file
    line: int       # 1-based source line
    msg: str        # what was found and what to use instead

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: {self.rule} {self.msg}"


def _relkey(relpath: str) -> str:
    """Key a path by its suffix after the last ``repro`` component, so
    scope prefixes match regardless of checkout layout."""
    parts = Path(relpath).as_posix().split("/")
    for i in range(len(parts) - 1, -1, -1):
        if parts[i] == "repro":
            return "/".join(parts[i + 1:])
    return "/".join(parts)


def _in_scope(rule: str, key: str) -> bool:
    prefixes, exempt = _SCOPES[rule]
    for e in exempt:
        if key == e or (e.endswith("/") and key.startswith(e)):
            return False
    return any(key.startswith(p) for p in prefixes)


def _callee_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def _dotted(node: ast.AST) -> Optional[str]:
    """'time.monotonic' for Attribute chains rooted at a Name."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class _Linter(ast.NodeVisitor):
    def __init__(self, key: str, path: str):
        self.key = key
        self.path = path
        self.out: List[Violation] = []

    def _emit(self, rule: str, line: int, msg: str) -> None:
        if _in_scope(rule, self.key):
            self.out.append(Violation(rule, self.path, line, msg))

    # -- RL002: bare assert -------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self._emit("RL002", node.lineno,
                   "bare `assert` on the serving path — raise a "
                   "runtime.errors fault (asserts vanish under -O)")
        self.generic_visit(node)

    # -- RL002: raise RuntimeError/AssertionError ---------------------------
    def visit_Raise(self, node: ast.Raise) -> None:
        exc = node.exc
        name = None
        if isinstance(exc, ast.Call):
            name = _callee_name(exc)
        elif isinstance(exc, (ast.Name, ast.Attribute)):
            name = exc.id if isinstance(exc, ast.Name) else exc.attr
        if name in BANNED_RAISES:
            self._emit("RL002", node.lineno,
                       f"raise {name} on the serving path — use the "
                       "runtime.errors taxonomy (ServingFault subclass)")
        self.generic_visit(node)

    # -- RL001 / RL003: calls -----------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        name = _callee_name(node)
        if name in DEPRECATED_SHIMS:
            self._emit("RL001", node.lineno,
                       f"call to deprecated shim `{name}` — use the "
                       "repro.rnn facade (compile/forward)")
        dotted = _dotted(node.func)
        if dotted is not None:
            if dotted.startswith("time.") or dotted.endswith(
                    ".block_until_ready") or dotted == "block_until_ready":
                self._emit(
                    "RL003", node.lineno,
                    f"`{dotted}` outside runtime/obs.py — time/fence via "
                    "obs.measure_us / obs.monotonic_s / obs.fence")
        self.generic_visit(node)

    # -- RL004: slot-field reads --------------------------------------------
    def visit_Attribute(self, node: ast.Attribute) -> None:
        if (isinstance(node.ctx, ast.Load) and node.attr in SLOT_FIELDS
                and not (isinstance(node.value, ast.Name)
                         and node.value.id == "self")):
            self._emit(
                "RL004", node.lineno,
                f"read of Slot packing field `.{node.attr}` outside "
                "planner/executor/analysis — go through DispatchPlan's "
                "public surface")
        self.generic_visit(node)


def lint_source(src: str, relpath: str) -> List[Violation]:
    """Lint one file's source text.  ``relpath`` decides rule scope (it
    is keyed by its suffix after the last ``repro`` path component)."""
    key = _relkey(relpath)
    tree = ast.parse(src, filename=relpath)
    linter = _Linter(key, relpath)
    linter.visit(tree)
    return sorted(linter.out, key=lambda v: (v.path, v.line, v.rule))


def collect(root: Path) -> List[Violation]:
    """Lint every ``*.py`` under ``root``; returns sorted violations."""
    out: List[Violation] = []
    for path in sorted(Path(root).rglob("*.py")):
        out.extend(lint_source(path.read_text(), str(path)))
    return sorted(out, key=lambda v: (v.path, v.line, v.rule))


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = list(sys.argv[1:] if argv is None else argv)
    roots = [Path(a) for a in args] or [Path("src/repro")]
    violations: List[Violation] = []
    for root in roots:
        if not root.exists():
            print(f"repolint: no such path: {root}", file=sys.stderr)
            return 2
        violations.extend(collect(root))
    for v in violations:
        print(v)
    n = len(violations)
    root_names = ", ".join(str(r) for r in roots)
    if n:
        print(f"repolint: {n} violation(s) in {root_names}",
              file=sys.stderr)
        return 1
    print(f"repolint: clean ({root_names})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())


__all__ = ["Violation", "lint_source", "collect", "main",
           "DEPRECATED_SHIMS", "BANNED_RAISES", "SLOT_FIELDS"]
