"""The dispatch executor: runs a DispatchPlan through the Pallas kernels.

The packed slot timeline executes in order; each ``Slot`` becomes exactly
one G-batched sequence-fused kernel launch (kernels.lstm_cell.lstm_seq or
kernels.gru_cell.gru_seq), with each cell's hoisted input GEMM issued in
the same slot (no recurrent dependence, so it overlaps the serial tail —
the paper's Fig. 8.d across items as well as layers).  Per-(item, layer)
recurrent state lives in host-side arrays between slots and inside VMEM
scratch within a launch; the final chunk of every layer is launched at its
true remainder length (the kernels T-edge-mask internally), so the state
left behind after the last slot is the exact t=T state — which is what the
serving engine splices into its decode slots.

Cross-B packing executes here too: a slot row may be several parameter-
sharing cells' batches concatenated (same U — the WorkItem.share contract),
and rows narrower than the slot's width are zero-padded and masked
in-kernel (``b_valid``) to exact no-ops.  ``chained`` slots (T=1 decode)
run a whole tick's dependent layer chain in ONE launch via the decode
kernels, the inter-layer value flowing through VMEM scratch.

Bidirectional cells execute in the packed timeline too (ISSUE-5): a "bwd"
cell walks its chunk in descending time — the executor feeds the sequence
kernel the time-reversed chunk slice and flips the produced stripe back
into original time order before storing it (pre-launch reversal; exact,
remainder chunks included, because the slice IS the chunk).  Each
direction carries its own recurrent state and its own parameter half
(layer["fwd"] / layer["bwd"]), and a deeper cell's input is the chunk of
the previous layer's fwd‖bwd feature concat.

Numerics: the per-cell math inside a G-batched launch is identical to the
G=1 launch (the kernel grid walks cells independently; padded rows are
masked no-ops), so a packed plan's outputs match per-item execution
exactly — property-tested in tests/dispatch/.

Fault isolation (ISSUE-6): every packed/chained launch runs behind a
guarded execution ladder.  Under ``on_fault="fallback"`` a launch that
raises (or that a ``runtime.errors.FaultInjector`` makes raise) re-executes
per-step — the same kernels at block_t=1, one launch per timestep (per
*layer* for chained decode slots) — and, failing that, through the
non-deprecated pure-jnp reference (``kernels.*.ref``), which is
oracle-equal by construction and cannot fail on a kernel launch.  Each
degradation is recorded in the caller's ``ExecutionReport``
(slot index, deepest rung, cause); ``on_fault="raise"`` preserves the
pre-ISSUE-6 fail-fast behaviour, wrapping the failure in a structured
``LaunchError`` naming the slot and the uids that shared the launch.
``check_finite`` additionally verifies each launch's recurrent state and
raises ``NonFiniteStateError`` naming exactly the poisoned items (a NaN is
deterministic — no rung can fix it — so this raises under either mode).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from repro.core.perfmodel import MXU_ROWS
from repro.dispatch.planner import DispatchPlan, ItemPlan
from repro.dispatch.workitem import GATES
from repro.kernels.quant import (bf16_roundtrip, compact_rows,
                                 active_row_indices, expand_rows,
                                 quantize_per_gate)
from repro.runtime.errors import (FALLBACK_LEVELS, ExecutionReport,
                                  FaultInjector, LaunchError,
                                  NonFiniteStateError)
from repro.runtime.obs import NULL_TRACER, as_tracer


def _hoist(layer_params, src, gates: int):
    """One cell's input half: (B, bt, X) @ (X, gates·H) + b -> (B,bt,g,H)."""
    B, bt, _ = src.shape
    H = layer_params["U"].shape[0]
    xw = (jnp.einsum("btx,xg->btg", src, layer_params["W"])
          + layer_params["b"])
    return xw.reshape(B, bt, gates, H)


def execute(plan: DispatchPlan, params: Dict[int, dict],
            inputs: Dict[int, jnp.ndarray], *,
            interpret: Optional[bool] = None,
            collect_state: bool = False,
            init_state: Optional[Dict[int, dict]] = None,
            prepared: Optional[Dict[int, dict]] = None,
            quant_cache: Optional[dict] = None,
            on_fault: str = "raise",
            check_finite: bool = False,
            inject: Optional[FaultInjector] = None,
            report: Optional[ExecutionReport] = None,
            tracer=None):
    """Run ``plan``.  params[uid] = stack params ({"layers": [...]}),
    inputs[uid] = xs (B, T, X).  Returns outputs {uid: (B, T, H)} —
    (B, T, 2H) for bidirectional items (fwd‖bwd concat) — or
    (outputs, states) when ``collect_state``: states[uid] is
    {"h": (L,B,H)[, "c": (L,B,H)]} (exact t=T recurrent state); for
    bidirectional items a per-direction pair {"fwd": {...}, "bwd": {...}}
    (fwd is the exact t=T state, bwd the exact t=0 state — the end of its
    walk); or ``None`` for items that expose no (h[, c]) state at all —
    rglru (diagonal recurrence, no gate state surfaced) and any item
    executed through an external stateless schedule.  Callers splicing
    decode state must check for a plain {"h": ...} dict, as the serving
    engine does.

    ``init_state`` optionally seeds the recurrent state of packed items:
    init_state[uid] = {"h": (L,B,H)[, "c": (L,B,H)]} replaces the zero
    initial state (the serving engine's decode ticks resume from it).
    External-fallback items ignore it (their schedule surfaces start from
    zeros) — the planner never routes a decode item external.
    Bidirectional items reject it: their two walks start from opposite
    sequence ends, so there is no mid-stream resume point.

    ``prepared`` optionally carries pre-stacked decode weights per uid
    (see ``prepare_decode_stack``) so steady-state decode ticks don't
    restack unchanged parameters every tick.

    ``quant_cache`` memoizes per-(item, layer, direction) quantized /
    row-compacted recurrent-weight operands across slots (and across
    calls, when the caller owns the dict — ``CompiledStack`` keeps one per
    plan, valid while the bound parameters don't change).  None builds a
    per-call cache, so each layer is still transformed at most once per
    execute().  Only consulted for slots whose ``precision != "fp32"`` or
    whose items carry a block-sparsity ``tile_map``.

    ``collect_state`` reroutes unpacked (external) unidirectional items
    through the per-layer fused path — the only surface that returns exact
    state — so for those items the plan's per_step/per_layer launch
    accounting describes the stateless execution, not this one.

    ``on_fault``/``check_finite``/``inject``/``report`` drive the guarded
    execution ladder (module doc): "fallback" re-executes a failed
    packed/chained launch per-step, then through the pure-jnp reference,
    recording each degradation in ``report``; "raise" fails fast with a
    structured ``LaunchError``.  ``check_finite`` raises
    ``NonFiniteStateError`` naming exactly the items whose post-launch
    recurrent state went NaN/Inf.  ``inject`` is the test-time fault hook
    (``runtime.errors.FaultInjector``).

    ``tracer`` (optional ``runtime.obs.Tracer``): every packed/chained
    slot gets a ``hoist`` span (row assembly + input GEMM dispatch) and a
    *fenced* ``slot_launch`` span (``block_until_ready`` inside the span,
    so its duration is the launch's wall-clock, not its async dispatch),
    tagged with the slot signature and uids and fed to the per-signature
    launch-latency histogram + the predicted-vs-measured launch-cost
    table; ladder recoveries appear as nested ``fallback_rung`` spans and
    ``launch_fault`` instants.  None (the default) binds the shared no-op
    tracer — no events, no fencing, outputs bit-identical.
    """
    tracer = as_tracer(tracer)
    if on_fault not in ("raise", "fallback"):
        raise ValueError(f"execute: on_fault={on_fault!r} invalid; "
                         "allowed: raise, fallback")

    # fail fast, before any work: a plan may legitimately carry plan-only
    # items (ItemPlan.executable == False) for admission pricing — callers
    # filter those out before executing (see examples/dispatch_demo.py)
    plan_only = [ip.uid for ip in plan.items if not ip.executable]
    if plan_only:
        raise NotImplementedError(
            f"plan contains plan-only items (uids {plan_only}): multi-layer "
            "rglru executes through its model, not the dispatcher — filter "
            "by ItemPlan.executable before execute()")
    # state resume is a packed-timeline feature only; silently dropping a
    # caller's init_state for an external item would compute from zeros
    dropped = sorted(set(init_state or {}) & set(plan.external))
    if dropped:
        raise ValueError(
            f"init_state given for external-fallback items {dropped}: their "
            "schedule surfaces start from zero state — plan them onto the "
            "packed timeline (e.g. schedule='wavefront') to resume")

    outputs: Dict[int, jnp.ndarray] = {}
    states: Dict[int, dict] = {}
    if quant_cache is None:
        quant_cache = {}  # per-call memo: each layer transforms at most once

    # ---- external fallbacks (reference schedules / per-step / rglru /
    # T=0) — bidirectional items land here only under a forced stateless
    # schedule; their planned path is the interleaved packed timeline ----
    for ip in plan.items:
        if ip.uid not in plan.external:
            continue
        it = ip.item
        xs = inputs[it.uid]
        if it.family == "rglru":
            outputs[it.uid] = _run_rglru(ip, xs, interpret=interpret)
            if collect_state:
                states[it.uid] = None  # rglru exposes no (h, c) state
            continue
        if collect_state and not it.bidirectional:
            # state collection forces the per-layer fused path (the seq
            # kernels are the only surface that returns exact t=T state)
            outputs[it.uid], states[it.uid] = _run_stack_collect(
                it, params[it.uid], xs, interpret=interpret)
            continue
        # per_layer (the forced-"fused" shape) is the per-layer fused
        # path; everything else external runs its own named schedule
        # through the reference library
        sched = "fused" if ip.schedule in ("per_layer", "fused") \
            else ip.schedule
        outputs[it.uid] = _run_reference(
            params[it.uid], xs, sched,
            interpret=interpret, block_t=ip.block_t)
        if collect_state:
            states[it.uid] = None  # stateless external schedule

    # ---- packed wavefront timeline --------------------------------------
    # live state is keyed (layer, direction): unidirectional items only
    # ever touch direction "fwd"; a bidirectional item's two walks carry
    # independent state and parameter halves
    live: Dict[int, dict] = {}
    for ip in plan.items:
        if ip.uid in plan.external:
            continue
        it = ip.item
        dirs = ("fwd", "bwd") if it.bidirectional else ("fwd",)
        dtype = inputs[it.uid].dtype
        st0 = (init_state or {}).get(it.uid)
        if st0 is not None and it.bidirectional:
            raise ValueError(
                f"init_state given for bidirectional item {it.uid}: the "
                "fwd/bwd walks start from opposite sequence ends, so there "
                "is no mid-stream state to resume from")

        def _c0(l):
            # cell state exists per LSTM layer only; a mixed stack's gru
            # layers carry None (their slots never read/write c)
            if it.families[l] != "lstm":
                return None
            if st0 is not None and "c" in st0:
                return st0["c"][l]
            return jnp.zeros((it.B, it.H), jnp.float32)

        live[it.uid] = {
            "plan": ip,
            "h": {(l, d): (st0["h"][l] if st0 is not None else
                           jnp.zeros((it.B, it.H), dtype))
                  for l in range(it.L) for d in dirs},
            "c": ({(l, d): _c0(l) for l in range(it.L) for d in dirs}
                  if "lstm" in it.families else None),
            "outs": {(l, d): [None] * ip.nk
                     for l in range(it.L) for d in dirs},
        }

    for slot in plan.slots:
        if slot.chained:
            _run_chained_slot(slot, params, inputs, live,
                              interpret=interpret, prepared=prepared,
                              on_fault=on_fault, check_finite=check_finite,
                              inject=inject, report=report,
                              tracer=tracer, macs=plan.macs)
            continue
        gates = GATES[slot.family]
        with tracer.span("hoist", slot=slot.index):
            xws, hs, cs = [], [], []
            for grp, b in zip(slot.groups, slot.group_b):
                xw_rows, h_rows, c_rows = [], [], []
                for cell in grp:
                    st = live[cell.uid]
                    ip: ItemPlan = st["plan"]
                    layer = _cell_layer_params(params, st, cell)
                    src = _cell_src(inputs, st, cell, slot.chunk_len)
                    xw_rows.append(_hoist(layer, src, gates))
                    h_rows.append(st["h"][(cell.layer, cell.direction)])
                    if slot.family == "lstm":
                        c_rows.append(st["c"][(cell.layer, cell.direction)])
                # cross-B row: parameter-sharing cells concatenate on B
                # (same U by the share contract — take the lead cell's);
                # rows narrower than the slot's width pad with zeros,
                # masked in-kernel to exact no-ops
                xw_g = _cat_pad(xw_rows, slot.B)
                xws.append(xw_g)
                hs.append(_cat_pad(h_rows, slot.B))
                if slot.family == "lstm":
                    cs.append(_cat_pad(c_rows, slot.B))

            xw = jnp.stack(xws)          # (G, B, bt, gates, H)
            U, u_scales, u_rows = _slot_weights(slot, params, live,
                                                quant_cache)
            h0 = jnp.stack(hs)           # (G, B, H)
            c0 = jnp.stack(cs) if slot.family == "lstm" else None
        b_valid = (jnp.asarray(slot.group_b, jnp.int32)
                   if any(b < slot.B for b in slot.group_b) else None)
        uids = sorted({c.uid for grp in slot.groups for c in grp})
        sig = slot.signature() if tracer.enabled else ""
        with tracer.span("slot_launch", slot=slot.index, sig=sig,
                         uids=uids) as sp:
            out, h_n, c_n = _guarded_launch(
                slot.index, uids,
                _seq_ladder(slot, U, xw, h0, c0, b_valid,
                            u_scales=u_scales, u_rows=u_rows,
                            interpret=interpret),
                on_fault=on_fault, inject=inject, report=report,
                tracer=tracer)
            out, h_n, c_n = tracer.fence((out, h_n, c_n))
        if tracer.enabled:
            tracer.observe_launch(sig, _slot_est_cycles(slot, plan.macs),
                                  sp.dur_us)

        bad: List[int] = []
        for g, grp in enumerate(slot.groups):
            off = 0
            for cell in grp:
                st = live[cell.uid]
                nb = st["plan"].item.B
                key = (cell.layer, cell.direction)
                st["h"][key] = h_n[g, off:off + nb].astype(h0.dtype)
                if c_n is not None:
                    st["c"][key] = c_n[g, off:off + nb]
                if check_finite and not _rows_finite(
                        h_n[g, off:off + nb],
                        None if c_n is None else c_n[g, off:off + nb]):
                    bad.append(cell.uid)
                chunk = out[g, off:off + nb].astype(inputs[cell.uid].dtype)
                if cell.direction == "bwd":
                    # the kernel walked the chunk in reversed time; store
                    # the stripe back in original time order
                    chunk = jnp.flip(chunk, axis=1)
                st["outs"][key][cell.chunk] = chunk
                off += nb
        if bad:
            bad = sorted(set(bad))
            raise NonFiniteStateError(
                f"non-finite recurrent state after slot {slot.index} "
                f"(uids {bad})", uids=bad, slot=slot.index,
                where="slot state")

    for uid, st in live.items():
        it = st["plan"].item
        top = jnp.concatenate(st["outs"][(it.L - 1, "fwd")], axis=1)
        if it.bidirectional:
            bwd = jnp.concatenate(st["outs"][(it.L - 1, "bwd")], axis=1)
            top = jnp.concatenate([top, bwd], axis=-1)
        outputs[uid] = top
        if collect_state:
            if it.bidirectional:
                # per-direction state: fwd's walk ends at t=T, bwd's at
                # t=0 — two exact end-of-walk states, no single t=T one
                states[uid] = {d: _dir_state(st, it, d)
                               for d in ("fwd", "bwd")}
            else:
                states[uid] = _dir_state(st, it, "fwd")

    return (outputs, states) if collect_state else outputs


def _slot_est_cycles(slot, macs: int, X: int = 0) -> float:
    """The perfmodel's estimate for ONE slot launch — the predicted half
    of the launch-cost table's predicted-vs-measured pair."""
    from repro.core.perfmodel import (Design, decode_plan_cycles,
                                      slot_launch_cycles)
    from repro.dispatch.planner import DEFAULT_MACS

    design = Design(macs=macs or DEFAULT_MACS, schedule="unfolded")
    if slot.chained:
        return decode_plan_cycles(slot.family, slot.H, X or slot.H,
                                  len(slot.groups), design)
    return slot_launch_cycles(slot.family, slot.H, slot.chunk_len,
                              list(slot.group_b), design,
                              precision=slot.precision)


def _slot_weights(slot, params, live, cache: dict):
    """Stack one sequence slot's per-group recurrent-weight operands under
    the slot's precision and its items' block-sparsity tile maps.

    Returns ``(U, u_scales, u_rows)``: dense fp32 ``(G, H, gates, H)`` with
    None markers for a plain fp32 slot; bf16 round-trips the values in
    place (still f32 storage — exact); int8 swaps in the per-gate quantized
    payload plus ``u_scales (G, gates)``; a tile_map row-compacts to the
    slot-uniform ``Ha`` active-row count plus ``u_rows (G, Ha)``.  Groups
    without a tile_map in a sparse slot ride along dense (all-ones bitmap).
    Per-(item, layer, direction) transforms memoize in ``cache`` so the
    chunk slots of one layer quantize/compact the weights ONCE per plan.
    """
    gates = GATES[slot.family]
    leads = [grp[0] for grp in slot.groups]
    quant = slot.precision == "int8"

    def _bitmap(cell):
        tm = live[cell.uid]["plan"].item.tile_map
        if tm is None:
            return (1,) * (-(-slot.H // MXU_ROWS))
        return tm[cell.layer]

    sparse = any(live[c.uid]["plan"].item.tile_map is not None
                 for c in leads)
    Ha = 0
    if sparse:
        # slot-uniform padded row count: the stacked (G, Ha) gather index
        # needs one Ha; padding rows are exact no-ops (kernels.quant)
        Ha = max(max(len(active_row_indices(_bitmap(c), slot.H))
                     for c in leads), 1)

    us, scales, rows = [], [], []
    for cell in leads:
        key = (cell.uid, cell.layer, cell.direction, slot.precision,
               Ha if sparse else -1)
        entry = cache.get(key)
        if entry is None:
            U = _cell_layer_params(params, live[cell.uid], cell)["U"] \
                .reshape(slot.H, gates, slot.H)
            if slot.precision == "bf16":
                U = bf16_roundtrip(U)
            s = None
            if quant:
                U, s = quantize_per_gate(U)
            r = None
            if sparse:
                U, r = compact_rows(U, _bitmap(cell), pad_to=Ha)
            entry = cache[key] = (U, s, r)
        us.append(entry[0])
        scales.append(entry[1])
        rows.append(entry[2])
    return (jnp.stack(us),
            jnp.stack(scales) if quant else None,
            jnp.stack(rows) if sparse else None)


# ---------------------------------------------------------------------------
# guarded execution ladder
# ---------------------------------------------------------------------------


def _guarded_launch(slot_index: int, uids, ladder, *, on_fault: str,
                    inject: Optional[FaultInjector],
                    report: Optional[ExecutionReport],
                    tracer=NULL_TRACER):
    """Run one slot's launch down the guarded execution ladder.

    ``ladder`` holds one thunk per ``FALLBACK_LEVELS`` rung, shallowest
    first.  Any exception a rung raises (including an injected one) is
    wrapped in a structured ``LaunchError``; under ``on_fault="fallback"``
    the next rung is tried, and a recovery at rung > 0 is recorded in
    ``report``.  The last rung is the pure-jnp reference — it cannot fail
    on a kernel launch, so under "fallback" only an armed-through-reference
    ``FaultInjector`` makes the error escape."""
    cause = None
    last = len(ladder) - 1
    for level, attempt in enumerate(ladder):
        try:
            if inject is not None:
                inject.maybe_fail(slot_index, level, uids)
            if level == 0:
                result = attempt()
            else:
                # recovery rungs get their own nested span so a trace shows
                # exactly where a launch's time went when it degraded
                with tracer.span("fallback_rung", slot=slot_index,
                                 rung=FALLBACK_LEVELS[level]):
                    result = attempt()
        except Exception as err:  # noqa: BLE001 — the ladder IS the boundary
            fault = err if isinstance(err, LaunchError) else LaunchError(
                f"launch failed: slot {slot_index} at ladder level "
                f"{FALLBACK_LEVELS[level]!r} "
                f"(uids {sorted(set(uids))}): {err!r}",
                uids=uids, slot=slot_index, level=FALLBACK_LEVELS[level])
            if tracer.enabled:
                tracer.instant("launch_fault", slot=slot_index,
                               rung=FALLBACK_LEVELS[level],
                               error=type(err).__name__)
                tracer.metrics.counter("launch_faults").add()
            if on_fault != "fallback" or level == last:
                raise fault from err
            cause = fault
            continue
        if level > 0:
            if report is not None:
                report.record(slot_index, level, cause)
            if tracer.enabled:
                tracer.metrics.counter("degraded_launches").add()
        return result
    raise LaunchError(
        f"guarded ladder for slot {slot_index} exhausted every rung "
        "without returning or raising — executor invariant broken",
        uids=uids, slot=slot_index, level=FALLBACK_LEVELS[last])


def _seq_ladder(slot, U, xw, h0, c0, b_valid, *, u_scales=None, u_rows=None,
                interpret):
    """The three launch strategies for a packed sequence slot, shallowest
    first: the planned fused launch; per-step — the same kernels at
    block_t=1, one launch per timestep; and the pure-jnp reference scan.
    All three consume the identical pre-hoisted ``xw`` (bwd cells arrive
    pre-flipped), so their outputs agree to the kernel's own tolerance and
    the scatter below is rung-agnostic.  Quantized / row-compacted slots
    pass their operands down the kernel rungs unchanged; the reference
    rung reconstructs the dense dequantized matrix (value-identical to
    what the kernel computes with, see kernels.quant), so every rung
    satisfies the same oracle bound."""
    from repro.kernels.gru_cell.ops import gru_seq
    from repro.kernels.gru_cell.ref import gru_seq_ref
    from repro.kernels.lstm_cell.ops import lstm_seq
    from repro.kernels.lstm_cell.ref import lstm_seq_ref

    lstm = slot.family == "lstm"

    def fused():
        if lstm:
            return lstm_seq(U, xw, h0, c0, b_valid=b_valid,
                            u_scales=u_scales, u_rows=u_rows,
                            block_t=slot.chunk_len, interpret=interpret)
        out, h_n = gru_seq(U, xw, h0, b_valid=b_valid,
                           u_scales=u_scales, u_rows=u_rows,
                           block_t=slot.chunk_len, interpret=interpret)
        return out, h_n, None

    def per_step():
        outs, h, c = [], h0, c0
        for t in range(slot.chunk_len):
            xw_t = xw[:, :, t:t + 1]
            if lstm:
                o, h, c = lstm_seq(U, xw_t, h, c, b_valid=b_valid,
                                   u_scales=u_scales, u_rows=u_rows,
                                   block_t=1, interpret=interpret)
            else:
                o, h = gru_seq(U, xw_t, h, b_valid=b_valid,
                               u_scales=u_scales, u_rows=u_rows,
                               block_t=1, interpret=interpret)
            outs.append(o)
        return jnp.concatenate(outs, axis=2), h, (c if lstm else None)

    def reference():
        Ud = U
        if u_scales is not None:  # dequantize the int8 payload
            Ud = Ud.astype(jnp.float32) * u_scales[:, None, :, None]
        if u_rows is not None:    # scatter compacted rows back to dense
            Ud = jnp.stack([expand_rows(Ud[g], u_rows[g], slot.H)
                            for g in range(Ud.shape[0])])
        if lstm:
            return lstm_seq_ref(Ud, xw, h0, c0)
        out, h_n = gru_seq_ref(Ud, xw, h0)
        return out, h_n, None

    return [fused, per_step, reference]


def _rows_finite(h_rows, c_rows=None) -> bool:
    """True when one cell's slice of post-launch state is all-finite."""
    ok = bool(jnp.isfinite(h_rows).all())
    if ok and c_rows is not None:
        ok = bool(jnp.isfinite(c_rows).all())
    return ok


def _dir_state(st, item, direction: str) -> dict:
    """Stack one direction's per-layer end-of-walk state into the
    documented {"h": (L,B,H)[, "c"]} shape (gru rows of a mixed stack's
    "c" are zeros)."""
    out = {"h": jnp.stack([st["h"][(l, direction)]
                           for l in range(item.L)])}
    if st["c"] is not None:
        out["c"] = jnp.stack(
            [st["c"][(l, direction)]
             if st["c"][(l, direction)] is not None
             else jnp.zeros((item.B, item.H), jnp.float32)
             for l in range(item.L)])
    return out


def _cell_layer_params(params, st, cell):
    """The parameter dict one cell's launch row binds: the cell's layer,
    and for bidirectional items the cell's direction half."""
    layer = params[cell.uid]["layers"][cell.layer]
    if st["plan"].item.bidirectional:
        layer = layer[cell.direction]
    return layer


def _cell_src(inputs, st, cell, chunk_len: int):
    """One cell's input chunk, in the cell's own walk order.

    Layer 0 reads the item's input slice; deeper layers read the previous
    layer's just-produced chunk — for bidirectional items the fwd‖bwd
    feature concat (both stored in original time order).  "bwd" cells walk
    descending time: the chunk slice is flipped before the hoist
    (pre-launch reversal — exact, the slice IS the chunk, remainders
    included)."""
    ip: ItemPlan = st["plan"]
    it = ip.item
    if cell.layer == 0:
        t0 = cell.chunk * ip.block_t
        src = inputs[cell.uid][:, t0:t0 + chunk_len]
    elif it.bidirectional:
        src = jnp.concatenate(
            [st["outs"][(cell.layer - 1, "fwd")][cell.chunk],
             st["outs"][(cell.layer - 1, "bwd")][cell.chunk]], axis=-1)
    else:
        src = st["outs"][(cell.layer - 1, "fwd")][cell.chunk]
    if cell.direction == "bwd":
        src = jnp.flip(src, axis=1)
    return src


def _cat_pad(rows, B: int):
    """Concatenate row arrays on the batch axis, zero-padding to width B
    (the padded rows are masked to exact no-ops in-kernel)."""
    cat = jnp.concatenate(rows) if len(rows) > 1 else rows[0]
    if cat.shape[0] == B:
        return cat
    pad = [(0, B - cat.shape[0])] + [(0, 0)] * (cat.ndim - 1)
    return jnp.pad(cat, pad)


def prepare_decode_stack(stack_params: dict, family: str,
                         precision: str = "fp32") -> dict:
    """Stack a parameter stack into the decode kernels' (L, ...) weight
    layout: {"Ws", "bs", "Us"}.  Steady-state callers (the serving engine)
    compute this ONCE per stack and pass it to ``execute(prepared=...)`` —
    the weights don't change between ticks, so restacking them per tick
    would dwarf the launch-overhead saving the chained slot exists for.

    Ws[0] is a zero placeholder when layer 0's input width differs from H;
    the kernel never reads it (layer 0's input half arrives pre-hoisted).

    ``precision`` != "fp32" round-trips each layer's recurrent matrix
    through the precision's fake-quant (``kernels.quant.fake_quant_stack``,
    U only — W/b stay full precision) before stacking: decode ticks run
    the dense dequantized values, so a quantized stack's decode output
    matches its dequantized oracle EXACTLY — the bounded-error contract
    only ever spends its budget in the sequence kernels' scaled dot.
    """
    gates = GATES[family]
    if precision != "fp32":
        from repro.kernels.quant import fake_quant_stack
        stack_params = fake_quant_stack(stack_params, precision)
    stack = stack_params["layers"]
    H = stack[0]["U"].shape[0]
    L = len(stack)
    W0 = (stack[0]["W"].reshape(H, gates, H)
          if stack[0]["W"].shape[0] == H else
          jnp.zeros((H, gates, H), stack[0]["W"].dtype))
    return {
        "Ws": jnp.stack([W0] + [stack[l]["W"].reshape(H, gates, H)
                                for l in range(1, L)]),
        "bs": jnp.stack([stack[l]["b"].reshape(gates, H)
                         for l in range(L)]),
        "Us": jnp.stack([stack[l]["U"].reshape(H, gates, H)
                         for l in range(L)]),
    }


def _run_chained_slot(slot, params, inputs, live, *, interpret=None,
                      prepared=None, on_fault: str = "raise",
                      check_finite: bool = False,
                      inject: Optional[FaultInjector] = None,
                      report: Optional[ExecutionReport] = None,
                      tracer=NULL_TRACER, macs: int = 0):
    """Execute a chained decode slot: ONE launch for a whole T=1 tick.

    The slot's groups are the L serially dependent layer cells, each the
    B-concatenation of the tick's parameter-sharing items; the decode
    kernel walks layers in grid order, chaining the inter-layer value
    through VMEM scratch (see kernels.*.lstm_decode/gru_decode).  Layer
    0's input GEMM is hoisted here, inside the slot (it exists before
    launch); deeper layers' input GEMMs run in-kernel off the chain.

    Runs behind the same guarded ladder as sequence slots — the per_step
    rung here is per-*layer*: L separate T=1 sequence-kernel launches
    chaining the inter-layer value on the host.
    """
    gates = GATES[slot.family]
    row_cells = slot.groups[0]      # request row order, fixed across layers
    lead_uid = row_cells[0].uid
    stack = params[lead_uid]["layers"]
    L = len(slot.groups)

    with tracer.span("hoist", slot=slot.index):
        xw0 = _cat_pad([_hoist(stack[0], inputs[c.uid], gates)[:, 0]
                        for c in row_cells], slot.B)    # (B, gates, H)
        prep = ((prepared or {}).get(lead_uid)
                or prepare_decode_stack(params[lead_uid], slot.family,
                                        precision=slot.precision))
        Ws, bs, Us = prep["Ws"], prep["bs"], prep["Us"]
        h0 = jnp.stack([_cat_pad([live[c.uid]["h"][(l, "fwd")]
                                  for c in row_cells],
                                 slot.B) for l in range(L)])  # (L, B, H)
        if slot.family == "lstm":
            c0 = jnp.stack([_cat_pad([live[c.uid]["c"][(l, "fwd")]
                                      for c in row_cells],
                                     slot.B) for l in range(L)])
        else:
            c0 = None
    uids = sorted({c.uid for c in row_cells})
    sig = slot.signature() if tracer.enabled else ""
    with tracer.span("slot_launch", slot=slot.index, sig=sig,
                     uids=uids) as sp:
        h_n, c_n = _guarded_launch(
            slot.index, uids,
            _chained_ladder(slot, xw0, Ws, bs, Us, h0, c0,
                            interpret=interpret),
            on_fault=on_fault, inject=inject, report=report, tracer=tracer)
        h_n, c_n = tracer.fence((h_n, c_n))
    if tracer.enabled:
        X = stack[0]["W"].shape[0]
        tracer.observe_launch(sig, _slot_est_cycles(slot, macs, X=X),
                              sp.dur_us)

    off = 0
    bad: List[int] = []
    for cell in row_cells:
        st = live[cell.uid]
        nb = st["plan"].item.B
        dtype = inputs[cell.uid].dtype
        if check_finite and not _rows_finite(
                h_n[:, off:off + nb],
                None if c_n is None else c_n[:, off:off + nb]):
            bad.append(cell.uid)
        for l in range(L):
            st["h"][(l, "fwd")] = h_n[l, off:off + nb].astype(h0.dtype)
            if c_n is not None:
                st["c"][(l, "fwd")] = c_n[l, off:off + nb]
            # layer l's new h IS its T=1 output frame
            st["outs"][(l, "fwd")][0] = \
                h_n[l, off:off + nb, None].astype(dtype)
        off += nb
    if bad:
        bad = sorted(set(bad))
        raise NonFiniteStateError(
            f"non-finite recurrent state after chained slot {slot.index} "
            f"(uids {bad})", uids=bad, slot=slot.index, where="decode tick")


def _chained_ladder(slot, xw0, Ws, bs, Us, h0, c0, *, interpret):
    """The three launch strategies for a chained T=1 decode slot: the
    planned single decode-kernel launch; per-layer — L separate T=1
    sequence-kernel launches with the inter-layer value (and its input
    GEMM) chained on the host; and the pure-jnp reference cells walked the
    same way.  All return ((L,B,H) h_n, (L,B,H) c_n | None)."""
    from repro.kernels.gru_cell.ops import gru_decode, gru_seq
    from repro.kernels.gru_cell.ref import gru_step_ref
    from repro.kernels.lstm_cell.ops import lstm_decode, lstm_seq
    from repro.kernels.lstm_cell.ref import lstm_cell_ref

    lstm = slot.family == "lstm"
    L = h0.shape[0]

    def fused():
        if lstm:
            return lstm_decode(xw0, Ws, bs, Us, h0, c0, interpret=interpret)
        return gru_decode(xw0, Ws, bs, Us, h0, interpret=interpret), None

    def chain(step):
        # walk the layer chain on the host: layer l>0's input half is the
        # previous layer's fresh h through that layer's input GEMM
        hs, cs = [], []
        xw_t = xw0
        for l in range(L):
            if l:
                xw_t = (jnp.einsum("bh,hgj->bgj", hs[-1], Ws[l])
                        + bs[l]).astype(xw0.dtype)
            h, c = step(l, xw_t)
            hs.append(h)
            cs.append(c)
        return jnp.stack(hs), (jnp.stack(cs) if lstm else None)

    def per_layer(l, xw_t):
        if lstm:
            _, h, c = lstm_seq(Us[l][None], xw_t[None, :, None],
                               h0[l][None], c0[l][None],
                               block_t=1, interpret=interpret)
            return h[0], c[0]
        _, h = gru_seq(Us[l][None], xw_t[None, :, None], h0[l][None],
                       block_t=1, interpret=interpret)
        return h[0], None

    def reference(l, xw_t):
        if lstm:
            return lstm_cell_ref(Us[l], xw_t, h0[l], c0[l])
        return gru_step_ref(Us[l], xw_t, h0[l]), None

    return [fused, lambda: chain(per_layer), lambda: chain(reference)]


def _run_reference(stack, xs, schedule, *, interpret=None,
                   block_t: int = 0):
    """External (unpacked) execution of a stack through the reference
    schedule library — per-layer family aware (families inferred from the
    bound parameters by ``core.schedules.walk_stack``), with the
    bidirectional fwd/bwd split.

    ``fused`` is one internally-striped sequence-kernel launch per layer
    (and per direction); ``per_step`` is the honest per-(layer, step)
    cell-kernel accounting for lstm layers (gru has no per-step pallas
    kernel — pure-jnp unfolded scan, zero launches); the research
    schedules (sequential/batch/intergate/unfolded) run the pure-jnp
    implementations in core.schedules / core.gru.
    """
    from repro.core import gru as gru_mod
    from repro.core import schedules as sch

    if schedule not in ("fused", "per_step"):
        # research schedules ARE the oracle: delegate, one dispatch table
        return sch.reference_stack(stack, xs, schedule)

    def one(family, layer, y):
        if schedule == "fused":
            fn = (sch.run_layer_fused if family == "lstm"
                  else gru_mod.run_layer_fused)
            return fn(layer, y, block_t=block_t, interpret=interpret)
        if family == "lstm":  # per_step: one cell-kernel launch per step
            from repro.kernels.lstm_cell.ops import as_cell_kernel

            return sch.run_layer_unfolded(
                layer, y, cell_kernel=as_cell_kernel(interpret=interpret))
        return gru_mod.run_layer_unfolded(layer, y)

    return sch.walk_stack(stack, xs, one)


def _run_stack_collect(item, stack, xs, *, interpret=None):
    """Unidirectional stack, layer by layer through the fused schedule APIs
    (return_state=True), returning (outputs, exact t=T states) — the
    fallback path when a caller needs state (serving prefill) for an
    unpacked item.  Mixed stacks: gru layers contribute zero rows to "c"
    (present whenever any layer is an LSTM)."""
    from repro.core import gru as gru_mod
    from repro.core import schedules as sch

    y = xs
    any_lstm = "lstm" in item.families
    hs_f, cs_f = [], []
    for fam, layer in zip(item.families, stack["layers"]):
        if fam == "lstm":
            y, (h_n, c_n) = sch.run_layer_fused(layer, y,
                                                interpret=interpret,
                                                return_state=True)
            cs_f.append(c_n)
        else:
            y, h_n = gru_mod.run_layer_fused(layer, y, interpret=interpret,
                                             return_state=True)
            if any_lstm:
                cs_f.append(jnp.zeros((xs.shape[0], item.H), jnp.float32))
        hs_f.append(h_n.astype(xs.dtype))
    state = {"h": jnp.stack(hs_f)}
    if cs_f:
        state["c"] = jnp.stack(cs_f)
    return y, state


def _run_rglru(ip: ItemPlan, xs, *, interpret=None):
    """rglru items execute layer-by-layer through the fused scan kernel.

    The dispatcher's contract for this family is the recurrence core only
    (the surrounding block mixing belongs to the model): inputs arrive as
    a (log_a, gx) pair per the kernel's signature, restricted to L == 1 —
    multi-layer rglru items are plan-only (latency/launch accounting).
    """
    from repro.kernels.rglru.ops import rglru_scan

    log_a, gx = xs
    B, T, W = gx.shape
    h0 = jnp.zeros((B, W), gx.dtype)
    hs, _ = rglru_scan(log_a, gx, h0, interpret=interpret)
    return hs
