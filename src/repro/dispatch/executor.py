"""The dispatch executor: runs a DispatchPlan through the Pallas kernels.

The packed slot timeline executes in order; each ``Slot`` becomes exactly
one G-batched sequence-fused kernel launch (kernels.lstm_cell.lstm_seq or
kernels.gru_cell.gru_seq), with each cell's hoisted input GEMM issued in
the same slot (no recurrent dependence, so it overlaps the serial tail —
the paper's Fig. 8.d across items as well as layers).  Per-(item, layer)
recurrent state lives in host-side arrays between slots and inside VMEM
scratch within a launch; the final chunk of every layer is launched at its
true remainder length (the kernels T-edge-mask internally), so the state
left behind after the last slot is the exact t=T state — which is what the
serving engine splices into its decode slots.

Numerics: the per-cell math inside a G-batched launch is identical to the
G=1 launch (the kernel grid walks cells independently), so a packed plan's
outputs match per-item execution exactly — property-tested in
tests/dispatch/.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.dispatch.planner import DispatchPlan, ItemPlan
from repro.dispatch.workitem import GATES


def _hoist(layer_params, src, gates: int):
    """One cell's input half: (B, bt, X) @ (X, gates·H) + b -> (B,bt,g,H)."""
    B, bt, _ = src.shape
    H = layer_params["U"].shape[0]
    xw = (jnp.einsum("btx,xg->btg", src, layer_params["W"])
          + layer_params["b"])
    return xw.reshape(B, bt, gates, H)


def execute(plan: DispatchPlan, params: Dict[int, dict],
            inputs: Dict[int, jnp.ndarray], *,
            interpret: Optional[bool] = None,
            collect_state: bool = False):
    """Run ``plan``.  params[uid] = stack params ({"layers": [...]}),
    inputs[uid] = xs (B, T, X).  Returns outputs {uid: (B, T, H)} — or
    (outputs, states) with states[uid] = {"h": (L,B,H)[, "c": (L,B,H)]}
    (exact t=T recurrent state) when ``collect_state``.

    ``collect_state`` reroutes unpacked (external) unidirectional items
    through the per-layer fused path — the only surface that returns exact
    state — so for those items the plan's per_step/per_layer launch
    accounting describes the stateless execution, not this one.
    """
    from repro.core import schedules as sch
    from repro.kernels.gru_cell.ops import gru_seq
    from repro.kernels.lstm_cell.ops import lstm_seq

    # fail fast, before any work: a plan may legitimately carry plan-only
    # items (ItemPlan.executable == False) for admission pricing — callers
    # filter those out before executing (see examples/dispatch_demo.py)
    plan_only = [ip.uid for ip in plan.items if not ip.executable]
    if plan_only:
        raise NotImplementedError(
            f"plan contains plan-only items (uids {plan_only}): multi-layer "
            "rglru executes through its model, not the dispatcher — filter "
            "by ItemPlan.executable before execute()")

    outputs: Dict[int, jnp.ndarray] = {}
    states: Dict[int, dict] = {}

    # ---- external fallbacks (bidirectional / per-step / rglru / T=0) ----
    for ip in plan.items:
        if ip.uid not in plan.external:
            continue
        it = ip.item
        xs = inputs[it.uid]
        if it.family == "rglru":
            outputs[it.uid] = _run_rglru(ip, xs, interpret=interpret)
            if collect_state:
                states[it.uid] = {}  # rglru recurrence exposes no (h, c)
            continue
        if collect_state and not it.bidirectional:
            # state collection forces the per-layer fused path (the seq
            # kernels are the only surface that returns exact t=T state)
            outputs[it.uid], states[it.uid] = _run_stack_collect(
                it, params[it.uid], xs, interpret=interpret)
            continue
        if it.family == "gru":
            outputs[it.uid] = _run_gru_stack(ip, params[it.uid], xs,
                                             interpret=interpret)
        elif ip.schedule == "per_step":
            # honest accounting: per_step really is one cell-kernel launch
            # per (layer, step) — L·T launches, matching naive_launches
            from repro.kernels.lstm_cell.ops import as_cell_kernel

            outputs[it.uid] = sch.run_stack(
                params[it.uid], xs, "unfolded",
                cell_kernel=as_cell_kernel(interpret=interpret))
        else:
            outputs[it.uid] = sch.run_stack(params[it.uid], xs, "fused",
                                            interpret=interpret)
        if collect_state:
            states[it.uid] = {}  # bidirectional: no single t=T state

    # ---- packed wavefront timeline --------------------------------------
    live: Dict[int, dict] = {}
    for ip in plan.items:
        if ip.uid in plan.external:
            continue
        it = ip.item
        dtype = inputs[it.uid].dtype
        live[it.uid] = {
            "plan": ip,
            "h": [jnp.zeros((it.B, it.H), dtype) for _ in range(it.L)],
            "c": [jnp.zeros((it.B, it.H), jnp.float32)
                  for _ in range(it.L)] if it.family == "lstm" else None,
            "outs": [[None] * ip.nk for _ in range(it.L)],
        }

    for slot in plan.slots:
        gates = GATES[slot.family]
        xws, us, hs, cs = [], [], [], []
        for cell in slot.cells:
            st = live[cell.uid]
            ip: ItemPlan = st["plan"]
            layer = params[cell.uid]["layers"][cell.layer]
            t0 = cell.chunk * ip.block_t
            if cell.layer == 0:
                src = inputs[cell.uid][:, t0:t0 + slot.chunk_len]
            else:
                src = st["outs"][cell.layer - 1][cell.chunk]
            xws.append(_hoist(layer, src, gates))
            us.append(layer["U"].reshape(slot.H, gates, slot.H))
            hs.append(st["h"][cell.layer])
            if slot.family == "lstm":
                cs.append(st["c"][cell.layer])

        xw = jnp.stack(xws)          # (G, B, bt, gates, H)
        U = jnp.stack(us)            # (G, H, gates, H)
        h0 = jnp.stack(hs)           # (G, B, H)
        if slot.family == "lstm":
            out, h_n, c_n = lstm_seq(U, xw, h0, jnp.stack(cs),
                                     block_t=slot.chunk_len,
                                     interpret=interpret)
        else:
            out, h_n = gru_seq(U, xw, h0, block_t=slot.chunk_len,
                               interpret=interpret)
            c_n = None

        for g, cell in enumerate(slot.cells):
            st = live[cell.uid]
            st["h"][cell.layer] = h_n[g].astype(h0.dtype)
            if c_n is not None:
                st["c"][cell.layer] = c_n[g]
            st["outs"][cell.layer][cell.chunk] = \
                out[g].astype(inputs[cell.uid].dtype)

    for uid, st in live.items():
        it = st["plan"].item
        outputs[uid] = jnp.concatenate(st["outs"][it.L - 1], axis=1)
        if collect_state:
            states[uid] = {"h": jnp.stack(st["h"])}
            if st["c"] is not None:
                states[uid]["c"] = jnp.stack(st["c"])

    return (outputs, states) if collect_state else outputs


def _run_gru_stack(ip: ItemPlan, stack, xs, *, interpret=None):
    """GRU stack fallback (mirrors core.schedules.run_stack for GRU layers,
    including the bidirectional fwd/bwd split)."""
    from repro.core import gru as gru_mod

    schedule = "unfolded" if ip.schedule == "per_step" else "fused"
    kw = {} if schedule == "unfolded" else \
        {"interpret": interpret, "block_t": ip.block_t}
    y = xs
    for layer in stack["layers"]:
        if "fwd" in layer:
            f = gru_mod.run_layer(layer["fwd"], y, schedule, **kw)
            b = gru_mod.run_layer(layer["bwd"], jnp.flip(y, axis=1),
                                  schedule, **kw)
            y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)
        else:
            y = gru_mod.run_layer(layer, y, schedule, **kw)
    return y


def _run_stack_collect(item, stack, xs, *, interpret=None):
    """Unidirectional lstm/gru stack, layer by layer through the fused
    schedule APIs (return_state=True), returning (outputs, exact t=T
    states) — the fallback path when a caller needs state (serving
    prefill) for an unpacked item."""
    from repro.core import gru as gru_mod
    from repro.core import schedules as sch

    y = xs
    hs_f, cs_f = [], []
    for layer in stack["layers"]:
        if item.family == "lstm":
            y, (h_n, c_n) = sch.run_layer_fused(layer, y,
                                                interpret=interpret,
                                                return_state=True)
            cs_f.append(c_n)
        else:
            y, h_n = gru_mod.run_layer_fused(layer, y, interpret=interpret,
                                             return_state=True)
        hs_f.append(h_n.astype(xs.dtype))
    state = {"h": jnp.stack(hs_f)}
    if cs_f:
        state["c"] = jnp.stack(cs_f)
    return y, state


def _run_rglru(ip: ItemPlan, xs, *, interpret=None):
    """rglru items execute layer-by-layer through the fused scan kernel.

    The dispatcher's contract for this family is the recurrence core only
    (the surrounding block mixing belongs to the model): inputs arrive as
    a (log_a, gx) pair per the kernel's signature, restricted to L == 1 —
    multi-layer rglru items are plan-only (latency/launch accounting).
    """
    from repro.kernels.rglru.ops import rglru_scan

    log_a, gx = xs
    B, T, W = gx.shape
    h0 = jnp.zeros((B, W), gx.dtype)
    hs, _ = rglru_scan(log_a, gx, h0, interpret=interpret)
    return hs
