"""Tile dispatcher: dependency-aware dispatch runtime for recurrent stacks.

See README.md in this directory for the mapping to SHARP §5–6.
"""
from repro.dispatch.executor import execute
from repro.dispatch.planner import (Cell, DispatchPlan, ItemPlan, Slot,
                                    plan)
from repro.dispatch.workitem import WorkItem

__all__ = ["WorkItem", "plan", "execute", "DispatchPlan", "ItemPlan",
           "Slot", "Cell"]
