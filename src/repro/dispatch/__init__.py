"""Tile dispatcher: dependency-aware dispatch runtime for recurrent stacks.

See README.md in this directory for the mapping to SHARP §5–6.
"""
from repro.dispatch.executor import execute, prepare_decode_stack
from repro.dispatch.planner import (Cell, DispatchPlan, ItemPlan, Slot,
                                    plan, plan_decode)
from repro.dispatch.workitem import WorkItem

__all__ = ["WorkItem", "plan", "plan_decode", "execute",
           "prepare_decode_stack", "DispatchPlan", "ItemPlan", "Slot",
           "Cell"]
