"""Workload descriptors for the tile dispatcher.

A ``WorkItem`` is the dispatcher's unit of admission: one recurrent stack
evaluation (family, B, T, H, L, dtype) plus scheduling metadata (priority,
soft deadline).  It is deliberately *shape-only* — parameters and inputs
are bound later, at execution — so the planner can be run offline over a
traffic mix (the software analogue of SHARP's offline configuration
exploration, §6.2.2) and its plans cached per shape.

``WorkItem.from_config`` extracts the recurrent core of any
``repro.configs`` ModelConfig:

  family "rnn"            -> lstm  (the paper's own stacks; set
                                    ``rnn_family="gru"`` for the §8 GRU
                                    variant of the same dims)
  family "ssm" / "hybrid" -> rglru (the gated-linear-recurrence core of
                                    each recurrent block)

Anything without a recurrence (dense/moe/audio/vlm) has nothing for this
dispatcher to do and raises.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs.base import ModelConfig

FAMILIES = ("lstm", "gru", "rglru")
GATES = {"lstm": 4, "gru": 3, "rglru": 1}

#: Weight precisions the fused sequence kernels execute: "fp32" is the
#: bit-exact default; "bf16" round-trips the recurrent matrix through
#: bfloat16 (exact vs its dequantized oracle); "int8" stores U as a
#: per-gate absmax int8 payload (4x smaller VMEM residency, fp32
#: accumulate) — bounded-error vs the dequantized oracle, not bit-equal
#: (see kernels.quant).
PRECISIONS = ("fp32", "bf16", "int8")

#: "none" runs dense; "block" row-compacts each layer's recurrent matrix
#: to its occupied MXU row-tiles (the ``tile_map`` bitmap) and the kernel
#: gathers h to the surviving rows — value-exact up to dot reduction
#: order.
SPARSITIES = ("none", "block")


@dataclass(frozen=True)
class WorkItem:
    uid: int
    family: str            # lstm | gru | rglru (layer-0 family)
    B: int                 # batch rows of this item (1 per serving request)
    T: int                 # time steps
    H: int                 # hidden / recurrence width
    L: int                 # recurrent layers
    X: int = 0             # layer-0 input width; 0 -> H
    dtype: str = "float32"
    priority: int = 0      # lower runs earlier within a slot/admission wave
    deadline_us: float = math.inf  # soft; tie-breaks equal priorities
    bidirectional: bool = False
    share: Optional[int] = None  # items with one non-None share key promise
    #                              to bind the SAME parameter stack at
    #                              execution (e.g. requests of one served
    #                              model), so their same-layer cells may
    #                              concatenate on B into one launch row
    #                              (cross-B packing) instead of occupying
    #                              separate G rows
    families: Optional[tuple] = None  # per-layer family, length L; None ->
    #                              homogeneous (family,) * L.  A mixed
    #                              lstm/gru stack wavefronts through the
    #                              same slot timeline — cells group into
    #                              launches by their OWN layer's family —
    #                              which is how the repro.rnn facade runs
    #                              heterogeneous stacks (rglru layers have
    #                              no (h, c)-state sequence kernel and
    #                              cannot appear in a mixed stack)
    precision: str = "fp32"  # recurrent-weight precision (PRECISIONS); the
    #                              executor hoists the quantized payload and
    #                              the planner prices the narrowed VMEM
    #                              residency + MAC discount
    tile_map: Optional[tuple] = None  # block-sparsity occupancy: one
    #                              length-cdiv(H, MXU_ROWS) tuple of 0/1
    #                              per layer (bidirectional layers OR-union
    #                              their halves); None = dense.  Hashable,
    #                              so shape-keyed plan caching still works

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}; {FAMILIES}")
        if self.X == 0:
            object.__setattr__(self, "X", self.H)
        if min(self.B, self.H, self.L) < 1 or self.T < 0:
            raise ValueError(f"degenerate item {self}")
        if self.families is None:
            object.__setattr__(self, "families", (self.family,) * self.L)
        else:
            fams = tuple(self.families)
            object.__setattr__(self, "families", fams)
            if len(fams) != self.L:
                raise ValueError(
                    f"item {self.uid}: families has {len(fams)} entries for "
                    f"L={self.L} layers")
            bad = [f for f in fams if f not in FAMILIES]
            if bad:
                raise ValueError(
                    f"item {self.uid}: unknown families {bad}; {FAMILIES}")
            if fams[0] != self.family:
                raise ValueError(
                    f"item {self.uid}: family={self.family!r} must equal "
                    f"families[0]={fams[0]!r}")
            if len(set(fams)) > 1:
                if not set(fams) <= {"lstm", "gru"}:
                    raise ValueError(
                        f"item {self.uid}: mixed-family stacks support "
                        f"lstm/gru layers only, got {sorted(set(fams))}")
                if self.bidirectional:
                    raise ValueError(
                        f"item {self.uid}: mixed-family stacks cannot be "
                        "bidirectional")
        if self.precision not in PRECISIONS:
            raise ValueError(
                f"item {self.uid}: unknown precision {self.precision!r}; "
                f"{PRECISIONS}")
        if self.tile_map is not None:
            from repro.core.perfmodel import MXU_ROWS
            tm = tuple(tuple(int(b) for b in layer) for layer in self.tile_map)
            object.__setattr__(self, "tile_map", tm)
            n_tiles = -(-self.H // MXU_ROWS)
            if len(tm) != self.L:
                raise ValueError(
                    f"item {self.uid}: tile_map has {len(tm)} layers for "
                    f"L={self.L}")
            for li, layer in enumerate(tm):
                if len(layer) != n_tiles or not set(layer) <= {0, 1}:
                    raise ValueError(
                        f"item {self.uid}: tile_map[{li}] must be "
                        f"{n_tiles} 0/1 tile bits for H={self.H}, got "
                        f"{layer}")

    @property
    def gates(self) -> int:
        """Widest gate axis across the item's layers — what tiling / VMEM
        sizing must budget for (exact for homogeneous items)."""
        return max(GATES[f] for f in self.families)

    @property
    def dirs(self) -> int:
        """Directions per layer: 2 for bidirectional stacks, whose every
        layer contributes a fwd and a bwd cell walk to the planner's
        interleaved timeline (each with its own parameter half and
        recurrent state)."""
        return 2 if self.bidirectional else 1

    @property
    def heterogeneous(self) -> bool:
        return len(set(self.families)) > 1

    @property
    def density(self) -> float:
        """Mean occupied-tile fraction of the recurrent matrices (1.0 when
        dense) — the planner's skipped-tile discount."""
        if self.tile_map is None:
            return 1.0
        return (sum(sum(layer) for layer in self.tile_map)
                / sum(len(layer) for layer in self.tile_map))

    def layer_density(self, layer: int) -> float:
        """Occupied-tile fraction of one layer's recurrent matrix."""
        if self.tile_map is None:
            return 1.0
        bits = self.tile_map[layer]
        return sum(bits) / len(bits)

    @property
    def max_density(self) -> float:
        """Densest layer's occupied-tile fraction — what VMEM stripe
        selection must budget for (``block_t`` is item-uniform, so the
        densest layer's resident set is the binding constraint; ``density``
        is the mean, for launch-cost pricing)."""
        if self.tile_map is None:
            return 1.0
        return max(self.layer_density(l) for l in range(self.L))

    def order_key(self):
        """Admission / intra-slot ordering: priority, then deadline, then
        uid (total, deterministic)."""
        return (self.priority, self.deadline_us, self.uid)

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: ModelConfig, T: int, *, B: int = 1,
                    uid: int = 0, priority: int = 0,
                    deadline_us: float = math.inf,
                    rnn_family: str = "lstm",
                    share: Optional[int] = None) -> "WorkItem":
        """Extract the recurrent workload of ``cfg`` as a WorkItem."""
        if cfg.family == "rnn":
            return cls(uid=uid, family=rnn_family, B=B, T=T,
                       H=cfg.lstm_hidden, L=cfg.n_layers, X=cfg.lstm_input,
                       dtype=cfg.dtype, priority=priority,
                       deadline_us=deadline_us,
                       bidirectional=cfg.bidirectional, share=share)
        if cfg.family in ("ssm", "hybrid"):
            kinds = cfg.layer_kinds()
            n_rec = sum(1 for k in kinds if k != "attn") or cfg.n_layers
            return cls(uid=uid, family="rglru", B=B, T=T,
                       H=cfg.rglru_width or cfg.d_model, L=n_rec,
                       X=cfg.rglru_width or cfg.d_model, dtype=cfg.dtype,
                       priority=priority, deadline_us=deadline_us,
                       share=share)
        raise ValueError(
            f"config {cfg.name!r} (family {cfg.family!r}) has no recurrent "
            "core to dispatch")
