"""Workload descriptors for the tile dispatcher.

A ``WorkItem`` is the dispatcher's unit of admission: one recurrent stack
evaluation (family, B, T, H, L, dtype) plus scheduling metadata (priority,
soft deadline).  It is deliberately *shape-only* — parameters and inputs
are bound later, at execution — so the planner can be run offline over a
traffic mix (the software analogue of SHARP's offline configuration
exploration, §6.2.2) and its plans cached per shape.

``WorkItem.from_config`` extracts the recurrent core of any
``repro.configs`` ModelConfig:

  family "rnn"            -> lstm  (the paper's own stacks; set
                                    ``rnn_family="gru"`` for the §8 GRU
                                    variant of the same dims)
  family "ssm" / "hybrid" -> rglru (the gated-linear-recurrence core of
                                    each recurrent block)

Anything without a recurrence (dense/moe/audio/vlm) has nothing for this
dispatcher to do and raises.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace
from typing import Optional

from repro.configs.base import ModelConfig

FAMILIES = ("lstm", "gru", "rglru")
GATES = {"lstm": 4, "gru": 3, "rglru": 1}


@dataclass(frozen=True)
class WorkItem:
    uid: int
    family: str            # lstm | gru | rglru (layer-0 family)
    B: int                 # batch rows of this item (1 per serving request)
    T: int                 # time steps
    H: int                 # hidden / recurrence width
    L: int                 # recurrent layers
    X: int = 0             # layer-0 input width; 0 -> H
    dtype: str = "float32"
    priority: int = 0      # lower runs earlier within a slot/admission wave
    deadline_us: float = math.inf  # soft; tie-breaks equal priorities
    bidirectional: bool = False
    share: Optional[int] = None  # items with one non-None share key promise
    #                              to bind the SAME parameter stack at
    #                              execution (e.g. requests of one served
    #                              model), so their same-layer cells may
    #                              concatenate on B into one launch row
    #                              (cross-B packing) instead of occupying
    #                              separate G rows
    families: Optional[tuple] = None  # per-layer family, length L; None ->
    #                              homogeneous (family,) * L.  A mixed
    #                              lstm/gru stack wavefronts through the
    #                              same slot timeline — cells group into
    #                              launches by their OWN layer's family —
    #                              which is how the repro.rnn facade runs
    #                              heterogeneous stacks (rglru layers have
    #                              no (h, c)-state sequence kernel and
    #                              cannot appear in a mixed stack)

    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}; {FAMILIES}")
        if self.X == 0:
            object.__setattr__(self, "X", self.H)
        if min(self.B, self.H, self.L) < 1 or self.T < 0:
            raise ValueError(f"degenerate item {self}")
        if self.families is None:
            object.__setattr__(self, "families", (self.family,) * self.L)
        else:
            fams = tuple(self.families)
            object.__setattr__(self, "families", fams)
            if len(fams) != self.L:
                raise ValueError(
                    f"item {self.uid}: families has {len(fams)} entries for "
                    f"L={self.L} layers")
            bad = [f for f in fams if f not in FAMILIES]
            if bad:
                raise ValueError(
                    f"item {self.uid}: unknown families {bad}; {FAMILIES}")
            if fams[0] != self.family:
                raise ValueError(
                    f"item {self.uid}: family={self.family!r} must equal "
                    f"families[0]={fams[0]!r}")
            if len(set(fams)) > 1:
                if not set(fams) <= {"lstm", "gru"}:
                    raise ValueError(
                        f"item {self.uid}: mixed-family stacks support "
                        f"lstm/gru layers only, got {sorted(set(fams))}")
                if self.bidirectional:
                    raise ValueError(
                        f"item {self.uid}: mixed-family stacks cannot be "
                        "bidirectional")

    @property
    def gates(self) -> int:
        """Widest gate axis across the item's layers — what tiling / VMEM
        sizing must budget for (exact for homogeneous items)."""
        return max(GATES[f] for f in self.families)

    @property
    def dirs(self) -> int:
        """Directions per layer: 2 for bidirectional stacks, whose every
        layer contributes a fwd and a bwd cell walk to the planner's
        interleaved timeline (each with its own parameter half and
        recurrent state)."""
        return 2 if self.bidirectional else 1

    @property
    def heterogeneous(self) -> bool:
        return len(set(self.families)) > 1

    def order_key(self):
        """Admission / intra-slot ordering: priority, then deadline, then
        uid (total, deterministic)."""
        return (self.priority, self.deadline_us, self.uid)

    # ------------------------------------------------------------------
    @classmethod
    def from_config(cls, cfg: ModelConfig, T: int, *, B: int = 1,
                    uid: int = 0, priority: int = 0,
                    deadline_us: float = math.inf,
                    rnn_family: str = "lstm",
                    share: Optional[int] = None) -> "WorkItem":
        """Extract the recurrent workload of ``cfg`` as a WorkItem."""
        if cfg.family == "rnn":
            return cls(uid=uid, family=rnn_family, B=B, T=T,
                       H=cfg.lstm_hidden, L=cfg.n_layers, X=cfg.lstm_input,
                       dtype=cfg.dtype, priority=priority,
                       deadline_us=deadline_us,
                       bidirectional=cfg.bidirectional, share=share)
        if cfg.family in ("ssm", "hybrid"):
            kinds = cfg.layer_kinds()
            n_rec = sum(1 for k in kinds if k != "attn") or cfg.n_layers
            return cls(uid=uid, family="rglru", B=B, T=T,
                       H=cfg.rglru_width or cfg.d_model, L=n_rec,
                       X=cfg.rglru_width or cfg.d_model, dtype=cfg.dtype,
                       priority=priority, deadline_us=deadline_us,
                       share=share)
        raise ValueError(
            f"config {cfg.name!r} (family {cfg.family!r}) has no recurrent "
            "core to dispatch")
