"""The dispatch planner: WorkItems -> an explicit, inspectable DispatchPlan.

This is the software rendition of SHARP's intelligent tile-based dispatch
(§5) plus dynamic reconfiguration (§6): for every admitted item the planner

  1. *tiles* it — the paper tile-engine K for its MVMs via
     ``core.autotune.table().tile`` (offline table, §6.2.2), the Pallas MVM
     block via ``table().block``, and the sequence kernel's T-stripe via
     ``table().seq_block`` (VMEM-budgeted, per gate count);
  2. *schedules* it — scores candidate execution shapes (per-layer
     ``fused`` = one launch per layer, ``wavefront`` = anti-diagonal
     (layer, time-chunk) cells, ``per_step`` fallback = one launch per
     cell) with ``core.perfmodel`` cycle estimates and picks the cheapest;
  3. *packs* it — cells of different items that share a launch signature
     (family, H, B, chunk length, dtype) are co-scheduled into one global
     slot timeline, each slot one G-batched sequence-kernel launch, so
     independent recurrences hide each other's serial dependencies.

The emitted ``DispatchPlan`` is a plain ordered tuple of ``Slot``s — every
launch the executor will make, with its tile/block configuration — so plans
can be printed, diffed, and unit-tested for determinism and launch counts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Sequence, Tuple

from repro.core.autotune import table
from repro.core.perfmodel import (Design, LAUNCH_CYCLES,
                                  per_step_plan_cycles, stack_plan_cycles)
from repro.core.schedules import wavefront_active
from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint
from repro.dispatch.workitem import WorkItem
from repro.kernels.common import cdiv

DEFAULT_MACS = 16384  # planner's reference tile-engine budget (paper 16K)


@dataclass(frozen=True)
class Cell:
    """One (item, layer, time-chunk) unit of recurrent work."""
    uid: int
    layer: int
    chunk: int


@dataclass(frozen=True)
class Slot:
    """One batched kernel launch: G independent cells sharing a signature.

    ``wave`` is the anti-diagonal index (all of a slot's cells have
    layer + chunk == wave for their item); slots execute in ``index``
    order and every cell's dependencies ran in an earlier wave.
    """
    index: int
    wave: int
    family: str
    H: int
    B: int
    chunk_len: int          # timesteps per cell in this launch
    dtype: str
    tile_k: int             # paper tile-engine K for this launch's MVMs
    mvm_block: Tuple[int, int]  # Pallas (bk, bh) block for the cell MVM
    cells: Tuple[Cell, ...]

    @property
    def g(self) -> int:
        return len(self.cells)

    def describe(self) -> str:
        cells = " ".join(f"({c.uid},l{c.layer},k{c.chunk})"
                         for c in self.cells)
        return (f"slot {self.index:3d} wave {self.wave:3d}  "
                f"{self.family} H{self.H} B{self.B} bt{self.chunk_len} "
                f"K{self.tile_k} blk{self.mvm_block}  G={self.g}  {cells}")


@dataclass(frozen=True)
class ItemPlan:
    """Per-item planning outcome (shape, chosen schedule, tiling)."""
    item: WorkItem
    schedule: str           # wavefront | fused | per_step | per_layer
    block_t: int            # chosen T-stripe (0 for non-striped fallbacks)
    nk: int                 # number of time chunks
    tile_k: int
    mvm_block: Tuple[int, int]
    naive_launches: int     # launches if this item ran alone
    est_cycles: float       # perfmodel score of the chosen schedule

    @property
    def uid(self) -> int:
        return self.item.uid

    @property
    def executable(self) -> bool:
        """False for plan-only items (priced for admission control but not
        runnable by the executor): multi-layer rglru, whose inter-layer
        block mixing lives outside the recurrence dispatcher."""
        return not (self.item.family == "rglru" and self.item.L != 1)

    def describe(self) -> str:
        it = self.item
        tag = "" if self.executable else " [plan-only]"
        return (f"item {it.uid:3d}  {it.family} H{it.H} L{it.L} B{it.B} "
                f"T{it.T} X{it.X} prio{it.priority}  -> {self.schedule} "
                f"bt={self.block_t} nk={self.nk} K={self.tile_k} "
                f"blk={self.mvm_block} launches={self.naive_launches} "
                f"est={self.est_cycles:.0f}cy{tag}")


@dataclass(frozen=True)
class DispatchPlan:
    items: Tuple[ItemPlan, ...]
    slots: Tuple[Slot, ...]     # the packed timeline (wavefront/fused items)
    external: Tuple[int, ...]   # uids executed outside the slot timeline
    macs: int

    def item(self, uid: int) -> ItemPlan:
        for ip in self.items:
            if ip.uid == uid:
                return ip
        raise KeyError(uid)

    @property
    def launches(self) -> int:
        ext = sum(ip.naive_launches for ip in self.items
                  if ip.uid in self.external)
        return len(self.slots) + ext

    @property
    def naive_launches(self) -> int:
        """Launch count if every item ran alone (no cross-item packing)."""
        return sum(ip.naive_launches for ip in self.items)

    @property
    def est_cycles(self) -> float:
        return sum(ip.est_cycles for ip in self.items)

    def describe(self) -> str:
        lines = [f"DispatchPlan: {len(self.items)} items, "
                 f"{len(self.slots)} packed slots, {self.launches} launches "
                 f"(naive {self.naive_launches}), macs={self.macs}"]
        lines += [ip.describe() for ip in self.items]
        lines += [s.describe() for s in self.slots]
        if self.external:
            lines.append(f"external (unpacked fallback): {self.external}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# per-item scheduling
# ---------------------------------------------------------------------------


def _chunk_lens(T: int, bt: int) -> List[int]:
    """Chunk lengths of a T walk striped at bt (last chunk = remainder)."""
    if T == 0:
        return []
    nk = cdiv(T, bt)
    out = [bt] * (nk - 1)
    out.append(T - (nk - 1) * bt)
    return out


def _item_cells(ip: ItemPlan) -> Dict[int, List[Tuple[int, Cell]]]:
    """wave -> [(chunk_len, Cell)] for one packable item."""
    it = ip.item
    lens = _chunk_lens(it.T, ip.block_t)
    nk = len(lens)
    waves: Dict[int, List[Tuple[int, Cell]]] = {}
    for s in range(it.L + nk - 1):
        lo, hi = wavefront_active(s, it.L, nk)
        for l in range(lo, hi + 1):
            k = s - l
            waves.setdefault(s, []).append(
                (lens[k], Cell(uid=it.uid, layer=l, chunk=k)))
    return waves


def _pack(item_plans: Sequence[ItemPlan], macs: int) -> Tuple[Slot, ...]:
    """Merge items' wavefront cells into one slot timeline.

    Every slot is one G-batched launch; cells group by launch signature
    (family, H, B, chunk_len, dtype).  Deterministic: slots ordered by
    (wave, signature), cells within a slot by item order_key then layer.
    """
    by_item = [(ip, _item_cells(ip)) for ip in item_plans]
    n_waves = max((max(w) + 1 for _, w in by_item if w), default=0)
    slots: List[Slot] = []
    for s in range(n_waves):
        groups: Dict[Tuple, List[Tuple[Tuple, Cell]]] = {}
        for ip, waves in by_item:
            it = ip.item
            for chunk_len, cell in waves.get(s, []):
                sig = (it.family, it.H, it.B, chunk_len, it.dtype)
                groups.setdefault(sig, []).append(
                    (it.order_key() + (cell.layer,), cell))
        for sig in sorted(groups, key=str):
            family, H, B, chunk_len, dtype = sig
            cells = tuple(c for _, c in sorted(groups[sig],
                                               key=lambda kc: kc[0]))
            # the slot's own launch shape: its in-kernel MVM is the
            # recurrent half (H x gates·H) per cell — X-independent, so
            # cells of different-X items share this config honestly
            gates = {"lstm": 4, "gru": 3}.get(family, 1)
            tile_k = table().tile(gates * H, H, macs).k if macs else 0
            mvm_block = table().block(H, H, vmem_budget=2 * 2**20)
            slots.append(Slot(
                index=len(slots), wave=s, family=family, H=H, B=B,
                chunk_len=chunk_len, dtype=dtype, tile_k=tile_k,
                mvm_block=mvm_block, cells=cells))
    return tuple(slots)


def _schedule_item(it: WorkItem, macs: int, design: Design) -> ItemPlan:
    """Tile + score one item: pick fused/wavefront striping or fallback."""
    tile_k = table().tile(it.gates * it.H, max(it.H, it.X), macs).k
    mvm_block = table().block(it.H, it.H, vmem_budget=2 * 2**20)

    if it.family == "rglru":
        # diagonal recurrence: one fused scan launch per recurrent layer,
        # no cross-layer wavefront (layers are separated by block mixing
        # that lives outside the dispatcher)
        est = stack_plan_cycles("rglru", it.H, it.X, it.T, it.L, design, nk=1)
        return ItemPlan(item=it, schedule="fused", block_t=it.T or 1, nk=1,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=it.L, est_cycles=est)

    if it.bidirectional:
        # fwd/bwd break the wavefront time alignment (core.schedules):
        # per-layer fused fallback, 2 launches per layer
        est = 2 * stack_plan_cycles(it.family, it.H, it.X, it.T, it.L,
                                    design, nk=1)
        return ItemPlan(item=it, schedule="per_layer", block_t=0, nk=1,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=2 * it.L, est_cycles=est)

    if it.T == 0:
        return ItemPlan(item=it, schedule="fused", block_t=1, nk=0,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=0, est_cycles=0.0)

    bt0 = table().seq_block(it.T, it.B, it.H, gates=it.gates)
    cands = sorted({min(it.T, bt0), min(it.T, max(1, bt0 // 2)),
                    min(it.T, bt0 * 2), it.T})
    # wider-than-bt0 candidates must still respect the sequence kernels'
    # VMEM working-set bound the autotune table enforces
    cands = [bt for bt in cands
             if bt <= 1 or seq_block_footprint(bt, it.B, it.H,
                                               gates=it.gates)
             <= SEQ_VMEM_BUDGET] or [min(it.T, bt0)]
    scored = []
    for bt in cands:
        nk = cdiv(it.T, bt)
        est = stack_plan_cycles(it.family, it.H, it.X, it.T, it.L,
                                design, nk=nk)
        scored.append((est, -bt, bt, nk, "wavefront" if nk > 1 else "fused"))
    est_ps = per_step_plan_cycles(it.family, it.H, it.X, it.T, it.L, design)
    scored.append((est_ps, 0, 0, it.T, "per_step"))
    est, _, bt, nk, sched = min(scored)

    if sched == "per_step":
        # lstm per_step runs one cell-kernel launch per (layer, step); gru
        # has no per-step pallas kernel (pure-jnp scan -> zero launches)
        n = it.L * it.T if it.family == "lstm" else 0
        return ItemPlan(item=it, schedule="per_step", block_t=0, nk=it.T,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=n, est_cycles=est)
    ip = ItemPlan(item=it, schedule=sched, block_t=bt, nk=nk, tile_k=tile_k,
                  mvm_block=mvm_block, naive_launches=0, est_cycles=est)
    return _with_naive(ip)


def _with_naive(ip: ItemPlan) -> ItemPlan:
    """naive_launches = this item's own slot count when packed alone."""
    from dataclasses import replace

    alone = _pack([replace(ip, naive_launches=0)], macs=0)
    return replace(ip, naive_launches=len(alone))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def plan(items: Iterable[WorkItem], *, macs: int = DEFAULT_MACS,
         align_stripes: bool = True) -> DispatchPlan:
    """Plan a batch of WorkItems into an explicit DispatchPlan.

    ``align_stripes``: items that could share launches (same family/H/B/
    dtype) re-align to a common T-stripe when the perfmodel says the
    re-striping cost is worth the packing (scored, not assumed).
    """
    items = sorted(items, key=WorkItem.order_key)
    if len({it.uid for it in items}) != len(items):
        raise ValueError("duplicate WorkItem uids")
    design = Design(macs=macs, schedule="unfolded")

    plans = {it.uid: _schedule_item(it, macs, design) for it in items}

    if align_stripes:
        _align_group_stripes(items, plans, design)

    packable, external = [], []
    for it in items:
        ip = plans[it.uid]
        if ip.schedule in ("wavefront", "fused") and it.family != "rglru" \
                and it.T > 0:
            packable.append(ip)
        else:
            external.append(ip.uid)

    slots = _pack(packable, macs)
    return DispatchPlan(items=tuple(plans[it.uid] for it in items),
                        slots=slots, external=tuple(external), macs=macs)


def _align_group_stripes(items: Sequence[WorkItem],
                         plans: Dict[int, ItemPlan],
                         design: Design) -> None:
    """Re-stripe packable same-signature items to one shared block_t.

    Candidate stripes are the members' chosen ones; each candidate is
    scored as the group's summed perfmodel cycles MINUS a launch credit
    for the cells that would merge into shared launches under that stripe
    (computed by actually packing the trial plans) — so the planner only
    re-stripes when the dependency structure genuinely lets items hide
    each other's launches."""
    from dataclasses import replace

    groups: Dict[Tuple, List[WorkItem]] = {}
    for it in items:
        ip = plans[it.uid]
        if ip.schedule in ("wavefront", "fused") and it.family != "rglru" \
                and it.T > 0 and not it.bidirectional:
            groups.setdefault((it.family, it.H, it.B, it.dtype), []).append(it)

    def trial_plans(members, bt):
        out = []
        for m in members:
            mbt = min(bt, m.T) if bt else plans[m.uid].block_t
            nk = cdiv(m.T, mbt)
            est = stack_plan_cycles(m.family, m.H, m.X, m.T, m.L, design,
                                    nk=nk)
            out.append(replace(plans[m.uid], block_t=mbt, nk=nk,
                               schedule="wavefront" if nk > 1 else "fused",
                               est_cycles=est))
        return out

    def group_cost(trial):
        naive = sum(len(_pack([t], 0)) for t in trial)
        packed = len(_pack(trial, 0))
        return (sum(t.est_cycles for t in trial)
                - LAUNCH_CYCLES * (naive - packed))

    for sig, members in groups.items():
        if len(members) < 2:
            continue
        # bt=0 keeps every member's own choice (the no-alignment baseline)
        cands = [0] + sorted({plans[m.uid].block_t for m in members})
        best = min(cands, key=lambda bt: (group_cost(trial_plans(members, bt)),
                                          bt))
        for t in trial_plans(members, best):
            plans[t.uid] = _with_naive(t)
