"""The dispatch planner: WorkItems -> an explicit, inspectable DispatchPlan.

This is the software rendition of SHARP's intelligent tile-based dispatch
(§5) plus dynamic reconfiguration (§6): for every admitted item the planner

  1. *tiles* it — the paper tile-engine K for its MVMs via
     ``core.autotune.table().tile`` (offline table, §6.2.2), the Pallas MVM
     block via ``table().block``, and the sequence kernel's T-stripe via
     ``table().seq_block`` (VMEM-budgeted, per gate count);
  2. *schedules* it — scores candidate execution shapes (per-layer
     ``fused`` = one launch per layer, ``wavefront`` = anti-diagonal
     (layer, time-chunk) cells, ``per_step`` fallback = one launch per
     cell) with ``core.perfmodel`` cycle estimates and picks the cheapest;
  3. *packs* it — cells of different items that share a launch signature
     (family, H, chunk length, dtype) are co-scheduled into one global
     slot timeline, each slot one G-batched sequence-kernel launch, so
     independent recurrences hide each other's serial dependencies.
     Cross-B packing goes further: same-layer cells of parameter-sharing
     items concatenate on B into one launch row, and ragged widths pad
     into one slot (in-kernel masked) when the perfmodel scores the
     widened launch cheaper than an extra one.

Bidirectional stacks are first-class in the packed timeline (ISSUE-5):
each bidirectional layer contributes a fwd cell walk (time-ascending
chunks) and a bwd walk (time-descending) interleaved into one wave
timeline — the two directions of a wave are data-independent and G-merge
into a single launch (and cross-B pack with other requests), instead of
the retired per-layer fused fallback that launched each direction of each
layer on its own with no packing at all.

``plan_decode`` plans a serving decode tick: T=1 items over one shared
stack become a single *chained* slot — one launch walks the L dependent
layer cells in grid order with the inter-layer value in VMEM scratch —
instead of L per-layer launches.

The emitted ``DispatchPlan`` is a plain ordered tuple of ``Slot``s — every
launch the executor will make, with its tile/block configuration — so plans
can be printed, diffed, and unit-tested for determinism and launch counts.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass, replace
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.autotune import table
from repro.core.perfmodel import (Design, LAUNCH_CYCLES,
                                  bidir_stack_plan_cycles, decode_plan_cycles,
                                  per_step_plan_cycles, slot_launch_cycles,
                                  stack_plan_cycles)
from repro.core.schedules import wavefront_active
from repro.core.tiling import SEQ_VMEM_BUDGET, seq_block_footprint
from repro.dispatch.workitem import GATES, WorkItem
from repro.kernels.common import cdiv
from repro.runtime.obs import NULL_TRACER, as_tracer, slot_signature

DEFAULT_MACS = 16384  # planner's reference tile-engine budget (paper 16K)


@dataclass(frozen=True)
class Cell:
    """One (item, layer, time-chunk, direction) unit of recurrent work.

    ``direction`` is "fwd" for unidirectional items and the forward half of
    bidirectional layers; "bwd" cells walk their chunk in *descending* time
    (the executor feeds the sequence kernel the time-reversed chunk slice
    and flips the produced stripe back — exact, including remainders)."""
    uid: int
    layer: int
    chunk: int
    direction: str = "fwd"


@dataclass(frozen=True)
class Slot:
    """One batched kernel launch: G independent rows sharing a signature.

    Each entry of ``groups`` is one launch row (one g of the G-batched
    sequence kernel): ordinarily a single cell, but under cross-B packing
    several same-layer cells of parameter-sharing items (WorkItem.share)
    concatenated on B.  ``group_b`` records each row's valid batch width;
    rows narrower than ``B`` are padded and masked in-kernel (ragged-B),
    so padded rows are exact no-ops.

    ``wave`` is the anti-diagonal index (all of a slot's cells have
    layer + chunk == wave for their item); slots execute in ``index``
    order and every cell's dependencies ran in an earlier wave.  The one
    exception is ``chained`` slots (T=1 decode): their groups are the L
    *serially dependent* layer cells of one tick, executed in group order
    inside ONE launch (the layer chain runs through VMEM scratch), so the
    whole tick is a single launch instead of L.
    """
    index: int
    wave: int
    family: str
    H: int
    B: int                  # the launch's (padded) batch width per row
    chunk_len: int          # timesteps per cell in this launch
    dtype: str
    tile_k: int             # paper tile-engine K for this launch's MVMs
    mvm_block: Tuple[int, int]  # Pallas (bk, bh) block for the cell MVM
    groups: Tuple[Tuple[Cell, ...], ...]
    group_b: Tuple[int, ...]    # valid batch rows per group (<= B)
    chained: bool = False
    precision: str = "fp32"     # recurrent-weight precision of every cell
    #                             in this launch (part of the signature —
    #                             int8 and fp32 launches never share a
    #                             slot or a measured-cost entry)

    @property
    def g(self) -> int:
        return len(self.groups)

    @property
    def cells(self) -> Tuple[Cell, ...]:
        return tuple(c for grp in self.groups for c in grp)

    def signature(self) -> str:
        """The launch signature string traces and the measured-launch cost
        table key on (family, G, padded B, H, T-stripe, dtype, direction
        mix, precision, chained) — see ``runtime.obs.slot_signature``."""
        return slot_signature(self.family, self.H, self.g, self.B,
                              self.chunk_len, self.dtype,
                              directions=[c.direction for c in self.cells],
                              chained=self.chained, precision=self.precision)

    def describe(self) -> str:
        grps = " ".join(
            "[" + " ".join(
                f"({c.uid},l{c.layer},k{c.chunk}"
                + ("" if c.direction == "fwd" else ",bwd") + ")"
                for c in grp)
            + f"]b{b}" for grp, b in zip(self.groups, self.group_b))
        tag = " chained" if self.chained else ""
        return (f"slot {self.index:3d} wave {self.wave:3d}  "
                f"{self.family} H{self.H} B{self.B} bt{self.chunk_len} "
                f"K{self.tile_k} blk{self.mvm_block}  G={self.g}{tag}  {grps}")


@dataclass(frozen=True)
class ItemPlan:
    """Per-item planning outcome (shape, chosen schedule, tiling)."""
    item: WorkItem
    schedule: str           # wavefront | fused | per_step | per_layer |
    #                         decode | a forced reference schedule
    #                         (sequential/batch/intergate/unfolded — these
    #                         route external through core.schedules/core.gru)
    block_t: int            # chosen T-stripe (0 for non-striped fallbacks)
    nk: int                 # number of time chunks
    tile_k: int
    mvm_block: Tuple[int, int]
    naive_launches: int     # launches if this item ran alone
    est_cycles: float       # perfmodel score of the chosen schedule

    @property
    def uid(self) -> int:
        return self.item.uid

    @property
    def executable(self) -> bool:
        """False for plan-only items (priced for admission control but not
        runnable by the executor): multi-layer rglru, whose inter-layer
        block mixing lives outside the recurrence dispatcher."""
        return not (self.item.family == "rglru" and self.item.L != 1)

    def describe(self) -> str:
        it = self.item
        tag = "" if self.executable else " [plan-only]"
        if it.bidirectional:
            tag = " bidir" + tag
        return (f"item {it.uid:3d}  {it.family} H{it.H} L{it.L} B{it.B} "
                f"T{it.T} X{it.X} prio{it.priority}  -> {self.schedule} "
                f"bt={self.block_t} nk={self.nk} K={self.tile_k} "
                f"blk={self.mvm_block} launches={self.naive_launches} "
                f"est={self.est_cycles:.0f}cy{tag}")


@dataclass(frozen=True)
class DispatchPlan:
    items: Tuple[ItemPlan, ...]
    slots: Tuple[Slot, ...]     # the packed timeline (wavefront/fused items)
    external: Tuple[int, ...]   # uids executed outside the slot timeline
    macs: int

    def item(self, uid: int) -> ItemPlan:
        for ip in self.items:
            if ip.uid == uid:
                return ip
        raise KeyError(uid)

    @property
    def launches(self) -> int:
        ext = sum(ip.naive_launches for ip in self.items
                  if ip.uid in self.external)
        return len(self.slots) + ext

    @property
    def naive_launches(self) -> int:
        """Launch count if every item ran alone (no cross-item packing)."""
        return sum(ip.naive_launches for ip in self.items)

    @property
    def est_cycles(self) -> float:
        return sum(ip.est_cycles for ip in self.items)

    def describe(self) -> str:
        lines = [f"DispatchPlan: {len(self.items)} items, "
                 f"{len(self.slots)} packed slots, {self.launches} launches "
                 f"(naive {self.naive_launches}), macs={self.macs}"]
        lines += [ip.describe() for ip in self.items]
        lines += [s.describe() for s in self.slots]
        if self.external:
            lines.append(f"external (unpacked fallback): {self.external}")
        return "\n".join(lines)


# ---------------------------------------------------------------------------
# per-item scheduling
# ---------------------------------------------------------------------------


def _chunk_lens(T: int, bt: int) -> List[int]:
    """Chunk lengths of a T walk striped at bt (last chunk = remainder)."""
    if T == 0:
        return []
    nk = cdiv(T, bt)
    out = [bt] * (nk - 1)
    out.append(T - (nk - 1) * bt)
    return out


def bidir_wavefront_launches(L: int, T: int, bt: int) -> int:
    """Launch count of one L-layer bidirectional item packed alone at
    T-stripe ``bt``: L·nk waves (see ``_item_cells``), each merging its fwd
    and bwd cells into ONE G-batched launch — except, under ragged T, the
    two waves per layer where the remainder chunk meets a full-length chunk
    of the opposite direction (different chunk_len -> different launch
    signature).  At most 2·L·nk, the per-direction-per-chunk count, and
    strictly below it except the nk=2 ragged boundary case, where every
    wave splits (L·(2+2) == 2·L·2); divisible stripes and nk=1 give the
    full win (L·nk — at nk=1 half the retired fallback's 2·L)."""
    nk = cdiv(T, bt)
    ragged = 2 if (nk > 1 and T % bt) else 0
    return L * (nk + ragged)


def _item_cells(ip: ItemPlan) -> Dict[int, List[Tuple[int, Cell]]]:
    """wave -> [(chunk_len, Cell)] for one packable item.

    Unidirectional items wavefront on the classic anti-diagonal (layer l's
    chunk k in wave l + k).  Bidirectional items run the *interleaved*
    timeline: layer l's fwd walk visits chunks ascending, its bwd walk
    descending, over the same chunk boundaries; layer l+1's chunk k becomes
    ready only once fwd has produced chunk k AND bwd has produced chunk k
    (the concat dependency — in the bwd walk's own order that is its chunk
    nk-1-k), so the earliest-start schedule is

        wave(l, fwd, k) = l·nk + k        wave(l, bwd, k) = l·nk + (nk-1-k)

    — L·nk waves, each holding one fwd and one bwd cell of one layer, the
    two directions hiding each other's serial dependence in one G-batched
    launch (same-signature merge in ``_pack``)."""
    it = ip.item
    lens = _chunk_lens(it.T, ip.block_t)
    nk = len(lens)
    waves: Dict[int, List[Tuple[int, Cell]]] = {}
    if it.bidirectional:
        for l in range(it.L):
            for k in range(nk):
                waves.setdefault(l * nk + k, []).append(
                    (lens[k], Cell(uid=it.uid, layer=l, chunk=k)))
                waves.setdefault(l * nk + (nk - 1 - k), []).append(
                    (lens[k], Cell(uid=it.uid, layer=l, chunk=k,
                                   direction="bwd")))
        return waves
    for s in range(it.L + nk - 1):
        lo, hi = wavefront_active(s, it.L, nk)
        for l in range(lo, hi + 1):
            k = s - l
            waves.setdefault(s, []).append(
                (lens[k], Cell(uid=it.uid, layer=l, chunk=k)))
    return waves


def _slot_config(family: str, H: int, macs: int) -> Tuple[int, Tuple[int, int]]:
    """The slot's own launch shape: its in-kernel MVM is the recurrent
    half (H x gates·H) per cell — X-independent, so cells of different-X
    items share this config honestly."""
    gates = GATES.get(family, 1)
    tile_k = table().tile(gates * H, H, macs).k if macs else 0
    mvm_block = table().block(H, H, vmem_budget=2 * 2**20)
    return tile_k, mvm_block


def _active_cost_model(cost_model):
    """Normalize the planner's ``cost_model`` kwarg: the model itself when
    it can actually score (a populated table for this backend), else None
    — an EMPTY table must leave every decision on the analytic path, so
    cold-start measured mode is bit-identical to analytic mode."""
    return cost_model if (cost_model is not None
                          and cost_model.active) else None


def _slots_us(slots: Sequence[Slot], cm) -> float:
    """Measured µs of a slot timeline: the sum of each launch's cost under
    the measured cost model (exact hit -> interpolated neighbor ->
    analytic-converted fallback; see ``calib.MeasuredCostModel``)."""
    return sum(
        cm.slot_us(s.family, s.H, s.g, s.B, s.chunk_len, s.dtype,
                   dirs=[c.direction for c in s.cells], chained=s.chained,
                   precision=s.precision)
        for s in slots)


def _pack(item_plans: Sequence[ItemPlan], macs: int, *,
          cross_b: bool = True, cost_model=None) -> Tuple[Slot, ...]:
    """Merge items' wavefront cells into one slot timeline.

    Every slot is one G-batched launch; cells group by launch signature
    (family, H, chunk_len, dtype — plus B when ``cross_b`` is off).  Under
    ``cross_b``, two extra merges apply:

      * same-layer cells of parameter-sharing items (equal non-None
        ``WorkItem.share``) concatenate on B into ONE launch row — the
        recurrent MVM is identical (one U), so the rows simply widen;
      * rows of different widths may share a slot by padding to the widest
        row with in-kernel ragged-B masking — adopted only when the
        cost model says the padded walk beats the extra launch: analytic
        ``slot_launch_cycles`` (B-widened vs G-batched) by default, or
        measured µs for the same two shapes when ``cost_model`` is an
        active ``calib.MeasuredCostModel``.

    Deterministic: slots ordered by (wave, signature), rows by the lead
    cell's item order_key then layer, cells within a row likewise.
    """
    cm = _active_cost_model(cost_model)
    design = Design(macs=macs or DEFAULT_MACS, schedule="unfolded")
    by_item = [(ip, _item_cells(ip)) for ip in item_plans]
    items_by_uid = {ip.uid: ip.item for ip in item_plans}
    n_waves = max((max(w) + 1 for _, w in by_item if w), default=0)
    slots: List[Slot] = []
    for s in range(n_waves):
        sigs: Dict[Tuple, Dict[Tuple, List[Tuple[Tuple, Cell, int]]]] = {}
        for ip, waves in by_item:
            it = ip.item
            for chunk_len, cell in waves.get(s, []):
                # the launch signature carries the CELL's layer family, not
                # the item's head family — a mixed lstm/gru stack's cells
                # land in per-family slots of the same wave timeline
                fam = it.families[cell.layer]
                # direction is part of every group key: a B-concat row
                # shares ONE recurrent matrix U, and a bidirectional
                # layer's fwd/bwd halves are distinct parameters (they may
                # still share the LAUNCH — different g rows of one slot)
                # precision joins every launch signature: an int8 cell can
                # never share a launch (or a measured-cost entry) with an
                # fp32 one — the U operands have different dtypes/shapes
                if cross_b:
                    sig = (fam, it.H, chunk_len, it.dtype, it.precision)
                    gkey = (("share", it.share, cell.layer, cell.direction)
                            if it.share is not None else
                            ("solo", it.uid, cell.layer, cell.chunk,
                             cell.direction))
                else:
                    sig = (fam, it.H, it.B, chunk_len, it.dtype,
                           it.precision)
                    gkey = ("solo", it.uid, cell.layer, cell.chunk,
                            cell.direction)
                sigs.setdefault(sig, {}).setdefault(gkey, []).append(
                    (it.order_key() + (cell.layer, cell.direction), cell,
                     it.B))
        for sig in sorted(sigs, key=str):
            if cross_b:
                family, H, chunk_len, dtype, precision = sig
            else:
                family, H, _, chunk_len, dtype, precision = sig
            gates = GATES.get(family, 1)

            def fits(width: int) -> bool:
                # every item validated its block_t at its OWN B; a concat
                # row is wider, so re-check the sequence kernels' VMEM
                # working-set bound before widening (a singleton row always
                # fits by the per-item validation).  The precision-narrowed
                # weight term applies; density stays conservative at 1.0 —
                # widening never ASSUMES sparsity
                return seq_block_footprint(chunk_len, width, H, gates=gates,
                                           precision=precision) \
                    <= SEQ_VMEM_BUDGET

            rows = []  # (lead order key, cells, valid B)
            for members in sigs[sig].values():
                members.sort(key=lambda m: m[0])
                run, width = [], 0
                for m in members:
                    if run and not fits(width + m[2]):
                        rows.append((run[0][0],
                                     tuple(c for _, c, _ in run), width))
                        run, width = [], 0
                    run.append(m)
                    width += m[2]
                rows.append((run[0][0], tuple(c for _, c, _ in run), width))
            rows.sort(key=lambda r: r[0])
            widths = [b for _, _, b in rows]
            classes = sorted(set(widths))
            if len(classes) > 1:
                # B-widened (one padded launch) vs G-batched by width
                # (exact rows, one launch per width class) — scored under
                # the slot's precision discount and the cells' mean
                # skipped-tile density
                cell_dens = [items_by_uid[c.uid].layer_density(c.layer)
                             for _, cells, _ in rows for c in cells]
                dens = sum(cell_dens) / len(cell_dens)
                if cm is not None:
                    dirs = sorted({c.direction for _, cells, _ in rows
                                   for c in cells})
                    merged = cm.slot_us(family, H, len(rows), max(widths),
                                        chunk_len, dtype, dirs=dirs,
                                        precision=precision)
                    split = sum(cm.slot_us(
                        family, H, sum(1 for w in widths if w == cls), cls,
                        chunk_len, dtype, dirs=dirs, precision=precision)
                        for cls in classes)
                else:
                    merged = slot_launch_cycles(family, H, chunk_len,
                                                widths, design,
                                                precision=precision,
                                                density=dens)
                    split = sum(slot_launch_cycles(
                        family, H, chunk_len,
                        [w for w in widths if w == cls],
                        design, precision=precision, density=dens)
                        for cls in classes)
                buckets = ([rows] if merged <= split else
                           [[r for r in rows if r[2] == cls]
                            for cls in classes])
            else:
                buckets = [rows]
            tile_k, mvm_block = _slot_config(family, H, macs)
            for bucket in buckets:
                slots.append(Slot(
                    index=len(slots), wave=s, family=family, H=H,
                    B=max(b for _, _, b in bucket), chunk_len=chunk_len,
                    dtype=dtype, tile_k=tile_k, mvm_block=mvm_block,
                    groups=tuple(cells for _, cells, _ in bucket),
                    group_b=tuple(b for _, _, b in bucket),
                    precision=precision))
    return tuple(slots)


REFERENCE_SCHEDULES = ("sequential", "batch", "intergate", "unfolded")
FORCED_SCHEDULES = REFERENCE_SCHEDULES + ("wavefront", "fused", "per_step")


def _fit_stripe(bt: int, B: int, H: int, gates: int,
                precision: str = "fp32", density: float = 1.0) -> int:
    """Halve a requested T-stripe until its sequence-kernel working set
    fits the VMEM budget (shared by the forced and auto paths).  The
    precision/density-narrowed weight residency applies — an int8 item
    keeps stripes an fp32 one would have to halve."""
    while bt > 1 and seq_block_footprint(
            bt, B, H, gates=gates, precision=precision,
            density=density) > SEQ_VMEM_BUDGET:
        bt //= 2
    return bt


def _stack_est(it: WorkItem, design: Design, *, nk: int) -> float:
    """Perfmodel stack estimate, per-layer-family aware: a mixed stack's
    cost is approximated as the sum of each family's sub-stack (the slot
    timeline splits by family anyway); exact for homogeneous items."""
    return sum(stack_plan_cycles(f, it.H, it.X, it.T, n, design, nk=nk)
               for f, n in sorted(Counter(it.families).items()))


def _wave_est(it: WorkItem, design: Design, *, nk: int) -> float:
    """Perfmodel estimate of the item's packed-timeline shape at striping
    ``nk``: the anti-diagonal wavefront for unidirectional items, the
    interleaved fwd/bwd timeline for bidirectional ones (which are always
    homogeneous, so the single-family bidir model is exact)."""
    if it.bidirectional:
        return bidir_stack_plan_cycles(it.family, it.H, it.X, it.T, it.L,
                                       design, nk=nk)
    return _stack_est(it, design, nk=nk)


def _per_step_plan(it: WorkItem, design: Design, tile_k, mvm_block,
                   dirs: int = 1) -> ItemPlan:
    """lstm per_step runs one cell-kernel launch per (layer, step); gru has
    no per-step pallas kernel (pure-jnp scan -> zero launches)."""
    est = dirs * sum(per_step_plan_cycles(f, it.H, it.X, it.T, n, design)
                     for f, n in sorted(Counter(it.families).items()))
    n_lstm = sum(1 for f in it.families if f == "lstm")
    return ItemPlan(item=it, schedule="per_step", block_t=0, nk=it.T,
                    tile_k=tile_k, mvm_block=mvm_block,
                    naive_launches=dirs * n_lstm * it.T, est_cycles=est)


def _forced_plan(it: WorkItem, design: Design, force: str, force_bt: int,
                 tile_k, mvm_block) -> ItemPlan:
    """Plan one item under an explicitly requested schedule (the repro.rnn
    ``ExecutionPolicy.schedule`` preference) instead of the scorer's pick.

    Reference schedules (sequential/batch/intergate/unfolded) route
    external: the executor runs them through the pure research
    implementations in core.schedules / core.gru (zero kernel launches).
    ``fused`` is the legacy per-layer fused path (one internally-striped
    sequence-kernel launch per layer -> schedule tag "per_layer") for
    unidirectional items, and the one-wave-per-layer interleaved shape for
    bidirectional ones (whose per-layer fallback ISSUE-5 retired);
    ``wavefront`` enters the packed slot timeline at the forced (or
    autotuned) T-stripe.
    """
    dirs = it.dirs
    if force in REFERENCE_SCHEDULES:
        if force == "batch" and set(it.families) != {"lstm"}:
            raise ValueError(
                f"item {it.uid}: schedule 'batch' has no gru reference "
                f"implementation (gru schedules: sequential, intergate, "
                f"unfolded, fused)")
        d = replace(design, schedule=force)
        est = dirs * sum(
            per_step_plan_cycles(f, it.H, it.X, it.T, n, d, launch_cycles=0)
            for f, n in sorted(Counter(it.families).items()))
        return ItemPlan(item=it, schedule=force, block_t=0, nk=1,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=0, est_cycles=est)
    if force == "per_step":
        return _per_step_plan(it, design, tile_k, mvm_block, dirs=dirs)
    if force == "fused":
        if not it.bidirectional:
            # per-layer fused launches (the sequence kernel stripes
            # internally, so any T fits in one launch per layer)
            est = _stack_est(it, design, nk=1)
            return ItemPlan(item=it, schedule="per_layer", block_t=force_bt,
                            nk=1, tile_k=tile_k, mvm_block=mvm_block,
                            naive_launches=it.L, est_cycles=est)
        # bidirectional "fused" is the one-wave-per-layer shape of the
        # interleaved timeline (nk collapses to 1 when the whole T fits the
        # VMEM budget — one G=2 launch per layer, fwd and bwd merged —
        # otherwise the minimal striping that does fit)
        force_bt = force_bt or it.T
    # wavefront: forced stripe if given (VMEM-checked), else the autotuned
    # one — nk may collapse to 1, which IS the packable fused shape
    bt = _fit_stripe(min(it.T, force_bt) if force_bt else
                     table().seq_block(it.T, it.B, it.H, gates=it.gates,
                                       precision=it.precision,
                                       density=it.max_density),
                     it.B, it.H, it.gates, it.precision, it.max_density)
    nk = cdiv(it.T, bt)
    est = _wave_est(it, design, nk=nk)
    ip = ItemPlan(item=it, schedule="wavefront" if nk > 1 else "fused",
                  block_t=bt, nk=nk, tile_k=tile_k, mvm_block=mvm_block,
                  naive_launches=0, est_cycles=est)
    return _with_naive(ip)


def _per_step_us(it: WorkItem, cm, design: Design) -> float:
    """Measured µs of the per_step candidate: its lstm launches priced by
    the cost model (one cell-kernel launch per (layer, step): the G=1,
    bt=1 signature at the item's B), plus any zero-launch gru scan compute
    converted from the analytic estimate — per_step must not look free
    just because pure-jnp work never hits the launch table."""
    n_lstm = sum(1 for f in it.families if f == "lstm")
    other = it.dirs * sum(
        per_step_plan_cycles(f, it.H, it.X, it.T, n, design,
                             launch_cycles=0)
        for f, n in sorted(Counter(it.families).items()) if f != "lstm")
    launches_us = (it.dirs * n_lstm * it.T *
                   cm.slot_us("lstm", it.H, 1, it.B, 1, it.dtype,
                              precision=it.precision)
                   if n_lstm else 0.0)
    return launches_us + (cm.cycles_to_us(other) if other else 0.0)


def _schedule_item(it: WorkItem, macs: int, design: Design,
                   force: Optional[str] = None,
                   force_bt: int = 0, tracer=NULL_TRACER,
                   cost_model=None) -> ItemPlan:
    """Tile + score one item: pick fused/wavefront striping or fallback.

    With an active measured ``cost_model``, the CHOICE among candidates is
    made on measured µs — each wavefront/fused candidate is solo-packed
    into its slot timeline and priced launch by launch, per_step through
    ``_per_step_us`` — while ``est_cycles`` stays the analytic estimate of
    whatever won (one unit for all downstream cycle accounting).  The
    ``plan_candidates`` instant then records BOTH scores per candidate, so
    analytic-vs-measured divergence stays observable in traces."""
    tile_k = table().tile(it.gates * it.H, max(it.H, it.X), macs).k
    mvm_block = table().block(it.H, it.H, vmem_budget=2 * 2**20)

    if it.family == "rglru":
        if force is not None:
            raise ValueError(
                f"item {it.uid}: rglru items have no schedule override "
                "(diagonal recurrence plans per-layer fused only)")
        # diagonal recurrence: one fused scan launch per recurrent layer,
        # no cross-layer wavefront (layers are separated by block mixing
        # that lives outside the dispatcher)
        est = stack_plan_cycles("rglru", it.H, it.X, it.T, it.L, design, nk=1)
        return ItemPlan(item=it, schedule="fused", block_t=it.T or 1, nk=1,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=it.L, est_cycles=est)

    if it.T == 0:
        return ItemPlan(item=it, schedule="fused", block_t=1, nk=0,
                        tile_k=tile_k, mvm_block=mvm_block,
                        naive_launches=0, est_cycles=0.0)

    if force is not None:
        return _forced_plan(it, design, force, force_bt, tile_k, mvm_block)

    if force_bt:
        # an explicit stripe override (ExecutionPolicy.block_t) pins the
        # wavefront candidate even under "auto" — the scorer still weighs
        # it against per_step, but never re-stripes it
        cands = [_fit_stripe(min(it.T, force_bt), it.B, it.H, it.gates,
                             it.precision, it.max_density)]
    else:
        bt0 = table().seq_block(it.T, it.B, it.H, gates=it.gates,
                                precision=it.precision,
                                density=it.max_density)
        cands = sorted({min(it.T, bt0), min(it.T, max(1, bt0 // 2)),
                        min(it.T, bt0 * 2), it.T})
        # wider-than-bt0 candidates must still respect the sequence
        # kernels' VMEM working-set bound the autotune table enforces
        cands = [bt for bt in cands
                 if bt <= 1 or seq_block_footprint(
                     bt, it.B, it.H, gates=it.gates,
                     precision=it.precision, density=it.max_density)
                 <= SEQ_VMEM_BUDGET] or [min(it.T, bt0)]
    scored = []
    for bt in cands:
        nk = cdiv(it.T, bt)
        est = _wave_est(it, design, nk=nk)
        scored.append((est, -bt, bt, nk, "wavefront" if nk > 1 else "fused"))
    ps = _per_step_plan(it, design, tile_k, mvm_block, dirs=it.dirs)
    scored.append((ps.est_cycles, 0, 0, it.T, "per_step"))

    cm = _active_cost_model(cost_model)
    measured_us: Dict[Tuple[str, int], float] = {}
    if cm is not None:
        # re-rank on measured µs: price each candidate's actual launches
        for e, _, b, n, s in scored:
            if s == "per_step":
                measured_us[(s, b)] = _per_step_us(it, cm, design)
                continue
            trial = ItemPlan(item=it, schedule=s, block_t=b, nk=n,
                             tile_k=tile_k, mvm_block=mvm_block,
                             naive_launches=0, est_cycles=e)
            measured_us[(s, b)] = _slots_us(
                _pack([trial], macs, cost_model=cm), cm)
        mu, _, bt, nk, sched = min(
            (measured_us[(s, b)], negb, b, n, s)
            for _, negb, b, n, s in scored)
        est = next(e for e, _, b, n, s in scored
                   if (s, b) == (sched, bt))
    else:
        est, _, bt, nk, sched = min(scored)

    if tracer.enabled:
        # chosen-vs-rejected: every candidate the scorer weighed, so a
        # trace shows WHY a shape won (and by how little); under an active
        # measured cost model each candidate carries both scores
        tracer.instant(
            "plan_candidates", uid=it.uid, chosen=f"{sched}@bt{bt}",
            cost_model="measured" if cm is not None else "analytic",
            candidates=[
                dict({"schedule": s, "block_t": b, "nk": n,
                      "est_cycles": e},
                     **({"est_us": measured_us[(s, b)]}
                        if cm is not None else {}))
                for e, _, b, n, s in sorted(scored)])

    if sched == "per_step":
        return ps
    ip = ItemPlan(item=it, schedule=sched, block_t=bt, nk=nk, tile_k=tile_k,
                  mvm_block=mvm_block, naive_launches=0, est_cycles=est)
    return _with_naive(ip)


def _with_naive(ip: ItemPlan) -> ItemPlan:
    """naive_launches = this item's own slot count when packed alone."""
    alone = _pack([replace(ip, naive_launches=0)], macs=0)
    return replace(ip, naive_launches=len(alone))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


def validate_unique_uids(items: Sequence[WorkItem]) -> None:
    """Reject duplicate ``uid``s — the one identity rule every planner
    entry point (and the static verifier's coverage check) shares.  A uid
    names one request's row range across every slot; a duplicate would
    silently alias two requests' state.  Raises ``PlanRejected`` (a
    ``ValueError``: duplicate ids are an input error)."""
    seen = Counter(it.uid for it in items)
    dups = sorted(u for u, n in seen.items() if n > 1)
    if dups:
        from repro.runtime.errors import PlanRejected
        raise PlanRejected(f"duplicate WorkItem uids {dups}", uids=dups)


def plan(items: Iterable[WorkItem], *, macs: int = DEFAULT_MACS,
         align_stripes: bool = True, cross_b: bool = True,
         schedule: Optional[str] = None, block_t: int = 0,
         tracer=None, cost_model=None) -> DispatchPlan:
    """Plan a batch of WorkItems into an explicit DispatchPlan.

    ``align_stripes``: items that could share launches (same family/H/
    dtype) re-align to a common T-stripe when the perfmodel says the
    re-striping cost is worth the packing (scored, not assumed).

    ``cross_b``: allow cells that differ only in batch rows to share a
    launch — parameter-sharing items' same-layer cells concatenate on B,
    and ragged widths pad+mask into one slot when the perfmodel scores the
    widened launch cheaper (see ``_pack``).  Off = the launch signature
    includes B, every cell its own row (the pre-cross-B behaviour, kept as
    the benchmark baseline).

    ``schedule``: force every item onto one schedule instead of the
    scorer's pick (the repro.rnn ``ExecutionPolicy.schedule`` preference);
    ``block_t`` pins the wavefront T-stripe (honored under ``schedule=None``
    too — the scorer then only weighs the pinned stripe against per_step).
    None/0 = score freely.

    ``tracer``: an optional ``runtime.obs.Tracer`` — planning gets a
    ``plan`` span tagged with the outcome (slots/launches/est_cycles) and
    each auto-scored item emits a ``plan_candidates`` instant with its
    chosen-vs-rejected schedule scores.

    ``cost_model``: an optional ``calib.MeasuredCostModel`` — when active
    (non-empty table for this backend), schedule/block_t choice and
    ``_pack``'s merge-vs-split are decided on measured µs instead of
    analytic cycles (``plan_candidates`` records both); when None or
    cold (empty table) every decision is exactly the analytic one.
    Stripe alignment stays analytic either way (a launch-credit
    heuristic, not a launch-shape choice).
    """
    tracer = as_tracer(tracer)
    if schedule is not None and schedule not in FORCED_SCHEDULES:
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"options {FORCED_SCHEDULES}")
    items = sorted(items, key=WorkItem.order_key)
    validate_unique_uids(items)
    design = Design(macs=macs, schedule="unfolded")
    cm = _active_cost_model(cost_model)

    with tracer.span("plan", n_items=len(items),
                     schedule=schedule or "auto",
                     cost_model="measured" if cm is not None
                     else "analytic") as sp:
        plans = {it.uid: _schedule_item(it, macs, design, force=schedule,
                                        force_bt=block_t, tracer=tracer,
                                        cost_model=cm)
                 for it in items}

        # a pinned block_t is a contract — alignment must not re-stripe it
        if align_stripes and schedule is None and not block_t:
            _align_group_stripes(items, plans, design, cross_b=cross_b)

        packable, external = [], []
        for it in items:
            ip = plans[it.uid]
            if ip.schedule in ("wavefront", "fused") \
                    and it.family != "rglru" and it.T > 0:
                packable.append(ip)
            else:
                external.append(ip.uid)

        slots = _pack(packable, macs, cross_b=cross_b, cost_model=cm)
        out = DispatchPlan(items=tuple(plans[it.uid] for it in items),
                           slots=slots, external=tuple(external), macs=macs)
        sp.tag(slots=len(out.slots), launches=out.launches,
               est_cycles=out.est_cycles)
    return out


def plan_decode(items: Iterable[WorkItem], *, macs: int = DEFAULT_MACS,
                tracer=None, cost_model=None) -> DispatchPlan:
    """Plan one serving decode tick: each item is a T=1 evaluation of the
    SAME parameter stack (all items must carry one non-None ``share`` key)
    for some batch rows — one active request each, in the serving engine.

    A T=1 item has no wavefront (its L layer cells are serially
    dependent), so the generic planner would emit L per-layer slots.  But
    the dependence chain can run inside ONE launch — the kernel grid walks
    layers in order and the inter-layer value chains through VMEM scratch
    (ROADMAP: "a T=1 wavefront over layers is a single slot") — and the
    items' rows concatenate on B (cross-B packing, trivially un-ragged:
    every layer carries the same rows).  The choice is scored, not
    assumed: ``decode_plan_cycles`` (1 launch) vs ``stack_plan_cycles``
    at nk=1 (L launches); analytically the chain wins whenever
    LAUNCH_CYCLES > 0.

    With an active measured ``cost_model``, chained-vs-loop becomes a REAL
    decision: the chained signature's measured µs against the per-layer
    timeline's (the generic planner at schedule="wavefront", block_t=1 —
    the exact plan shape ``repro.rnn`` already executes for mixed-stack
    decode, so the executor, plancheck, and the serving engine all handle
    it unchanged).  On backends where one chained launch wall-clocks worse
    than L small launches (every interpret backend we measure), the
    measured table flips this tick to the per-layer plan.
    """
    tracer = as_tracer(tracer)
    items = sorted(items, key=WorkItem.order_key)
    if not items:
        raise ValueError("plan_decode needs at least one item")
    validate_unique_uids(items)
    head = items[0]
    if head.family not in ("lstm", "gru"):
        raise ValueError(f"no decode kernel for family {head.family!r}")
    for it in items:
        if it.T != 1:
            raise ValueError(f"item {it.uid}: decode items are T=1, got "
                             f"T={it.T}")
        if it.heterogeneous:
            raise ValueError(
                f"item {it.uid}: mixed-family stacks have no chained decode "
                "kernel; repro.rnn falls back to a per-layer T=1 plan")
        if it.share is None:
            raise ValueError(f"item {it.uid}: decode items must declare a "
                             "shared parameter stack (share=...)")
        if it.bidirectional:
            raise ValueError(
                f"item {it.uid}: bidirectional stacks have no streaming "
                f"decode — the backward walk of its {it.L} layer(s) "
                "consumes the FULL sequence, so a T=1 tick cannot exist; "
                "run whole sequences through forward()/prefill() (the "
                "interleaved-wavefront prefill path) instead")
        key = (it.family, it.H, it.L, it.X, it.dtype, it.share, it.precision)
        if key != (head.family, head.H, head.L, head.X, head.dtype,
                   head.share, head.precision):
            raise ValueError(f"item {it.uid}: decode tick items must share "
                             f"(family, H, L, X, dtype, share, precision); "
                             f"{key} != first item's")

    design = Design(macs=macs, schedule="unfolded")
    tile_k, mvm_block = _slot_config(head.family, head.H, macs)
    est_chain = decode_plan_cycles(head.family, head.H, head.X, head.L,
                                   design)
    est_layers = stack_plan_cycles(head.family, head.H, head.X, 1, head.L,
                                   design, nk=1)
    # scoring sanity, not a choice: the chain does the identical serial
    # compute with ONE launch instead of L — the estimates can only differ
    # by the (L-1)·LAUNCH_CYCLES term, so a flip means the perfmodel broke
    # (fail here with context rather than confuse the serving engine with
    # an unexpected plan shape)
    if est_chain > est_layers:
        from repro.runtime.errors import PlanInvariantError
        raise PlanInvariantError(
            f"decode cost model inverted: chained launch estimated at "
            f"{est_chain} cycles > {est_layers} for the per-layer walk, "
            f"but they differ only by the (L-1)·LAUNCH_CYCLES term "
            f"({head.family} H{head.H} L{head.L}) — the perfmodel broke",
            rule="decode-cost-model", uids=[it.uid for it in items])
    B_total = sum(it.B for it in items)

    # measured mode: chained-vs-loop is a real decision, scored in µs.
    # The per-layer alternative is the generic planner's own plan (the
    # shape repro.rnn already executes for mixed stacks) so returning it
    # changes nothing downstream but the launch count.
    cm = _active_cost_model(cost_model)
    chosen = "chained"
    alt = None
    est_chain_us = est_layers_us = None
    if cm is not None:
        est_chain_us = cm.slot_us(head.family, head.H, head.L, B_total, 1,
                                  head.dtype, chained=True,
                                  precision=head.precision)
        alt = plan(items, macs=macs, cross_b=True, schedule="wavefront",
                   block_t=1, tracer=None, cost_model=cost_model)
        est_layers_us = _slots_us(alt.slots, cm)
        if est_layers_us < est_chain_us:
            chosen = "per_layer"

    if tracer.enabled:
        cands = [{"schedule": "chained", "est_cycles": est_chain},
                 {"schedule": "per_layer", "est_cycles": est_layers}]
        if cm is not None:
            cands[0]["est_us"] = est_chain_us
            cands[1]["est_us"] = est_layers_us
        tracer.instant(
            "plan_candidates", uids=[it.uid for it in items],
            chosen=chosen,
            cost_model="measured" if cm is not None else "analytic",
            candidates=cands)

    if chosen == "per_layer":
        return alt

    with tracer.span("plan", n_items=len(items), schedule="decode",
                     est_cycles=est_chain):
        item_plans = tuple(
            ItemPlan(item=it, schedule="decode", block_t=1, nk=1,
                     tile_k=tile_k, mvm_block=mvm_block,
                     naive_launches=it.L,
                     est_cycles=est_chain / len(items))
            for it in items)
        slot = Slot(index=0, wave=0, family=head.family, H=head.H,
                    B=B_total, chunk_len=1, dtype=head.dtype, tile_k=tile_k,
                    mvm_block=mvm_block,
                    groups=tuple(tuple(Cell(uid=it.uid, layer=l, chunk=0)
                                       for it in items)
                                 for l in range(head.L)),
                    group_b=(B_total,) * head.L, chained=True,
                    precision=head.precision)
    return DispatchPlan(items=item_plans, slots=(slot,), external=(),
                        macs=macs)


def _align_group_stripes(items: Sequence[WorkItem],
                         plans: Dict[int, ItemPlan],
                         design: Design, *, cross_b: bool = True) -> None:
    """Re-stripe packable same-signature items to one shared block_t.

    Candidate stripes are the members' chosen ones; each candidate is
    scored as the group's summed perfmodel cycles MINUS a launch credit
    for the cells that would merge into shared launches under that stripe
    (computed by actually packing the trial plans) — so the planner only
    re-stripes when the dependency structure genuinely lets items hide
    each other's launches."""
    groups: Dict[Tuple, List[WorkItem]] = {}
    for it in items:
        ip = plans[it.uid]
        if ip.schedule in ("wavefront", "fused") and it.family != "rglru" \
                and it.T > 0 and not it.bidirectional \
                and not it.heterogeneous:
            # under cross-B, different-B items can share launches too.
            # heterogeneous items keep their own validated stripe (their
            # perfmodel trial costs are per-family sums, not comparable);
            # bidirectional items likewise — their interleaved timeline is
            # costed by bidir_stack_plan_cycles, and their cells still
            # pack with any same-signature wave through _pack
            sig = ((it.family, it.H, it.dtype, it.precision) if cross_b
                   else (it.family, it.H, it.B, it.dtype, it.precision))
            groups.setdefault(sig, []).append(it)

    def trial_plans(members, bt):
        out = []
        for m in members:
            mbt = min(bt, m.T) if bt else plans[m.uid].block_t
            # a cross-B group mixes batch widths: the shared stripe must
            # respect the VMEM working-set bound at each member's OWN B
            # (its original block_t was only validated there) — members the
            # stripe doesn't fit keep their own validated choice
            if mbt > 1 and seq_block_footprint(
                    mbt, m.B, m.H, gates=m.gates, precision=m.precision,
                    density=m.max_density) > SEQ_VMEM_BUDGET:
                mbt = plans[m.uid].block_t
            nk = cdiv(m.T, mbt)
            est = stack_plan_cycles(m.family, m.H, m.X, m.T, m.L, design,
                                    nk=nk)
            out.append(replace(plans[m.uid], block_t=mbt, nk=nk,
                               schedule="wavefront" if nk > 1 else "fused",
                               est_cycles=est))
        return out

    def group_cost(trial):
        naive = sum(len(_pack([t], 0, cross_b=cross_b)) for t in trial)
        packed = len(_pack(trial, 0, cross_b=cross_b))
        return (sum(t.est_cycles for t in trial)
                - LAUNCH_CYCLES * (naive - packed))

    for sig, members in groups.items():
        if len(members) < 2:
            continue
        # bt=0 keeps every member's own choice (the no-alignment baseline)
        cands = [0] + sorted({plans[m.uid].block_t for m in members})
        best = min(cands, key=lambda bt: (group_cost(trial_plans(members, bt)),
                                          bt))
        for t in trial_plans(members, best):
            plans[t.uid] = _with_naive(t)
