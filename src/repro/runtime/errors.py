"""Structured error taxonomy + fault-injection hooks for the serving path.

A production RNN service is a low-latency datacenter workload where many
requests share one packed launch — which must NOT mean they share one
failure domain.  Every fault the dispatch/rnn/serving layers can surface
is a subclass of ``ServingFault`` carrying the *ids involved* (launch slot
index, request uids), so callers can quarantine exactly the offending
work instead of unwinding the whole engine:

  * ``LaunchError``        — a kernel launch raised (or a fault-injection
                             hook made it raise); carries the slot index,
                             the uids whose cells shared the launch, and
                             the deepest fallback rung that was attempted.
  * ``NonFiniteStateError`` — recurrent state or output frames went
                             non-finite (NaN/Inf); carries the uids whose
                             rows are poisoned and where they were caught.
  * ``PlanRejected``       — a request's shape/configuration cannot be
                             served by the planned path (also a
                             ``ValueError``: rejection is an input error).
  * ``PlanInvariantError`` — a ``DispatchPlan`` failed static verification
                             (``analysis.plancheck``): a dispatch invariant
                             — coverage, wavefront readiness, packing
                             legality, resource budget — does not hold.
                             Carries the violated ``rule`` name, the slot
                             index, and the offending cell, so a CI failure
                             or a serving-side rejection names the exact
                             broken theorem instead of a launch-time
                             mystery.
  * ``RequestTimeout``     — a deadline expired; carries the uids still in
                             flight and, from the engine's
                             ``run_to_completion``, the completions already
                             finished (``.done``) so an overrun never loses
                             completed work.
  * ``QueueFull``          — bounded-admission backpressure: the engine's
                             queue is at capacity and the policy is
                             "reject".

``FaultInjector`` is the serving-path analogue of
``runtime.ft.TrainLoop.failure_at_steps``: armed with launch (slot)
indices, it makes the executor's guarded ladder raise on demand so every
recovery path — per-step re-execution, reference fallback, engine
quarantine — is provable in CPU tests.  ``ExecutionReport`` is the
per-execute() degradation record the CompiledStack folds into ``.stats``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence, Set, Tuple

#: The guarded execution ladder, shallowest first: 0 = the planned fused/
#: chained launch, 1 = per-step (per-layer for chained slots) kernel
#: launches, 2 = the non-deprecated pure-jnp reference — oracle-equal by
#: construction and unable to fail on a kernel launch.
FALLBACK_LEVELS = ("fused", "per_step", "reference")


class ServingFault(RuntimeError):
    """Base class: a structured fault naming the work it affects."""

    def __init__(self, msg: str, *, uids: Sequence[int] = (),
                 slot: Optional[int] = None):
        super().__init__(msg)
        self.uids: Tuple[int, ...] = tuple(uids)
        self.slot = slot


class LaunchError(ServingFault):
    """A kernel launch raised.  ``slot`` is the plan's slot index,
    ``uids`` the items whose cells shared the launch, ``level`` the
    deepest ladder rung attempted (a ``FALLBACK_LEVELS`` name), and
    ``injected`` whether a fault-injection hook raised it."""

    def __init__(self, msg: str, *, uids: Sequence[int] = (),
                 slot: Optional[int] = None, level: str = "fused",
                 injected: bool = False):
        super().__init__(msg, uids=uids, slot=slot)
        self.level = level
        self.injected = injected


class NonFiniteStateError(ServingFault):
    """Recurrent state / output frames went NaN or Inf.  ``where`` names
    the check point (e.g. "prompt", "prefill state", "decode frame")."""

    def __init__(self, msg: str, *, uids: Sequence[int] = (),
                 slot: Optional[int] = None, where: str = "state"):
        super().__init__(msg, uids=uids, slot=slot)
        self.where = where


class PlanRejected(ServingFault, ValueError):
    """The planned path cannot serve this request/configuration (shape,
    family, or state-surface mismatch).  Also a ValueError: rejection is
    a property of the input, not a runtime failure."""


class PlanInvariantError(ServingFault):
    """A ``DispatchPlan`` failed static verification.

    Raised by ``analysis.plancheck`` (and by planner-internal consistency
    checks) with no execution involved: ``rule`` names the violated
    invariant (one of ``analysis.plancheck.RULES`` plus the planner's
    "decode-cost-model" and the engine's "decode-active-rows"), ``slot``
    the plan slot it anchors to (None for plan-level rules), and ``cell``
    the offending ``(uid, layer, chunk, direction)`` cell when one exists.
    """

    def __init__(self, msg: str, *, rule: str, uids: Sequence[int] = (),
                 slot: Optional[int] = None, cell=None):
        super().__init__(msg, uids=uids, slot=slot)
        self.rule = rule
        self.cell = cell


class RequestTimeout(ServingFault):
    """A per-request or engine-level deadline expired.  ``done`` carries
    the completions already finished (never lose completed work on an
    overrun); ``uids`` the requests still in flight."""

    def __init__(self, msg: str, *, uids: Sequence[int] = (),
                 done: Optional[list] = None):
        super().__init__(msg, uids=uids)
        self.done = list(done) if done is not None else []


class QueueFull(ServingFault):
    """Bounded admission queue at capacity under backpressure="reject"."""


# ---------------------------------------------------------------------------
# fault injection + degradation accounting
# ---------------------------------------------------------------------------


@dataclass
class FaultInjector:
    """Makes executor launches raise on demand (CPU-provable recovery).

    ``fail_launch_at`` holds plan slot indices whose launch attempts
    raise an (injected) ``LaunchError``; ``fail_through_level`` is the
    deepest ladder rung that still fails (0 = only the fused attempt
    fails, so the per-step rung recovers; 2 = every rung fails and the
    error escapes even under ``on_fault="fallback"``).  With ``once``
    (the ``ft.failure_at_steps`` semantics) an armed index is discarded
    after its final failing rung fires, so a retry succeeds; bench/soak
    callers set ``once=False`` to degrade every call.
    """

    fail_launch_at: Set[int] = field(default_factory=set)
    fail_through_level: int = 0
    once: bool = True
    fired: List[Tuple[int, int]] = field(default_factory=list)

    @property
    def armed(self) -> bool:
        return bool(self.fail_launch_at)

    def arm(self, slots: Sequence[int], *, through_level: int = 0,
            once: bool = True) -> None:
        self.fail_launch_at = set(slots)
        self.fail_through_level = through_level
        self.once = once

    def disarm(self) -> None:
        self.fail_launch_at = set()

    def maybe_fail(self, slot_index: int, level: int,
                   uids: Sequence[int]) -> None:
        """Called by the executor before each launch attempt."""
        if slot_index not in self.fail_launch_at:
            return
        if level > self.fail_through_level:
            return
        self.fired.append((slot_index, level))
        if self.once and level >= self.fail_through_level:
            self.fail_launch_at.discard(slot_index)
        raise LaunchError(
            f"injected launch fault: slot {slot_index} at ladder level "
            f"{FALLBACK_LEVELS[level]!r} (uids {sorted(set(uids))})",
            uids=uids, slot=slot_index, level=FALLBACK_LEVELS[level],
            injected=True)


@dataclass
class ExecutionReport:
    """Per-execute() degradation record (folded into ``StackStats``).

    ``degraded_launches`` counts slots that needed any fallback rung;
    ``fallback_level`` is the deepest rung used (index into
    ``FALLBACK_LEVELS``); ``faults`` is the human-readable fault trail
    (one entry per recovered launch failure)."""

    degraded_launches: int = 0
    fallback_level: int = 0
    faults: List[str] = field(default_factory=list)

    def record(self, slot_index: int, level: int, cause: Exception) -> None:
        self.degraded_launches += 1
        self.fallback_level = max(self.fallback_level, level)
        self.faults.append(
            f"slot {slot_index}: fell back to "
            f"{FALLBACK_LEVELS[level]!r} after {cause!r}")


__all__ = ["ServingFault", "LaunchError", "NonFiniteStateError",
           "PlanRejected", "PlanInvariantError", "RequestTimeout",
           "QueueFull", "FaultInjector", "ExecutionReport",
           "FALLBACK_LEVELS"]
