"""Fault-tolerant, elastic training runtime.

The loop a 1000-node deployment actually needs, testable on CPU:

  * checkpoint/restart — async checkpoints every N steps; on ANY step
    failure the loop restores the last committed checkpoint and replays
    (the data pipeline is a pure function of the step index, so replay is
    exact).
  * elasticity — restore re-shards to whatever mesh the restarted job got
    (``Checkpointer.restore`` device_puts per the *new* shardings), so
    losing a pod degrades to the single-pod mesh instead of halting.
  * straggler mitigation — per-step wall-time EWMA watchdog; steps slower
    than ``straggler_factor``x the EWMA are logged and counted, and the
    policy hook fires (on real fleets: re-shard away from the slow host;
    here: the hook is observable state for tests).
  * fault injection — ``failure_at_steps`` raises inside the loop to let
    tests prove the recovery path end-to-end.
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, Iterable, List, Optional, Sequence

import numpy as np

from repro.checkpoint import Checkpointer
from repro.runtime import obs

log = logging.getLogger("repro.runtime")


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 3
    max_restarts: int = 3
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


class StragglerWatchdog:
    def __init__(self, factor: float, alpha: float):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged: List[int] = []

    def observe(self, step: int, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged.append(step)
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, self.ewma)
        # EWMA excludes flagged outliers so one straggler doesn't mask the next
        if not slow:
            self.ewma = dt if self.ewma is None else (
                self.alpha * dt + (1 - self.alpha) * self.ewma)
        return slow


class TrainLoop:
    """Drives (params, opt_state) through ``train_step`` with FT semantics."""

    def __init__(self, train_step: Callable, batch_fn: Callable[[int], Any],
                 cfg: FTConfig, shardings: Any = None):
        self.train_step = train_step
        self.batch_fn = batch_fn  # step -> device-ready batch (pure)
        self.cfg = cfg
        self.shardings = shardings  # (param_sh, opt_sh) for elastic restore
        self.ckpt = Checkpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.watchdog = StragglerWatchdog(cfg.straggler_factor, cfg.ewma_alpha)
        self.restarts = 0
        self.metrics_history: List[Dict] = []
        self.failure_at_steps: set = set()  # fault injection (tests)

    # ------------------------------------------------------------------
    def run(self, params, opt_state, start_step: int, num_steps: int):
        state = {"params": params, "opt": opt_state}
        step = start_step
        end = start_step + num_steps
        while step < end:
            try:
                state, step = self._run_span(state, step, end)
            except Exception as e:  # noqa: BLE001 — any step failure
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d",
                          getattr(self, "_current_step", step), e,
                          self.restarts, self.cfg.max_restarts)
                if self.restarts > self.cfg.max_restarts:
                    raise
                state, step = self._restore(state)
        self.ckpt.save(step, self._saveable(state), blocking=True)
        return state["params"], state["opt"], step

    def _run_span(self, state, step, end):
        while step < end:
            self._current_step = step
            if step in self.failure_at_steps:
                self.failure_at_steps.discard(step)
                raise RuntimeError(f"injected fault at step {step}")
            t0 = obs.monotonic_s()
            batch = self.batch_fn(step)
            params, opt, metrics = self.train_step(state["params"],
                                                   state["opt"], batch)
            obs.fence(params)
            state = {"params": params, "opt": opt}
            dt = obs.monotonic_s() - t0
            self.watchdog.observe(step, dt)
            self.metrics_history.append(
                {"step": step, "time_s": dt,
                 **{k: float(np.asarray(v)) for k, v in metrics.items()}})
            step += 1
            if step % self.cfg.ckpt_every == 0:
                self.ckpt.save(step, self._saveable(state))
        return state, step

    def _saveable(self, state):
        return {"params": state["params"], "opt": state["opt"]}

    def _restore(self, like_state):
        self.ckpt.wait()
        last = self.ckpt.latest_step()
        if last is None:
            raise RuntimeError("no checkpoint to restore from")
        sh = None
        if self.shardings is not None and self.shardings[0] is not None:
            sh = {"params": self.shardings[0], "opt": self.shardings[1]}
        tree = self.ckpt.restore(last, self._saveable(like_state), sh)
        log.info("restored step %d", last)
        return {"params": tree["params"], "opt": tree["opt"]}, last
