"""Zero-dependency tracing + metrics for the planned execution path.

The ROADMAP's standing caveat is that the analytic ``perfmodel`` optimizes
a proxy nobody has measured: several launch-count wins are wall-clock
losses and nothing records per-launch timing to say why.  This module is
the instrument — it measures the pipeline the perfmodel only estimates,
and produces the calibration signal the future measured-launch cost model
will consume.

Three cooperating pieces, stdlib-only (``time`` + ``json``; jax is
imported lazily, only to fence):

``Tracer``
    Records nested wall-clock spans (``plan``, ``hoist``, ``slot_launch``,
    ``fallback_rung``, ``decode_tick``, ``admit``, ``request`` ...) tagged
    with the slot signature (family, G, B, H, block_t, direction,
    chained), plan id, and request uids.  Launch spans are *fenced*: the
    instrumented call sites run ``tracer.fence(result)`` —
    ``jax.block_until_ready`` — inside the span, so a span's duration is
    the wall-clock of the work it encloses, not of its async dispatch.
    Exports: ``export_chrome_trace(path)`` (chrome://tracing /
    ``about:tracing`` trace-event JSON), ``snapshot()`` (machine-readable
    dict), ``describe()`` (text, merged into ``CompiledStack.describe()``).

``MetricsRegistry``
    Counters and streaming histograms (bounded reservoir; nearest-rank
    p50/p90/p99) for launch latency per slot signature, decode tick
    latency, queue depth, slot occupancy, degraded launches.

``LaunchCostTable``
    The predicted-vs-measured record: per slot signature, the perfmodel's
    ``est_cycles`` next to the measured µs distribution, and their ratio
    (cycles per measured µs — flat across signatures iff the analytic
    model ranks shapes correctly; the spread IS the miscalibration).
    ``save()`` persists a ``signature -> measured µs`` table next to the
    autotune table (``artifacts/launch_costs.json``) for the
    measured-launch cost model to consume as its warm-start.

The whole subsystem is opt-in via ``ExecutionPolicy(trace=True)``.  Off
(the default), every instrumented call site holds the module-level
``NULL_TRACER`` whose ``span()`` returns one reused no-op context manager
and whose ``fence()`` is the identity — no events, no fencing, no jax
import, and executor outputs bit-identical to the un-instrumented code
(asserted in tests/rnn/test_obs.py and priced in BENCH_dispatch's
``obs_*`` rows).

``measure_us`` is the one benchmark timer (warmup exclusion +
``block_until_ready`` fencing + median/min reduction) — the bench suites
route through it so bench medians and traced span durations share a
single measurement code path.
"""
from __future__ import annotations

import json
import math
import os
import statistics
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

#: persisted measured-launch table, next to artifacts/autotune_table.json
LAUNCH_COSTS_PATH = os.path.join("artifacts", "launch_costs.json")


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


class Counter:
    """A monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def add(self, n: int = 1) -> None:
        self.value += n


class Histogram:
    """Streaming distribution: exact count/sum/min/max plus a bounded
    reservoir the quantiles are computed over (Vitter's algorithm R with a
    deterministic LCG, so identical observation streams give identical
    snapshots).  Quantiles are nearest-rank over the retained sample —
    exact while ``count <= cap``."""

    __slots__ = ("count", "total", "min", "max", "_sample", "_cap", "_lcg")

    def __init__(self, cap: int = 2048):
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf
        self._sample: List[float] = []
        self._cap = cap
        self._lcg = 0x2545F4914F6CDD1D

    def observe(self, value: float) -> None:
        value = float(value)
        self.count += 1
        self.total += value
        self.min = min(self.min, value)
        self.max = max(self.max, value)
        if len(self._sample) < self._cap:
            self._sample.append(value)
            return
        # deterministic reservoir replacement (64-bit LCG)
        self._lcg = (self._lcg * 6364136223846793005 + 1442695040888963407) \
            & 0xFFFFFFFFFFFFFFFF
        j = self._lcg % self.count
        if j < self._cap:
            self._sample[j] = value

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile over the retained sample (q in [0, 1])."""
        if not self._sample:
            return 0.0
        vals = sorted(self._sample)
        rank = max(1, math.ceil(q * len(vals)))
        return vals[min(rank, len(vals)) - 1]

    def snapshot(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0}
        return {"count": self.count, "mean": self.mean,
                "min": self.min, "max": self.max,
                "p50": self.quantile(0.50), "p90": self.quantile(0.90),
                "p99": self.quantile(0.99)}

    def describe(self) -> str:
        if not self.count:
            return "n=0"
        return (f"n={self.count} mean={self.mean:.1f} p50="
                f"{self.quantile(.5):.1f} p90={self.quantile(.9):.1f} "
                f"p99={self.quantile(.99):.1f} max={self.max:.1f}")


class MetricsRegistry:
    """Named counters + histograms with text and dict export."""

    def __init__(self):
        self._counters: Dict[str, Counter] = {}
        self._hists: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter()
        return c

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            h = self._hists[name] = Histogram()
        return h

    def snapshot(self) -> Dict[str, Any]:
        return {
            "counters": {k: c.value
                         for k, c in sorted(self._counters.items())},
            "histograms": {k: h.snapshot()
                           for k, h in sorted(self._hists.items())},
        }

    def describe(self) -> str:
        lines = []
        if self._counters:
            lines.append("counters: " + " ".join(
                f"{k}={c.value}" for k, c in sorted(self._counters.items())))
        for k, h in sorted(self._hists.items()):
            lines.append(f"{k}: {h.describe()}")
        return "\n".join(lines) if lines else "metrics: (none)"


# ---------------------------------------------------------------------------
# predicted vs measured
# ---------------------------------------------------------------------------


class LaunchCostTable:
    """Per-slot-signature measured launch cost next to the perfmodel's
    estimate.  ``cycles_per_us = est_cycles / median measured µs`` is the
    calibration signal: if the analytic model were right up to one clock
    constant, the ratio would be flat across signatures — the spread is
    exactly what the measured-launch cost model (ROADMAP) must correct."""

    def __init__(self):
        self._est: Dict[str, float] = {}
        self._us: Dict[str, Histogram] = {}

    def record(self, sig: str, est_cycles: float, us: float) -> None:
        self._est[sig] = float(est_cycles)
        h = self._us.get(sig)
        if h is None:
            h = self._us[sig] = Histogram()
        h.observe(us)

    def __len__(self) -> int:
        return len(self._us)

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        out = {}
        for sig in sorted(self._us):
            h = self._us[sig]
            med = h.quantile(0.5)
            out[sig] = {"n": h.count, "med_us": med,
                        "p90_us": h.quantile(0.9),
                        "est_cycles": self._est[sig],
                        "cycles_per_us": (self._est[sig] / med
                                          if med > 0 else 0.0)}
        return out

    def describe(self) -> str:
        rows = self.snapshot()
        if not rows:
            return "launch costs: (none measured)"
        lines = ["launch costs (predicted vs measured):"]
        for sig, r in rows.items():
            lines.append(
                f"  {sig}: n={r['n']} med={r['med_us']:.1f}us "
                f"est={r['est_cycles']:.0f}cy "
                f"ratio={r['cycles_per_us']:.2f}cy/us")
        return "\n".join(lines)

    def save(self, path: str = LAUNCH_COSTS_PATH) -> str:
        """Persist ``signature -> measured µs summary`` (merging with an
        existing table: this run's signatures overwrite, unseen ones are
        kept — the same accumulate-across-runs contract as the autotune
        table next door)."""
        merged: Dict[str, Dict[str, float]] = {}
        if os.path.exists(path):
            with open(path) as f:
                merged = json.load(f).get("signatures", {})
        merged.update(self.snapshot())
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"signatures": merged}, f, indent=1, sort_keys=True)
        return path

    @staticmethod
    def load(path: str = LAUNCH_COSTS_PATH) -> Dict[str, Dict[str, float]]:
        with open(path) as f:
            return json.load(f)["signatures"]


# ---------------------------------------------------------------------------
# spans + tracer
# ---------------------------------------------------------------------------


class Span:
    """One completed (or in-flight) traced region.  Context manager:
    entering stamps ``start_us``, exiting stamps ``dur_us`` and files the
    span with its tracer.  ``depth`` is the nesting level at entry (the
    span-tree proof the tests assert)."""

    __slots__ = ("name", "track", "tags", "start_us", "dur_us", "depth",
                 "_tracer")

    def __init__(self, tracer: "Tracer", name: str, track: str, tags: dict):
        self._tracer = tracer
        self.name = name
        self.track = track
        self.tags = tags
        self.start_us: float = 0.0
        self.dur_us: Optional[float] = None
        self.depth: int = 0

    def tag(self, **tags) -> "Span":
        self.tags.update(tags)
        return self

    def __enter__(self) -> "Span":
        self.depth = len(self._tracer._stack)
        self._tracer._stack.append(self)
        self.start_us = self._tracer.now_us()
        return self

    def __exit__(self, *exc) -> None:
        self.dur_us = self._tracer.now_us() - self.start_us
        self._tracer._stack.pop()
        self._tracer.events.append(self)


class _NullSpan:
    """The reused no-op span NULL_TRACER hands out (overhead: one attribute
    lookup + two no-op calls per instrumented region)."""

    __slots__ = ()
    name = track = ""
    tags: dict = {}
    start_us = 0.0
    dur_us = None
    depth = 0

    def tag(self, **tags) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Nested wall-clock span recorder + metrics + launch-cost table.

    Timestamps are µs since tracer construction (``time.perf_counter``
    based).  Spans nest per the call stack (single-threaded, like the
    executor); retroactive spans (``span_at``) and instants land on named
    *tracks* — chrome://tracing rows — so per-request admit→retire spans
    live on a "requests" track beside the "exec" track's launches."""

    enabled = True

    def __init__(self):
        self._t0 = time.perf_counter()
        self.events: List[Span] = []
        self._stack: List[Span] = []
        self.metrics = MetricsRegistry()
        self.launch_costs = LaunchCostTable()
        self._plan_ids: Dict[int, int] = {}

    # -- time ----------------------------------------------------------
    def now_us(self) -> float:
        return (time.perf_counter() - self._t0) * 1e6

    def fence(self, value):
        """``jax.block_until_ready`` — call INSIDE a span so its duration
        measures the enclosed work, not its async dispatch."""
        import jax

        return jax.block_until_ready(value)

    # -- recording -----------------------------------------------------
    def span(self, name: str, track: str = "exec", **tags) -> Span:
        return Span(self, name, track, tags)

    def span_at(self, name: str, start_us: float, end_us: float,
                track: str = "exec", **tags) -> Span:
        """File an already-elapsed span (e.g. a request's admit→retire
        lifetime, closed at retirement)."""
        sp = Span(self, name, track, tags)
        sp.start_us = start_us
        sp.dur_us = max(0.0, end_us - start_us)
        self.events.append(sp)
        return sp

    def instant(self, name: str, track: str = "exec", **tags) -> Span:
        """A zero-duration marker (fault, straggler, candidate scores)."""
        sp = Span(self, name, track, tags)
        sp.start_us = self.now_us()
        self.events.append(sp)
        return sp

    def plan_id(self, plan) -> int:
        """Small stable id for a plan object (plans are cached and live as
        long as their CompiledStack, so id() aliasing is not a concern)."""
        pid = self._plan_ids.get(id(plan))
        if pid is None:
            pid = len(self._plan_ids)
            self._plan_ids[id(plan)] = pid
        return pid

    def observe_launch(self, sig: str, est_cycles: float,
                       dur_us: float) -> None:
        """One measured launch: feeds both the per-signature latency
        histogram and the predicted-vs-measured table."""
        self.metrics.histogram(f"launch_us/{sig}").observe(dur_us)
        self.launch_costs.record(sig, est_cycles, dur_us)

    # -- export --------------------------------------------------------
    def export_chrome_trace(self, path: str) -> str:
        """Write chrome://tracing (about:tracing / Perfetto) trace-event
        JSON: complete ("X") events for spans, instant ("i") events for
        markers, metadata thread names for tracks."""
        tracks = {"exec": 0}
        events: List[dict] = [
            {"ph": "M", "name": "process_name", "pid": 0, "tid": 0,
             "args": {"name": "repro"}},
        ]
        for sp in self.events:
            tid = tracks.setdefault(sp.track, len(tracks))
            ev = {"name": sp.name, "pid": 0, "tid": tid,
                  "ts": round(sp.start_us, 3), "args": sp.tags}
            if sp.dur_us is None:
                ev.update(ph="i", s="t")
            else:
                ev.update(ph="X", dur=round(sp.dur_us, 3))
            events.append(ev)
        for track, tid in tracks.items():
            events.append({"ph": "M", "name": "thread_name", "pid": 0,
                           "tid": tid, "args": {"name": track}})
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump({"traceEvents": events, "displayTimeUnit": "ms"}, f)
        return path

    def snapshot(self) -> Dict[str, Any]:
        """Machine-readable state: span count, metrics (counters +
        histogram quantiles), the per-signature launch-cost table, and the
        aggregate predicted-vs-measured ratio."""
        costs = self.launch_costs.snapshot()
        ratios = [r["cycles_per_us"] for r in costs.values()
                  if r["cycles_per_us"] > 0]
        return {
            "spans": len(self.events),
            "metrics": self.metrics.snapshot(),
            "launch_costs": costs,
            "predicted_vs_measured": {
                "signatures": len(ratios),
                "mean_cycles_per_us": (sum(ratios) / len(ratios)
                                       if ratios else 0.0),
                "spread": (max(ratios) / min(ratios)
                           if len(ratios) > 1 and min(ratios) > 0 else 1.0),
            },
        }

    def describe(self) -> str:
        lines = [f"trace: {len(self.events)} spans"]
        lines += self.metrics.describe().splitlines()
        lines += self.launch_costs.describe().splitlines()
        return "\n".join(lines)


class NullTracer:
    """The disabled tracer: every hook is a no-op, ``enabled`` is False so
    instrumented sites skip fencing/metric work entirely.  One shared
    instance (``NULL_TRACER``) serves every untraced stack."""

    enabled = False

    def __init__(self):
        self.events: List[Span] = ()  # immutable: nothing ever records
        self.metrics = MetricsRegistry()
        self.launch_costs = LaunchCostTable()

    def now_us(self) -> float:
        return 0.0

    def fence(self, value):
        return value

    def span(self, name: str, track: str = "exec", **tags) -> _NullSpan:
        return _NULL_SPAN

    def span_at(self, name, start_us, end_us, track="exec",
                **tags) -> _NullSpan:
        return _NULL_SPAN

    def instant(self, name, track="exec", **tags) -> _NullSpan:
        return _NULL_SPAN

    def plan_id(self, plan) -> int:
        return 0

    def observe_launch(self, sig, est_cycles, dur_us) -> None:
        pass

    def snapshot(self) -> Dict[str, Any]:
        return {"spans": 0, "metrics": self.metrics.snapshot(),
                "launch_costs": {}, "predicted_vs_measured": {
                    "signatures": 0, "mean_cycles_per_us": 0.0,
                    "spread": 1.0}}

    def describe(self) -> str:
        return "trace: disabled"


NULL_TRACER = NullTracer()


def as_tracer(tracer: Optional[Tracer]) -> Tracer:
    """Normalize an optional tracer kwarg: None -> the shared no-op."""
    return NULL_TRACER if tracer is None else tracer


# ---------------------------------------------------------------------------
# the one benchmark timer (and the serving path's one clock)
# ---------------------------------------------------------------------------


def monotonic_s() -> float:
    """Monotonic seconds — the serving/runtime layers' one wall-clock for
    deadlines and tick durations.  ``analysis.repolint`` (rule
    timing-outside-obs) bans direct ``time.*`` calls on those paths so
    every measurement funnels through this module's discipline; interval
    consumers call this instead."""
    return time.monotonic()


def fence(value):
    """``jax.block_until_ready`` as a function, for callers that time
    around device work without holding a ``Tracer`` (the tracer's
    ``fence`` method is the traced-path equivalent)."""
    import jax

    return jax.block_until_ready(value)


def measure_samples(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
                    **kwargs) -> List[float]:
    """The raw samples behind ``measure_us``: ``warmup`` untimed calls
    (compile/plan-cache exclusion), then ``repeats`` calls each fenced with
    ``jax.block_until_ready``, returned as a list of µs.  Callers that need
    more than one summary statistic (the calibration replay harness records
    median AND p90 per signature) consume this directly so every timed
    number in the repo still originates from this one code path."""
    import jax

    for _ in range(max(0, warmup)):
        fn(*args, **kwargs)
    ts = []
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append((time.perf_counter() - t0) * 1e6)
    return ts


def measure_us(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
               reduce: str = "median", **kwargs) -> float:
    """Time ``fn(*args)``: ``warmup`` untimed calls (compile/plan-cache
    exclusion), then ``repeats`` calls each fenced with
    ``jax.block_until_ready``, reduced by ``median`` (default) or ``min``.
    Returns µs.  This is the measurement discipline of the executor's
    ``slot_launch`` spans, shared so bench rows and traced latencies are
    comparable numbers."""
    if reduce not in ("median", "min"):
        raise ValueError(f"measure_us: reduce={reduce!r} invalid; "
                         "allowed: median, min")
    ts = measure_samples(fn, *args, repeats=repeats, warmup=warmup, **kwargs)
    red = statistics.median if reduce == "median" else min
    return red(ts)


def slot_signature(family: str, H: int, G: int, B: int, chunk_len: int,
                   dtype: str, directions: Sequence[str] = ("fwd",),
                   chained: bool = False, precision: str = "fp32") -> str:
    """The canonical slot-signature string every layer tags launches with
    (and the launch-cost table keys on): family, G-batch width, padded B,
    H, T-stripe, dtype, direction mix, precision, chained flag.  The
    precision token (``|pint8`` / ``|pbf16``) is emitted only for
    non-fp32, so pre-existing persisted signatures stay valid — and an
    int8 measurement can never key an fp32 lookup."""
    dirs = "+".join(sorted(set(directions)))
    sig = f"{family}|H{H}|G{G}|B{B}|bt{chunk_len}|{dtype}|{dirs}"
    if precision != "fp32":
        sig += f"|p{precision}"
    return sig + "|chained" if chained else sig


__all__ = ["Tracer", "NullTracer", "NULL_TRACER", "as_tracer", "Span",
           "Counter", "Histogram", "MetricsRegistry", "LaunchCostTable",
           "LAUNCH_COSTS_PATH", "measure_us", "measure_samples",
           "monotonic_s", "fence", "slot_signature"]
