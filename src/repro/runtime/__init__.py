from repro.runtime.errors import (  # noqa: F401
    FALLBACK_LEVELS, ExecutionReport, FaultInjector, LaunchError,
    NonFiniteStateError, PlanRejected, QueueFull, RequestTimeout,
    ServingFault)
from repro.runtime.ft import FTConfig, StragglerWatchdog, TrainLoop  # noqa: F401
