from repro.runtime.errors import (  # noqa: F401
    FALLBACK_LEVELS, ExecutionReport, FaultInjector, LaunchError,
    NonFiniteStateError, PlanInvariantError, PlanRejected, QueueFull,
    RequestTimeout, ServingFault)
from repro.runtime.ft import FTConfig, StragglerWatchdog, TrainLoop  # noqa: F401
from repro.runtime.obs import (  # noqa: F401
    LAUNCH_COSTS_PATH, Counter, Histogram, LaunchCostTable, MetricsRegistry,
    NULL_TRACER, NullTracer, Span, Tracer, as_tracer, fence, measure_us,
    monotonic_s, slot_signature)
