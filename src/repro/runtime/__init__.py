from repro.runtime.ft import FTConfig, StragglerWatchdog, TrainLoop  # noqa: F401
