"""Generic decoder assembly for every assigned architecture.

One functional model covering: dense GQA transformers (opt. SWA), MoE
(+ arctic dense residual), audio/vlm backbones over precomputed embeddings
(frontend stubs per the assignment), xLSTM (mLSTM/sLSTM), and
RecurrentGemma-style hybrids (RG-LRU + local attention, 1:2 pattern).

Homogeneous stacks run under ``lax.scan`` over stacked layer params (compile
time stays flat in depth — deepseek's 95 layers trace once) with a remat
policy; heterogeneous stacks (ssm/hybrid) unroll a python loop.

Caches:
  attn   -> {"k","v"} (B, T_cache, KV) flattened kv (always divisible by the
            model axis), ring-buffered at ``window`` when SWA bounds it
  rglru  -> {"state" (B,W) fp32, "conv" (B,k-1,W)}
  mlstm  -> {"C","n","m"}; slstm -> {"h","c","n","m"}
plus a global {"idx": (B,) int32} cursor.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import attention as attn_lib
from repro.models.layers import rglru as rglru_lib
from repro.models.layers import xlstm as xlstm_lib
from repro.models.layers.common import dense_init, param_dtype, shard_act
from repro.models.layers.embedding import embed, init_embedding, unembed
from repro.models.layers.mlp import apply_mlp, init_mlp
from repro.models.layers.moe import apply_moe, init_moe
from repro.models.layers.norm import init_norm, rms_norm
from repro.models.layers.rope import apply_rope, mrope_angles, rope_angles

NAIVE_ATTN_MAX_SEQ = 1024  # above this, blockwise/local paths engage


# ===========================================================================
# init
# ===========================================================================


def _init_attn(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    return {
        "w_q": dense_init(ks[0], (d, cfg.q_dim), dtype),
        "w_kv": dense_init(ks[1], (d, 2 * cfg.kv_dim), dtype),
        "w_o": dense_init(ks[2], (cfg.q_dim, d), dtype),
    }


def _init_layer(cfg: ModelConfig, key, kind: str, dtype):
    ks = jax.random.split(key, 4)
    d = cfg.d_model
    p: Dict[str, Any] = {"norm1": init_norm(d, dtype)}
    if kind == "attn":
        p["attn"] = _init_attn(cfg, ks[0], dtype)
    elif kind == "rglru":
        w = cfg.rglru_width
        kk = jax.random.split(ks[0], 5)
        p["rec"] = {
            "w_in": dense_init(kk[0], (d, w), dtype),
            "w_gate": dense_init(kk[1], (d, w), dtype),
            "conv": rglru_lib.init_conv1d(kk[2], w, cfg.conv1d_width, dtype),
            "rglru": rglru_lib.init_rglru(kk[3], w, dtype),
            "w_out": dense_init(kk[4], (w, d), dtype),
        }
    elif kind == "mlstm":
        p["mlstm"] = xlstm_lib.init_mlstm(ks[0], d, cfg.n_heads, dtype)
    elif kind == "slstm":
        p["slstm"] = xlstm_lib.init_slstm(ks[0], d, cfg.n_heads, dtype)
    else:
        raise ValueError(kind)
    if kind in ("attn", "rglru") and cfg.d_ff:
        p["norm2"] = init_norm(d, dtype)
        if cfg.n_experts:
            p["moe"] = init_moe(ks[1], d, cfg.d_ff, cfg.n_experts, dtype,
                                dense_ff=cfg.moe_dense_ff)
        else:
            p["mlp"] = init_mlp(ks[1], d, cfg.d_ff, dtype)
    return p


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = param_dtype(cfg)
    kinds = cfg.layer_kinds()
    key, k_emb, k_out = jax.random.split(key, 3)
    params: Dict[str, Any] = {"final_norm": init_norm(cfg.d_model, dtype)}
    if cfg.embed_stub:
        params["head"] = {"unembed": dense_init(k_out, (cfg.d_model, cfg.vocab_size), dtype)}
    else:
        params["head"] = init_embedding(k_emb, cfg.vocab_size, cfg.d_model, dtype,
                                        cfg.tie_embeddings)
    layer_keys = jax.random.split(key, cfg.n_layers)
    layers = [_init_layer(cfg, layer_keys[i], kinds[i], dtype)
              for i in range(cfg.n_layers)]
    if cfg.scan_layers:
        params["layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    else:
        params["layers"] = layers
    return params


# ===========================================================================
# caches
# ===========================================================================


def cache_len(cfg: ModelConfig, seq_len: int) -> int:
    """SWA bounds the live KV working set to a ring of ``window`` slots."""
    if cfg.window and cfg.window < seq_len:
        return cfg.window
    return seq_len


def _init_layer_cache(cfg: ModelConfig, kind: str, batch: int, T: int, dtype):
    if kind == "attn":
        kv = cfg.kv_dim
        return {
            "k": jnp.zeros((batch, T, kv), dtype),
            "v": jnp.zeros((batch, T, kv), dtype),
        }
    if kind == "rglru":
        w = cfg.rglru_width
        return {
            "state": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype),
        }
    if kind == "mlstm":
        dh = 2 * cfg.d_model // cfg.n_heads
        return xlstm_lib.mlstm_state_init(batch, cfg.n_heads, dh)
    if kind == "slstm":
        return xlstm_lib.slstm_state_init(batch, cfg.d_model)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int) -> Dict[str, Any]:
    dtype = param_dtype(cfg)
    T = cache_len(cfg, seq_len)
    kinds = cfg.layer_kinds()
    per_layer = [_init_layer_cache(cfg, k, batch, T, dtype) for k in kinds]
    if cfg.scan_layers:
        layers = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
    else:
        layers = per_layer
    return {"layers": layers, "idx": jnp.zeros((batch,), jnp.int32)}


# ===========================================================================
# blocks
# ===========================================================================


def _rope_for(cfg: ModelConfig, positions):
    if cfg.mrope_sections:
        if positions.ndim == 2:  # (B,S) text-only -> all three streams equal
            positions = jnp.broadcast_to(positions[None], (3,) + positions.shape)
        return mrope_angles(positions, cfg.head_dim, cfg.rope_theta,
                            cfg.mrope_sections)
    return rope_angles(positions, cfg.head_dim, cfg.rope_theta)


def _attn_block(cfg: ModelConfig, p, x, rope_cs, cache, idx, mode: str):
    """x (B,S,d).  Returns (out, new_cache)."""
    B, S, d = x.shape
    Hq, Hk, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["w_q"]).reshape(B, S, Hq, D)
    kv = x @ p["w_kv"]
    k, v = jnp.split(kv, 2, axis=-1)
    cos, sin = rope_cs
    q = apply_rope(q, cos, sin)
    k = apply_rope(k.reshape(B, S, Hk, D), cos, sin).reshape(B, S, Hk * D)

    new_cache = cache
    if mode == "decode":
        from repro.models.layers.common import current_mesh

        T = cache["k"].shape[1]
        slot = idx % T if (cfg.window and cfg.window <= T) else jnp.minimum(idx, T - 1)
        k_cache = cache["k"].at[jnp.arange(B), slot].set(k[:, 0])
        v_cache = cache["v"].at[jnp.arange(B), slot].set(v[:, 0])
        k_cache = shard_act(k_cache, "batch", "cache_seq", None)
        v_cache = shard_act(v_cache, "batch", "cache_seq", None)
        new_cache = {"k": k_cache, "v": v_cache}
        valid = jnp.minimum(idx + 1, T)  # number of live slots
        # distributed: direct path (scores sharded over the T axis, softmax
        # stats psum'd); single host: chunked online-softmax for memory
        o = attn_lib.decode_attention(
            q, k_cache.reshape(B, T, Hk, D), v_cache.reshape(B, T, Hk, D),
            valid, window=0 if (cfg.window and cfg.window <= T) else cfg.window,
            prefer_chunked=current_mesh() is None)
    else:
        k4 = k.reshape(B, S, Hk, D)
        v4 = v.reshape(B, S, Hk, D)
        # distributed prefill/train: GQA kv-head counts (2-8) don't divide
        # the 16-way model axis, so the (Hk, G) grouping re-gathers k/v
        # inside every blockwise chunk.  Repeating kv to Hq heads (when Hq
        # divides the axis) makes every attention einsum head-local; the
        # one-off repeat reshard replaces ~4 TB/chip of per-chunk gathers
        # (EXPERIMENTS.md §Perf).
        from repro.models.layers.common import current_mesh

        mesh = current_mesh()
        if mesh is not None and Hk < Hq:
            msize = dict(zip(mesh.axis_names, mesh.devices.shape)).get("model", 1)
            if Hk % msize != 0:
                # smallest duplication r with (Hk*r) % msize == 0 and
                # Hq % (Hk*r) == 0 (grouping must stay valid)
                rep = next((r for r in range(2, Hq // Hk + 1)
                            if (Hk * r) % msize == 0 and Hq % (Hk * r) == 0),
                           None)
                if rep is not None:
                    k4 = jnp.repeat(k4, rep, axis=2)
                    v4 = jnp.repeat(v4, rep, axis=2)
                    k4 = shard_act(k4, "batch", "seq", "heads", None)
                    v4 = shard_act(v4, "batch", "seq", "heads", None)
        if cfg.window and S > cfg.window:
            o = attn_lib.local_attention(q, k4, v4, window=cfg.window)
        elif S > NAIVE_ATTN_MAX_SEQ:
            o = attn_lib.blockwise_attention(q, k4, v4)
        else:
            o = attn_lib.naive_attention(q, k4, v4, window=cfg.window)
        if mode == "prefill":
            T = cache["k"].shape[1]
            if T >= S:
                pad = ((0, 0), (0, T - S), (0, 0))
                new_cache = {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}
            else:  # ring: keep the last T positions at slot = pos % T
                shift = (S - T) % T
                new_cache = {"k": jnp.roll(k[:, S - T:], shift, axis=1),
                             "v": jnp.roll(v[:, S - T:], shift, axis=1)}
    o = shard_act(o.reshape(B, S, Hq * D), "batch", "seq", "qdim")
    return o @ p["w_o"], new_cache


def _rglru_block(cfg: ModelConfig, p, x, cache, mode: str):
    B, S, d = x.shape
    r = p["rec"]
    gate = jax.nn.gelu((x @ r["w_gate"]).astype(jnp.float32)).astype(x.dtype)
    h = x @ r["w_in"]
    h = shard_act(h, "batch", "seq", "state")
    conv_state = cache["conv"] if cache is not None else None
    h, new_conv = rglru_lib.apply_conv1d(r["conv"], h, conv_state)
    h0 = cache["state"] if cache is not None else None
    if mode == "decode":
        y, new_state = rglru_lib.decode_step(r["rglru"], h[:, 0], h0)
        y = y[:, None, :]
    else:
        y, new_state = rglru_lib.apply_rglru(r["rglru"], h, h0)
    y = y * gate
    out = y @ r["w_out"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "conv": new_conv}
    return out, new_cache


def _layer_apply(cfg: ModelConfig, kind: str, p, x, rope_cs, cache, idx, mode: str):
    """Pre-norm residual block.  Returns (x, new_cache, aux_loss)."""
    aux = jnp.zeros((), jnp.float32)
    h = rms_norm(x, p["norm1"], cfg.norm_eps)
    if kind == "attn":
        o, new_cache = _attn_block(cfg, p["attn"], h, rope_cs, cache, idx, mode)
    elif kind == "rglru":
        o, new_cache = _rglru_block(cfg, p, h, cache, mode)
    elif kind == "mlstm":
        if mode == "decode":
            o, state = xlstm_lib.apply_mlstm(p["mlstm"], h, cfg.n_heads, cache)
        else:  # chunkwise-parallel: O(T/L) state traffic (see §Perf)
            o, state = xlstm_lib.apply_mlstm_chunked(p["mlstm"], h,
                                                     cfg.n_heads, cache)
        new_cache = state if cache is not None else None
    elif kind == "slstm":
        o, state = xlstm_lib.apply_slstm(p["slstm"], h, cfg.n_heads, cache)
        new_cache = state if cache is not None else None
    else:
        raise ValueError(kind)
    x = x + o
    if "norm2" in p:
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if "moe" in p:
            cap = 0
            if mode == "decode":
                # decode: near-drop-free capacity without computing every
                # expert over every token slot (capacity=T wastes E/k x the
                # expert FLOPs — see EXPERIMENTS.md §Perf)
                import math as _math

                T = h.shape[0] * h.shape[1]
                cf = max(4.0, cfg.capacity_factor)
                cap = min(T, max(8, _math.ceil(
                    T * cfg.experts_per_token * cf / cfg.n_experts)))
            o, aux = apply_moe(p["moe"], h, k=cfg.experts_per_token,
                               capacity_factor=cfg.capacity_factor,
                               deterministic_capacity=cap)
        else:
            o = apply_mlp(p["mlp"], h)
        x = x + o
    x = shard_act(x, "batch", "seq", "embed")
    return x, new_cache, aux


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat_policy == "none":
        return fn
    if cfg.remat_policy == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
    else:
        policy = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=policy)


# ===========================================================================
# forward
# ===========================================================================


def forward(cfg: ModelConfig, params, *, tokens=None, embeds=None,
            positions=None, cache=None, mode: str = "train"):
    """Returns (logits, new_cache, aux_loss).

    train/prefill: tokens (B,S) or embeds (B,S,d).
    decode: tokens (B,1) / embeds (B,1,d) + cache (required).
    """
    dtype = param_dtype(cfg)
    if embeds is None:
        x = embed(params["head"], tokens, dtype)
    else:
        x = embeds.astype(dtype)
    B, S = x.shape[:2]
    x = shard_act(x, "batch", "seq", "embed")

    idx = cache["idx"] if cache is not None else None
    if positions is None:
        if mode == "decode":
            positions = idx[:, None]  # (B,1)
        else:
            positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    rope_cs = _rope_for(cfg, positions)

    kinds = cfg.layer_kinds()
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.scan_layers:
        kind = kinds[0]  # homogeneous by construction
        g = cfg.remat_group if (cache is None and cfg.remat_group > 1
                                and cfg.n_layers % cfg.remat_group == 0) else 1

        def body(carry, inp):
            x, aux = carry
            if cache is not None:
                p_l, cache_l = inp
            else:
                p_l, cache_l = inp, None
            if g == 1:
                x, new_cache_l, aux_l = _layer_apply(cfg, kind, p_l, x, rope_cs,
                                                     cache_l, idx, mode)
                aux = aux + aux_l
            else:
                # grouped remat: k layers per checkpoint unit, so only one
                # residual per GROUP is stored for the backward pass
                new_cache_l = None
                for i in range(g):
                    p_i = jax.tree.map(lambda a: a[i], p_l)
                    x, _, aux_l = _layer_apply(cfg, kind, p_i, x, rope_cs,
                                               None, idx, mode)
                    aux = aux + aux_l
            if new_cache_l is None:
                new_cache_l = 0.0  # dummy scan output
            return (x, aux), new_cache_l

        body = _remat_wrap(cfg, body)
        layer_params = params["layers"]
        if g > 1:
            layer_params = jax.tree.map(
                lambda a: a.reshape((cfg.n_layers // g, g) + a.shape[1:]),
                layer_params)
        xs = (layer_params, cache["layers"]) if cache is not None else layer_params
        (x, aux_total), new_layer_caches = jax.lax.scan(body, (x, aux_total), xs)
    else:
        new_layer_caches = []
        for i, kind in enumerate(kinds):
            p_l = params["layers"][i]
            cache_l = cache["layers"][i] if cache is not None else None

            def run(p_l, x, cache_l, kind=kind):
                return _layer_apply(cfg, kind, p_l, x, rope_cs, cache_l, idx, mode)

            run_m = _remat_wrap(cfg, run)
            x, new_cache_l, aux_l = run_m(p_l, x, cache_l)
            aux_total = aux_total + aux_l
            new_layer_caches.append(new_cache_l)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["head"], x)

    new_cache = None
    if cache is not None:
        step = 1 if mode == "decode" else S
        new_cache = {"layers": new_layer_caches, "idx": idx + step}
    return logits, new_cache, aux_total


# ===========================================================================
# losses / step functions (model-level; the launcher wraps these in pjit)
# ===========================================================================


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Next-token CE.  batch: {tokens|embeds, labels?}."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    positions = batch.get("positions")
    logits, _, aux = forward(cfg, params, tokens=tokens, embeds=embeds,
                             positions=positions, mode="train")
    if "labels" in batch:
        labels = batch["labels"]
        tgt_logits = logits
    else:
        labels = tokens[:, 1:]
        tgt_logits = logits[:, :-1]
    logp = jax.nn.log_softmax(tgt_logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


def prefill(cfg: ModelConfig, params, batch, seq_len: int):
    """Full-sequence forward that also builds the cache."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    B = (tokens if tokens is not None else embeds).shape[0]
    cache = init_cache(cfg, B, seq_len)
    logits, new_cache, _ = forward(cfg, params, tokens=tokens, embeds=embeds,
                                   positions=batch.get("positions"),
                                   cache=cache, mode="prefill")
    return logits, new_cache


def decode_step(cfg: ModelConfig, params, cache, batch):
    """One token for every sequence in the batch."""
    logits, new_cache, _ = forward(cfg, params, tokens=batch.get("tokens"),
                                   embeds=batch.get("embeds"),
                                   positions=batch.get("positions"),
                                   cache=cache, mode="decode")
    return logits, new_cache
