"""Shared building blocks: parameter init, dtype policy, activation sharding."""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# ---------------------------------------------------------------------------
# dtype policy
# ---------------------------------------------------------------------------

DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32, "float16": jnp.float16}


def param_dtype(cfg) -> jnp.dtype:
    return DTYPES[cfg.dtype]


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def dense_init(key, shape: Sequence[int], dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else 1
    if scale is None:
        scale = 1.0 / np.sqrt(max(fan_in, 1))
    x = jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * scale
    return x.astype(dtype)


def zeros_init(shape, dtype):
    return jnp.zeros(shape, dtype)


def ones_init(shape, dtype):
    return jnp.ones(shape, dtype)


# ---------------------------------------------------------------------------
# matmul with fp32 accumulation (MXU-style: bf16 inputs, fp32 accumulate)
# ---------------------------------------------------------------------------


def matmul(x, w, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    y = jnp.einsum("...d,df->...f", x, w, preferred_element_type=jnp.float32)
    return y.astype(out_dtype)


# ---------------------------------------------------------------------------
# logical activation sharding
#
# Model code annotates activations with logical axis names; a thread-local
# context binds those names to physical mesh axes.  Without an active context
# the annotation is a no-op, so single-device smoke tests never touch meshes.
# ---------------------------------------------------------------------------

_CTX = threading.local()

# logical name -> physical mesh axes (tuple -> sharded over multiple axes)
DEFAULT_RULES = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "qdim": "model",
    "ff": "model",
    "experts": "model",
    "capacity": None,
    "ff_fsdp": ("pod", "data"),
    "vocab": "model",
    "state": "model",
    "cache_seq": "model",
}


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= dict(zip(mesh.axis_names, mesh.devices.shape)).get(a, 1)
    return n


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[dict] = None):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, dict(DEFAULT_RULES, **(rules or {}))) if mesh is not None else None
    try:
        yield
    finally:
        _CTX.state = prev


def current_mesh() -> Optional[Mesh]:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def logical_spec(names: Sequence[Optional[str]], shape=None) -> Optional[P]:
    """Resolve logical names to a PartitionSpec under the active rules.

    Axes absent from the mesh are dropped (a single-pod mesh ignores 'pod').
    If ``shape`` is given, dims whose size does not divide evenly by the
    mesh-axis product are dropped (replicated) — required because jit input
    shardings must divide evenly.
    """
    st = getattr(_CTX, "state", None)
    if st is None:
        return None
    mesh, rules = st
    present = set(mesh.axis_names)
    spec = []
    for i, nm in enumerate(names):
        axes = rules.get(nm) if nm else None
        if isinstance(axes, str):
            axes = (axes,)
        if axes is not None:
            axes = tuple(a for a in axes if a in present)
            if not axes:
                axes = None
        if axes is not None and shape is not None:
            if shape[i] % _axis_size(mesh, axes) != 0:
                axes = None
        if axes is not None and len(axes) == 1:
            axes = axes[0]
        spec.append(axes)
    return P(*spec)


def chunked_scan(step, carry, xs, chunk: int = 128, remat: bool = True):
    """lax.scan over time in rematerialized chunks.

    A plain scan saves its carry at every step for the backward pass —
    for a (B, H, dk, dv) mLSTM matrix memory over 4096 steps that is
    O(T * state) and dominates training memory.  Scanning over chunks with
    a jax.checkpoint'd inner scan stores one carry per *chunk* and
    recomputes the inner steps on the backward pass: memory drops by the
    chunk factor for a <2x recompute cost.  Numerically identical to the
    plain scan (same reduction order).
    """
    leaves = jax.tree.leaves(xs)
    T = leaves[0].shape[0]
    if chunk <= 1 or T <= chunk or T % chunk:
        return jax.lax.scan(step, carry, xs)
    n = T // chunk

    xs_c = jax.tree.map(
        lambda a: a.reshape((n, chunk) + a.shape[1:]), xs)

    def inner(c, xc):
        return jax.lax.scan(step, c, xc)

    if remat:
        inner = jax.checkpoint(
            inner, policy=jax.checkpoint_policies.nothing_saveable)

    carry, ys = jax.lax.scan(inner, carry, xs_c)
    ys = jax.tree.map(lambda a: a.reshape((T,) + a.shape[2:]), ys)
    return carry, ys


def shard_act(x, *names: Optional[str]):
    """with_sharding_constraint by logical names (no-op without a context)."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, _ = st
    spec = logical_spec(names)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
