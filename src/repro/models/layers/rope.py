"""Rotary position embeddings, including Qwen2-VL style M-RoPE.

M-RoPE splits the head_dim/2 rotary frequency bands into (temporal, height,
width) sections, each driven by its own position-id stream.  Text-only
positions degenerate to all three streams equal, which reduces M-RoPE to
standard RoPE — that equivalence is property-tested.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


def rope_angles(positions, head_dim: int, theta: float):
    """positions (..., S) -> cos/sin (..., S, head_dim//2) in fp32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def mrope_angles(positions3, head_dim: int, theta: float, sections: Tuple[int, ...]):
    """positions3 (3, B, S) -> cos/sin (B, S, head_dim//2).

    Section i of the frequency bands takes its positions from stream i.
    """
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    # select the position stream per frequency band
    band_stream = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,)
    pos = positions3.astype(jnp.float32)  # (3, B, S)
    pos_per_band = pos[band_stream]  # (half, B, S)
    ang = jnp.moveaxis(pos_per_band, 0, -1) * freqs  # (B, S, half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, D); cos/sin (B, S, D//2) or (S, D//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:  # (S, half) -> broadcast over batch
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, :, None, :]  # (B, S, 1, half)
    sin = sin[:, :, None, :]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1.astype(x.dtype), o2.astype(x.dtype)], axis=-1)
