"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Both follow the Unfolded decomposition (DESIGN.md §4): every input-side
projection (q/k/v/z and the gate pre-activations from x) is computed for the
whole sequence as one GEMM *outside* the scan; the scan body carries only the
state recurrences — exactly the paper's input/hidden split.  For sLSTM the
per-head recurrent matmul R h_{t-1} stays inside (it is the true serial MVM,
the paper's `U·h` half).

Stabilized exponential gating per the xLSTM paper (m_t running max).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.common import chunked_scan, dense_init

# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(key, d: int, n_heads: int, dtype):
    di = 2 * d
    ks = jax.random.split(key, 8)
    return {
        "w_up_v": dense_init(ks[0], (d, di), dtype),
        "w_up_g": dense_init(ks[1], (d, di), dtype),
        "w_q": dense_init(ks[2], (di, di), dtype),
        "w_k": dense_init(ks[3], (di, di), dtype),
        "w_v": dense_init(ks[4], (di, di), dtype),
        "w_i": dense_init(ks[5], (di, n_heads), jnp.float32),  # input gate
        "w_f": dense_init(ks[6], (di, n_heads), jnp.float32),  # forget gate
        "b_i": jnp.zeros((n_heads,), jnp.float32),
        "b_f": jnp.full((n_heads,), 3.0, jnp.float32),  # bias toward remember
        "w_down": dense_init(ks[7], (di, d), dtype),
    }


def mlstm_inputs(params, x, n_heads: int):
    """Sequence-parallel half: all projections + gate pre-activations."""
    B, T, d = x.shape
    di = params["w_up_v"].shape[1]
    dh = di // n_heads
    xv = x @ params["w_up_v"]
    xg = x @ params["w_up_g"]
    q = (xv @ params["w_q"]).reshape(B, T, n_heads, dh)
    k = (xv @ params["w_k"]).reshape(B, T, n_heads, dh) / jnp.sqrt(dh).astype(x.dtype)
    v = (xv @ params["w_v"]).reshape(B, T, n_heads, dh)
    i_pre = xv.astype(jnp.float32) @ params["w_i"] + params["b_i"]  # (B,T,H)
    f_pre = xv.astype(jnp.float32) @ params["w_f"] + params["b_f"]
    return q, k, v, i_pre, f_pre, xg


def mlstm_state_init(B: int, n_heads: int, dh: int):
    return {
        "C": jnp.zeros((B, n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((B, n_heads, dh), jnp.float32),
        "m": jnp.full((B, n_heads), -jnp.inf, jnp.float32),
    }


def mlstm_cell(state, q_t, k_t, v_t, i_pre, f_pre):
    """One recurrent step.  q/k/v_t (B,H,dh); i/f_pre (B,H)."""
    C, n, m = state["C"], state["n"], state["m"]
    log_f = jax.nn.log_sigmoid(f_pre)
    m_new = jax.lax.stop_gradient(jnp.maximum(log_f + m, i_pre))
    f_sc = jnp.exp(log_f + m - m_new)[..., None, None]
    i_sc = jnp.exp(i_pre - m_new)[..., None]
    kf = k_t.astype(jnp.float32)
    vf = v_t.astype(jnp.float32)
    C = f_sc * C + (i_sc[..., None] * kf[..., :, None]) * vf[..., None, :]
    n = f_sc[..., 0] * n + i_sc * kf
    qf = q_t.astype(jnp.float32)
    num = jnp.einsum("bhkv,bhk->bhv", C, qf)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf))
    h = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return {"C": C, "n": n, "m": m_new}, h


def apply_mlstm(params, x, n_heads: int, state=None):
    """x (B,T,d) -> (y (B,T,d), state)."""
    B, T, d = x.shape
    di = params["w_up_v"].shape[1]
    dh = di // n_heads
    q, k, v, i_pre, f_pre, xg = mlstm_inputs(params, x, n_heads)
    if state is None:
        state = mlstm_state_init(B, n_heads, dh)

    def step(st, inp):
        qt, kt, vt, it, ft = inp
        st, h = mlstm_cell(st, qt, kt, vt, it, ft)
        return st, h

    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          i_pre.swapaxes(0, 1), f_pre.swapaxes(0, 1))
    state, hs = chunked_scan(step, state, xs)
    hs = hs.swapaxes(0, 1).reshape(B, T, di).astype(x.dtype)  # (B,T,di)
    y = (hs * jax.nn.silu(xg.astype(jnp.float32)).astype(x.dtype)) @ params["w_down"]
    return y, state


def apply_mlstm_chunked(params, x, n_heads: int, state=None, chunk: int = 128):
    """Exact chunkwise-parallel mLSTM (the Unfolded split at chunk level).

    The recurrent form touches the (B,H,dk,dv) matrix memory every step —
    O(T * state) HBM traffic that dominates training (EXPERIMENTS.md §Perf,
    xlstm hillclimb).  Chunkwise, the state is read/written once per chunk
    and the intra-chunk part becomes decay-masked attention (MXU matmuls):

      F_t   = cumsum(log f) within the chunk;  a_s = i_s - F_s
      M_t   = max(m0, cummax_s<=t a_s);        m_t = F_t + M_t
      D_ts  = exp(a_s - M_t) * [s <= t]
      num_t = e^{m0 - M_t} (q_t C0) + sum_s D_ts (q_t k_s) v_s
      n_t   = e^{m0 - M_t} n0      + sum_s D_ts k_s
      h_t   = num_t / max(|n_t q_t|, e^{-m_t})

    Identical numerics to ``apply_mlstm`` (property-tested): the same
    stabilizer recursion m_t = max(log f_t + m_{t-1}, i_t) unrolls to
    F_t + M_t.  Falls back to the recurrent scan when T % chunk != 0.
    """
    B, T, d = x.shape
    di = params["w_up_v"].shape[1]
    dh = di // n_heads
    if T % chunk or T <= chunk:
        return apply_mlstm(params, x, n_heads, state)
    q, k, v, i_pre, f_pre, xg = mlstm_inputs(params, x, n_heads)
    if state is None:
        state = mlstm_state_init(B, n_heads, dh)
    n_chunks = T // chunk

    def to_chunks(a):  # (B,T,H,...) -> (n, B, H, L, ...)
        a = a.reshape((B, n_chunks, chunk) + a.shape[2:])
        a = jnp.moveaxis(a, 3, 1)  # (B, H, n, L, ...) if heads present
        return a

    qc = jnp.moveaxis(q.reshape(B, n_chunks, chunk, n_heads, dh), 3, 1)
    kc = jnp.moveaxis(k.reshape(B, n_chunks, chunk, n_heads, dh), 3, 1)
    vc = jnp.moveaxis(v.reshape(B, n_chunks, chunk, n_heads, dh), 3, 1)
    ic = jnp.moveaxis(i_pre.reshape(B, n_chunks, chunk, n_heads), 3, 1)
    fc = jnp.moveaxis(f_pre.reshape(B, n_chunks, chunk, n_heads), 3, 1)
    # all now (B, H, n, L, ...) -> scan over n
    xs = tuple(jnp.moveaxis(a, 2, 0) for a in (qc, kc, vc, ic, fc))

    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.float32))

    def chunk_step(st, inp):
        qt, kt, vt, it, ft = inp  # (B,H,L,dh) / (B,H,L)
        C0, n0, m0 = st["C"], st["n"], st["m"]
        qf = qt.astype(jnp.float32)
        kf = kt.astype(jnp.float32)
        vf = vt.astype(jnp.float32)
        log_f = jax.nn.log_sigmoid(ft)                      # (B,H,L)
        F = jnp.cumsum(log_f, axis=-1)
        a = it - F                                          # (B,H,L)
        M = jax.lax.stop_gradient(
            jnp.maximum(m0[..., None], jax.lax.cummax(a, axis=2)))  # (B,H,L)
        m_t = F + M
        inter = jnp.exp(m0[..., None] - M)                  # (B,H,L)
        D = jnp.exp(a[:, :, None, :] - M[..., None]) * tri  # (B,H,L,L) [t,s]
        s_qk = jnp.einsum("bhtd,bhsd->bhts", qf, kf)
        num = (inter[..., None] * jnp.einsum("bhtk,bhkv->bhtv", qf, C0)
               + jnp.einsum("bhts,bhsv->bhtv", D * s_qk, vf))
        n_t = (inter[..., None] * n0[:, :, None, :]
               + jnp.einsum("bhts,bhsk->bhtk", D, kf))
        den = jnp.maximum(jnp.abs(jnp.einsum("bhtk,bhtk->bht", n_t, qf)),
                          jnp.exp(-m_t))
        h = num / den[..., None]                            # (B,H,L,dv)
        # chunk-end state
        w_end = jnp.exp(a - M[..., -1:])                    # (B,H,L)
        C1 = (inter[..., -1, None, None] * C0
              + jnp.einsum("bhs,bhsk,bhsv->bhkv", w_end, kf, vf))
        n1 = (inter[..., -1, None] * n0
              + jnp.einsum("bhs,bhsk->bhk", w_end, kf))
        m1 = m_t[..., -1]
        return {"C": C1, "n": n1, "m": m1}, h

    state, hs = jax.lax.scan(jax.checkpoint(
        chunk_step, policy=jax.checkpoint_policies.nothing_saveable),
        state, xs)
    # hs (n, B, H, L, dv) -> (B, T, di)
    hs = jnp.moveaxis(hs, 0, 2).reshape(B, n_heads, T, dh)
    hs = jnp.moveaxis(hs, 1, 2).reshape(B, T, di).astype(x.dtype)
    y = (hs * jax.nn.silu(xg.astype(jnp.float32)).astype(x.dtype)) @ params["w_down"]
    return y, state


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(key, d: int, n_heads: int, dtype):
    dh = d // n_heads
    ks = jax.random.split(key, 3)
    return {
        "W": dense_init(ks[0], (d, 4 * d), dtype),       # input half (z,i,f,o)
        "R": dense_init(ks[1], (n_heads, dh, 4 * dh), dtype),  # recurrent half
        "b": jnp.zeros((4 * d,), jnp.float32),
        "w_out": dense_init(ks[2], (d, d), dtype),
    }


def slstm_state_init(B: int, d: int):
    return {
        "h": jnp.zeros((B, d), jnp.float32),
        "c": jnp.zeros((B, d), jnp.float32),
        "n": jnp.ones((B, d), jnp.float32),
        "m": jnp.zeros((B, d), jnp.float32),
    }


def slstm_cell(state, x_pre, R, n_heads: int):
    """x_pre (B, 4d) = x_t W + b (input half, precomputed).  R (H,dh,4dh)."""
    from repro.models.layers.common import shard_act

    B = x_pre.shape[0]
    d = x_pre.shape[1] // 4
    dh = d // n_heads
    h_prev = state["h"].reshape(B, n_heads, dh)
    rec = jnp.einsum("bhd,hdk->bhk", h_prev.astype(R.dtype), R,
                     preferred_element_type=jnp.float32)  # (B,H,4dh)
    pre = x_pre.astype(jnp.float32).reshape(B, n_heads, 4 * dh) + rec
    # gate axis sharded over 'model': spreads the serial R/dR traffic across
    # the otherwise-idle tensor axis (EXPERIMENTS.md §Perf, xlstm iter 2)
    pre = shard_act(pre, "batch", None, "ff")
    # gate layout per head-block: (z, i, f, o), each dh wide
    pre4 = pre.reshape(B, n_heads, 4, dh)
    z = jnp.tanh(pre4[:, :, 0]).reshape(B, d)
    i_pre = pre4[:, :, 1].reshape(B, d)
    f_pre = pre4[:, :, 2].reshape(B, d)
    o = jax.nn.sigmoid(pre4[:, :, 3]).reshape(B, d)
    log_f = jax.nn.log_sigmoid(f_pre)
    # h is invariant to the stabilizer (c and n carry the same exp(-m)
    # factor, cancelling in c/n) -> keep it out of the autodiff graph
    m_new = jax.lax.stop_gradient(jnp.maximum(log_f + state["m"], i_pre))
    i_sc = jnp.exp(i_pre - m_new)
    f_sc = jnp.exp(log_f + state["m"] - m_new)
    c = f_sc * state["c"] + i_sc * z
    n = f_sc * state["n"] + i_sc
    h = o * (c / jnp.maximum(n, 1e-6))
    return {"h": h, "c": c, "n": n, "m": m_new}


def apply_slstm(params, x, n_heads: int, state=None):
    """x (B,T,d) -> (y (B,T,d), state)."""
    B, T, d = x.shape
    if state is None:
        state = slstm_state_init(B, d)
    # Unfolded: input half hoisted out of the scan (one GEMM for all t)
    x_pre = x @ params["W"] + params["b"].astype(x.dtype)  # (B,T,4d)

    def step(st, xp):
        st = slstm_cell(st, xp, params["R"], n_heads)
        return st, st["h"]

    state, hs = chunked_scan(step, state, x_pre.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype) @ params["w_out"]
    return y, state
