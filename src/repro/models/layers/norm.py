"""RMSNorm (fp32 statistics, cast back to input dtype)."""
import jax.numpy as jnp


def rms_norm(x, scale, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jnp.reciprocal(jnp.sqrt(var + eps))
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def init_norm(d: int, dtype):
    return jnp.zeros((d,), dtype)
