"""Token embedding and output head."""
import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init, shard_act


def init_embedding(key, vocab: int, d: int, dtype, tie: bool):
    k1, k2 = jax.random.split(key)
    p = {"table": dense_init(k1, (vocab, d), dtype, scale=1.0)}
    if not tie:
        p["unembed"] = dense_init(k2, (d, vocab), dtype)
    return p


def embed(params, tokens, dtype):
    return params["table"].astype(dtype)[tokens]


def unembed(params, x):
    if "unembed" in params:
        w = params["unembed"]
    else:
        w = params["table"].T
    logits = jnp.einsum("bsd,dv->bsv", x, w, preferred_element_type=jnp.float32)
    return shard_act(logits, "batch", "seq", "vocab")
