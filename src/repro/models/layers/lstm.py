"""The paper's LSTM: cell math + stack init.

Gate layout follows the paper's Fig. 2: order (i, f, g, o) stacked along the
4H axis so one GEMM produces all four gate pre-activations ("Intergate"
dispatch in SHARP terms).  Execution *order* (Sequential / Batch / Intergate /
Unfolded) is the business of ``repro.core.schedules`` — the math here is the
single source of truth those schedules must reproduce bit-for-bit.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init


def init_lstm_layer(key, x_dim: int, hidden: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "W": dense_init(k1, (x_dim, 4 * hidden), dtype),   # input half
        "U": dense_init(k2, (hidden, 4 * hidden), dtype),  # recurrent half
        "b": jnp.zeros((4 * hidden,), dtype),
    }


def init_lstm_stack(key, cfg, dtype):
    layers = []
    x_dim = cfg.lstm_input
    for i in range(cfg.n_layers):
        key, sub = jax.random.split(key)
        if cfg.bidirectional:
            kf, kb = jax.random.split(sub)
            layers.append({
                "fwd": init_lstm_layer(kf, x_dim, cfg.lstm_hidden, dtype),
                "bwd": init_lstm_layer(kb, x_dim, cfg.lstm_hidden, dtype),
            })
            x_dim = 2 * cfg.lstm_hidden
        else:
            layers.append(init_lstm_layer(sub, x_dim, cfg.lstm_hidden, dtype))
            x_dim = cfg.lstm_hidden
    return {"layers": layers}


def split_gates(g):
    """(..., 4H) -> i, f, g, o each (..., H)."""
    H = g.shape[-1] // 4
    return g[..., :H], g[..., H:2 * H], g[..., 2 * H:3 * H], g[..., 3 * H:]


def cell_update(gates, c_prev):
    """Pointwise tail of the LSTM cell (SHARP's A-MFU + Cell-Updater stages).

    gates (..., 4H) pre-activation; returns (h, c).  fp32 internally.
    """
    gates = gates.astype(jnp.float32)
    i, f, g, o = split_gates(gates)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    g = jnp.tanh(g)
    o = jax.nn.sigmoid(o)
    c = f * c_prev.astype(jnp.float32) + i * g
    h = o * jnp.tanh(c)
    return h, c


def lstm_step(params, x_t, h_prev, c_prev):
    """One full step: both MVM halves + pointwise tail.  x_t (B, X)."""
    gates = (
        x_t @ params["W"].astype(x_t.dtype)
        + h_prev.astype(x_t.dtype) @ params["U"].astype(x_t.dtype)
        + params["b"].astype(x_t.dtype)
    )
    h, c = cell_update(gates, c_prev)
    return h.astype(x_t.dtype), c


def reference_unroll(params, xs):
    """Ground-truth layer evaluation: python loop over time. xs (B, T, X)."""
    B, T, _ = xs.shape
    H = params["U"].shape[0]
    h = jnp.zeros((B, H), xs.dtype)
    c = jnp.zeros((B, H), jnp.float32)
    outs = []
    for t in range(T):
        h, c = lstm_step(params, xs[:, t], h, c)
        outs.append(h)
    return jnp.stack(outs, axis=1)
