"""Attention: GQA/MQA, full-causal, sliding-window, blockwise (flash-style),
and single-token decode against a KV cache.

Three execution paths, all bit-compatible (property-tested against the naive
reference):

* ``naive_attention``      — exact O(S^2) reference; small shapes/tests.
* ``blockwise_attention``  — flash-style online-softmax over KV chunks with a
  lax.scan; bounded memory, used for long prefill.  Upper-triangular KV chunks
  are masked (not skipped) — the ~2x causal FLOP overhead vs. the triangular
  optimum is visible in the roofline and addressed in the perf pass.
* ``local_attention``      — sliding-window (SWA) via chunking: each chunk of
  size W attends to [previous chunk, own chunk] with a banded causal mask;
  exact for window <= W and O(S*W).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_scores(q, k):
    """q (B,S,Hq,D), k (B,T,Hk,D) -> scores (B,Hk,G,S,T) in fp32."""
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    qg = q.reshape(B, S, Hk, G, D)
    s = jnp.einsum("bshgd,bthd->bhgst", qg, k, preferred_element_type=jnp.float32)
    return s * (1.0 / jnp.sqrt(D).astype(jnp.float32))


def _gqa_out(probs, v, dtype):
    """probs (B,Hk,G,S,T), v (B,T,Hk,D) -> (B,S,Hq,D)."""
    B, Hk, G, S, T = probs.shape
    o = jnp.einsum("bhgst,bthd->bshgd", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, S, Hk * G, -1).astype(dtype)


def causal_mask(S: int, T: int, q_offset, window: int = 0):
    """(S, T) additive mask; query i sits at absolute position q_offset + i."""
    qpos = q_offset + jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = kpos <= qpos
    if window:
        ok &= kpos > qpos - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def naive_attention(q, k, v, *, window: int = 0, q_offset=0):
    s = _gqa_scores(q, k)  # (B,Hk,G,S,T)
    s = s + causal_mask(q.shape[1], k.shape[1], q_offset, window)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v, q.dtype)


# ---------------------------------------------------------------------------
# blockwise / flash-style
# ---------------------------------------------------------------------------


def blockwise_attention(q, k, v, *, q_chunk: int = 512, kv_chunk: int = 1024):
    """Causal attention with online softmax; memory O(q_chunk * kv_chunk)."""
    B, S, Hq, D = q.shape
    T = k.shape[1]
    Hk = k.shape[2]
    G = Hq // Hk
    q_chunk = min(q_chunk, S)
    kv_chunk = min(kv_chunk, T)
    assert S % q_chunk == 0 and T % kv_chunk == 0, (S, q_chunk, T, kv_chunk)
    nq, nk = S // q_chunk, T // kv_chunk

    qc = q.reshape(B, nq, q_chunk, Hk, G, D)
    kc = k.reshape(B, nk, kv_chunk, Hk, D)
    vc = v.reshape(B, nk, kv_chunk, Hk, D)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    def q_step(_, qi_and_i):
        qi, i = qi_and_i  # qi (B, q_chunk, Hk, G, D)

        def kv_step(carry, kj_and_j):
            m, l, o = carry
            kj, vj, j = kj_and_j
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qi, kj,
                           preferred_element_type=jnp.float32) * scale
            qpos = i * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = j * kv_chunk + jnp.arange(kv_chunk)[None, :]
            s = jnp.where(kpos <= qpos, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(axis=-1)
            pv = jnp.einsum("bhgqk,bkhd->bhgqd", p.astype(vj.dtype), vj,
                            preferred_element_type=jnp.float32)
            o_new = o * alpha[..., None] + pv
            return (m_new, l_new, o_new), ()

        m0 = jnp.full((B, Hk, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hk, G, q_chunk), jnp.float32)
        o0 = jnp.zeros((B, Hk, G, q_chunk, D), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_step, (m0, l0, o0),
            (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nk)))
        o = o / jnp.maximum(l[..., None], 1e-30)
        # (B,Hk,G,q_chunk,D) -> (B,q_chunk,Hq,D)
        return (), o.transpose(0, 3, 1, 2, 4).reshape(B, q_chunk, Hq, D)

    # remat per q-chunk: the backward pass recomputes the inner kv scan
    # instead of saving (m, l, o) carries for every kv step
    q_step = jax.checkpoint(q_step,
                            policy=jax.checkpoint_policies.nothing_saveable)
    _, out = jax.lax.scan(q_step, (), (qc.swapaxes(0, 1), jnp.arange(nq)))
    # out (nq, B, q_chunk, Hq, D)
    return out.transpose(1, 0, 2, 3, 4).reshape(B, S, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# sliding window via chunking
# ---------------------------------------------------------------------------


def local_attention(q, k, v, *, window: int):
    """Exact SWA (kpos in (qpos-window, qpos]) with O(S*window) cost."""
    B, S, Hq, D = q.shape
    Hk = k.shape[2]
    G = Hq // Hk
    W = window
    pad = (-S) % W
    if pad:
        zq = jnp.zeros((B, pad, Hq, D), q.dtype)
        zk = jnp.zeros((B, pad, Hk, D), k.dtype)
        q = jnp.concatenate([q, zq], 1)
        k = jnp.concatenate([k, zk], 1)
        v = jnp.concatenate([v, zk], 1)
    Sp = q.shape[1]
    n = Sp // W
    qc = q.reshape(B, n, W, Hk, G, D)
    kc = k.reshape(B, n, W, Hk, D)
    vc = v.reshape(B, n, W, Hk, D)
    # keys for chunk i: chunk i-1 ++ chunk i
    k2 = jnp.concatenate([jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0))), kc], axis=2)
    v2 = jnp.concatenate([jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0))), vc], axis=2)
    s = jnp.einsum("bnqhgd,bnkhd->bnhgqk", qc, k2,
                   preferred_element_type=jnp.float32)
    s = s * (1.0 / jnp.sqrt(D).astype(jnp.float32))
    qpos = jnp.arange(W)[:, None] + W  # position within the 2W key window
    kpos = jnp.arange(2 * W)[None, :]
    ok = (kpos <= qpos) & (kpos > qpos - W)
    first = jnp.arange(n) == 0  # chunk 0 has no previous chunk
    ok = ok[None, :, :] & ~(first[:, None, None] & (kpos < W)[None])
    s = jnp.where(ok[None, :, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bnhgqk,bnkhd->bnqhgd", p.astype(v2.dtype), v2,
                   preferred_element_type=jnp.float32)
    o = o.reshape(B, Sp, Hq, D)[:, :S]
    return o.astype(q.dtype)


# ---------------------------------------------------------------------------
# decode (single new token against a cache)
# ---------------------------------------------------------------------------


DECODE_CHUNK = 4096  # above this cache length, stream chunks (flash-decode)


def decode_attention(q, k_cache, v_cache, cache_index, *, window: int = 0,
                     prefer_chunked: bool = True):
    """q (B,1,Hq,D); caches (B,T,Hk,D); cache_index (B,) int32 = current length
    (the new token's k/v must already be written at cache_index - 1)."""
    B, _, Hq, D = q.shape
    T = k_cache.shape[1]
    if prefer_chunked and T > DECODE_CHUNK and T % DECODE_CHUNK == 0:
        return _decode_attention_chunked(q, k_cache, v_cache, cache_index,
                                         window=window, chunk=DECODE_CHUNK)
    s = _gqa_scores(q, k_cache)  # (B,Hk,G,1,T)
    kpos = jnp.arange(T)[None, :]  # (1,T)
    ok = kpos < cache_index[:, None]
    if window:
        ok &= kpos >= cache_index[:, None] - window
    s = jnp.where(ok[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return _gqa_out(p, v_cache, q.dtype)


def _decode_attention_chunked(q, k_cache, v_cache, cache_index, *,
                              window: int, chunk: int):
    """Online-softmax over KV chunks: never materializes the (B, H, T)
    score row — the pure-JAX shape of the flash-decode kernel, used by the
    32k/500k serve steps so decode temp memory is O(chunk)."""
    B, _, Hq, D = q.shape
    T = k_cache.shape[1]
    Hk = k_cache.shape[2]
    G = Hq // Hk
    n = T // chunk
    qg = q.reshape(B, Hk, G, D)
    kc = k_cache.reshape(B, n, chunk, Hk, D)
    vc = v_cache.reshape(B, n, chunk, Hk, D)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    def kv_step(carry, inp):
        m, l, o = carry
        kj, vj, j = inp  # kj/vj (B, chunk, Hk, D)
        s = jnp.einsum("bhgd,bkhd->bhgk", qg, kj,
                       preferred_element_type=jnp.float32) * scale
        kpos = j * chunk + jnp.arange(chunk)[None, :]
        ok = kpos < cache_index[:, None]
        if window:
            ok &= kpos >= cache_index[:, None] - window
        s = jnp.where(ok[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jnp.einsum("bhgk,bkhd->bhgd", p.astype(vj.dtype), vj,
                        preferred_element_type=jnp.float32)
        o_new = o * alpha[..., None] + pv
        return (m_new, l_new, o_new), ()

    m0 = jnp.full((B, Hk, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hk, G), jnp.float32)
    o0 = jnp.zeros((B, Hk, G, D), jnp.float32)
    (m, l, o), _ = jax.lax.scan(
        kv_step, (m0, l0, o0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n)))
    o = o / jnp.maximum(l[..., None], 1e-30)
    return o.reshape(B, 1, Hq, D).astype(q.dtype)
