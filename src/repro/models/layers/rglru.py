"""RG-LRU recurrent block (RecurrentGemma / Griffin).

Recurrence (per channel):
    r_t = sigmoid(x_t W_a + b_a)              -- recurrence gate
    i_t = sigmoid(x_t W_x + b_x)              -- input gate
    log a_t = c * r_t * log sigmoid(Lambda)   -- c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The input-dependent pieces (r, i, gated x, a) have **no recurrent
dependency** — the Unfolded split hoists them out of the scan as one
sequence-parallel computation; the scan body keeps only the two fused
multiply-adds.  This is the paper's across-sequence overlap, verbatim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.common import chunked_scan, dense_init

C_EXP = 8.0


def init_rglru(key, width: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    # Lambda init so a^c spans ~(0.9, 0.999) as in Griffin
    u = jax.random.uniform(k3, (width,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** (1.0 / C_EXP) / (1 - u ** (1.0 / C_EXP)))
    return {
        "w_a": dense_init(k1, (width, width), dtype),
        "b_a": jnp.zeros((width,), dtype),
        "w_x": dense_init(k2, (width, width), dtype),
        "b_x": jnp.zeros((width,), dtype),
        "Lambda": lam.astype(jnp.float32),
    }


def gate_inputs(params, x):
    """Sequence-parallel half (hoisted by the Unfolded schedule).

    x (B, T, W) -> (log_a (B,T,W) fp32, gx (B,T,W) fp32)
    """
    r = jax.nn.sigmoid((x @ params["w_a"] + params["b_a"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ params["w_x"] + params["b_x"]).astype(jnp.float32))
    log_a = C_EXP * r * jax.nn.log_sigmoid(params["Lambda"])
    gx = i * x.astype(jnp.float32)
    return log_a, gx


def scan_recurrence(log_a, gx, h0):
    """Serial half: h_t = a_t h_{t-1} + sqrt(1-a_t^2) gx_t.  All fp32."""

    def step(h, inp):
        la, g = inp
        a = jnp.exp(la)
        h = a * h + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * g
        return h, h

    hT, hs = chunked_scan(step, h0, (log_a.swapaxes(0, 1), gx.swapaxes(0, 1)))
    return hT, hs.swapaxes(0, 1)  # (B, T, W)


def apply_rglru(params, x, h0=None):
    """x (B, T, W) -> (y (B, T, W), h_T)."""
    B, T, W = x.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)
    log_a, gx = gate_inputs(params, x)
    hT, hs = scan_recurrence(log_a, gx, h0)
    return hs.astype(x.dtype), hT


def decode_step(params, x_t, h_prev):
    """x_t (B, W), h_prev (B, W) fp32 -> (y_t, h_t)."""
    log_a, gx = gate_inputs(params, x_t[:, None, :])
    a = jnp.exp(log_a[:, 0])
    h = a * h_prev + jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0)) * gx[:, 0]
    return h.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# temporal conv (width-k causal depthwise conv), part of the Griffin block
# ---------------------------------------------------------------------------


def init_conv1d(key, width: int, k: int, dtype):
    return {"w": dense_init(key, (k, width), dtype, scale=0.5), "b": jnp.zeros((width,), dtype)}


def apply_conv1d(params, x, state=None):
    """Causal depthwise conv.  x (B,T,W); state (B,k-1,W) for decode.

    Returns (y, new_state)."""
    k = params["w"].shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, T+k-1, W)
    y = sum(xp[:, i:i + x.shape[1]] * params["w"][i] for i in range(k))
    y = y + params["b"]
    new_state = xp[:, xp.shape[1] - (k - 1):]
    return y.astype(x.dtype), new_state
