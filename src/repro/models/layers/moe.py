"""Mixture-of-Experts FFN: top-k routing with capacity-based scatter dispatch.

GShard/Switch-style: tokens pick top-k experts; each expert has a fixed
capacity C = ceil(T * k * capacity_factor / E); overflowing tokens are
dropped (their contribution is zero — the residual connection carries them).
Dispatch/combine use scatter/gather with (expert, slot) index pairs instead
of the T x E x C one-hot einsum, keeping memory at O(E*C*d) so the 1M-token
prefill cells stay compileable.

Invariants (property-tested):
  * combine weights per token sum to <= 1 (== 1 when nothing dropped)
  * each (expert, slot) holds at most one token
  * with capacity_factor large enough, output == dense-einsum reference
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init, shard_act


def init_moe(key, d: int, ff: int, n_experts: int, dtype, dense_ff: int = 0):
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], (d, n_experts), jnp.float32),
        "w_gate": dense_init(ks[1], (n_experts, d, ff), dtype),
        "w_up": dense_init(ks[2], (n_experts, d, ff), dtype),
        "w_down": dense_init(ks[3], (n_experts, ff, d), dtype),
    }
    if dense_ff:
        from repro.models.layers.mlp import init_mlp

        p["dense"] = init_mlp(ks[4], d, dense_ff, dtype)
    return p


def _capacity(T: int, k: int, E: int, factor: float) -> int:
    c = math.ceil(T * k * factor / E)
    return max(8, min(c, T))


def route(router_logits, k: int, capacity: int, n_experts: int):
    """router_logits (T, E) fp32 -> dispatch info.

    Returns (expert_idx, slot_idx, weight, valid), each (T, k).
    """
    T = router_logits.shape[0]
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # slot assignment: position of each (token, choice) within its expert,
    # ordered token-major (tokens earlier in the batch win capacity).
    flat_e = top_e.reshape(-1)  # (T*k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)  # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1  # position among same-expert picks
    slot = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]  # (T*k,)
    valid = slot < capacity
    return (
        top_e,
        slot.reshape(T, k),
        top_w,
        valid.reshape(T, k),
    )


def aux_load_balance_loss(router_logits, top_e, n_experts: int):
    """Switch-style load balance loss (mean over experts of f_e * p_e * E)."""
    probs = jax.nn.softmax(router_logits, axis=-1)
    p_mean = probs.mean(axis=0)  # (E,)
    counts = jnp.zeros((n_experts,), jnp.float32).at[top_e.reshape(-1)].add(1.0)
    f = counts / jnp.maximum(counts.sum(), 1.0)
    return n_experts * jnp.sum(f * p_mean)


def apply_moe(params, x, *, k: int, capacity_factor: float, deterministic_capacity: int = 0):
    """x (B, S, d) -> (y (B, S, d), aux_loss scalar fp32)."""
    B, S, d = x.shape
    E = params["router"].shape[1]
    T = B * S
    xt = x.reshape(T, d)
    C = deterministic_capacity or _capacity(T, k, E, capacity_factor)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    expert_idx, slot_idx, weight, valid = route(logits, k, C, E)
    aux = aux_load_balance_loss(logits, expert_idx, E)

    # ---- dispatch: scatter tokens into (E, C, d) buffers --------------
    flat_e = expert_idx.reshape(-1)
    flat_s = slot_idx.reshape(-1)
    flat_v = valid.reshape(-1)
    flat_s = jnp.where(flat_v, flat_s, 0)  # clamp (contribution masked below)
    src = jnp.repeat(xt, k, axis=0) * flat_v[:, None].astype(x.dtype)
    buf = jnp.zeros((E, C, d), x.dtype)
    buf = buf.at[flat_e, flat_s].add(src, mode="drop")
    # EP over experts only.  (Sharding C over 'data' was tried and REFUTED:
    # it misaligns the expert contraction and blew the collective term up
    # 4x on arctic — see EXPERIMENTS.md §Perf.)
    buf = shard_act(buf, "experts", None, None)

    # ---- expert computation (E, C, d) x (E, d, f) ---------------------
    g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u).astype(x.dtype)
    h = shard_act(h, "experts", None, "ff_fsdp")
    out = jnp.einsum("ecf,efd->ecd", h, params["w_down"],
                     preferred_element_type=jnp.float32).astype(x.dtype)

    # ---- combine: gather back and weight ------------------------------
    gathered = out[flat_e, flat_s]  # (T*k, d)
    w = (weight.reshape(-1) * valid.reshape(-1)).astype(x.dtype)
    y = (gathered * w[:, None]).reshape(T, k, d).sum(axis=1)
    y = y.reshape(B, S, d)

    if "dense" in params:
        from repro.models.layers.mlp import apply_mlp

        y = y + apply_mlp(params["dense"], x)
    return y, aux


def moe_reference(params, x, *, k: int):
    """Dense all-experts reference (no capacity drops): every token computes
    every expert, combined by renormalized top-k weights.  O(T*E*ff)."""
    B, S, d = x.shape
    xt = x.reshape(-1, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    E = probs.shape[-1]
    mask = (jax.nn.one_hot(top_e, E, dtype=jnp.float32) * top_w[..., None]).sum(1)
    g = jnp.einsum("td,edf->tef", xt, params["w_gate"],
                   preferred_element_type=jnp.float32)
    u = jnp.einsum("td,edf->tef", xt, params["w_up"],
                   preferred_element_type=jnp.float32)
    h = (jax.nn.silu(g) * u)
    o = jnp.einsum("tef,efd->ted", h.astype(x.dtype), params["w_down"],
                   preferred_element_type=jnp.float32)
    y = (o * mask[..., None]).sum(1).astype(x.dtype).reshape(B, S, d)
    if "dense" in params:
        from repro.models.layers.mlp import apply_mlp

        y = y + apply_mlp(params["dense"], x)
    return y
