"""Gated (SwiGLU-style) MLP."""
import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init, matmul, shard_act


def init_mlp(key, d: int, ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d, ff), dtype),
        "w_up": dense_init(k2, (d, ff), dtype),
        "w_down": dense_init(k3, (ff, d), dtype),
    }


def apply_mlp(params, x):
    g = matmul(x, params["w_gate"])
    u = matmul(x, params["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    h = shard_act(h, "batch", "seq", "ff")
    return matmul(h, params["w_down"])
