"""SHARP's contribution, generalized: schedules, reconfigurable tiling,
the critical-path performance model, and the offline autotune table."""
from repro.core import autotune, perfmodel, schedules, tiling, unfolded  # noqa: F401
