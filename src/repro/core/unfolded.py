"""Generalized Unfolded scheduling: the paper's technique as a reusable tool.

``unfold`` factors any gated recurrence into:
  (1) an input half computed for all T steps as one sequence-parallel GEMM
      (MXU-dense, no recurrent dependency), and
  (2) a recurrent scan whose body consumes the precomputed slice.

The LSTM/xLSTM/RG-LRU layers use this structurally (see models/layers);
this module adds the *distributed* form: the 4H gate axis is sharded over
the ``model`` mesh axis, so each chip holds a (H x 4H/n) slice of U and the
per-step reduction is a psum that XLA overlaps with the already-issued
input GEMM of later timesteps — the TPU rendition of Fig. 8.d, where the
tree-adder's implicit synchronization becomes an ICI collective.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.layers.lstm import cell_update


def unfold(input_fn: Callable, recur_fn: Callable, xs, state, *,
           seq_fn: Optional[Callable] = None):
    """Generic unfolded runner.

    input_fn: xs (B,T,...) -> precomputed (B,T,...) input-half tensors
    recur_fn: (state, pre_t) -> (state, out_t)
    seq_fn:   (state, pre) -> (state, outs) — a sequence-fused recurrence
              (e.g. kernels.lstm_cell.ops.as_seq_kernel) that consumes the
              whole precomputed tensor in ONE kernel launch, replacing the
              per-step scan entirely.  ``pre``/``outs`` stay batch-major.
    """
    pre = input_fn(xs)
    if seq_fn is not None:
        return seq_fn(state, pre)

    def step(st, pre_t):
        return recur_fn(st, pre_t)

    state, outs = jax.lax.scan(step, state, jax.tree.map(lambda a: a.swapaxes(0, 1), pre))
    return state, jax.tree.map(lambda a: a.swapaxes(0, 1), outs)


# ---------------------------------------------------------------------------
# distributed LSTM layer (gate-dim tensor parallel)
# ---------------------------------------------------------------------------


def lstm_param_specs(mesh_axis: str = "model"):
    """PartitionSpecs for an LSTM layer: gate (4H) axis sharded."""
    return {"W": P(None, mesh_axis), "U": P(None, mesh_axis), "b": P(mesh_axis)}


def run_layer_unfolded_tp(params, xs, mesh: Mesh, axis: str = "model"):
    """Unfolded schedule with the gate axis tensor-parallel over ``axis``.

    Weights arrive sharded (lstm_param_specs); activations: xs replicated on
    ``axis`` (sharded over 'data' on batch).  Each step's recurrent GEMM
    produces the local 4H/n gate slice; the hidden state h (H,) must be
    all-gathered for the next step's U·h — expressed here via sharding
    constraints so GSPMD schedules the collective, which can overlap the
    next step's (independent) input GEMM slice.
    """
    H = params["U"].shape[0]
    B, T, X = xs.shape

    def constrained(v, spec):
        return jax.lax.with_sharding_constraint(v, NamedSharding(mesh, spec))

    # sequence-parallel input half — one big GEMM, gate axis sharded
    xw = jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]
    xw = constrained(xw, P(None, None, axis))

    def step(carry, xw_t):
        h, c = carry
        gates = xw_t + h @ params["U"]  # local gate slice
        gates = constrained(gates, P(None, axis))
        h2, c2 = cell_update(gates, c)  # pointwise on the local slice...
        # ...but h is consumed un-sharded next step: constrain to replicated
        h2 = constrained(h2.astype(xs.dtype), P(None))
        c2 = constrained(c2, P(None))
        return (h2, c2), h2

    h0 = jnp.zeros((B, H), xs.dtype)
    c0 = jnp.zeros((B, H), jnp.float32)
    (_, _), hs = jax.lax.scan(step, (h0, c0), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)
