"""Offline configuration table (paper §6.2.2).

The paper explores configurations offline and preloads a table mapping each
LSTM dimension to its optimal tile configuration; runtime reconfiguration is
a table lookup + mux select.  Here the table maps (rows, cols, macs) -> K
for the cycle model and (m, n) -> Pallas block shape for the kernels, and is
persisted as JSON next to the artifacts.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Optional, Tuple

from repro.core.tiling import (TileConfig, select_block_shape,
                               select_time_block, select_tile)

DEFAULT_PATH = os.path.join("artifacts", "autotune_table.json")


class ConfigTable:
    def __init__(self, path: str = DEFAULT_PATH):
        self.path = path
        self._tiles: Dict[str, int] = {}
        self._blocks: Dict[str, Tuple[int, int]] = {}
        self._seq_blocks: Dict[str, int] = {}
        if os.path.exists(path):
            with open(path) as f:
                data = json.load(f)
            self._tiles = data.get("tiles", {})
            self._blocks = {k: tuple(v) for k, v in data.get("blocks", {}).items()}
            self._seq_blocks = data.get("seq_blocks", {})

    # -- paper tile engine ------------------------------------------------
    def tile(self, rows: int, cols: int, macs: int) -> TileConfig:
        key = f"{rows}x{cols}@{macs}"
        if key not in self._tiles:
            self._tiles[key] = select_tile(rows, cols, macs).k
        return TileConfig(k=self._tiles[key], macs=macs)

    # -- Pallas blocks ----------------------------------------------------
    def block(self, m: int, n: int, **kw) -> Tuple[int, int]:
        key = f"{m}x{n}"
        if key not in self._blocks:
            self._blocks[key] = select_block_shape(m, n, **kw)
        return self._blocks[key]

    def seq_block(self, T: int, B: int, H: int, *, gates: int = 4,
                  precision: str = "fp32", density: float = 1.0, **kw) -> int:
        """T-block for the sequence-fused recurrent kernels (LSTM: gates=4,
        GRU: gates=3).  Keys for gates=4 / fp32 / dense stay unsuffixed so
        persisted PR-1 tables remain valid; quantized (``p{precision}``)
        and block-sparse (``d{density}``) variants key separately — the
        narrowed resident-U footprint re-tunes them to larger stripes."""
        key = f"{T}x{B}x{H}" if gates == 4 else f"{T}x{B}x{H}g{gates}"
        if precision != "fp32":
            key += f"p{precision}"
        if density != 1.0:
            key += f"d{round(density, 4):g}"
        if key not in self._seq_blocks:
            self._seq_blocks[key] = select_time_block(
                T, B, H, gates=gates, precision=precision, density=density,
                **kw)
        return self._seq_blocks[key]

    def save(self):
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "w") as f:
            json.dump({"tiles": self._tiles, "blocks": self._blocks,
                       "seq_blocks": self._seq_blocks}, f, indent=1)


_GLOBAL: Optional[ConfigTable] = None


def table() -> ConfigTable:
    global _GLOBAL
    if _GLOBAL is None:
        _GLOBAL = ConfigTable()
    return _GLOBAL
