"""Critical-path cycle model of SHARP (and the E-PUR / BrainWave baselines).

The paper's own evaluation is a cycle-accurate C++ simulator fed with
synthesis timings (§7).  This module is the analytical counterpart: it models
the three-stage pipeline (Compute Unit -> A-MFU -> Cell Updater) per schedule
and regenerates the paper's figures/tables, which is how we validate the
reproduction against the paper's claims (see EXPERIMENTS.md):

  Fig. 9   K-width exploration          -> ``fig9_kwidth_sweep``
  Fig. 10  padding reconfiguration      -> ``fig10_padding_speedup``
  Fig. 11  schedule comparison          -> ``fig11_schedule_speedups``
  Fig. 12  latency & utilization        -> ``fig12_latency_utilization``
  Table 4  vs BrainWave (DeepBench)     -> ``table4_vs_brainwave``
  Table 6  vs E-PUR (4 networks)        -> ``table6_vs_epur``
  Fig. 14  energy vs E-PUR              -> ``fig14_energy``

Model constants follow Table 1: 500 MHz, K/4 hidden elements retired per
cycle by the Cell Updater, pipelined activation (1/cycle throughput,
ACT_LAT fill latency from the 29.14 ns synthesized tanh critical path).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.core.tiling import K_CHOICES, TileConfig, mvm_cycles, select_tile

FREQ_HZ = 500e6
ACT_LAT = 15  # pipeline-fill latency of the A-MFU (29.14ns @ ~2ns stages)
# Fig. 15 caption: total power under 1K..64K MACs
POWER_W = {1024: 8.11, 4096: 11.36, 16384: 22.13, 65536: 47.7}
# §8: SHARP dissipates 1.4%..36% more power than E-PUR at 1K..64K
EPUR_POWER_RATIO = {1024: 1.014, 4096: 1.10, 16384: 1.25, 65536: 1.36}
PEAK_TFLOPS = {1024: 0.46e12, 4096: 1.86e12, 16384: 7.4e12, 65536: 29.8e12}


@dataclass(frozen=True)
class Design:
    macs: int
    k: int = 0                  # 0 -> offline-autotuned K_opt per model
    schedule: str = "unfolded"
    reconfigure: bool = True    # §6.2.1 padding reconfiguration
    freq_hz: float = FREQ_HZ
    pipeline_penalty: int = 0   # extra dependent-writeback stall (BrainWave)
    efficiency: float = 1.0     # static pipeline efficiency (BrainWave)


def _tile_for(design: Design, rows: int, cols: int) -> TileConfig:
    if design.k:
        return TileConfig(k=design.k, macs=design.macs)
    return select_tile(rows, cols, design.macs, reconfigure=design.reconfigure)


def step_cycles(H: int, X: int, design: Design) -> float:
    """Critical-path cycles of one LSTM time step under a schedule (Fig. 8)."""
    tile = _tile_for(design, 4 * H, max(H, X))
    rc = design.reconfigure
    upd_full = math.ceil(4 * H / tile.k)
    upd_chunk = max(1, upd_full // 4)  # output-based tiling: only last chunk exposed
    s = design.schedule
    if s == "sequential":
        mvm = 4 * (mvm_cycles(H, X, tile, rc) + mvm_cycles(H, H, tile, rc))
        cp = mvm + ACT_LAT + upd_full
    elif s == "batch":
        mvm = 4 * (mvm_cycles(H, X, tile, rc) + mvm_cycles(H, H, tile, rc))
        cp = mvm + ACT_LAT + upd_chunk + 2
    elif s == "intergate":
        mvm = mvm_cycles(4 * H, X, tile, rc) + mvm_cycles(4 * H, H, tile, rc)
        cp = mvm + ACT_LAT + upd_chunk
    elif s == "unfolded":
        mvm_h = mvm_cycles(4 * H, H, tile, rc)
        mvm_in = mvm_cycles(4 * H, X, tile, rc)
        # the serial tail hides under the (independent) next-step input MVM
        cp = mvm_h + max(mvm_in, ACT_LAT + upd_chunk)
    elif s == "epur":
        # E-PUR (paper §5/§9): hoists ALL input MVMs up front (locality), but
        # the recurrent phase is fully serial — hidden MVM then the complete
        # activation + cell/hidden update, nothing overlapped across steps.
        mvm_h = mvm_cycles(4 * H, H, tile, rc)
        mvm_in = mvm_cycles(4 * H, X, tile, rc)
        cp = mvm_in + mvm_h + ACT_LAT + upd_full
    else:
        raise ValueError(s)
    return (cp + design.pipeline_penalty) / design.efficiency


def layer_cycles(H: int, X: int, T: int, design: Design,
                 bidirectional: bool = False) -> float:
    per = step_cycles(H, X, design)
    dirs = 2 if bidirectional else 1
    return dirs * T * per


def network_cycles(cfg: ModelConfig, T: int, design: Design) -> float:
    """Whole network: layer l>0 consumes the previous layer's hidden output
    ((2)H wide when bidirectional)."""
    H = cfg.lstm_hidden
    X = cfg.lstm_input
    total = 0.0
    for l in range(cfg.n_layers):
        x_dim = X if l == 0 else H * (2 if cfg.bidirectional else 1)
        total += layer_cycles(H, x_dim, T, design, cfg.bidirectional)
    return total


def network_time_s(cfg: ModelConfig, T: int, design: Design) -> float:
    return network_cycles(cfg, T, design) / design.freq_hz


def ideal_cycles(cfg: ModelConfig, T: int, macs: int) -> float:
    H, X = cfg.lstm_hidden, cfg.lstm_input
    dirs = 2 if cfg.bidirectional else 1
    total = 0.0
    for l in range(cfg.n_layers):
        x_dim = X if l == 0 else H * dirs
        total += dirs * T * (4 * H * x_dim + 4 * H * H) / macs
    return total


def utilization(cfg: ModelConfig, T: int, design: Design) -> float:
    return min(1.0, ideal_cycles(cfg, T, design.macs) / network_cycles(cfg, T, design))


def energy_j(cfg: ModelConfig, T: int, design: Design,
             power_w: Optional[float] = None) -> float:
    p = power_w if power_w is not None else POWER_W[design.macs]
    return p * network_time_s(cfg, T, design)


# ===========================================================================
# dispatch-plan scoring (repro.dispatch planner)
# ===========================================================================

# Fixed cost charged per kernel launch (dispatch + state HBM round-trip) —
# the cycle-model analogue of what the sequence-fused kernels eliminate.
# Calibrated coarse: a launch is worth a few hundred retired tiles.
LAUNCH_CYCLES = 400


def recurrent_step_cycles(family: str, H: int, X: int, design: Design) -> float:
    """Per-step critical-path cycles of one recurrent cell under the design's
    schedule, per family.  RG-LRU has no recurrent MVM (diagonal recurrence):
    its step is the pointwise tail only."""
    if family == "lstm":
        return step_cycles(H, X, design)
    if family == "gru":
        from repro.core.gru import gru_step_cycles

        return gru_step_cycles(H, X, design)
    if family == "rglru":
        return ACT_LAT + math.ceil(H / max(design.k or 64, 1))
    raise ValueError(family)


def stack_plan_cycles(family: str, H: int, X: int, T: int, L: int,
                      design: Design, *, nk: int,
                      launch_cycles: float = LAUNCH_CYCLES) -> float:
    """Wall-clock cycle estimate of running an L-layer stack over T steps as
    an (L x nk) wavefront of time-chunks (nk=1 == the per-layer fused path).

    Slot s holds up to min(L, nk) cells which execute *concurrently* on the
    tile engine (one G-batched launch), so the wall is the slot count times
    one chunk's serial cost, plus the per-launch overhead — the quantity the
    planner minimizes when it chooses a schedule and T-striping per item.
    """
    nk = max(1, min(nk, T)) if T else 1
    bt = -(-T // nk) if T else 0
    per0 = recurrent_step_cycles(family, H, X, design)
    per = recurrent_step_cycles(family, H, H, design) if L > 1 else per0
    # a slot's serial cost is one chunk through one (average) layer: the
    # wave mixes layer-0 and deeper cells, so charge the stack's per-layer
    # mean — this also keeps nk=1 exactly equal to per_step's compute
    # (same work, L launches instead of L·T)
    slot_cost = bt * (per0 + (L - 1) * per) / L
    slots = L + nk - 1
    return slots * slot_cost + slots * launch_cycles


def bidir_stack_plan_cycles(family: str, H: int, X: int, T: int, L: int,
                            design: Design, *, nk: int,
                            launch_cycles: float = LAUNCH_CYCLES) -> float:
    """Wall-clock cycle estimate of an L-layer *bidirectional* stack run as
    the interleaved fwd/bwd wavefront (dispatch planner, ISSUE-5).

    Each layer contributes a fwd chunk walk (time-ascending) and a bwd walk
    (time-descending) over the same nk chunk boundaries.  The concat
    dependency — layer l+1's chunk k needs BOTH fwd chunk k and bwd chunk k
    of layer l — means the walks of consecutive layers barely overlap, so
    the timeline is L·nk waves; but within a wave the two directions are
    data-independent and share ONE G-batched launch (they hide each other's
    serial tails), halving the serial wall versus running the directions
    back to back.  Ragged T adds two unmerged waves per layer (the
    remainder chunk meets a full-length chunk of the opposite direction,
    breaking the launch signature), each costing one extra launch.
    """
    nk = max(1, min(nk, T)) if T else 1
    bt = -(-T // nk) if T else 0
    per0 = recurrent_step_cycles(family, H, X, design)
    # deeper layers consume the previous layer's CONCAT output (2H wide)
    per = recurrent_step_cycles(family, H, 2 * H, design) if L > 1 else per0
    slot_cost = bt * (per0 + (L - 1) * per) / L
    waves = L * nk
    ragged = 2 if (T and nk > 1 and T % bt) else 0
    launches = L * (nk + ragged)
    return waves * slot_cost + launches * launch_cycles


def per_step_plan_cycles(family: str, H: int, X: int, T: int, L: int,
                         design: Design, *,
                         launch_cycles: float = LAUNCH_CYCLES) -> float:
    """Wall-clock cycle estimate of the per-step fallback: every (layer,
    timestep) cell is its own launch with its state round-tripping HBM."""
    per0 = recurrent_step_cycles(family, H, X, design)
    per = recurrent_step_cycles(family, H, H, design) if L > 1 else per0
    return T * (per0 + (L - 1) * per) + L * T * launch_cycles


# B rows retire through the datapath in row-tiles of this width (the MXU/
# sublane granularity): padding a cell's B up to the tile edge is free,
# which is what makes B-widened (padded + masked) slots usually beat an
# extra same-signature launch.
MXU_ROWS = 8

#: Relative per-step MAC cost under each recurrent-weight precision.
#: fp32 is the unit; bf16 narrows the weight operand (half the weight
#: bandwidth feeding the MXU); int8 halves it again plus the dequantize
#: ride-along on the accumulate.  These are planner-scoring ratios, not
#: silicon truth — ``cost_model="measured"`` replaces them with replayed
#: reality (calib signatures carry the precision tag).
PRECISION_MAC_FACTOR = {"fp32": 1.0, "bf16": 0.75, "int8": 0.5}


def slot_launch_cycles(family: str, H: int, chunk_len: int,
                       widths: Sequence[int], design: Design, *,
                       launch_cycles: float = LAUNCH_CYCLES,
                       precision: str = "fp32",
                       density: float = 1.0) -> float:
    """Cycle cost of ONE G-batched sequence-kernel launch whose g-rows are
    the given batch widths, padded to max(widths).

    The kernel grid walks rows serially; each row's per-step cost scales
    with its padded B-row-tile count.  The planner uses this to score a
    B-widened slot (pad ragged widths to one launch, mask the dead rows)
    against splitting by width (exact rows, one more launch each) — the
    "B-widened vs G-batched" decision of cross-B packing.

    ``precision`` applies the PRECISION_MAC_FACTOR discount and
    ``density`` the block-sparse skipped-row-tile discount (the recurrent
    MVM only visits occupied input-row tiles) — both scale the per-step
    MAC term, never the launch overhead."""
    per = recurrent_step_cycles(family, H, H, design)
    per *= PRECISION_MAC_FACTOR[precision] * density
    row_tiles = math.ceil(max(widths) / MXU_ROWS)
    return len(widths) * chunk_len * per * row_tiles + launch_cycles


def decode_plan_cycles(family: str, H: int, X: int, L: int, design: Design, *,
                       launch_cycles: float = LAUNCH_CYCLES) -> float:
    """Wall-clock cycle estimate of one chained T=1 decode launch: the L
    layer cells are serially dependent (no wavefront exists at T=1), but
    they share a single launch — the layer chain runs through VMEM scratch
    inside one kernel — so only one launch overhead is paid per tick,
    versus L for the per-layer path (stack_plan_cycles with nk=1)."""
    per0 = recurrent_step_cycles(family, H, X, design)
    per = recurrent_step_cycles(family, H, H, design) if L > 1 else per0
    return per0 + (L - 1) * per + launch_cycles


# ===========================================================================
# paper figure/table generators
# ===========================================================================

from repro.configs.sharp_lstm import (  # noqa: E402
    DEEPBENCH, MAC_BUDGETS, PAPER_NETWORKS, SWEEP_HIDDEN_DIMS, lstm_config,
)


def fig9_kwidth_sweep(k_widths=K_CHOICES, dims=SWEEP_HIDDEN_DIMS,
                      budgets=MAC_BUDGETS) -> Dict:
    """Speedup of (K, H, M) vs the 1K-MAC best design (paper's normalization)."""
    out = {}
    for m in budgets:
        base = {h: network_cycles(lstm_config(h), 25, Design(macs=1024))
                for h in dims}
        for k in k_widths:
            if k > m:
                continue
            for h in dims:
                d = Design(macs=m, k=k, reconfigure=False)
                out[(m, k, h)] = base[h] / network_cycles(lstm_config(h), 25, d)
    return out


def fig9_best_k(budget: int, dims=SWEEP_HIDDEN_DIMS) -> Dict[int, int]:
    """argmax_K speedup per hidden dim (the 'no single best K' claim)."""
    sweep = fig9_kwidth_sweep(budgets=[budget], dims=dims)
    best = {}
    for h in dims:
        ks = [(v, k) for (m, k, hh), v in sweep.items() if hh == h]
        best[h] = max(ks)[1]
    return best


def fig10_padding_speedup(dims=SWEEP_HIDDEN_DIMS, budgets=MAC_BUDGETS) -> Dict:
    """Speedup of edge reconfiguration vs fixed K (paper: <=1.22x, =1 @512).

    Faithful to §6.2.1: K_opt is configured per (dim, budget) first; the two
    designs share that K and differ only in the edge-stripe reconfiguration.
    """
    out = {}
    for m in budgets:
        for h in dims:
            cfg = lstm_config(h)
            k_opt = select_tile(4 * h, h, m, reconfigure=True).k
            fixed = Design(macs=m, k=k_opt, reconfigure=False)
            rec = Design(macs=m, k=k_opt, reconfigure=True)
            out[(m, h)] = network_cycles(cfg, 25, fixed) / network_cycles(cfg, 25, rec)
    return out


def fig11_schedule_speedups(dims=SWEEP_HIDDEN_DIMS, budgets=MAC_BUDGETS) -> Dict:
    """Speedup of each schedule vs Sequential (k=32 column-wise per §8)."""
    out = {}
    for m in budgets:
        for h in dims:
            cfg = lstm_config(h)
            seq = network_cycles(cfg, 25, Design(macs=m, k=32, schedule="sequential"))
            for s in ("sequential", "batch", "intergate", "unfolded"):
                c = network_cycles(cfg, 25, Design(macs=m, k=32, schedule=s))
                out[(m, h, s)] = seq / c
    return out


def fig12_latency_utilization(dims=SWEEP_HIDDEN_DIMS, budgets=MAC_BUDGETS) -> Dict:
    out = {}
    for m in budgets:
        for h in dims:
            cfg = lstm_config(h)
            d = Design(macs=m)
            out[(m, h)] = {
                "latency_us": network_time_s(cfg, 25, d) * 1e6,
                "utilization": utilization(cfg, 25, d),
                "epur_utilization": utilization(cfg, 25, _epur(m)),
            }
    return out


def _epur(macs: int) -> Design:
    """E-PUR: fixed dot-product tiling, input MVMs hoisted for locality,
    serial recurrent tail (no across-step overlap), no reconfiguration."""
    return Design(macs=macs, k=64, schedule="epur", reconfigure=False)


def table6_vs_epur(budgets=MAC_BUDGETS) -> Dict:
    out = {}
    for name, (cfg, T) in PAPER_NETWORKS.items():
        for m in budgets:
            sharp = network_cycles(cfg, T, Design(macs=m))
            epur = network_cycles(cfg, T, _epur(m))
            out[(name, m)] = epur / sharp
    return out


# --- BrainWave (Table 4) ----------------------------------------------------
# Modeled as a sequential-schedule NPU with a large hardened tile and a deep
# dependent-writeback pipeline; (K_bw, penalty, efficiency) are calibrated
# against the paper's reported speedups, mirroring the paper's own
# "Structurally-Constrained Model Critical-Path" validation of its BW model.

BW_MACS = 96 * 1024
BW_FREQ = 250e6
TABLE4_PAPER = {(256, 150): 5.39, (512, 25): 3.57, (1024, 25): 1.85, (1536, 50): 1.73}


def _bw_design(k_bw: int, penalty: int, eff: float) -> Design:
    return Design(macs=BW_MACS, k=k_bw, schedule="sequential", reconfigure=False,
                  freq_hz=BW_FREQ, pipeline_penalty=penalty, efficiency=eff)


def table4_vs_brainwave(k_bw: int = 0, penalty: int = 0, eff: float = 0.0) -> Dict:
    """SHARP@96K-MAC/250MHz vs the BrainWave model on DeepBench dims."""
    if not k_bw:
        k_bw, penalty, eff = fit_brainwave()
    out = {}
    for (h, T) in DEEPBENCH:
        cfg = lstm_config(h)
        sharp = network_cycles(cfg, T, Design(macs=BW_MACS, freq_hz=BW_FREQ))
        bw = network_cycles(cfg, T, _bw_design(k_bw, penalty, eff))
        out[(h, T)] = bw / sharp
    return out


def fit_brainwave() -> Tuple[int, int, float]:
    """Small grid search calibrating the BW model to Table 4."""
    best = None
    for k_bw in (512, 1024, 2048, 4096):
        for penalty in (0, 10, 20, 40, 80, 160):
            for eff in (0.3, 0.4, 0.5, 0.6, 0.8, 1.0):
                pred = table4_vs_brainwave(k_bw, penalty, eff)
                err = sum((math.log(pred[k] / v)) ** 2 for k, v in TABLE4_PAPER.items())
                if best is None or err < best[0]:
                    best = (err, (k_bw, penalty, eff))
    return best[1]


def fig14_energy(budgets=MAC_BUDGETS, dims=SWEEP_HIDDEN_DIMS) -> Dict:
    """Energy (J), normalized to E-PUR@1K per dim, plus the avg reduction."""
    out = {}
    for m in budgets:
        for h in dims:
            cfg = lstm_config(h)
            e_sharp = energy_j(cfg, 25, Design(macs=m))
            p_epur = POWER_W[m] / EPUR_POWER_RATIO[m]
            e_epur = energy_j(cfg, 25, _epur(m), power_w=p_epur)
            out[(m, h)] = {"sharp": e_sharp, "epur": e_epur,
                           "reduction": 1.0 - e_sharp / e_epur}
    return out


def gflops_per_watt(macs: int = 65536, dims=SWEEP_HIDDEN_DIMS) -> float:
    """Paper §10: 50% avg utilization of 29.8 TFLOPS at 47.7 W -> ~0.32 TF/W."""
    utils = [utilization(lstm_config(h), 25, Design(macs=macs)) for h in dims]
    avg_u = sum(utils) / len(utils)
    return PEAK_TFLOPS[macs] * avg_u / POWER_W[macs] / 1e9
