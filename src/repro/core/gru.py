"""GRU under SHARP's schedules (paper §8: "the same improvement can be
achieved in other networks that have similar design, such as GRU").

GRU is the harder case for Unfolded scheduling: the candidate gate
    n_t = tanh(W_n x_t + r_t * (U_n h_{t-1}) + b_n)
couples the recurrent MVM with the reset gate *multiplicatively*, so unlike
the LSTM not all of U·h can be hidden behind the next step's input GEMM —
only W·x is hoistable, and the three recurrent MVMs (U_z, U_r, U_n) remain
serial.  The schedules below mirror core/schedules.py and are numerically
equivalent (property-tested); the perf-model hook exposes the (slightly
smaller) Unfolded win the paper predicts for GRU.

``fused`` goes one further (mirroring core/schedules.py): the recurrent
scan itself moves inside ONE Pallas kernel launch (kernels.gru_cell), with
h resident in VMEM scratch for all T steps and the hoisted xw streamed in
T-block stripes — the per-step dispatch and the state HBM round-trip both
disappear, which is what lets the tile dispatcher plan GRU items.

Gate order along the 3H axis: (z, r, n).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers.common import dense_init

SCHEDULES = ("sequential", "intergate", "unfolded", "fused")


def init_gru_layer(key, x_dim: int, hidden: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "W": dense_init(k1, (x_dim, 3 * hidden), dtype),
        "U": dense_init(k2, (hidden, 3 * hidden), dtype),
        "b": jnp.zeros((3 * hidden,), dtype),
    }


def init_gru_stack(key, x_dim: int, hidden: int, n_layers: int, dtype):
    """Multi-layer GRU stack params, shaped like models.layers.lstm's
    ``init_lstm_stack`` ({"layers": [...]}) so the dispatcher can treat
    LSTM and GRU stacks uniformly."""
    layers = []
    for i in range(n_layers):
        key, sub = jax.random.split(key)
        layers.append(init_gru_layer(sub, x_dim if i == 0 else hidden,
                                     hidden, dtype))
    return {"layers": layers}


def _gates(xw, hu, H):
    """xw, hu (B, 3H) pre-activations -> new h (fp32)."""
    z = jax.nn.sigmoid((xw[:, :H] + hu[:, :H]).astype(jnp.float32))
    r = jax.nn.sigmoid((xw[:, H:2 * H] + hu[:, H:2 * H]).astype(jnp.float32))
    n = jnp.tanh(xw[:, 2 * H:].astype(jnp.float32)
                 + r * hu[:, 2 * H:].astype(jnp.float32))
    return z, n


def gru_step(params, x_t, h):
    H = params["U"].shape[0]
    xw = x_t @ params["W"] + params["b"]
    hu = h @ params["U"]
    z, n = _gates(xw, hu, H)
    h32 = (1 - z) * n + z * h.astype(jnp.float32)
    return h32.astype(x_t.dtype)


def reference_unroll(params, xs):
    B, T, _ = xs.shape
    H = params["U"].shape[0]
    h = jnp.zeros((B, H), xs.dtype)
    outs = []
    for t in range(T):
        h = gru_step(params, xs[:, t], h)
        outs.append(h)
    return jnp.stack(outs, axis=1)


def run_layer_sequential(params, xs):
    """One gate MVM pair after another per step."""
    B, T, X = xs.shape
    H = params["U"].shape[0]

    def step(h, x_t):
        parts_x, parts_h = [], []
        for g in range(3):
            Wg = jax.lax.dynamic_slice_in_dim(params["W"], g * H, H, 1)
            Ug = jax.lax.dynamic_slice_in_dim(params["U"], g * H, H, 1)
            bg = jax.lax.dynamic_slice_in_dim(params["b"], g * H, H, 0)
            parts_x.append(x_t @ Wg + bg)
            parts_h.append(h @ Ug)
        xw = jnp.concatenate(parts_x, -1)
        hu = jnp.concatenate(parts_h, -1)
        z, n = _gates(xw, hu, H)
        h2 = ((1 - z) * n + z * h.astype(jnp.float32)).astype(xs.dtype)
        return h2, h2

    _, hs = jax.lax.scan(step, jnp.zeros((B, H), xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_intergate(params, xs):
    B, T, X = xs.shape
    H = params["U"].shape[0]

    def step(h, x_t):
        h2 = gru_step(params, x_t, h)
        return h2, h2

    _, hs = jax.lax.scan(step, jnp.zeros((B, H), xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_unfolded(params, xs):
    """Input half W·x hoisted for every step; U·h (all three gates, fused)
    stays serial — the GRU-shaped Unfolded split."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    xw = jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    def step(h, xw_t):
        hu = h @ params["U"]
        z, n = _gates(xw_t, hu, H)
        h2 = ((1 - z) * n + z * h.astype(jnp.float32)).astype(xs.dtype)
        return h2, h2

    _, hs = jax.lax.scan(step, jnp.zeros((B, H), xs.dtype), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_fused(params, xs, block_t: int = 0, interpret=None,
                    return_state: bool = False):
    """Sequence-fused schedule: the whole GRU recurrence in ONE kernel
    launch — the lstm_seq T-stripe pattern ported to the 3-gate cell.
    ``return_state``: also return the exact t=T hidden state."""
    from repro.kernels.gru_cell.ops import gru_seq

    B, T, X = xs.shape
    H = params["U"].shape[0]
    xw = (jnp.einsum("btx,xg->btg", xs, params["W"])
          + params["b"]).reshape(B, T, 3, H)
    hs, h_n = gru_seq(params["U"].reshape(H, 3, H), xw, block_t=block_t,
                      interpret=interpret)
    hs = hs.astype(xs.dtype)
    return (hs, h_n.astype(xs.dtype)) if return_state else hs


LAYER_FNS = {"sequential": run_layer_sequential,
             "intergate": run_layer_intergate,
             "unfolded": run_layer_unfolded, "fused": run_layer_fused}


def run_layer(params, xs, schedule: str = "unfolded", **kw):
    """DEPRECATED shim over the unified front-end (repro.rnn) — a GRU
    layer's parameter dict is a one-layer stack, and ``compile`` infers the
    family from its 3H gate axis.  An unknown schedule now fails with a
    ValueError naming the options (this used to be a bare KeyError)."""
    import warnings

    warnings.warn(
        "repro.core.gru.run_layer is deprecated; use "
        "repro.rnn.compile({'layers': [params]}, "
        "ExecutionPolicy(schedule=...)).forward(xs) "
        "(see src/repro/rnn/README.md for the migration table)",
        DeprecationWarning, stacklevel=2)
    if any(k in kw for k in ("return_state",)):
        if schedule not in LAYER_FNS:
            raise ValueError(
                f"unknown schedule {schedule!r}; gru options {SCHEDULES}")
        return LAYER_FNS[schedule](params, xs, **kw)
    from repro.rnn import ExecutionPolicy, compile as _compile

    pol = ExecutionPolicy(schedule=schedule, block_t=kw.pop("block_t", 0),
                          interpret=kw.pop("interpret", None))
    if kw:
        raise TypeError(f"gru.run_layer: unexpected kwargs {sorted(kw)}")
    return _compile({"layers": [params]}, pol).forward(xs)


# --- perf-model hook (3 gates instead of 4; tail has no cell state) --------


def gru_step_cycles(H: int, X: int, design) -> float:
    """Critical-path cycles per GRU step under the SHARP model."""
    import math

    from repro.core.perfmodel import ACT_LAT, _tile_for
    from repro.core.tiling import mvm_cycles

    tile = _tile_for(design, 3 * H, max(H, X))
    rc = design.reconfigure
    upd_chunk = max(1, math.ceil(3 * H / tile.k) // 3)
    s = design.schedule
    if s == "sequential":
        mvm = 3 * (mvm_cycles(H, X, tile, rc) + mvm_cycles(H, H, tile, rc))
        return (mvm + ACT_LAT + upd_chunk * 3 + design.pipeline_penalty) / design.efficiency
    if s == "intergate":
        mvm = mvm_cycles(3 * H, X, tile, rc) + mvm_cycles(3 * H, H, tile, rc)
        return (mvm + ACT_LAT + upd_chunk + design.pipeline_penalty) / design.efficiency
    if s == "unfolded":
        mvm_h = mvm_cycles(3 * H, H, tile, rc)
        mvm_in = mvm_cycles(3 * H, X, tile, rc)
        return (mvm_h + max(mvm_in, ACT_LAT + upd_chunk)
                + design.pipeline_penalty) / design.efficiency
    raise ValueError(s)
