"""Reconfigurable MVM tile-engine, abstracted (paper §4.2, §6).

The hardware: N vector-scalar units of width K=32 ganged row-/column-wise
(Config1..4 in Fig. 7), so a fixed MAC budget M yields tile shapes
(K rows x M/K cols) for K in {32, 64, 128, 256}.  A tile is retired per
cycle; an MVM over a (rows x cols) weight matrix costs
ceil(rows/K) * ceil(cols/(M/K)) cycles, and every ceil() is *padding waste*.

Two artifacts live here:

1. The paper-faithful cycle/padding math + per-model tile selection
   (``select_tile``) and edge reconfiguration (``cycles`` with
   ``reconfigure=True`` shrinks K at the last row stripe — §6.2.1, the
   <=1.22x of Fig. 10).

2. The TPU translation (``select_block_shape``): BlockSpec tiles for the
   Pallas kernels, minimizing the same ceil-padding waste subject to MXU
   lane alignment (8, 128) and a VMEM budget — the paper's "offline table"
   becomes a block-shape autotuner.
"""
from __future__ import annotations

import functools
import math
from dataclasses import dataclass
from typing import List, Sequence, Tuple

K_CHOICES = (32, 64, 128, 256, 512)  # paper Fig. 9 exploration range


@dataclass(frozen=True)
class TileConfig:
    k: int        # VS width = tile rows
    macs: int     # total multiply-adders

    @property
    def cols(self) -> int:  # tile columns
        return max(1, self.macs // self.k)


def mvm_cycles(rows: int, cols: int, tile: TileConfig, reconfigure: bool = False) -> int:
    """Cycles to stream a (rows x cols) MVM through the tile engine.

    ``reconfigure``: at the final row stripe, the controller re-gangs the VS
    units to the largest K' <= K (power-of-two multiple of 32, or 8/16 for
    the smallest remainders) that does not overshoot the remaining rows —
    the padding reconfiguration of §6.2.1.
    """
    full_stripes, rem = divmod(rows, tile.k)
    col_passes = math.ceil(cols / tile.cols)
    cycles = full_stripes * col_passes
    if rem:
        if not reconfigure:
            cycles += col_passes
        else:
            # re-gang: bring K' as close to the remainder as the 32-wide
            # VS units allow (halving K doubles the columns)
            k2 = tile.k
            while k2 > 32 and k2 // 2 >= rem:
                k2 //= 2
            # K' halves free VS units to double the columns
            cols2 = max(1, tile.macs // k2)
            stripes2 = math.ceil(rem / k2)
            cycles += stripes2 * math.ceil(cols / cols2)
    return max(cycles, 1)


def padding_waste(rows: int, cols: int, tile: TileConfig) -> float:
    """Fraction of MAC-cycles burned on padding (fixed configuration)."""
    eff_r = math.ceil(rows / tile.k) * tile.k
    eff_c = math.ceil(cols / tile.cols) * tile.cols
    return 1.0 - (rows * cols) / (eff_r * eff_c)


def select_tile(rows: int, cols: int, macs: int,
                k_choices: Sequence[int] = K_CHOICES,
                reconfigure: bool = True) -> TileConfig:
    """The paper's offline exploration: argmin cycles over the K family."""
    best, best_cycles = None, None
    for k in k_choices:
        if k > macs:
            continue
        t = TileConfig(k=k, macs=macs)
        c = mvm_cycles(rows, cols, t, reconfigure=reconfigure)
        if best_cycles is None or c < best_cycles:
            best, best_cycles = t, c
    assert best is not None
    return best


# ---------------------------------------------------------------------------
# TPU translation: Pallas block shapes
# ---------------------------------------------------------------------------

LANE = 128     # MXU/VPU lane width (last dim)
SUBLANE = 8    # second-to-last dim granule (fp32)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def block_waste(m: int, n: int, bm: int, bn: int) -> float:
    em = math.ceil(m / bm) * bm
    en = math.ceil(n / bn) * bn
    return 1.0 - (m * n) / (em * en)


@functools.lru_cache(maxsize=None)
def select_block_shape(m: int, n: int, *, vmem_budget: int = 4 * 2**20,
                       bytes_per_el: int = 4,
                       bm_choices: Sequence[int] = (8, 16, 32, 64, 128, 256, 512),
                       bn_choices: Sequence[int] = (128, 256, 512, 1024, 2048),
                       ) -> Tuple[int, int]:
    """Choose (bm, bn) minimizing ceil-padding waste, then maximizing tile
    area (fewer grid steps), under a VMEM footprint bound — the TPU analogue
    of the paper's K-width table."""
    best = None
    for bm in bm_choices:
        if bm % SUBLANE and bm < m:
            continue
        for bn in bn_choices:
            if bm * bn * bytes_per_el > vmem_budget:
                continue
            w = block_waste(m, n, bm, bn)
            area = min(bm, _round_up(m, SUBLANE)) * min(bn, _round_up(n, LANE))
            key = (round(w, 6), -area)
            if best is None or key < best[0]:
                best = (key, (bm, bn))
    assert best is not None, (m, n)
    bm, bn = best[1]
    return min(bm, _round_up(m, SUBLANE)), min(bn, _round_up(n, LANE))


SEQ_VMEM_BUDGET = 8 * 2**20  # working-set bound for the sequence kernels

# bytes per element of the RESIDENT recurrent weight U under each weight
# precision (activations/state keep the launch dtype's width); int8 adds
# the per-gate f32 scale vector on top, accounted separately below
PRECISION_WEIGHT_BYTES = {"fp32": 4, "bf16": 2, "int8": 1}


def seq_block_footprint(bt: int, B: int, H: int, *, gates: int = 4,
                        bytes_per_el: int = 4, precision: str = "fp32",
                        density: float = 1.0) -> int:
    """VMEM working set of one sequence-kernel grid step at T-stripe ``bt``:
    resident U (gates·H²) + streamed xw stripe (B·bt·gates·H) + hs stripe
    (B·bt·H) + state/seed tiles (≤4·B·H).

    ``precision`` narrows the resident U term only (int8 is 1 byte/weight
    + a gates-wide f32 scale vector; bf16 is 2; fp32 keeps ``bytes_per_el``
    so the formula is byte-identical to the historical one), and
    ``density`` scales it for the block-sparse row-compacted payload
    (Ha ≈ density·H surviving rows + a 4-byte int32 row index each)."""
    if precision == "fp32":
        w_bytes = bytes_per_el * gates * H * H
    else:
        w_bytes = PRECISION_WEIGHT_BYTES[precision] * gates * H * H
        if precision == "int8":
            w_bytes += 4 * gates  # the per-gate f32 scale vector
    if density < 1.0:
        # Ha compacted weight rows + the (Ha,) int32 row-index operand
        w_bytes = int(w_bytes * density) + 4 * int(density * H)
    return w_bytes + bytes_per_el * (B * bt * (gates + 1) * H + 4 * B * H)


@functools.lru_cache(maxsize=None)
def select_time_block(T: int, B: int, H: int, *,
                      vmem_budget: int = SEQ_VMEM_BUDGET,
                      bytes_per_el: int = 4, gates: int = 4,
                      precision: str = "fp32", density: float = 1.0,
                      bt_choices: Sequence[int] = (1, 2, 4, 8, 16, 32, 64,
                                                   128, 256),
                      ) -> int:
    """T-block for the sequence-fused recurrent kernels (kernels.lstm_cell,
    kernels.gru_cell).

    The kernel's VMEM working set per grid step is the resident recurrent
    weight U (gates·H²), the streamed xw stripe (B·bt·gates·H), the hs
    output stripe (B·bt·H), and the state + seed tiles (4·B·H for the LSTM's
    (h, c), half for GRU's h-only — bounded above by the LSTM case).  Pick
    the bt minimizing the T-edge ceil-padding waste, then the largest such
    bt (fewest grid steps / launch amortization), under the budget — the
    time-axis analogue of ``select_block_shape``.  ``gates`` is 4 for the
    LSTM, 3 for GRU.  ``precision``/``density`` narrow the resident weight
    term (see seq_block_footprint), so quantized/sparse launches re-tune
    to larger time stripes at the same budget."""
    if T <= 0:
        return 1

    best = None
    for bt in bt_choices:
        bt = min(bt, T)
        if bt > 1 and seq_block_footprint(
                bt, B, H, gates=gates, bytes_per_el=bytes_per_el,
                precision=precision, density=density) > vmem_budget:
            continue
        waste = math.ceil(T / bt) * bt - T
        key = (round(waste / T, 6), -bt)
        if best is None or key < best[0]:
            best = (key, bt)
    return best[1]
