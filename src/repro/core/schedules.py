"""SHARP's four LSTM schedules as real JAX computation orders (paper §5, Fig. 8).

All four produce numerically equivalent outputs (property-tested against
``models.layers.lstm.reference_unroll``); they differ in *dependence
structure*, which is what the paper is about:

  sequential  one gate after another per time step; the cell/hidden update
              waits for the last (output) gate.  [BrainWave/TPU-style]
  batch       same order but the weight matrix is dispatched in column tiles
              (MVM partial sums accumulated tile by tile) — models the
              tiled-dispatch pipeline of Fig. 8.b.
  intergate   all four gates issued as one fused GEMM per step (the 4H gate
              axis is SHARP's "processing all gates simultaneously");
              hides the intra-sequence dependency.  [E-PUR-style]
  unfolded    SHARP's contribution: the input half W·x_t of EVERY step is
              hoisted out of the recurrence into one sequence-parallel GEMM;
              the scan keeps only U·h_{t-1} + the pointwise tail.  On TPU the
              hoisted GEMM is MXU-dense and, once the data dependence is cut,
              XLA's scheduler overlaps it with the serial tail — the paper's
              across-sequence overlap.
  fused       unfolded taken to its endpoint: the recurrent scan itself moves
              inside ONE Pallas kernel launch (kernels.lstm_cell.lstm_seq),
              with (h, c) resident in VMEM scratch for all T steps and the
              hoisted xw streamed in T-block stripes — the per-step dispatch
              and the state HBM round-trip both disappear.  One pallas_call
              per layer invocation instead of T.

Stack-level scheduling (``run_stack``) additionally accepts

  wavefront   layer l at time t depends only on layer l-1 at time t, so an
              L-layer stack over T steps (chunked into nk T-blocks) runs as
              L + nk - 1 anti-diagonal *slots* instead of L·nk serial cell
              evaluations.  Each slot gathers its active (layer, chunk)
              cells — a contiguous run of layers — and executes them as ONE
              G-batched sequence-fused kernel launch; each cell's input half
              (the hoisted GEMM against the previous layer's just-produced
              chunk) is issued in the same slot and carries no recurrent
              dependence, so it overlaps with the serial tail exactly as in
              the paper's Fig. 8.d, now across layers as well as time.
              Bidirectional stacks break the time alignment (the backward
              direction consumes the previous layer's FULL sequence) and
              fall back to per-layer fused execution.

``tile`` (from core.tiling) controls the dispatch granularity of the
batch/unfolded paths, mirroring the reconfigurable tile-engine;
``core.tiling.select_time_block`` (via the autotune table) picks the fused
paths' T-stripe under the VMEM budget.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.unfolded import unfold
from repro.kernels.common import cdiv
from repro.models.layers.lstm import cell_update

# NOTE: repro.kernels.lstm_cell.ops imports repro.core.autotune; importing
# it lazily inside the fused/wavefront paths keeps repro.core's package
# import acyclic regardless of which side is imported first.

SCHEDULES = ("sequential", "batch", "intergate", "unfolded", "fused")
STACK_SCHEDULES = SCHEDULES + ("wavefront",)


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------


def _init_state(B: int, H: int, dtype):
    return jnp.zeros((B, H), dtype), jnp.zeros((B, H), jnp.float32)


def run_layer_sequential(params, xs):
    """One gate at a time; update strictly after the O gate (Fig. 8.a)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]

    def step(carry, x_t):
        h, c = carry
        gates = []
        for g in range(4):  # i, f, g, o — strictly in order
            Wg = jax.lax.dynamic_slice_in_dim(W, g * H, H, axis=1)
            Ug = jax.lax.dynamic_slice_in_dim(U, g * H, H, axis=1)
            bg = jax.lax.dynamic_slice_in_dim(b, g * H, H, axis=0)
            gates.append(x_t @ Wg + h @ Ug + bg)
        h_new, c_new = cell_update(jnp.concatenate(gates, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_batch(params, xs, tile_cols: int = 0):
    """Tiled dispatch: the 4H gate axis is processed in column tiles whose
    partial results stream into the accumulator (Fig. 8.b)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]
    tc = tile_cols or min(4 * H, 512)
    n_tiles = -(-4 * H // tc)

    def step(carry, x_t):
        h, c = carry
        parts = []
        for i in range(n_tiles):  # tile-by-tile dispatch
            lo = i * tc
            w = min(tc, 4 * H - lo)
            Wt = jax.lax.dynamic_slice_in_dim(W, lo, w, axis=1)
            Ut = jax.lax.dynamic_slice_in_dim(U, lo, w, axis=1)
            bt = jax.lax.dynamic_slice_in_dim(b, lo, w, axis=0)
            parts.append(x_t @ Wt + h @ Ut + bt)
        h_new, c_new = cell_update(jnp.concatenate(parts, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_intergate(params, xs):
    """All four gates fused per step (Fig. 8.c)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ params["W"] + h @ params["U"] + params["b"]
        h_new, c_new = cell_update(gates, c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_unfolded(params, xs, cell_kernel=None):
    """SHARP: hoisted input GEMM + recurrent-only scan (Fig. 8.d).

    ``cell_kernel``: optional fused recurrent-step implementation with
    signature (U, b_zeros, xw_t, h, c) -> (h, c) — the Pallas lstm_cell
    kernel plugs in here.
    """
    B, T, X = xs.shape
    H = params["U"].shape[0]
    # ---- sequence-parallel input half: one big GEMM for every t ----------
    xw = jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    if cell_kernel is None:
        def cell(xw_t, h, c):
            gates = xw_t + h @ params["U"]
            h2, c2 = cell_update(gates, c)
            return h2.astype(xs.dtype), c2
    else:
        def cell(xw_t, h, c):
            return cell_kernel(params["U"], xw_t, h, c)

    def step(carry, xw_t):
        h, c = carry
        h, c = cell(xw_t, h, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_fused(params, xs, block_t: int = 0, interpret=None,
                    seq_kernel=None, return_state: bool = False):
    """Sequence-fused schedule: the whole recurrence in ONE kernel launch.

    The input half is hoisted exactly as in ``unfolded`` (routed through
    core.unfolded.unfold), but the scan is replaced by the Pallas
    sequence kernel: state stays in VMEM scratch, xw streams in T-stripes.
    ``return_state``: also return the exact t=T (h, c) — the dispatcher's
    serving-prefill path needs it.
    """
    from repro.kernels.lstm_cell.ops import as_seq_kernel

    B, T, X = xs.shape
    H = params["U"].shape[0]
    kern = seq_kernel or as_seq_kernel(interpret=interpret, block_t=block_t)

    def input_fn(xs):
        return jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    def seq_fn(state, pre):
        h0, c0 = state
        hs, h_n, c_n = kern(params["U"], pre, h0, c0)
        return (h_n.astype(xs.dtype), c_n), hs.astype(xs.dtype)

    state, hs = unfold(input_fn, None, xs, _init_state(B, H, xs.dtype),
                       seq_fn=seq_fn)
    return (hs, state) if return_state else hs


_LAYER_FNS = {
    "sequential": run_layer_sequential,
    "batch": run_layer_batch,
    "intergate": run_layer_intergate,
    "unfolded": run_layer_unfolded,
    "fused": run_layer_fused,
}


def run_layer(params, xs, schedule: str = "unfolded", **kw):
    if schedule not in _LAYER_FNS:
        raise ValueError(f"unknown schedule {schedule!r}; options {SCHEDULES}")
    return _LAYER_FNS[schedule](params, xs, **kw)


# ---------------------------------------------------------------------------
# stacks (multi-layer, optional bidirectional — EESEN-style)
# ---------------------------------------------------------------------------


def run_stack(stack_params, xs, schedule: str = "unfolded", **kw):
    """stack_params from models.layers.lstm.init_lstm_stack.  xs (B,T,X)."""
    if schedule == "wavefront":
        return run_stack_wavefront(stack_params, xs, **kw)
    if schedule not in _LAYER_FNS:
        raise ValueError(
            f"unknown schedule {schedule!r}; options {STACK_SCHEDULES}")
    y = xs
    for layer in stack_params["layers"]:
        if "fwd" in layer:  # bidirectional
            f = run_layer(layer["fwd"], y, schedule, **kw)
            bwd_in = jnp.flip(y, axis=1)
            b = run_layer(layer["bwd"], bwd_in, schedule, **kw)
            y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)
        else:
            y = run_layer(layer, y, schedule, **kw)
    return y


# ---------------------------------------------------------------------------
# wavefront: anti-diagonal (layer, time-chunk) scheduling over the stack
# ---------------------------------------------------------------------------


def wavefront_slots(n_layers: int, T: int, block_t: int) -> int:
    """Number of anti-diagonal slots: L + ceil(T / block_t) - 1."""
    return n_layers + cdiv(T, block_t) - 1


def wavefront_active(s: int, n_layers: int, nk: int):
    """Layer range [lo, hi] whose cells (l, k=s-l) are live in slot ``s``
    of an (n_layers x nk) wavefront; empty range when s is out of bounds.
    Shared with repro.dispatch, whose planner packs several items' cells
    into one global slot timeline."""
    lo = max(0, s - nk + 1)
    hi = min(n_layers - 1, s)
    return lo, hi


def run_stack_wavefront(stack_params, xs, block_t: int = 0, interpret=None):
    """Wavefront schedule: cell (l, k) = layer l over time-chunk k runs in
    slot s = l + k; every slot's cells (a contiguous run of layers) execute
    as ONE G-batched sequence-fused kernel launch.

    The sequence is zero-padded to a whole number of chunks — dependencies
    are time-aligned, so pad-region garbage never flows into real outputs
    and is sliced off at the end.
    """
    from repro.kernels.lstm_cell.ops import lstm_seq

    layers = stack_params["layers"]
    if any("fwd" in l for l in layers):  # bidirectional: no time alignment
        return run_stack(stack_params, xs, "fused",
                         block_t=block_t, interpret=interpret)
    L = len(layers)
    B, T, X = xs.shape
    H = layers[0]["U"].shape[0]
    bt = block_t or min(T, 16)
    nk = cdiv(T, bt)
    xs_pad = jnp.pad(xs, ((0, 0), (0, nk * bt - T), (0, 0)))

    U_all = jnp.stack([l["U"].reshape(H, 4, H) for l in layers])  # (L,H,4,H)
    h = jnp.zeros((L, B, H), xs.dtype)
    c = jnp.zeros((L, B, H), jnp.float32)
    outs = [[None] * nk for _ in range(L)]  # (B, bt, H) chunks

    for s in range(L + nk - 1):
        lo, hi = wavefront_active(s, L, nk)
        # input halves for this slot's cells: layer l consumes the chunk the
        # previous layer produced in slot s-1 (layer 0 reads the input)
        xw = []
        for l in range(lo, hi + 1):
            k = s - l
            src = xs_pad[:, k * bt:(k + 1) * bt] if l == 0 else outs[l - 1][k]
            xw.append((jnp.einsum("btx,xg->btg", src, layers[l]["W"])
                       + layers[l]["b"]).reshape(B, bt, 4, H))
        hs, h_n, c_n = lstm_seq(
            U_all[lo:hi + 1], jnp.stack(xw), h[lo:hi + 1], c[lo:hi + 1],
            block_t=bt, interpret=interpret)
        h = h.at[lo:hi + 1].set(h_n.astype(h.dtype))
        c = c.at[lo:hi + 1].set(c_n)
        for i, l in enumerate(range(lo, hi + 1)):
            outs[l][s - l] = hs[i].astype(xs.dtype)

    return jnp.concatenate(outs[L - 1], axis=1)[:, :T]
