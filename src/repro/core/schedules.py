"""SHARP's four LSTM schedules as real JAX computation orders (paper §5, Fig. 8).

All four produce numerically equivalent outputs (property-tested against
``models.layers.lstm.reference_unroll``); they differ in *dependence
structure*, which is what the paper is about:

  sequential  one gate after another per time step; the cell/hidden update
              waits for the last (output) gate.  [BrainWave/TPU-style]
  batch       same order but the weight matrix is dispatched in column tiles
              (MVM partial sums accumulated tile by tile) — models the
              tiled-dispatch pipeline of Fig. 8.b.
  intergate   all four gates issued as one fused GEMM per step (the 4H gate
              axis is SHARP's "processing all gates simultaneously");
              hides the intra-sequence dependency.  [E-PUR-style]
  unfolded    SHARP's contribution: the input half W·x_t of EVERY step is
              hoisted out of the recurrence into one sequence-parallel GEMM;
              the scan keeps only U·h_{t-1} + the pointwise tail.  On TPU the
              hoisted GEMM is MXU-dense and, once the data dependence is cut,
              XLA's scheduler overlaps it with the serial tail — the paper's
              across-sequence overlap.

``tile`` (from core.tiling) controls the dispatch granularity of the
batch/unfolded paths, mirroring the reconfigurable tile-engine.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.layers.lstm import cell_update

SCHEDULES = ("sequential", "batch", "intergate", "unfolded")


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------


def _init_state(B: int, H: int, dtype):
    return jnp.zeros((B, H), dtype), jnp.zeros((B, H), jnp.float32)


def run_layer_sequential(params, xs):
    """One gate at a time; update strictly after the O gate (Fig. 8.a)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]

    def step(carry, x_t):
        h, c = carry
        gates = []
        for g in range(4):  # i, f, g, o — strictly in order
            Wg = jax.lax.dynamic_slice_in_dim(W, g * H, H, axis=1)
            Ug = jax.lax.dynamic_slice_in_dim(U, g * H, H, axis=1)
            bg = jax.lax.dynamic_slice_in_dim(b, g * H, H, axis=0)
            gates.append(x_t @ Wg + h @ Ug + bg)
        h_new, c_new = cell_update(jnp.concatenate(gates, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_batch(params, xs, tile_cols: int = 0):
    """Tiled dispatch: the 4H gate axis is processed in column tiles whose
    partial results stream into the accumulator (Fig. 8.b)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]
    tc = tile_cols or min(4 * H, 512)
    n_tiles = -(-4 * H // tc)

    def step(carry, x_t):
        h, c = carry
        parts = []
        for i in range(n_tiles):  # tile-by-tile dispatch
            lo = i * tc
            w = min(tc, 4 * H - lo)
            Wt = jax.lax.dynamic_slice_in_dim(W, lo, w, axis=1)
            Ut = jax.lax.dynamic_slice_in_dim(U, lo, w, axis=1)
            bt = jax.lax.dynamic_slice_in_dim(b, lo, w, axis=0)
            parts.append(x_t @ Wt + h @ Ut + bt)
        h_new, c_new = cell_update(jnp.concatenate(parts, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_intergate(params, xs):
    """All four gates fused per step (Fig. 8.c)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ params["W"] + h @ params["U"] + params["b"]
        h_new, c_new = cell_update(gates, c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_unfolded(params, xs, cell_kernel=None):
    """SHARP: hoisted input GEMM + recurrent-only scan (Fig. 8.d).

    ``cell_kernel``: optional fused recurrent-step implementation with
    signature (U, b_zeros, xw_t, h, c) -> (h, c) — the Pallas lstm_cell
    kernel plugs in here.
    """
    B, T, X = xs.shape
    H = params["U"].shape[0]
    # ---- sequence-parallel input half: one big GEMM for every t ----------
    xw = jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    if cell_kernel is None:
        def cell(xw_t, h, c):
            gates = xw_t + h @ params["U"]
            h2, c2 = cell_update(gates, c)
            return h2.astype(xs.dtype), c2
    else:
        def cell(xw_t, h, c):
            return cell_kernel(params["U"], xw_t, h, c)

    def step(carry, xw_t):
        h, c = carry
        h, c = cell(xw_t, h, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


_LAYER_FNS = {
    "sequential": run_layer_sequential,
    "batch": run_layer_batch,
    "intergate": run_layer_intergate,
    "unfolded": run_layer_unfolded,
}


def run_layer(params, xs, schedule: str = "unfolded", **kw):
    if schedule not in _LAYER_FNS:
        raise ValueError(f"unknown schedule {schedule!r}; options {SCHEDULES}")
    return _LAYER_FNS[schedule](params, xs, **kw)


# ---------------------------------------------------------------------------
# stacks (multi-layer, optional bidirectional — EESEN-style)
# ---------------------------------------------------------------------------


def run_stack(stack_params, xs, schedule: str = "unfolded", **kw):
    """stack_params from models.layers.lstm.init_lstm_stack.  xs (B,T,X)."""
    y = xs
    for layer in stack_params["layers"]:
        if "fwd" in layer:  # bidirectional
            f = run_layer(layer["fwd"], y, schedule, **kw)
            bwd_in = jnp.flip(y, axis=1)
            b = run_layer(layer["bwd"], bwd_in, schedule, **kw)
            y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)
        else:
            y = run_layer(layer, y, schedule, **kw)
    return y
