"""SHARP's four LSTM schedules as real JAX computation orders (paper §5, Fig. 8).

All four produce numerically equivalent outputs (property-tested against
``models.layers.lstm.reference_unroll``); they differ in *dependence
structure*, which is what the paper is about:

  sequential  one gate after another per time step; the cell/hidden update
              waits for the last (output) gate.  [BrainWave/TPU-style]
  batch       same order but the weight matrix is dispatched in column tiles
              (MVM partial sums accumulated tile by tile) — models the
              tiled-dispatch pipeline of Fig. 8.b.
  intergate   all four gates issued as one fused GEMM per step (the 4H gate
              axis is SHARP's "processing all gates simultaneously");
              hides the intra-sequence dependency.  [E-PUR-style]
  unfolded    SHARP's contribution: the input half W·x_t of EVERY step is
              hoisted out of the recurrence into one sequence-parallel GEMM;
              the scan keeps only U·h_{t-1} + the pointwise tail.  On TPU the
              hoisted GEMM is MXU-dense and, once the data dependence is cut,
              XLA's scheduler overlaps it with the serial tail — the paper's
              across-sequence overlap.
  fused       unfolded taken to its endpoint: the recurrent scan itself moves
              inside ONE Pallas kernel launch (kernels.lstm_cell.lstm_seq),
              with (h, c) resident in VMEM scratch for all T steps and the
              hoisted xw streamed in T-block stripes — the per-step dispatch
              and the state HBM round-trip both disappear.  One pallas_call
              per layer invocation instead of T.

Stack-level scheduling additionally accepts

  wavefront   layer l at time t depends only on layer l-1 at time t, so an
              L-layer stack over T steps (chunked into nk T-blocks) runs as
              L + nk - 1 anti-diagonal *slots* instead of L·nk serial cell
              evaluations.  Each slot gathers its active (layer, chunk)
              cells — a contiguous run of layers — and executes them as ONE
              G-batched sequence-fused kernel launch; each cell's input half
              (the hoisted GEMM against the previous layer's just-produced
              chunk) is issued in the same slot and carries no recurrent
              dependence, so it overlaps with the serial tail exactly as in
              the paper's Fig. 8.d, now across layers as well as time.
              Bidirectional stacks run an *interleaved* wavefront: each
              layer's fwd walk visits chunks ascending and its bwd walk
              descending, the two directions of a wave sharing one
              G-batched launch (the concat dependency — layer l+1's chunk
              k needs both directions' chunk k of layer l — shapes the
              timeline; see dispatch/README.md "Bidirectional").

``tile`` (from core.tiling) controls the dispatch granularity of the
batch/unfolded paths, mirroring the reconfigurable tile-engine;
``core.tiling.select_time_block`` (via the autotune table) picks the fused
paths' T-stripe under the VMEM budget.

NOTE — front-end status: the per-schedule implementations here remain the
reference library (they ARE the paper's contribution and stay property-
tested), but the dispatch wrappers ``run_layer``/``run_stack`` are
DEPRECATED shims over the one planned execution path, ``repro.rnn``:

    from repro import rnn
    rnn.compile(stack_params, rnn.ExecutionPolicy(schedule="wavefront",
                                                  block_t=4)).forward(xs)

Every call — batch, serving, single layer — lowers to dispatch.WorkItems
and runs through dispatch.planner/executor, so wavefront packing, cross-B
merging, and plan caching apply uniformly (the stack-level ``wavefront``
schedule is now literally the dispatcher's packed slot timeline; the old
LSTM-only ``run_stack_wavefront`` is retired).  ``reference_stack`` below
is the non-deprecated pure-jnp oracle tests and benchmarks compare against.
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.unfolded import unfold
from repro.kernels.common import cdiv
from repro.models.layers.lstm import cell_update

# NOTE: repro.kernels.lstm_cell.ops imports repro.core.autotune; importing
# it lazily inside the fused/wavefront paths keeps repro.core's package
# import acyclic regardless of which side is imported first.

SCHEDULES = ("sequential", "batch", "intergate", "unfolded", "fused")
STACK_SCHEDULES = SCHEDULES + ("wavefront",)


# ---------------------------------------------------------------------------
# single layer
# ---------------------------------------------------------------------------


def _init_state(B: int, H: int, dtype):
    return jnp.zeros((B, H), dtype), jnp.zeros((B, H), jnp.float32)


def run_layer_sequential(params, xs):
    """One gate at a time; update strictly after the O gate (Fig. 8.a)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]

    def step(carry, x_t):
        h, c = carry
        gates = []
        for g in range(4):  # i, f, g, o — strictly in order
            Wg = jax.lax.dynamic_slice_in_dim(W, g * H, H, axis=1)
            Ug = jax.lax.dynamic_slice_in_dim(U, g * H, H, axis=1)
            bg = jax.lax.dynamic_slice_in_dim(b, g * H, H, axis=0)
            gates.append(x_t @ Wg + h @ Ug + bg)
        h_new, c_new = cell_update(jnp.concatenate(gates, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_batch(params, xs, tile_cols: int = 0):
    """Tiled dispatch: the 4H gate axis is processed in column tiles whose
    partial results stream into the accumulator (Fig. 8.b)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]
    W, U, b = params["W"], params["U"], params["b"]
    tc = tile_cols or min(4 * H, 512)
    n_tiles = -(-4 * H // tc)

    def step(carry, x_t):
        h, c = carry
        parts = []
        for i in range(n_tiles):  # tile-by-tile dispatch
            lo = i * tc
            w = min(tc, 4 * H - lo)
            Wt = jax.lax.dynamic_slice_in_dim(W, lo, w, axis=1)
            Ut = jax.lax.dynamic_slice_in_dim(U, lo, w, axis=1)
            bt = jax.lax.dynamic_slice_in_dim(b, lo, w, axis=0)
            parts.append(x_t @ Wt + h @ Ut + bt)
        h_new, c_new = cell_update(jnp.concatenate(parts, axis=-1), c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_intergate(params, xs):
    """All four gates fused per step (Fig. 8.c)."""
    B, T, X = xs.shape
    H = params["U"].shape[0]

    def step(carry, x_t):
        h, c = carry
        gates = x_t @ params["W"] + h @ params["U"] + params["b"]
        h_new, c_new = cell_update(gates, c)
        h_new = h_new.astype(xs.dtype)
        return (h_new, c_new), h_new

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xs.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_unfolded(params, xs, cell_kernel=None):
    """SHARP: hoisted input GEMM + recurrent-only scan (Fig. 8.d).

    ``cell_kernel``: optional fused recurrent-step implementation with
    signature (U, b_zeros, xw_t, h, c) -> (h, c) — the Pallas lstm_cell
    kernel plugs in here.
    """
    B, T, X = xs.shape
    H = params["U"].shape[0]
    # ---- sequence-parallel input half: one big GEMM for every t ----------
    xw = jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    if cell_kernel is None:
        def cell(xw_t, h, c):
            gates = xw_t + h @ params["U"]
            h2, c2 = cell_update(gates, c)
            return h2.astype(xs.dtype), c2
    else:
        def cell(xw_t, h, c):
            return cell_kernel(params["U"], xw_t, h, c)

    def step(carry, xw_t):
        h, c = carry
        h, c = cell(xw_t, h, c)
        return (h, c), h

    (_, _), hs = jax.lax.scan(step, _init_state(B, H, xs.dtype), xw.swapaxes(0, 1))
    return hs.swapaxes(0, 1)


def run_layer_fused(params, xs, block_t: int = 0, interpret=None,
                    seq_kernel=None, return_state: bool = False):
    """Sequence-fused schedule: the whole recurrence in ONE kernel launch.

    The input half is hoisted exactly as in ``unfolded`` (routed through
    core.unfolded.unfold), but the scan is replaced by the Pallas
    sequence kernel: state stays in VMEM scratch, xw streams in T-stripes.
    ``return_state``: also return the exact t=T (h, c) — the dispatcher's
    serving-prefill path needs it.
    """
    from repro.kernels.lstm_cell.ops import as_seq_kernel

    B, T, X = xs.shape
    H = params["U"].shape[0]
    kern = seq_kernel or as_seq_kernel(interpret=interpret, block_t=block_t)

    def input_fn(xs):
        return jnp.einsum("btx,xg->btg", xs, params["W"]) + params["b"]

    def seq_fn(state, pre):
        h0, c0 = state
        hs, h_n, c_n = kern(params["U"], pre, h0, c0)
        return (h_n.astype(xs.dtype), c_n), hs.astype(xs.dtype)

    state, hs = unfold(input_fn, None, xs, _init_state(B, H, xs.dtype),
                       seq_fn=seq_fn)
    return (hs, state) if return_state else hs


LAYER_FNS = {
    "sequential": run_layer_sequential,
    "batch": run_layer_batch,
    "intergate": run_layer_intergate,
    "unfolded": run_layer_unfolded,
    "fused": run_layer_fused,
}

# implementation-specific escape hatches the ExecutionPolicy surface does
# not (and should not) carry — a shim call using one of these goes straight
# to the reference implementation instead of through repro.rnn.compile
_IMPL_ONLY_KW = ("tile_cols", "cell_kernel", "seq_kernel", "return_state")


def _deprecated(old: str, new: str) -> None:
    warnings.warn(
        f"repro.core.schedules.{old} is deprecated; use {new} "
        "(see src/repro/rnn/README.md for the migration table)",
        DeprecationWarning, stacklevel=3)


def run_layer(params, xs, schedule: str = "unfolded", **kw):
    """DEPRECATED shim over the unified front-end (kept so pre-facade
    callers keep working): routes through ``repro.rnn.compile`` unless an
    implementation-specific kwarg (tile_cols/cell_kernel/...) pins it to
    the reference implementation directly."""
    _deprecated(
        "run_layer(params, xs, schedule)",
        "repro.rnn.compile({'layers': [params]}, "
        "ExecutionPolicy(schedule=...)).forward(xs)")
    if any(k in kw for k in _IMPL_ONLY_KW):
        if schedule not in LAYER_FNS:
            raise ValueError(
                f"unknown schedule {schedule!r}; options {SCHEDULES}")
        return LAYER_FNS[schedule](params, xs, **kw)
    from repro.rnn import ExecutionPolicy, compile as _compile

    pol = ExecutionPolicy(schedule=schedule, block_t=kw.pop("block_t", 0),
                          interpret=kw.pop("interpret", None))
    if kw:
        raise TypeError(f"run_layer: unexpected kwargs {sorted(kw)}")
    return _compile({"layers": [params]}, pol).forward(xs)


# ---------------------------------------------------------------------------
# stacks (multi-layer, optional bidirectional — EESEN-style)
# ---------------------------------------------------------------------------


def run_stack(stack_params, xs, schedule: str = "unfolded", **kw):
    """DEPRECATED shim over the unified front-end.  stack_params from
    models.layers.lstm.init_lstm_stack (or core.gru.init_gru_stack, or a
    mixed list).  xs (B,T,X).

    All schedules — including ``wavefront``, whose LSTM-only hand-rolled
    loop this shim retired — now lower to dispatch.WorkItems and execute
    through the planner/executor, exactly like ``repro.rnn.compile``."""
    _deprecated(
        "run_stack(stack_params, xs, schedule)",
        "repro.rnn.compile(stack_params, "
        "ExecutionPolicy(schedule=...)).forward(xs)")
    if any(k in kw for k in _IMPL_ONLY_KW):
        # escape-hatch kwargs pin each layer to its family's reference
        # implementation directly — only per-layer schedules qualify here
        def one(fam, layer, y):
            fns = _family_fns(fam)
            if schedule not in fns:
                raise ValueError(
                    f"schedule {schedule!r} has no per-layer {fam} "
                    f"reference implementation (the "
                    f"{sorted(_IMPL_ONLY_KW)} kwargs pin to one); "
                    f"{fam} options {tuple(fns)}")
            return fns[schedule](layer, y, **kw)

        return walk_stack(stack_params, xs, one)
    from repro.rnn import ExecutionPolicy, compile as _compile

    pol = ExecutionPolicy(schedule=schedule, block_t=kw.pop("block_t", 0),
                          interpret=kw.pop("interpret", None))
    if kw:
        raise TypeError(f"run_stack: unexpected kwargs {sorted(kw)}")
    return _compile(stack_params, pol).forward(xs)


# ---------------------------------------------------------------------------
# stack introspection + the pure-jnp oracle (non-deprecated)
# ---------------------------------------------------------------------------


def stack_families(stack_params):
    """Per-layer recurrence family of a parameter stack, inferred from the
    gate-axis width: U (H, 4H) -> lstm, U (H, 3H) -> gru.  Bidirectional
    layers are classified by their fwd half."""
    fams = []
    for i, layer in enumerate(stack_params["layers"]):
        half = layer.get("fwd", layer)
        H, G = half["U"].shape
        if G == 4 * H:
            fams.append("lstm")
        elif G == 3 * H:
            fams.append("gru")
        else:
            raise ValueError(
                f"layer {i}: unrecognized gate width {G} for H={H} "
                "(expected 4H lstm / 3H gru)")
    return tuple(fams)


def walk_stack(stack_params, xs, one):
    """THE per-layer stack walk (family- and bidirectional-aware), shared
    by the oracle, the shims' escape-hatch path, and the executor's
    external path: ``one(family, layer_params, y) -> y`` is applied layer
    by layer, with bidirectional layers running fwd on y and bwd on the
    time-flipped y, concatenated on the feature axis."""
    fams = stack_families(stack_params)
    y = xs
    for fam, layer in zip(fams, stack_params["layers"]):
        if "fwd" in layer:  # bidirectional
            f = one(fam, layer["fwd"], y)
            b = one(fam, layer["bwd"], jnp.flip(y, axis=1))
            y = jnp.concatenate([f, jnp.flip(b, axis=1)], axis=-1)
        else:
            y = one(fam, layer, y)
    return y


def _family_fns(fam):
    if fam == "lstm":
        return LAYER_FNS
    from repro.core import gru as gru_mod

    return gru_mod.LAYER_FNS


def reference_stack(stack_params, xs, schedule: str = "unfolded"):
    """Run a stack through the per-layer reference implementations — the
    pure-jnp oracle tests and benchmarks compare every planned execution
    against.  Family-aware per layer (mixed lstm/gru stacks run each layer
    through its own library) and bidirectional-aware.  NOT deprecated and
    NOT routed through the dispatcher — this is the ground truth the
    dispatcher must reproduce."""
    def one(fam, layer, y):
        fns = _family_fns(fam)
        if schedule not in fns:
            raise ValueError(f"unknown schedule {schedule!r}; "
                             f"{fam} options {tuple(fns)}")
        return fns[schedule](layer, y)

    return walk_stack(stack_params, xs, one)


# ---------------------------------------------------------------------------
# wavefront geometry (shared with repro.dispatch, whose planner packs
# several items' cells into one global slot timeline)
# ---------------------------------------------------------------------------


def wavefront_slots(n_layers: int, T: int, block_t: int) -> int:
    """Number of anti-diagonal slots: L + ceil(T / block_t) - 1."""
    return n_layers + cdiv(T, block_t) - 1


def wavefront_active(s: int, n_layers: int, nk: int):
    """Layer range [lo, hi] whose cells (l, k=s-l) are live in slot ``s``
    of an (n_layers x nk) wavefront; empty range when s is out of bounds."""
    lo = max(0, s - nk + 1)
    hi = min(n_layers - 1, s)
    return lo, hi
