# Tier-1 verify + benchmark entry points.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test bench-kernels bench

test:
	$(PY) -m pytest -x -q

# Kernel microbench suite; writes BENCH_kernels.json (committed — the
# cross-PR perf trajectory).
bench-kernels:
	$(PY) benchmarks/run.py --suite kernels

bench:
	$(PY) benchmarks/run.py
