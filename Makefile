# Tier-1 verify + benchmark entry points.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test ci chaos deprecations lint-repro verify-plans api-demo \
        trace-demo calibrate bench-kernels bench-dispatch bench

test:
	$(PY) -m pytest -x -q

# Fault-injection (chaos) suite only: guarded execution ladder, poisoned-
# slot quarantine, deadline retirement (tests marked @pytest.mark.chaos).
# Included in `make test` too — this target is the fast failure-semantics
# gate CI runs by name.
chaos:
	$(PY) -m pytest -x -q -m chaos

# Deprecation gate: the FULL tier-1 suite, erroring on any
# DeprecationWarning ATTRIBUTED TO a repro.* module — i.e. repro-internal
# code still calling the deprecated run_layer/run_stack shims (tests may
# call them — the warning is attributed to the caller; internal code must
# go through repro.rnn).  The module field is a pytest regex.  A strict
# superset of `make test`, so CI runs the suite exactly once, under it.
deprecations:
	$(PY) -m pytest -x -q -W "error::DeprecationWarning:repro\."

# Static repo lint (repro.analysis.repolint): no deprecated-shim calls, no
# bare assert/RuntimeError on the serving path, one fenced clock
# (runtime/obs.py), no Slot-internals coupling outside planner/executor/
# analysis.  Pure AST walk — no test execution, fails CI before pytest.
lint-repro:
	$(PY) -m repro.analysis.repolint src/repro

# The static-analysis suite by name: the plan-invariant mutation tests
# (every seeded corruption rejected with its rule, pristine plans clean)
# plus the lint's own tests.  A subset of `make test`; CI runs it early
# as the fast dispatch-invariant gate.
verify-plans:
	$(PY) -m pytest -x -q tests/analysis

# The unified front-end tour (compile/forward/prefill/decode + plans).
api-demo:
	$(PY) examples/rnn_api_demo.py

# Traced forward + decode -> artifacts/trace.json (chrome://tracing),
# metrics_snapshot.json, launch_costs.json (predicted vs measured).
# CI runs this and uploads the trace as a build artifact.
trace-demo:
	$(PY) examples/trace_demo.py --out-dir artifacts

# Compile-and-replay calibration (repro.calib): replay the smoke grid of
# launch shapes through the shared obs clock into
# artifacts/measured_costs.json (merged across runs, backend-tagged), then
# re-replay every signature and exit nonzero if any fresh measurement
# disagrees with the stored median beyond 25x — the unit/lowering sanity
# gate (generous: it catches a broken replay, not scheduler jitter).  CI
# runs this and uploads the table as a build artifact; plan against it
# with ExecutionPolicy(cost_model="measured").
calibrate:
	$(PY) -m repro.calib --grid smoke --repeats 3 --check 25

# What CI runs (.github/workflows/ci.yml): the static lint first (no test
# execution needed), then the tier-1 suite (which already includes the
# benchmark smoke tests — tests/test_bench_smoke.py runs the kernels +
# dispatch suites end-to-end and checks their claims) under the
# deprecations gate — one pytest run covers both.
ci: lint-repro deprecations

# Kernel microbench suite; writes BENCH_kernels.json (committed — the
# cross-PR perf trajectory).
bench-kernels:
	$(PY) benchmarks/run.py --suite kernels

# Tile-dispatcher suite; writes BENCH_dispatch.json (committed — packed
# vs per-request launch counts + oracle latency).
bench-dispatch:
	$(PY) benchmarks/run.py --suite dispatch

bench:
	$(PY) benchmarks/run.py
