# Tier-1 verify + benchmark entry points.
PY ?= python
export PYTHONPATH := src:$(PYTHONPATH)

.PHONY: test ci bench-kernels bench-dispatch bench

test:
	$(PY) -m pytest -x -q

# What CI runs (.github/workflows/ci.yml): the tier-1 suite, which already
# includes the benchmark smoke tests (tests/test_bench_smoke.py runs the
# kernels + dispatch suites end-to-end and checks their claims).
ci: test

# Kernel microbench suite; writes BENCH_kernels.json (committed — the
# cross-PR perf trajectory).
bench-kernels:
	$(PY) benchmarks/run.py --suite kernels

# Tile-dispatcher suite; writes BENCH_dispatch.json (committed — packed
# vs per-request launch counts + oracle latency).
bench-dispatch:
	$(PY) benchmarks/run.py --suite dispatch

bench:
	$(PY) benchmarks/run.py
